"""Non-i.i.d. data distributions (paper Sec. V-C): extreme (one label per
node) and moderate (two labels per node) partitions, BRIDGE-T vs BRDSO.

    PYTHONPATH=src python examples/noniid.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_decentralized

print(f"{'partition':10s} {'b':>2s} {'BRIDGE-T acc':>13s}")
for part in ["iid", "moderate", "extreme"]:
    for b in [0, 2, 4]:
        r = run_decentralized(
            model="linear", rule="trimmed_mean",
            attack="random" if b else "none",
            num_nodes=20, num_byzantine=b, partition=part, steps=150,
        )
        print(f"{part:10s} {b:2d} {r['accuracy']:13.4f}")
