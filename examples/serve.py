"""Batched serving example: prefill a prompt batch, then decode with the
KV-cache/SSM-state serve path — the same `decode_step` the dry-run lowers
for decode_32k / long_500k.

    PYTHONPATH=src python examples/serve.py --arch qwen3-4b --tokens 32
    PYTHONPATH=src python examples/serve.py --arch rwkv6-3b --tokens 32
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api as model_api

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-4b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--tokens", type=int, default=32)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
api = model_api.build(cfg)
params = api.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
max_len = args.prompt_len + args.tokens
cache = api.init_cache(cfg, args.batch, max_len)

step = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))

if cfg.family == "encdec":
    audio = jnp.asarray(rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
    cache = api.extra["prefill_cache"](params, cache, audio, cfg)
    tok = jnp.full((args.batch, 1), 1, jnp.int32)
else:
    # prefill by stepping the prompt through the decode path (simple host
    # loop; the dry-run's prefill_step is the batched variant)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t : t + 1])
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

out = []
t0 = time.perf_counter()
for _ in range(args.tokens):
    logits, cache = step(params, cache, tok)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(np.asarray(tok)[:, 0])
dt = (time.perf_counter() - t0) / args.tokens
seq = np.stack(out, axis=1)
print(f"arch={cfg.name} decoded {args.tokens} tokens x batch {args.batch} "
      f"({dt*1000:.1f} ms/token on CPU, reduced config)")
print("sample token ids:", seq[0][:16].tolist())
