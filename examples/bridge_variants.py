"""Compare all four BRIDGE screening variants (T/M/K/B) under attack —
reproduces the shape of the paper's Fig. 2 on the synthetic MNIST-like set.

    PYTHONPATH=src python examples/bridge_variants.py [--byzantine 2] [--attack random]

``--codec`` routes every broadcast through a `repro.comm` wire codec and
prints bytes/edge/step next to accuracy — e.g. ``--codec int4`` sends 4-bit
stochastic codewords whose delta-tracking + error feedback matches the
uncompressed run's accuracy at ~1/8 of the bytes:

    PYTHONPATH=src python examples/bridge_variants.py --codec int4
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--byzantine", type=int, default=2)
ap.add_argument("--attack", default="random",
                choices=["random", "sign_flip", "same_value", "alie", "shift",
                         "garbage_codeword", "scale_abuse", "index_lie"])
ap.add_argument("--codec", default=None,
                help="wire codec (repro.comm): int8, int4, topk50_int8, ... ; "
                     "when set, each variant runs uncompressed AND compressed")
ap.add_argument("--nodes", type=int, default=20)
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

from benchmarks.common import run_decentralized

codecs = ["identity"] + ([args.codec] if args.codec and args.codec != "identity" else [])
print(f"{args.nodes} nodes, {args.byzantine} byzantine, attack={args.attack}")
print(f"{'variant':12s} {'codec':12s} {'accuracy':>9s} {'consensus':>10s} "
      f"{'B/edge/step':>12s} {'ms/step':>8s}")
for rule, label in [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"),
                    ("median", "BRIDGE-M"), ("krum", "BRIDGE-K"),
                    ("bulyan", "BRIDGE-B")]:
    for codec in codecs:
        r = run_decentralized(model="linear", rule=rule, attack=args.attack,
                              codec=codec, num_nodes=args.nodes,
                              num_byzantine=args.byzantine, steps=args.steps)
        print(f"{label:12s} {codec:12s} {r['accuracy']:9.4f} {r['consensus']:10.4f} "
              f"{r['wire_bits_per_edge']/8:12.0f} {r['us_per_step']/1000:8.1f}")
