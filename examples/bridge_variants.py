"""Compare all four BRIDGE screening variants (T/M/K/B) under attack —
reproduces the shape of the paper's Fig. 2 on the synthetic MNIST-like set —
plus the two non-BRIDGE baselines the paper benchmarks against: ByRDiE
(coordinate descent, Fig. 3) and BRDSO (TV-penalty subgradient, Figs. 6-7).

    PYTHONPATH=src python examples/bridge_variants.py [--byzantine 2] [--attack random]

``--adversary`` swaps the static attack for a `repro.adversary` adaptive one
(omniscient, trajectory-tracking — e.g. ``ipm``, ``alie_online``,
``inner_max``); ``--codec`` routes every broadcast through a `repro.comm`
wire codec and prints bytes/edge/step next to accuracy:

    PYTHONPATH=src python examples/bridge_variants.py --adversary inner_max
    PYTHONPATH=src python examples/bridge_variants.py --codec int4

``--no-baselines`` skips the (slower) ByRDiE/BRDSO rows.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--byzantine", type=int, default=2)
ap.add_argument("--attack", default="random",
                choices=["random", "sign_flip", "same_value", "alie", "shift",
                         "garbage_codeword", "scale_abuse", "index_lie"])
ap.add_argument("--adversary", default="none",
                help="adaptive adversary (repro.adversary): ipm, alie_online, "
                     "dissensus, inner_max; overrides --attack when set")
ap.add_argument("--codec", default=None,
                help="wire codec (repro.comm): int8, int4, topk50_int8, ... ; "
                     "when set, each variant runs uncompressed AND compressed")
ap.add_argument("--nodes", type=int, default=20)
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--no-baselines", action="store_true",
                help="skip the ByRDiE / BRDSO comparison rows")
args = ap.parse_args()

from benchmarks.common import run_brdso, run_byrdie, run_decentralized

attack = "none" if args.adversary != "none" else args.attack
codecs = ["identity"] + ([args.codec] if args.codec and args.codec != "identity" else [])
label_attack = args.adversary if args.adversary != "none" else args.attack
print(f"{args.nodes} nodes, {args.byzantine} byzantine, attack={label_attack}")
print(f"{'variant':12s} {'codec':12s} {'accuracy':>9s} {'consensus':>10s} "
      f"{'B/edge/step':>12s} {'ms/step':>8s}")
for rule, label in [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"),
                    ("median", "BRIDGE-M"), ("krum", "BRIDGE-K"),
                    ("bulyan", "BRIDGE-B")]:
    for codec in codecs:
        r = run_decentralized(model="linear", rule=rule, attack=attack,
                              adversary=args.adversary, codec=codec,
                              num_nodes=args.nodes,
                              num_byzantine=args.byzantine, steps=args.steps)
        print(f"{label:12s} {codec:12s} {r['accuracy']:9.4f} {r['consensus']:10.4f} "
              f"{r['wire_bits_per_edge']/8:12.0f} {r['us_per_step']/1000:8.1f}")

if not args.no_baselines:
    # the paper's comparison baselines run with the static broadcast attack
    # (neither protocol takes a repro.adversary bank)
    base_attack = args.attack if args.attack in ("random", "sign_flip", "same_value",
                                                 "alie", "shift") else "random"
    r = run_byrdie(num_nodes=args.nodes, num_byzantine=args.byzantine,
                   attack=base_attack, sweeps=2)
    print(f"{'ByRDiE':12s} {'scalar':12s} {r['accuracy']:9.4f} {'-':>10s} "
          f"{'-':>12s} {r['us_per_step']/1000:8.1f}  "
          f"(2 sweeps = {int(r['scalars_sent'])} scalar broadcasts/node)")
    r = run_brdso(num_nodes=args.nodes, num_byzantine=args.byzantine,
                  attack=base_attack, steps=args.steps)
    print(f"{'BRDSO':12s} {'identity':12s} {r['accuracy']:9.4f} {r['consensus']:10.4f} "
          f"{'-':>12s} {r['us_per_step']/1000:8.1f}")
