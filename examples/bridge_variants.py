"""Compare all four BRIDGE screening variants (T/M/K/B) under attack —
reproduces the shape of the paper's Fig. 2 on the synthetic MNIST-like set.

    PYTHONPATH=src python examples/bridge_variants.py [--byzantine 2] [--attack random]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--byzantine", type=int, default=2)
ap.add_argument("--attack", default="random",
                choices=["random", "sign_flip", "same_value", "alie", "shift"])
ap.add_argument("--nodes", type=int, default=20)
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

from benchmarks.common import run_decentralized

print(f"{args.nodes} nodes, {args.byzantine} byzantine, attack={args.attack}")
print(f"{'variant':12s} {'accuracy':>9s} {'consensus':>10s} {'ms/step':>8s}")
for rule, label in [("mean", "DGD"), ("trimmed_mean", "BRIDGE-T"),
                    ("median", "BRIDGE-M"), ("krum", "BRIDGE-K"),
                    ("bulyan", "BRIDGE-B")]:
    r = run_decentralized(model="linear", rule=rule, attack=args.attack,
                          num_nodes=args.nodes, num_byzantine=args.byzantine,
                          steps=args.steps)
    print(f"{label:12s} {r['accuracy']:9.4f} {r['consensus']:10.4f} "
          f"{r['us_per_step']/1000:8.1f}")
