"""Quickstart: Byzantine-resilient decentralized learning in ~40 lines.

Trains a linear classifier over a 12-node decentralized network where 2
nodes broadcast random garbage every iteration (the paper's attack model),
with DGD (breaks) vs BRIDGE-T (survives).

    PYTHONPATH=src python examples/quickstart.py

This is the single-cell path everything else generalizes: `repro.sim`
batches whole rule x attack grids of it into one compiled program,
`repro.net` runs it over unreliable links, `repro.obs` / `repro.trust`
bolt forensics and reputation onto the same step — see README.md and
docs/ARCHITECTURE.md for the map.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.data import make_mnist_like, partition_iid
from repro.data.partition import stack_node_batches
from repro.models import small

M, B = 12, 2

x, y, xt, yt = make_mnist_like(3000, 600)
shards = partition_iid(x, y, M)
batch_fn = stack_node_batches(shards, 32)
topo = erdos_renyi(M, 0.6, B, seed=0)


def grad_fn(params, batch):
    return jax.value_and_grad(lambda p: small.linear_loss(p, batch))(params)


for rule, label in [("mean", "DGD      "), ("trimmed_mean", "BRIDGE-T ")]:
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=B, attack="random", t0=30)
    trainer = BridgeTrainer(cfg, grad_fn)
    params = replicate(small.init_linear(jax.random.PRNGKey(0)), M, perturb=0.01,
                       key=jax.random.PRNGKey(1))
    state = trainer.init(params)
    for i in range(100):
        bx, by = batch_fn(i)
        state, metrics = trainer.step(state, (jnp.asarray(bx), jnp.asarray(by)))
    # evaluate the first honest node's model
    j = int(jnp.argmax(trainer.honest_mask))
    p = jax.tree_util.tree_map(lambda l: l[j], state.params)
    acc = small.linear_accuracy(p, jnp.asarray(xt), jnp.asarray(yt))
    print(f"{label} under {B}-node random attack: accuracy {float(acc):.3f}  "
          f"consensus {float(metrics['consensus_dist']):.3f}")
