"""Empirical study of Assumption 4 (network redundancy): how often do
Erdos-Renyi graphs satisfy the sampled reduced-graph source-component check,
as a function of edge probability p and Byzantine budget b?

The paper observes A4 is "often satisfied in Erdos-Renyi graphs as long as
the degree of the least connected node is larger than 2b" — this script
quantifies that at M in {20, 50}.

    PYTHONPATH=src python examples/assumption4_study.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.graph import Topology, check_assumption4

print(f"{'M':>3s} {'p':>5s} {'b':>2s} {'deg>2b':>7s} {'A4-pass':>8s}  (20 graphs, 15 samples each)")
rng = np.random.default_rng(0)
for m in (20, 50):
    for p in (0.2, 0.3, 0.5):
        for b in (1, 2, 4):
            deg_ok = a4_ok = 0
            for trial in range(20):
                upper = rng.random((m, m)) < p
                adj = np.triu(upper, 1)
                adj = adj | adj.T
                np.fill_diagonal(adj, False)
                topo = Topology(adjacency=adj, num_byzantine=b)
                if topo.min_in_degree > 2 * b:
                    deg_ok += 1
                    if check_assumption4(topo, num_samples=15, seed=trial):
                        a4_ok += 1
            print(f"{m:3d} {p:5.2f} {b:2d} {deg_ok:6d}/20 {a4_ok:7d}/{deg_ok}")
