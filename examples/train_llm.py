"""End-to-end driver: decentralized BRIDGE training of a ~100M-parameter
transformer for a few hundred steps on the synthetic token pipeline.

This exercises the FULL stack — model zoo config, BRIDGE trainer with
screening + Byzantine injection, data pipeline, checkpointing — on local
devices.  At ~100M params x 4 nodes this is CPU-heavy; trim with --small.

    PYTHONPATH=src python examples/train_llm.py --steps 200 [--small]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.data.tokens import TokenPipeline
from repro.models import api as model_api

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--nodes", type=int, default=4)
ap.add_argument("--byzantine", type=int, default=1)
ap.add_argument("--attack", default="random")
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--small", action="store_true", help="~5M params instead of ~100M")
ap.add_argument("--ckpt", default="/tmp/bridge_llm_ckpt")
args = ap.parse_args()

# a ~100M-param qwen3-family config (12 layers, d=768)
base = get_config("qwen3-4b")
if args.small:
    cfg = base.reduced(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                       d_ff=512, vocab_size=8192, head_dim=64)
else:
    cfg = dataclasses.replace(
        base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=32768, head_dim=64, kv_chunk=256, q_chunk=128,
    )
api = model_api.build(cfg)
n = model_api.param_count(cfg)
print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params x {args.nodes} nodes")

topo = erdos_renyi(args.nodes, 0.9, args.byzantine, seed=0)
bcfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=args.byzantine,
                    attack=args.attack, lr=0.02, screen_chunk=1 << 20)
trainer = BridgeTrainer(bcfg, api.grad_fn())
key = jax.random.PRNGKey(0)
params = replicate(api.init_params(key, cfg), args.nodes, perturb=0.005, key=key)
state = trainer.init(params)
pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, args.nodes, seed=0)

t0 = time.time()
for step in range(args.steps):
    batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(step))
    state, metrics = trainer.step(state, batch)
    if (step + 1) % 10 == 0:
        print(f"step {step+1:4d}  loss {float(metrics['loss']):.4f}  "
              f"consensus {float(metrics['consensus_dist']):.3f}  "
              f"{(time.time()-t0)/(step+1):.2f}s/step", flush=True)
    if (step + 1) % 100 == 0:
        path = checkpoint.save(args.ckpt, step + 1, (state.params, state.t))
        print(f"checkpoint -> {path}")
print("done.")
