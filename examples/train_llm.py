"""End-to-end driver: decentralized BRIDGE training of a ~100M-parameter
transformer for a few hundred steps on the synthetic token pipeline.

This exercises the FULL stack — model zoo config, chunk-streaming BRIDGE
(`repro.stream`, the default: screening runs per coordinate block, never
materializing the flat [M, d] matrix), topology builders, wire codecs,
observability traces, trust/reputation, Byzantine injection, data pipeline,
checkpointing — on local devices.  At ~100M params x 4 nodes this is
CPU-heavy; trim with --small.

    PYTHONPATH=src python examples/train_llm.py --steps 200 [--small]
    PYTHONPATH=src python examples/train_llm.py --small --topology small_world:3 \\
        --sparse --codec int8 --trust --trace --attack sign_flip

``--flat`` selects the legacy flat-matrix `BridgeTrainer` (small models
only); ``--resume`` restores the full state — including comm/trust carries —
from the newest checkpoint, bit-identical to an uninterrupted run.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config
from repro.core import BridgeConfig, BridgeTrainer, replicate
from repro.core.graph import make_topology
from repro.data.tokens import TokenPipeline
from repro.models import api as model_api
from repro.stream import StreamBridgeTrainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--nodes", type=int, default=4)
ap.add_argument("--byzantine", type=int, default=1)
ap.add_argument("--attack", default="random")
ap.add_argument("--rule", default="trimmed_mean",
                help="screening rule (streaming: coordinate-wise rules only)")
ap.add_argument("--topology", default="erdos_renyi:0.9",
                help="name[:arg] from repro.core.graph.TOPOLOGIES")
ap.add_argument("--sparse", action="store_true",
                help="neighbor-indexed [M, K] screening layout")
ap.add_argument("--codec", default="identity",
                help="wire codec (identity | int8 | int4 | topk<P> | randk<P>)")
ap.add_argument("--trace", action="store_true",
                help="compile screening forensics into the step (repro.obs)")
ap.add_argument("--metrics", default=None, metavar="DIR",
                help="stream per-tick live metrics (repro.obs.metrics) to "
                     "DIR/metrics.jsonl via the chunked runner; watch with "
                     "`python -m repro.obs.monitor DIR`")
ap.add_argument("--profile", default=None, metavar="DIR",
                help="capture a jax.profiler trace of the loop into DIR")
ap.add_argument("--trust", action="store_true",
                help="reputation-weighted screening + eviction (repro.trust)")
ap.add_argument("--flat", action="store_true",
                help="legacy flat [M, d] BridgeTrainer instead of repro.stream")
ap.add_argument("--chunk", type=int, default=1 << 16,
                help="streaming block width (coordinates per block)")
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--small", action="store_true", help="~5M params instead of ~100M")
ap.add_argument("--ckpt", default="/tmp/bridge_llm_ckpt")
ap.add_argument("--ckpt-every", type=int, default=100)
ap.add_argument("--resume", action="store_true",
                help="restore the newest checkpoint (full state incl. carries)")
args = ap.parse_args()

# a ~100M-param qwen3-family config (12 layers, d=768)
base = get_config("qwen3-4b")
if args.small:
    cfg = base.reduced(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                       d_ff=512, vocab_size=8192, head_dim=64)
else:
    cfg = dataclasses.replace(
        base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=32768, head_dim=64, kv_chunk=256, q_chunk=128,
    )
api = model_api.build(cfg)
n = model_api.param_count(cfg)
print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params x {args.nodes} nodes")

trace = trust = None
if args.trace:
    from repro.obs.trace import TraceSpec

    trace = TraceSpec()
if args.trust:
    from repro.trust.reputation import TrustSpec

    # no echo on the broadcast paths; the streaming engine rejects it anyway
    trust = TrustSpec(echo=False)

mspec = None
if args.metrics:
    from repro.obs import MetricSpec

    mspec = MetricSpec()

topo = make_topology(args.topology, args.nodes, args.byzantine, seed=0)
bcfg = BridgeConfig(topology=topo, rule=args.rule, num_byzantine=args.byzantine,
                    attack=args.attack, codec=args.codec, lr=0.02,
                    sparse=args.sparse, trace=trace, trust=trust, metrics=mspec,
                    screen_chunk=(1 << 20) if args.flat else args.chunk)
trainer = (BridgeTrainer(bcfg, api.grad_fn()) if args.flat
           else StreamBridgeTrainer(bcfg, api.grad_fn()))
mode = "flat" if args.flat else f"stream(chunk={args.chunk})"
print(f"trainer: {mode}  rule={args.rule}  topology={args.topology}  "
      f"codec={args.codec}  sparse={args.sparse}  trace={args.trace}  "
      f"trust={args.trust}")

key = jax.random.PRNGKey(0)
params = replicate(api.init_params(key, cfg), args.nodes, perturb=0.005, key=key)
state = trainer.init(params)
start = 0
if args.resume:
    latest = checkpoint.latest_step(args.ckpt)
    if latest is not None:
        # template-based restore: the freshly init'ed state provides the
        # exact pytree (params AND comm/net/trust carries + PRNG key)
        state, _ = checkpoint.restore(args.ckpt, state, step=latest)
        start = latest
        print(f"resumed from step {latest}")
pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, args.nodes, seed=0)

if args.profile:
    os.makedirs(args.profile, exist_ok=True)
    jax.profiler.start_trace(args.profile)

t0 = time.time()
if args.metrics:
    # chunked scan loop with donated carries (both trainers share it): the
    # metric ring streams to DIR/metrics.jsonl through a background writer
    from repro.obs import AlertRules, EventLog, MetricWriter, write_manifest

    os.makedirs(args.metrics, exist_ok=True)
    write_manifest(args.metrics, kind="train-llm", config=vars(args))
    events = EventLog(os.path.join(args.metrics, "events.jsonl"))
    writer = MetricWriter(os.path.join(args.metrics, "metrics.jsonl"),
                          alerts=AlertRules(), events=events)
    batch_at = lambda i: jax.tree_util.tree_map(jnp.asarray, pipe.batch(i))
    done = start
    while done < args.steps:
        n = min(args.ckpt_every, args.steps - done)
        state, ms = trainer.run_chunks(state, batch_at, n, writer=writer,
                                       events=events, start=done)
        done += n
        print(f"step {done:4d}  loss {float(ms['loss'][-1]):.4f}  "
              f"consensus {float(ms['consensus_dist'][-1]):.3f}  "
              f"{(time.time()-t0)/(done-start):.2f}s/step", flush=True)
        path = checkpoint.save(args.ckpt, done, state)
        print(f"checkpoint -> {path}")
    writer.close()
    events.close()
    write_manifest(args.metrics, extra={"ended": True, "wall_s": time.time() - t0})
    print(f"metric stream -> {os.path.join(args.metrics, 'metrics.jsonl')}")
else:
    for step in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(step))
        state, metrics = trainer.step(state, batch)
        if (step + 1) % 10 == 0 or step + 1 == args.steps:
            extra = ""
            if args.trust:
                extra += f"  evicted {float(metrics['trust_evicted_frac']):.2f}"
            print(f"step {step+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"consensus {float(metrics['consensus_dist']):.3f}{extra}  "
                  f"{(time.time()-t0)/(step-start+1):.2f}s/step", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            path = checkpoint.save(args.ckpt, step + 1, state)
            print(f"checkpoint -> {path}")

if args.profile:
    jax.profiler.stop_trace()
    print(f"profiler trace -> {args.profile}")
print("done.")
