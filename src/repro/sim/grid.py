"""Experiment-grid specifications.

The paper's headline results (Figs. 2-5, Sec. V) are *grids* — screening rule
x attack x Byzantine count x seed (x network scenario).  An `ExperimentGrid`
names the axes; `cells()` expands the cross product into `Cell`s, each a
single experiment identical in meaning to one `BridgeTrainer` /
`AsyncBridgeTrainer` run.  `repro.sim.engine.GridEngine` lowers a list of
cells (the full product, or the not-yet-computed subset of a resumable sweep)
into one compiled program.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Sequence
from typing import NamedTuple

import numpy as np

from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core.graph import Topology, erdos_renyi


class Cell(NamedTuple):
    """One experiment: a single point of the grid's cross product.

    ``scenario`` is ``None`` for the synchronous broadcast path, or a
    `repro.net.scenarios` name for the unreliable-network path.  ``codec``
    names the wire format (`repro.comm`) neighbor exchange travels in.
    ``adversary`` names a `repro.adversary` entry (adaptive or re-registered
    static; ``"none"`` keeps the classic attack-only path), ``theta`` its
    optional per-cell hyperparameter override (`THETA_DIM` floats — the
    red-team search's proposal vector), and ``mask_seed`` the draw that
    picks *which* nodes are Byzantine (None falls back to the grid's shared
    ``byzantine_seed`` — the pre-fix behavior where every seed reran the
    same mask).
    """

    rule: str
    attack: str
    b: int
    seed: int
    scenario: str | None = None
    codec: str = "identity"
    adversary: str = "none"
    mask_seed: int | None = None
    theta: tuple | None = None

    @property
    def tag(self) -> str:
        """Stable result-store key (file stem) for this cell.  Identity-codec
        / no-adversary tags match the pre-codec layout, so existing stores
        stay resumable — EXCEPT cells whose Byzantine placement actually
        changed under the mask_seed fix (mask_seed != 0 with a live mask),
        which get a ``_m<seed>`` marker so resumable stores never silently
        mix old-mask and new-mask results under one key."""
        base = f"{self.rule}_{self.attack}_b{self.b}_s{self.seed}"
        if (self.mask_seed not in (None, 0) and self.b > 0
                and not (self.attack == "none" and self.adversary == "none")):
            base = f"{base}_m{self.mask_seed}"
        if self.adversary != "none":
            base = f"{base}_adv_{self.adversary}"
        if self.theta is not None:
            import zlib

            base = f"{base}_th{zlib.crc32(repr(tuple(self.theta)).encode()):08x}"
        if self.scenario:
            base = f"{base}_{self.scenario}"
        return f"{base}_{self.codec}" if self.codec != "identity" else base


@dataclasses.dataclass(frozen=True)
class ExperimentGrid:
    """The cross product rules x attacks x byzantine_counts x seeds
    (x scenarios), over one shared topology and step-size schedule.

    ``scenarios=None`` runs the synchronous broadcast simulation; otherwise
    every cell runs through the unreliable-network runtime (the two paths
    carry different state and cannot mix inside one batch — split them into
    two grids).
    """

    topology: Topology
    rules: Sequence[str]
    attacks: Sequence[str]
    byzantine_counts: Sequence[int] = (1,)
    seeds: Sequence[int] = (0,)
    scenarios: Sequence[str] | None = None
    codecs: Sequence[str] = ("identity",)
    adversaries: Sequence[str] = ("none",)
    lam: float = 1.0
    t0: float = 50.0
    lr: float = 0.0
    byzantine_seed: int = 0
    # seed-axis sweeps vary WHICH nodes are Byzantine (mask_seed =
    # byzantine_seed + seed), not just data/init.  False restores the legacy
    # behavior where one shared mask made every "seed" replicate the same
    # Byzantine placement.
    mask_from_seed: bool = True

    def __post_init__(self):
        for axis in ("rules", "attacks", "byzantine_counts", "seeds", "scenarios",
                     "codecs", "adversaries"):
            vals = getattr(self, axis)
            if vals is not None and len(vals) != len(set(vals)):
                raise ValueError(f"duplicate entries on grid axis {axis}: {vals}")
        for rule in self.rules:
            screening.get_rule(rule)
        for attack in self.attacks:
            if self.scenarios is None:
                byz_lib.get_attack(attack)  # raises for message-only attacks
            else:
                byz_lib.get_message_attack(attack)
        from repro.adversary import get_adversary
        from repro.comm import get_codec

        for adv in self.adversaries:
            get_adversary(adv)
        for codec in self.codecs:
            get_codec(codec)
        if self.scenarios is not None:
            from repro.net.scenarios import get_scenario

            for s in self.scenarios:
                get_scenario(s)
        for rule in self.rules:
            for b in self.byzantine_counts:
                need = screening.min_neighbors(rule, b)
                if self.topology.min_in_degree < need:
                    raise ValueError(
                        f"rule {rule!r} with b={b} needs min in-degree >= {need}, "
                        f"grid topology has {self.topology.min_in_degree}"
                    )

    @property
    def num_cells(self) -> int:
        s = len(self.scenarios) if self.scenarios else 1
        return (len(self.rules) * len(self.attacks) * len(self.byzantine_counts)
                * len(self.seeds) * s * len(self.codecs) * len(self.adversaries))

    def cells(self) -> list[Cell]:
        """Rule-major expansion of the cross product."""
        scen = self.scenarios if self.scenarios is not None else (None,)
        return [
            Cell(r, a, b, s, sc, cd, adv,
                 mask_seed=(self.byzantine_seed + s) if self.mask_from_seed else None)
            for r, a, b, s, sc, cd, adv in itertools.product(
                self.rules, self.attacks, self.byzantine_counts, self.seeds, scen,
                self.codecs, self.adversaries,
            )
        ]


def default_topology(num_nodes: int, rules: Sequence[str], byzantine_counts: Sequence[int],
                     *, seed: int = 0) -> Topology:
    """An ER topology dense enough for every (rule, b) cell of a grid —
    escalating edge probability until Table-II minimum degrees hold (p = 1.0
    is the complete graph, which satisfies every rule at paper scale)."""
    b_max = max(byzantine_counts)
    need = max(screening.min_neighbors(r, b) for r in rules for b in byzantine_counts)
    for p in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
        try:
            topo = erdos_renyi(num_nodes, p, b_max, seed=seed)
        except RuntimeError:
            continue
        if topo.min_in_degree >= need:
            return topo
    raise RuntimeError(
        f"no ER({num_nodes}) topology supports rules={list(rules)} with b up to {b_max} "
        f"(need min in-degree >= {need}; use more nodes)"
    )


def pick_byz_mask(num_nodes: int, cell: Cell, byzantine_seed: int = 0) -> np.ndarray:
    """The cell's attacking-node mask — exactly `BridgeTrainer.__init__`'s
    rule: no attackers when neither an attack nor an adversary is named or
    b == 0, else a seeded draw of b nodes.  The draw uses the cell's own
    ``mask_seed`` when set (seed-axis sweeps then vary *which* nodes attack),
    falling back to the grid-shared ``byzantine_seed``."""
    if (cell.attack == "none" and cell.adversary == "none") or cell.b == 0:
        return np.zeros((num_nodes,), dtype=bool)
    nbyz = min(cell.b, num_nodes)
    seed = cell.mask_seed if cell.mask_seed is not None else byzantine_seed
    return np.asarray(byz_lib.pick_byzantine_mask(num_nodes, nbyz, seed))
