"""The canonical smoke task grid consumers share.

Breakdown certification (`repro.adversary.breakdown` via ``sweep --mode
breakdown`` and ``benchmarks/breakdown_bench.py``) and the red-team search
CLI all drive the same paper-scale task: the MNIST-like linear classifier
with a non-iid partition, scanned as stacked batches, scored by honest test
accuracy.  One builder keeps the three entry points certifying the *same*
task — they had already begun to drift apart as inline copies.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LinearTask(NamedTuple):
    """Everything a grid consumer needs to run + score the linear task."""

    grad_fn: Callable  # (node_params, batch) -> (loss, grads)
    init_fn: Callable  # seed -> [M, ...] replicated params
    batches: Any  # [T, ...] stacked batch pytree for scan-over-ticks (None if ticks=0)
    eval_accuracy: Callable  # (params [M, ...], honest_mask [M]) -> mean acc
    x_test: jax.Array
    y_test: jax.Array
    # a FRESH per-tick batch closure (stack_node_batches closures advance a
    # private rng per call, so this one is independent of `batches`' draws
    # but replays the identical sequence) — for step-at-a-time consumers
    # (ByRDiE sweeps, BRDSO steps) that don't scan stacked batches
    batch_fn: Callable = None


def linear_task(num_nodes: int, ticks: int, *, partition: str = "extreme",
                batch: int = 32, num_train: int = 2000, num_test: int = 400,
                seed: int = 0) -> LinearTask:
    """Assemble the MNIST-like linear task for ``num_nodes`` nodes over
    ``ticks`` stacked batches.  ``partition="extreme"`` (each node sees only
    one class — consensus is *required* for test accuracy, which is exactly
    what adaptive adversaries break) needs ``num_nodes >= 10``."""
    from repro.core import replicate
    from repro.data import (
        make_mnist_like,
        partition_extreme_noniid,
        partition_iid,
        partition_moderate_noniid,
    )
    from repro.data.partition import stack_node_batches
    from repro.models import small
    from repro.sim.engine import stack_batches

    part = {"iid": partition_iid, "extreme": partition_extreme_noniid,
            "moderate": partition_moderate_noniid}[partition]
    x, y, xt, yt = make_mnist_like(num_train, num_test, seed=seed)
    shards = part(x, y, num_nodes, seed=seed)
    batches = None
    if ticks > 0:
        bf = stack_node_batches(shards, batch, seed=seed)
        batches = stack_batches(
            lambda i: jax.tree_util.tree_map(jnp.asarray, bf(i)), ticks)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)

    def grad_fn(params, b):
        return jax.value_and_grad(lambda p: small.linear_loss(p, b))(params)

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return replicate(small.init_linear(key), num_nodes, perturb=0.01, key=key)

    def eval_accuracy(params, honest_mask):
        accs = [float(small.linear_accuracy(
            jax.tree_util.tree_map(lambda leaf: leaf[j], params), xt, yt))
            for j in np.nonzero(np.asarray(honest_mask))[0]]
        return float(np.mean(accs)) if accs else 0.0

    return LinearTask(grad_fn, init_fn, batches, eval_accuracy, xt, yt,
                      batch_fn=stack_node_batches(shards, batch, seed=seed))
