"""Batched experiment-grid engine: E experiments as one compiled program.

`repro.launch.sweep --mode net` historically ran each (rule, attack, scenario)
cell as a subprocess — re-tracing, re-compiling, and re-loading data per cell,
orders of magnitude slower than the math requires.  `GridEngine` instead
lowers a list of `Cell`s to stacked ``[E, M, D]`` state and drives a single
``lax.scan`` whose body is the *same* cell-parameterized step function
`BridgeTrainer` / `AsyncBridgeTrainer` bind (`repro.core.bridge`), ``vmap``-ed
over the experiment axis:

* rule / attack / scenario selection is **data** — int32 indices into static
  banks resolved by ``lax.switch`` (branchless under vmap; banks contain only
  the distinct names the cells use);
* the Byzantine bound ``b``, node masks, seeds, and step-size schedules ride
  along as per-cell arrays;
* network scenarios stack their `repro.net` channel/mailbox state over E
  (`GridNetRuntime` — one mailbox ring sized for the slowest scenario).

Banked switches make *arbitrary* cell mixtures correct, but under vmap a
switch computes every branch for every cell — an R-rule bank does R times the
screening work.  Since real sweeps are (near-)products, the engine also
**groups** cells with equal (rule, attack) and unrolls the groups statically
inside the same compiled program (``group=True``, the default): each group
runs the single-entry-bank step — zero bank waste — while scenario selection
and any leftover heterogeneity stay banked.  Cells are re-ordered group-major
internally and results are returned in the caller's order.

``chunk`` bounds peak memory: each group's cells are run ``chunk`` at a time,
padded so chunks of a group share one compilation (compilations scale with
the number of groups, never with E — asserted by ``tests/test_grid.py``).
Correctness anchor: any single cell is bit-identical to the corresponding
per-experiment trainer run.
"""
from __future__ import annotations

import time
from collections.abc import Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.adversary import protocols as adv_lib
from repro.comm import codec_bank as resolve_codec_bank
from repro.comm import exchange as comm_lib
from repro.core import byzantine as byz_lib
from repro.core.bridge import (
    BridgeState,
    CellParams,
    build_cell_runtime_step,
    build_cell_step,
    stack_batches,
    stack_flatten,
)

__all__ = ["GridEngine", "GridNetRuntime", "stack_batches"]
from repro.sim import grid as grid_lib
from repro.sim.grid import Cell, ExperimentGrid


def _dedup(names: Iterable) -> list:
    out = []
    for n in names:
        if n not in out:
            out.append(n)
    return out


class GridNetRuntime:
    """A scenario-banked network runtime: `UnreliableRuntime`s stacked over
    the experiment axis.

    Holds one `repro.net.runtime.UnreliableRuntime` per distinct scenario
    (full-length ``[T, M, M]`` schedules so they stack) and dispatches
    `exchange` through ``lax.switch`` on the cell's scenario index — under
    the engine's vmap every cell carries its own mailbox state, channel
    randomness, and staleness bound.  The shared mailbox ring is sized for
    the largest latency in the bank (ring semantics are invariant to extra
    capacity, so each cell remains bit-identical to its dedicated runtime).
    """

    cell_aware = True  # step passes the cell through (see build_cell_runtime_step)

    def __init__(self, topology, scenarios: Sequence[str], num_ticks: int, *, seed: int = 0,
                 sparse: bool = False):
        from repro.net.runtime import SparseUnreliableRuntime, UnreliableRuntime
        from repro.net.scenarios import build_schedule, get_scenario

        if not scenarios:
            raise ValueError("GridNetRuntime needs at least one scenario")
        self.scenario_names = tuple(scenarios)
        self._specs = [get_scenario(n) for n in self.scenario_names]
        self.num_ticks = int(num_ticks)
        scheds = [build_schedule(s, topology, self.num_ticks, seed=seed)
                  for s in self._specs]
        # neighbor-indexed mode: ONE table over the union of every scenario's
        # schedule, so all cells share the [M, K, ...] state layout (a slot
        # that any scenario can use exists in all of them; extra slots are
        # inert padding for the others — capacity-invariance again)
        self.neighbors = None
        if sparse:
            from repro.core.neighbors import NeighborTable

            self.neighbors = NeighborTable.from_schedule(
                np.concatenate([np.asarray(s, bool) for s in scheds], axis=0))
        runtimes = []
        for s, sched in zip(self._specs, scheds, strict=True):
            if sparse:
                runtimes.append(SparseUnreliableRuntime(
                    sched, s.channel, staleness_bound=s.staleness_bound,
                    neighbors=self.neighbors))
            else:
                runtimes.append(
                    UnreliableRuntime(sched, s.channel, staleness_bound=s.staleness_bound)
                )
        self._schedules_np = np.stack([np.asarray(s, bool) for s in scheds])  # [S, T, M, M]
        if sparse:
            # pre-gathered per-scenario live slots: [S, T, M, K]
            self._lives = jnp.asarray(np.stack(
                [self.neighbors.live_schedule(s) for s in self._schedules_np]))
            self._schedules = None
        else:
            self._schedules = jnp.asarray(self._schedules_np)
        self._runtimes = tuple(runtimes)

    def schedule_for(self, name: str) -> np.ndarray:
        """The exact ``[T, M, M]`` schedule a sequential comparator run must
        use to reproduce this runtime's cell bit-for-bit."""
        return self._schedules_np[self.scenario_names.index(name)]

    def adjacency_at(self, t: jax.Array, cell: CellParams) -> jax.Array:
        if self.neighbors is not None:
            return self._lives[cell.scenario_idx, t % self.num_ticks]  # [M, K]
        return self._schedules[cell.scenario_idx, t % self.num_ticks]

    def init(self, num_nodes: int, dim: int, max_wire_bits: int | None = None):
        from repro.net import mailbox as mb

        # shared ring sized for the slowest scenario's worst case: propagation
        # latency plus serialization of the largest codeword in the codec bank
        # (ring semantics are capacity-invariant, so smaller-latency cells
        # stay bit-identical to their dedicated runtimes)
        if max_wire_bits is None:
            max_wire_bits = 32 * dim
        ring = max(s.channel.max_total_latency(max_wire_bits) for s in self._specs)
        width = None if self.neighbors is None else self.neighbors.k
        return mb.init_mailbox(num_nodes, dim, ring, width=width)

    def exchange(self, net_state, msgs, self_vals, adjacency, key, t, cell: CellParams,
                 *, wire_bits=None):
        if len(self._runtimes) == 1:
            return self._runtimes[0].exchange(
                net_state, msgs, self_vals, adjacency, key, t, wire_bits=wire_bits)
        branches = [
            (lambda rt: lambda ns, ms, sv, adj, k, tt, wb: rt.exchange(
                ns, ms, sv, adj, k, tt, wire_bits=wb))(rt)
            for rt in self._runtimes
        ]
        wb = jnp.zeros((), jnp.int32) if wire_bits is None else jnp.asarray(wire_bits, jnp.int32)
        return jax.lax.switch(
            cell.scenario_idx, branches, net_state, msgs, self_vals, adjacency, key, t, wb
        )


class GridEngine:
    """Runs a list of grid `Cell`s as one jitted, vmapped ``lax.scan``.

    ``cells`` defaults to the grid's full cross product; a resumable sweep
    passes the not-yet-computed subset.  All cells must be on the same side
    of the sync/net split (their state pytrees differ).  ``num_ticks`` is
    required for net grids (schedule length); sync grids take their length
    from the scanned batches.

    ``group=True`` (default) statically unrolls one vmapped sub-scan per
    distinct (rule, attack, codec) inside the compiled program, eliminating
    the compute-every-branch cost of the banked switches for product grids;
    ``group=False`` forces the fully banked single-scan path (bit-for-bit
    equal for every cell whose codec is lossless; lossy codecs inside a
    *multi-codec* bank may differ from their grouped twin by ~1 ULP/step —
    XLA's FMA contraction of the dequantize multiply is program-shape
    dependent — and are asserted allclose by the tests).

    ``sparse=True`` runs every cell on the neighbor-indexed ``[M, K]`` state
    layout (`repro.core.neighbors`): net grids share ONE table over the
    union of all scenario schedules (mailboxes ``[E, M, K, L, d]``), sync
    grids screen gathered views — each cell bit-identical to its dense twin
    (``tests/test_sparse.py``) and the only layout that fits large M.

    Usage — a rule x attack x seed product as one compiled program::

        grid = ExperimentGrid(topology, rules=("trimmed_mean", "median"),
                              attacks=("random", "alie"),
                              byzantine_counts=(1,), seeds=(0, 1, 2, 3))
        engine = GridEngine(grid, grad_fn, trace=TraceSpec(),
                            trust=TrustSpec())
        final, metrics = engine.run(engine.init(init_fn), batches)
        losses = metrics["loss"]        # [E, T], ordered like engine.cells

    See ``examples/quickstart.py`` for the single-cell path this engine
    batches, and ``docs/ARCHITECTURE.md`` for what one tick does.
    """

    def __init__(
        self,
        grid: ExperimentGrid,
        grad_fn: Callable,
        *,
        cells: Sequence[Cell] | None = None,
        num_ticks: int | None = None,
        screen_chunk: int | None = None,
        scenario_seed: int = 0,
        group: bool = True,
        sparse: bool = False,
        trace=None,
        trust=None,
        metrics=None,
        events=None,
    ):
        # observability (repro.obs): `trace` is an engine-wide TraceSpec
        # compiled into every cell's step (None = untraced, the default);
        # `trust` the engine-wide repro.trust.TrustSpec (None = trust-free,
        # bit-identical to the pre-trust program);
        # `metrics` the engine-wide repro.obs.metrics.MetricSpec — per-tick
        # scalar rings stacked over [E], flushed per chunk to a MetricWriter
        # passed to `run` (None = metric-free, bit-identical program);
        # `events` an EventLog receiving run/chunk/divergence records from
        # the host-side loop around the jitted scans
        self._trace_spec = trace
        self._trust_spec = trust
        self._metric_spec = metrics
        self._events = events
        self.grid = grid
        self.cells = list(cells) if cells is not None else grid.cells()
        if not self.cells:
            raise ValueError("no cells to run")
        scen = [c.scenario for c in self.cells]
        if any(s is None for s in scen) != all(s is None for s in scen):
            raise ValueError(
                "cannot mix synchronous and net-scenario cells in one grid batch "
                "(their carried state differs); split into two grids"
            )
        self.net_mode = scen[0] is not None
        topo = grid.topology
        m = topo.num_nodes
        self.rule_bank = _dedup(c.rule for c in self.cells)
        self.attack_bank = _dedup(c.attack for c in self.cells)
        self.scenario_bank = _dedup(s for s in scen if s is not None)
        self.codec_bank = _dedup(c.codec for c in self.cells)
        self.adversary_bank = _dedup(c.adversary for c in self.cells)
        # the adversary axis engages only when some cell names one, so
        # adversary-free grids keep their exact pre-adversary program shape
        self._adv_engaged = any(c.adversary != "none" for c in self.cells)
        self._adv_stateful = self._adv_engaged and adv_lib.bank_stateful(
            adv_lib.adversary_bank(self.adversary_bank))
        self._bind_cells(self.cells)
        # neighbor-indexed [M, K] state layout (repro.core.neighbors): the
        # sync path screens gathered views, the net path runs sparse
        # runtimes; every cell stays bit-identical to its dense twin
        self.sparse = bool(sparse)
        self.neighbors = None
        if self.net_mode:
            if num_ticks is None:
                raise ValueError("num_ticks is required for net-scenario grids (schedule length)")
            self.runtime = GridNetRuntime(topo, self.scenario_bank, num_ticks,
                                          seed=scenario_seed, sparse=self.sparse)
            self.neighbors = self.runtime.neighbors
        else:
            self.runtime = None
            if self.sparse:
                from repro.core.neighbors import NeighborTable

                self.neighbors = NeighborTable.from_adjacency(topo.adjacency)
        self._screen_chunk = screen_chunk
        self._grad_fn = grad_fn
        self._adjacency = jnp.asarray(topo.adjacency)

        # Execution order: group-major (stable), identity when group=False.
        # Results are always returned in the caller's cell order via _inv.
        e = len(self.cells)
        self._group = group
        gkey = self._group_keys(self.cells)
        self._perm = np.asarray(sorted(range(e), key=lambda i: gkey[i]), np.int64)
        self._inv = np.argsort(self._perm)
        # group boundaries (over the permuted order) + one step per group
        self._bounds: list[tuple[int, int]] = []
        self._vsteps: list = []
        lo = 0
        for i in range(1, e + 1):
            if i == e or gkey[self._perm[i]] != gkey[self._perm[lo]]:
                head = self.cells[self._perm[lo]]
                if group:
                    rules, attacks, codecs = (head.rule,), (head.attack,), (head.codec,)
                    advs = (head.adversary,) if self._adv_engaged else None
                else:
                    rules, attacks, codecs = (tuple(self.rule_bank), tuple(self.attack_bank),
                                              tuple(self.codec_bank))
                    advs = tuple(self.adversary_bank) if self._adv_engaged else None
                self._vsteps.append(
                    jax.vmap(self._build_step(rules, attacks, codecs, advs),
                             in_axes=(0, 0, None)))
                self._bounds.append((lo, i))
                lo = i
        self._cell_perm = jax.tree_util.tree_map(lambda x: x[self._perm], self._cell_stack)
        self.trace_count = 0  # incremented once per scan (re)compilation

        def scan_all(cells_p, state_p, batches):
            # ONE compiled program: the group loop is statically unrolled.
            self.trace_count += 1  # Python side effect: runs only while tracing
            tree = jax.tree_util.tree_map
            finals, mss = [], []
            for vstep, (glo, ghi) in zip(self._vsteps, self._bounds, strict=True):
                cp = tree(lambda x: x[glo:ghi], cells_p)
                st = tree(lambda x: x[glo:ghi], state_p)
                f, ms = jax.lax.scan(lambda s, b: vstep(cp, s, b), st, batches)
                finals.append(f)
                mss.append(ms)
            final = tree(lambda *xs: jnp.concatenate(xs, axis=0), *finals)
            ms = tree(lambda *xs: jnp.concatenate(xs, axis=1), *mss)
            return final, ms

        self._scan_all = jax.jit(scan_all)
        self._group_scans: dict[int, Callable] = {}

    def _group_keys(self, cells) -> list[tuple[int, ...]]:
        if not self._group:
            return [(0, 0, 0, 0)] * len(cells)
        return [(self.rule_bank.index(c.rule), self.attack_bank.index(c.attack),
                 self.adversary_bank.index(c.adversary), self.codec_bank.index(c.codec))
                for c in cells]

    def _bind_cells(self, cells) -> None:
        """Stack per-cell parameters (byz masks, bank indices, schedules,
        adversary thetas) into the `CellParams` rows the vmapped steps read."""
        m = self.grid.topology.num_nodes
        e = len(cells)
        self.byz_masks = np.stack(
            [grid_lib.pick_byz_mask(m, c, self.grid.byzantine_seed) for c in cells]
        )
        adv_idx = adv_theta = None
        if self._adv_engaged:
            adv_idx = jnp.asarray(
                [self.adversary_bank.index(c.adversary) for c in cells], jnp.int32)
            adv_theta = jnp.asarray(
                [c.theta if c.theta is not None
                 else adv_lib.get_adversary(c.adversary).default_theta
                 for c in cells], jnp.float32)
        self._cell_stack = CellParams(
            rule_idx=jnp.asarray([self.rule_bank.index(c.rule) for c in cells], jnp.int32),
            attack_idx=jnp.asarray([self.attack_bank.index(c.attack) for c in cells], jnp.int32),
            b=jnp.asarray([c.b for c in cells], jnp.int32),
            byz_mask=jnp.asarray(self.byz_masks),
            lam=jnp.full((e,), self.grid.lam, jnp.float32),
            t0=jnp.full((e,), self.grid.t0, jnp.float32),
            lr=jnp.full((e,), self.grid.lr, jnp.float32),
            scenario_idx=jnp.asarray(
                [self.scenario_bank.index(c.scenario) if c.scenario else 0 for c in cells],
                jnp.int32,
            ),
            codec_idx=jnp.asarray(
                [self.codec_bank.index(c.codec) for c in cells], jnp.int32
            ),
            adv_idx=adv_idx,
            adv_theta=adv_theta,
            trace=self._trace_spec,  # zero-leaf aux data: no vmapped axis
            trust=self._trust_spec,  # zero-leaf aux data: no vmapped axis
            metrics=self._metric_spec,  # zero-leaf aux data: no vmapped axis
        )

    def set_cells(self, cells: Sequence[Cell]) -> None:
        """Swap the engine onto a new cell list of identical *structure* —
        same length and same per-position (rule, attack, adversary, codec,
        scenario) group keys — without invalidating the compiled programs.

        Everything that changed (b, seeds, byz masks, adversary thetas) is
        jit *data*, so the next `run` hits the existing compilation: this is
        what lets `repro.adversary.search` evaluate generation after
        generation of proposal populations at zero retrace cost
        (``trace_count`` stays 1 — asserted by its tests).
        """
        cells = list(cells)
        if len(cells) != len(self.cells):
            raise ValueError(
                f"set_cells needs {len(self.cells)} cells (engine shape), got {len(cells)}")
        # every name must resolve inside the compiled banks — the group-key
        # check alone is blind in group=False mode, where keys are constant
        for c in cells:
            for bank, name, axis in ((self.rule_bank, c.rule, "rule"),
                                     (self.attack_bank, c.attack, "attack"),
                                     (self.adversary_bank, c.adversary, "adversary"),
                                     (self.codec_bank, c.codec, "codec")):
                if name not in bank:
                    raise ValueError(
                        f"set_cells: {axis} {name!r} is outside this engine's "
                        f"compiled bank {bank}; rebuild a GridEngine to change "
                        f"the grid's structure")
            if c.scenario is not None and c.scenario not in self.scenario_bank:
                raise ValueError(
                    f"set_cells: scenario {c.scenario!r} is outside this "
                    f"engine's compiled bank {self.scenario_bank}")
        if self._group_keys(self.cells) != self._group_keys(cells):
            raise ValueError(
                "set_cells cells must keep the per-position (rule, attack, "
                "adversary, codec) group keys; rebuild a GridEngine to change "
                "the grid's structure")
        for c_old, c_new in zip(self.cells, cells, strict=True):
            if (c_new.scenario is None) != (c_old.scenario is None):
                raise ValueError("set_cells cannot move cells across the sync/net split")
        if not self._adv_engaged and any(c.adversary != "none" for c in cells):
            raise ValueError(
                "set_cells: this engine compiled without the adversary stage "
                "(all cells were adversary='none'); rebuild a GridEngine to add one")
        if self._adv_engaged and any(c.theta is not None and len(c.theta) != adv_lib.THETA_DIM
                                     for c in cells):
            raise ValueError(f"cell theta must have {adv_lib.THETA_DIM} entries")
        # bind BEFORE committing, so a failure leaves the engine untouched
        old_cells = self.cells
        try:
            self.cells = cells
            self._bind_cells(cells)
        except Exception:
            self.cells = old_cells
            self._bind_cells(old_cells)
            raise
        self._cell_perm = jax.tree_util.tree_map(lambda x: x[self._perm], self._cell_stack)

    def _build_step(self, rules: tuple[str, ...], attacks: tuple[str, ...],
                    codecs: tuple[str, ...], adversaries: tuple[str, ...] | None = None):
        wire_bank = byz_lib.wire_attack_bank(attacks)
        if self.net_mode:
            return build_cell_runtime_step(
                self._grad_fn, self.runtime, rules, byz_lib.message_attack_bank(attacks),
                codecs=codecs, wire_attacks=wire_bank, adversaries=adversaries,
                screen_chunk=self._screen_chunk,
            )
        return build_cell_step(
            self._grad_fn, self._adjacency, rules, byz_lib.attack_bank(attacks),
            codecs=codecs, wire_attacks=wire_bank, adversaries=adversaries,
            screen_chunk=self._screen_chunk, neighbors=self.neighbors,
        )

    def _group_scan(self, gi: int) -> Callable:
        """Lazily-jitted per-group scan for the chunked path (one trace per
        group, shared by all of the group's equally-shaped chunks)."""
        if gi not in self._group_scans:
            vstep = self._vsteps[gi]

            def core(cp, st, xs):
                self.trace_count += 1
                return jax.lax.scan(lambda s, b: vstep(cp, s, b), st, xs)

            self._group_scans[gi] = jax.jit(core)
        return self._group_scans[gi]

    @property
    def num_cells(self) -> int:
        return len(self.cells)

    def init(self, init_fn: Callable[[int], object]) -> BridgeState:
        """Stack per-cell initial states.  ``init_fn(seed) -> [M, ...]``
        pytree must be exactly what the sequential trainer would be handed —
        cells with equal seeds share initial replicas, and ``PRNGKey(seed)``
        matches ``BridgeTrainer.init(params, seed=seed)``."""
        m = self.grid.topology.num_nodes
        params = [init_fn(c.seed) for c in self.cells]
        lead = jax.tree_util.tree_leaves(params[0])[0].shape[0]
        if lead != m:
            raise ValueError(f"init_fn params leading axis {lead} != num_nodes {m}")
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *params
        )
        keys = jnp.stack([jax.random.PRNGKey(c.seed) for c in self.cells])
        t = jnp.zeros((len(self.cells),), jnp.int32)
        e = len(self.cells)
        w, _ = stack_flatten(params[0])
        dim = w.shape[1]
        bank = resolve_codec_bank(tuple(self.codec_bank))
        net = None
        if self.runtime is not None:
            one = self.runtime.init(m, dim, max_wire_bits=comm_lib.max_wire_bits(bank, dim))
            net = jax.tree_util.tree_map(
                lambda leaf: jnp.broadcast_to(leaf[None], (e,) + leaf.shape), one
            )
        # error-feedback carry: present engine-wide iff any codec in the bank
        # is lossy (state pytrees must be uniform across groups); per-link on
        # the net path ([M, K] slots when neighbor-indexed), per-sender on
        # the broadcast path
        if self.runtime is not None:
            link = m if self.runtime.neighbors is None else self.runtime.neighbors.k
            shape = (e, m, link, dim)
        else:
            shape = (e, m, dim)
        comm = comm_lib.init_residual(shape, bank)
        # adversary carry: present engine-wide iff any adversary in the bank
        # is stateful (same uniformity constraint); stateless cells thread it
        # through untouched (all-zeros in, all-zeros out)
        adv = adv_lib.init_state(dim, lead=(e,)) if self._adv_stateful else None
        # observability carry (repro.obs): engine-wide spec, stacked over [E]
        obs = trust = None
        width = m if self.neighbors is None else self.neighbors.k
        if self._trace_spec is not None:
            from repro.obs import trace as obs_trace

            obs = obs_trace.init_state(self._trace_spec, m, width, lead=(e,))
        # trust carry (repro.trust): engine-wide spec, stacked over [E]
        if self._trust_spec is not None:
            from repro.trust import reputation as trust_lib

            trust = trust_lib.init_state(self._trust_spec, m, width, lead=(e,))
        # metric rings (repro.obs.metrics): engine-wide spec, stacked over [E]
        mets = None
        if self._metric_spec is not None:
            from repro.obs import metrics as obs_metrics

            mets = obs_metrics.init_state(self._metric_spec, lead=(e,))
        return BridgeState(params=stacked, t=t, key=keys, net=net, comm=comm,
                           adv=adv, obs=obs, trust=trust, mets=mets)

    def run(self, state: BridgeState, batches, *, chunk: int | None = None,
            metric_writer=None):
        """Scan all cells over ``batches`` (a pytree of ``[T, ...]`` arrays,
        shared across cells).  Returns ``(final_state, metrics)`` with state
        leaves ``[E, ...]`` and metric leaves ``[E, T]``, in the order of
        ``self.cells``.

        ``chunk`` runs at most that many cells per compiled call (memory
        bound): each group's ragged last chunk is padded with copies of its
        final cell so all of a group's chunks share one compilation, then
        trimmed — compilations scale with the number of groups, never E.

        ``metric_writer`` (a `repro.obs.metrics.MetricWriter`, requires the
        engine's ``metrics=`` spec) streams each cell's per-tick scalar ring
        to ``metrics.jsonl`` tagged by cell — per finished chunk on the
        chunked path, once at the end otherwise.  The ring holds the last
        ``capacity`` ticks of each cell, so grid metric streams are a tail
        window, not the full trajectory (use per-cell trainers via
        ``run_chunks`` for gapless streams).
        """
        e = self.num_cells
        tree = jax.tree_util.tree_map
        perm, inv = self._perm, self._inv
        if metric_writer is not None and self._metric_spec is None:
            raise ValueError("metric_writer needs GridEngine(..., metrics=MetricSpec(...))")
        cells_p = self._cell_perm
        state_p = tree(lambda x: x[perm], state)
        ev = self._events
        t_run = time.perf_counter()
        if ev is not None:
            ticks = int(jax.tree_util.tree_leaves(batches)[0].shape[0])
            ev.emit("run.start", kind="grid", cells=e, ticks=ticks, chunk=chunk,
                    groups=len(self._bounds), sparse=self.sparse,
                    traced=self._trace_spec is not None)
        if chunk is None or chunk >= e:
            final_p, ms_p = self._scan_all(cells_p, state_p, batches)
        else:
            if chunk < 1:
                raise ValueError(f"chunk must be >= 1, got {chunk}")
            finals, mss = [], []
            for gi, (glo, ghi) in enumerate(self._bounds):
                gscan = self._group_scan(gi)
                n = ghi - glo
                width = min(chunk, n)  # one trace per group; pad ragged tails

                def padded(x, lo, hi):
                    sl = x[lo:hi]
                    pad = width - (hi - lo)
                    if not pad:
                        return sl
                    return jnp.concatenate(
                        [sl, jnp.broadcast_to(sl[-1:], (pad,) + sl.shape[1:])])

                for lo in range(glo, ghi, width):
                    hi = min(lo + width, ghi)
                    t_chunk = time.perf_counter()
                    f, ms = gscan(
                        tree(lambda x: padded(x, lo, hi), cells_p),
                        tree(lambda x: padded(x, lo, hi), state_p),
                        batches,
                    )
                    if ev is not None:
                        # block so the chunk wall is real compute, not
                        # dispatch (events-enabled runs trade async overlap
                        # for honest per-chunk timings)
                        f = jax.block_until_ready(f)
                        ev.emit("grid.chunk", group=gi, lo=int(lo), hi=int(hi),
                                wall_s=time.perf_counter() - t_chunk)
                    valid = hi - lo
                    f = tree(lambda x: x[:valid], f)
                    if metric_writer is not None:
                        metric_writer.flush(
                            f.mets,
                            tags=[self.cells[perm[j]].tag for j in range(lo, hi)])
                    finals.append(f)
                    mss.append(tree(lambda x: x[:, :valid], ms))
            final_p = tree(lambda *xs: jnp.concatenate(xs, axis=0), *finals)
            ms_p = tree(lambda *xs: jnp.concatenate(xs, axis=1), *mss)
        final = tree(lambda x: x[inv], final_p)
        ms = tree(lambda x: jnp.swapaxes(x[:, inv], 0, 1), ms_p)
        if metric_writer is not None and (chunk is None or chunk >= e):
            metric_writer.flush(final.mets, tags=[c.tag for c in self.cells])
        if ev is not None:
            final = jax.block_until_ready(final)
            ev.emit("run.end", kind="grid", wall_s=time.perf_counter() - t_run,
                    trace_count=self.trace_count)
            if final.obs is not None and self._trace_spec.sentinel:
                first_bad = np.asarray(final.obs.first_bad)
                for i, tick in enumerate(first_bad):
                    if tick >= 0:
                        ev.emit("obs.divergence", cell=self.cells[i].tag,
                                first_bad_tick=int(tick))
        return final, ms

    def cell_params_of(self, i: int) -> CellParams:
        """Row ``i`` of the stacked cell parameters (diagnostics/tests)."""
        return jax.tree_util.tree_map(lambda x: x[i], self._cell_stack)

    def sender_grid(self) -> np.ndarray:
        """``[M, W]`` sender node id per obs edge slot (-1 = never live) —
        what `repro.obs.trace.summarize` needs to name suspect edges.  Net
        grids keep every dense slot (schedules vary per tick); sync grids
        mask by the static adjacency."""
        from repro.obs import trace as obs_trace

        m = self.grid.topology.num_nodes
        if self.neighbors is not None:
            return obs_trace.sender_grid(m, neighbors=self.neighbors)
        return obs_trace.sender_grid(
            m, adjacency=None if self.net_mode else self.grid.topology.adjacency)


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "grid.set_cells.zero_retrace", "retrace",
        "swapping a generation of cells at fixed structure (set_cells) and "
        "re-running hits the existing compilation: trace_count is unchanged "
        "(the adversary-search zero-retrace contract)",
    ),
    Contract(
        "grid.specs.zero_leaf", "lint",
        "the obs/trust/metric specs carried by CellParams are zero-leaf "
        "pytrees (pure jit structure) — a leaf would be vmapped across "
        "cells and retrace per generation",
        params=(("check", "zero_leaf_specs"),
                ("classes", ("repro.obs.trace:TraceSpec",
                             "repro.obs.metrics:MetricSpec",
                             "repro.trust.reputation:TrustSpec"))),
    ),
)
