"""Structured grid results — the contract between the engine, the resumable
sweep store, and the benchmark/figure consumers.

A `GridResult` is the host-side record of one engine run: a list of per-cell
records (axes + final/averaged metrics) plus run metadata (wall time,
cells/sec, trace count, banks).  It serializes to one aggregate JSON
(`save`) and, for resumable sweeps, to one JSON per cell keyed by the cell's
stable tag (`save_cells` / `existing_tags`) — re-running a sweep only
computes the cells whose files are missing.  `rows()` renders the CSV rows
`benchmarks.run` prints, so `benchmarks/paper_figs.py` and
`benchmarks/grid_bench.py` consume grid runs through one type.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from collections.abc import Callable, Sequence

import numpy as np

from repro.sim.grid import Cell

# ---------------------------------------------------------------------------
# Metric-stream reducer registry
# ---------------------------------------------------------------------------
#
# `collect` used to reduce two hardcoded key tuples — any other engine metric
# stream vanished silently (``rho`` and ``active_links`` already had).  The
# registry is extensible: subsystems that add metric streams register a
# reducer for them (`repro.obs.trace` registers its aggregates at import),
# and `collect` *warns* on streams nothing registered instead of dropping
# them without a trace.

_REDUCERS: dict[str, tuple[str, Callable[[np.ndarray], float]]] = {}


def register_reducer(key: str, out_key: str, fn: Callable[[np.ndarray], float]) -> None:
    """Register ``fn`` to reduce the per-tick stream ``key`` ([T] per cell)
    into the cell-record field ``out_key``."""
    _REDUCERS[key] = (out_key, fn)


def register_final(key: str) -> None:
    """Reduce ``key`` to its final tick as ``final_<key>``."""
    register_reducer(key, f"final_{key}", lambda a: float(a[-1]))


def register_mean(key: str) -> None:
    """Reduce ``key`` to its tick-mean as ``mean_<key>`` (keys already
    ``mean_``-prefixed keep their name — no double prefix)."""
    out = key if key.startswith("mean_") else f"mean_{key}"
    register_reducer(key, out, lambda a: float(a.mean()))


for _k in ("loss", "consensus_dist", "ef_residual_norm", "rho"):
    register_final(_k)
for _k in ("delivered_frac", "mean_staleness", "screened_frac", "usable_in",
           "wire_bits_per_edge", "wire_bytes_total", "active_links"):
    register_mean(_k)
# chunk-streaming per-block trim stream (repro.stream / repro.obs): a [T, NB]
# stream per cell; the mean reducer collapses ticks AND blocks, matching the
# scalar obs_trim_frac semantics at NB = 1
register_mean("stream_block_trim_frac")


def collect(cells: Sequence[Cell], metrics: dict, *, meta: dict | None = None) -> "GridResult":
    """Summarize engine metrics (``[E, T]`` leaves) into a `GridResult`."""
    host = {k: np.asarray(v) for k, v in metrics.items()}
    unregistered = sorted(k for k in host if k not in _REDUCERS)
    if unregistered:
        warnings.warn(
            f"metric streams {unregistered} have no registered reducer and are "
            f"dropped from cell records; add one via "
            f"repro.sim.results.register_reducer/register_final/register_mean "
            f"(registered: {sorted(_REDUCERS)})",
            stacklevel=2)
    records = []
    for i, c in enumerate(cells):
        rec = {
            "rule": c.rule, "attack": c.attack, "b": int(c.b), "seed": int(c.seed),
            "scenario": c.scenario, "codec": c.codec, "adversary": c.adversary,
            "mask_seed": c.mask_seed,
            "theta": None if c.theta is None else [float(x) for x in c.theta],
        }
        for k, (out_key, fn) in _REDUCERS.items():
            if k in host:
                rec[out_key] = fn(host[k][i])
        records.append(rec)
    return GridResult(cells=records, meta=dict(meta or {}))


def cell_of(record: dict) -> Cell:
    """The grid `Cell` a record describes (tag round-trips through this)."""
    theta = record.get("theta")
    mask_seed = record.get("mask_seed")
    return Cell(record["rule"], record["attack"], int(record["b"]), int(record["seed"]),
                record.get("scenario"), record.get("codec", "identity"),
                record.get("adversary", "none"),
                None if mask_seed is None else int(mask_seed),
                None if theta is None else tuple(float(x) for x in theta))


@dataclasses.dataclass
class GridResult:
    """One grid run: per-cell records + run metadata."""

    cells: list[dict]
    meta: dict

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"meta": self.meta, "cells": self.cells}, f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "GridResult":
        with open(path) as f:
            data = json.load(f)
        return cls(cells=data["cells"], meta=data.get("meta", {}))

    def save_cells(self, out_dir: str) -> None:
        """Per-cell files for the resumable sweep store (one JSON per tag)."""
        os.makedirs(out_dir, exist_ok=True)
        for rec in self.cells:
            with open(os.path.join(out_dir, cell_of(rec).tag + ".json"), "w") as f:
                json.dump(rec, f, indent=2, sort_keys=True)

    def rows(self, prefix: str = "grid") -> list[tuple[str, float, str]]:
        """CSV rows for the `benchmarks.run` harness: one row per cell, timed
        at the run's amortized us/cell."""
        us_per_cell = float(self.meta.get("us_per_cell", 0.0))
        rows = []
        for rec in self.cells:
            derived = ";".join(
                f"{k.replace('final_', '').replace('mean_', '')}={rec[k]:.4f}"
                for k in ("accuracy", "final_loss", "final_consensus_dist", "mean_delivered_frac")
                if k in rec
            )
            rows.append((f"{prefix}/{cell_of(rec).tag}", us_per_cell, derived))
        return rows


def existing_tags(out_dir: str) -> set[str]:
    """Tags already present in a per-cell result store (sweep resumability)."""
    if not os.path.isdir(out_dir):
        return set()
    return {f[:-5] for f in os.listdir(out_dir)
            if f.endswith(".json") and f != "GridResult.json"}


def load_cell_store(out_dir: str) -> GridResult:
    """Assemble a `GridResult` from every per-cell file in a store — the
    on-disk records are the source of truth, so aggregates rebuilt after a
    resumed sweep cover all runs, not just the latest."""
    records = []
    for tag in sorted(existing_tags(out_dir)):
        with open(os.path.join(out_dir, tag + ".json")) as f:
            records.append(json.load(f))
    return GridResult(cells=records, meta={"total_cells": len(records)})
