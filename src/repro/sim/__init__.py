"""repro.sim — the batched experiment-grid engine.

The paper's evidence is a *grid* (screening rule x attack x Byzantine count x
seed x network scenario); this package runs E grid cells as **one compiled
program** instead of E subprocesses:

* `grid` — `ExperimentGrid` / `Cell` specs (axes, tags, topology helpers).
* `engine` — `GridEngine`: stacked ``[E, M, D]`` state driven by a single
  ``lax.scan`` with ``vmap`` over the experiment axis, reusing the
  cell-parameterized `repro.core.bridge` step functions; `GridNetRuntime`
  stacks `repro.net` channel/mailbox state over E; ``chunk`` bounds memory.
* `results` — `GridResult`: the structured record benchmarks, paper figures,
  and the resumable sweep store consume.
"""
from repro.sim.engine import GridEngine, GridNetRuntime, stack_batches
from repro.sim.grid import Cell, ExperimentGrid, default_topology, pick_byz_mask
from repro.sim.results import GridResult, cell_of, collect, existing_tags, load_cell_store

__all__ = [
    "GridEngine", "GridNetRuntime", "stack_batches",
    "Cell", "ExperimentGrid", "default_topology", "pick_byz_mask",
    "GridResult", "cell_of", "collect", "existing_tags", "load_cell_store",
]
