from repro.data.mnist_like import make_mnist_like
from repro.data.partition import partition_extreme_noniid, partition_iid, partition_moderate_noniid
from repro.data.tokens import TokenPipeline, synthetic_token_batch

__all__ = [
    "make_mnist_like",
    "partition_iid", "partition_extreme_noniid", "partition_moderate_noniid",
    "TokenPipeline", "synthetic_token_batch",
]
