"""Synthetic token pipeline for LM training (offline environment).

Generates structured sequences (a mixture of n-gram-ish Markov chains) so the
loss actually decreases during the example runs — pure-uniform tokens give a
flat loss and hide training bugs.  Deterministic per (seed, step, node).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_per_node: int
    num_nodes: int
    seed: int = 0
    order: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 1024)  # active vocabulary
        self._v = v
        # sparse-ish Markov transition: each token has ~8 likely successors
        succ = rng.integers(0, v, (v, 8))
        self._succ = succ

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        m, b, s = self.num_nodes, self.batch_per_node, self.seq_len
        toks = np.empty((m, b, s + 1), np.int32)
        cur = rng.integers(0, self._v, (m, b))
        toks[..., 0] = cur
        for t in range(1, s + 1):
            choice = rng.integers(0, 8, (m, b))
            nxt = self._succ[cur, choice]
            # 10% random restarts for entropy
            mask = rng.random((m, b)) < 0.1
            nxt = np.where(mask, rng.integers(0, self._v, (m, b)), nxt)
            toks[..., t] = nxt
            cur = nxt
        return {"tokens": toks}


def synthetic_token_batch(vocab: int, shape: tuple, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, min(vocab, 1024), shape).astype(np.int32)
