"""Dataset partitioners across decentralized nodes (paper Sec. V).

* iid — shuffle and split evenly (V-A, V-B).
* extreme non-iid — group by label; all samples of label c go to the
  num_nodes/num_classes agents assigned to c (V-C "extreme").
* moderate non-iid — each label's samples are split evenly over
  2*num_nodes/num_classes agents so every agent holds exactly two labels
  (V-C "moderate").
"""
from __future__ import annotations

import numpy as np


def partition_iid(x, y, num_nodes: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    return [
        (x[s], y[s]) for s in np.array_split(idx, num_nodes)
    ]


def partition_extreme_noniid(x, y, num_nodes: int, *, n_classes: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    per_class = num_nodes // n_classes
    assert per_class >= 1, "need num_nodes >= n_classes"
    shards: list = [None] * num_nodes
    node = 0
    for c in range(n_classes):
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        for s in np.array_split(idx, per_class):
            shards[node] = (x[s], y[s])
            node += 1
    # any leftover nodes get iid remainder
    while node < num_nodes:
        idx = rng.permutation(len(x))[: len(x) // num_nodes]
        shards[node] = (x[idx], y[idx])
        node += 1
    return shards


def partition_moderate_noniid(x, y, num_nodes: int, *, n_classes: int = 10, seed: int = 0):
    """Each label split over 2*num_nodes/n_classes agents; each agent ends up
    with two labels."""
    rng = np.random.default_rng(seed)
    splits_per_class = 2 * num_nodes // n_classes
    pieces = []  # (class, x, y)
    for c in range(n_classes):
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        for s in np.array_split(idx, splits_per_class):
            pieces.append((c, x[s], y[s]))
    rng.shuffle(pieces)
    # assign two pieces of different classes per node
    shards = []
    used = [False] * len(pieces)
    for _ in range(num_nodes):
        first = next(i for i in range(len(pieces)) if not used[i])
        used[first] = True
        second = next(
            (i for i in range(len(pieces)) if not used[i] and pieces[i][0] != pieces[first][0]),
            None,
        )
        if second is None:
            second = next(i for i in range(len(pieces)) if not used[i])
        used[second] = True
        xs = np.concatenate([pieces[first][1], pieces[second][1]])
        ys = np.concatenate([pieces[first][2], pieces[second][2]])
        shards.append((xs, ys))
    return shards


def stack_node_batches(shards, batch_size: int, *, seed: int = 0):
    """Build an infinite iterator of stacked [M, B, ...] minibatches drawn
    per-node from the given shards."""
    rng = np.random.default_rng(seed)
    m = len(shards)

    def batch_fn(step: int):
        xs, ys = [], []
        for j in range(m):
            xj, yj = shards[j]
            idx = rng.integers(0, len(xj), batch_size)
            xs.append(xj[idx])
            ys.append(yj[idx])
        return np.stack(xs), np.stack(ys)

    return batch_fn
