"""Synthetic MNIST-like dataset (offline environment — no downloads).

Generates 28x28 single-channel images from 10 deterministic class templates
(random low-frequency patterns) plus per-sample Gaussian noise and random
shifts.  Classes are linearly separable enough that a linear classifier
reaches high accuracy — mirroring the roles MNIST plays in the paper's
experiments (Sec. V): a well-understood convex task and a CNN task whose
*relative* degradation under Byzantine attacks is the quantity of interest.
"""
from __future__ import annotations

import numpy as np


def _templates(rng: np.random.Generator, n_classes: int) -> np.ndarray:
    """Smooth class templates: superpositions of a few 2D sinusoids."""
    yy, xx = np.mgrid[0:28, 0:28] / 28.0
    t = np.zeros((n_classes, 28, 28), np.float32)
    for c in range(n_classes):
        for _ in range(3):
            fx, fy = rng.uniform(0.5, 3.0, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            amp = rng.uniform(0.5, 1.0)
            t[c] += amp * np.sin(2 * np.pi * fx * xx + px) * np.sin(2 * np.pi * fy * yy + py)
        t[c] = (t[c] - t[c].min()) / (t[c].max() - t[c].min() + 1e-9)
    return t


def make_mnist_like(
    num_train: int = 6000,
    num_test: int = 1000,
    *,
    n_classes: int = 10,
    noise: float = 0.35,
    seed: int = 0,
):
    """Returns (x_train [N,784], y_train [N], x_test, y_test), float32/int32."""
    rng = np.random.default_rng(seed)
    templates = _templates(rng, n_classes)

    def gen(n):
        y = rng.integers(0, n_classes, n).astype(np.int32)
        x = templates[y].copy()
        # random +-2 pixel shift
        for i in range(n):
            sx, sy = rng.integers(-2, 3, 2)
            x[i] = np.roll(np.roll(x[i], sx, axis=0), sy, axis=1)
        x += noise * rng.standard_normal(x.shape).astype(np.float32)
        return x.reshape(n, 784).astype(np.float32), y

    x_tr, y_tr = gen(num_train)
    x_te, y_te = gen(num_test)
    mu, sd = x_tr.mean(), x_tr.std() + 1e-6
    return (x_tr - mu) / sd, y_tr, (x_te - mu) / sd, y_te
