"""repro.adversary — adaptive omniscient adversaries + breakdown certification.

The red-team side of BRIDGE as a first-class subsystem:

* `protocols` — the stateful `Adversary` API: an `AdvState` pytree threaded
  through `BridgeState` and the training scan (running honest statistics,
  tracked consensus direction), banked via ``lax.switch`` on
  ``CellParams.adv_idx`` exactly like rules/attacks/codecs.  Static attacks
  are re-registered as stateless adversaries, so one grid axis covers both.
* `adaptive` — omniscient attacks that optimize per tick: inner maximization
  through the differentiable screening step, online-sigma ALIE, IPM, and
  time-coupled dissensus.
* `equivocation` — protocol-level adversaries the trust layer exists for:
  equivocators (per-receiver inconsistent lies — only the echo protocol
  sees them) and slanderers (honest values, forged gossip digests — the
  echo quorum defeats them).
* `breakdown` — certification engine: binary-search the breakdown point b*
  per (rule, topology, adversary) with batched probe rounds on the grid
  engine, emitting ``BENCH_breakdown.json`` (import explicitly:
  ``from repro.adversary import breakdown`` — it depends on `repro.sim`).
* `search` — red-team hyperparameter search (random + evolutionary) running
  proposal populations as grid cells of one compiled program (import
  explicitly, same reason).
"""
from repro.adversary import adaptive as _adaptive  # noqa: F401  (registers)
from repro.adversary import equivocation as _equivocation  # noqa: F401  (registers)
from repro.adversary.protocols import (
    ADVERSARIES,
    THETA_DIM,
    Adversary,
    AdvCtx,
    AdvState,
    adversary_bank,
    apply_accuse_bank,
    apply_adversary_bank,
    apply_message_adversary_bank,
    attack_names,
    bank_accuses,
    bank_engaged,
    bank_stateful,
    cell_theta,
    default_thetas,
    get_adversary,
    init_state,
    registry_tiers,
)

__all__ = [
    "ADVERSARIES", "THETA_DIM", "Adversary", "AdvCtx", "AdvState",
    "adversary_bank", "apply_accuse_bank", "apply_adversary_bank",
    "apply_message_adversary_bank", "attack_names", "bank_accuses",
    "bank_engaged", "bank_stateful", "cell_theta", "default_thetas",
    "get_adversary", "init_state", "registry_tiers",
]
