"""Breakdown-point certification: the largest b a (rule, topology, adversary)
triple actually tolerates.

"Achieving Optimal Breakdown for Byzantine Robust Gossip" (Gaucher &
Dieuleveut, 2024) frames resilience as a *breakdown point* and shows that
screening-rule rankings invert once the adversary adapts — a rule's Table-II
degree bound says when screening is *defined*, not when it *works*.  This
module turns that framing into a certification engine on top of
`repro.sim.GridEngine`:

* every probe (rule, adversary, b, seed) is one grid cell; a probe *round*
  runs all pending probes across every (rule, adversary) pair as ONE batched
  engine call;
* ``mode="bisect"`` binary-searches b* per pair — ceil(log2(b_max)) rounds,
  each a fresh compile; ``mode="ladder"`` probes every feasible b in a
  single compiled run (the right choice at smoke scale, and what the
  breakdown *curve* figure needs anyway);
* divergence detection runs on the stacked loss trace: a cell diverges when
  its trace goes non-finite, its final honest loss exceeds
  ``loss_ratio x`` the faultless (b=0) reference, or — when a host-side
  ``eval_fn`` is given (e.g. honest test accuracy, the paper's metric) — its
  score drops more than ``score_drop`` below the reference;
* certification is *monotone*: after the search, every b <= b* the bisection
  skipped is probed too (ladder mode has them already), and b* is lowered to
  the longest all-surviving prefix — a bisection can otherwise overshoot on
  a non-monotone fluke.

The result feeds ``BENCH_breakdown.json`` (CI-gated) and the
``fig_breakdown`` paper figure (loss / score vs b per rule).
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable, Sequence

import jax
import numpy as np

from repro.core import screening
from repro.obs import TraceSpec
from repro.sim import Cell, ExperimentGrid, GridEngine

# ctor sentinel: "use the default sentinel-only trace" (pass trace=None to
# run with observability fully off)
_DEFAULT_TRACE = object()


@dataclasses.dataclass(frozen=True)
class BreakdownConfig:
    """Knobs of the certification run.

    ``b_max`` caps the searched range (None: whatever the topology's minimum
    in-degree admits per rule); ``loss_ratio`` is the divergence threshold
    relative to the faultless reference's final loss; ``score_drop`` (with an
    ``eval_fn``) flags cells whose host-side score fell that far below the
    reference; ``seeds`` must all survive for a probe to count as surviving.
    ``measure_compile`` double-runs each probe round (second call hits the
    jit cache) to split ``compile_s`` from ``steady_state_s`` in the meta —
    opt-in because it doubles device work.
    """

    b_max: int | None = None
    seeds: tuple[int, ...] = (0,)
    loss_ratio: float = 4.0
    score_drop: float | None = None
    mode: str = "ladder"  # ladder | bisect
    measure_compile: bool = False


def feasible_b(rule: str, topology, b_cap: int | None = None) -> int:
    """The largest b whose Table-II minimum in-degree the topology satisfies
    (never more than M - 2: at least one honest pair must remain)."""
    m = topology.num_nodes
    hi = 0
    for b in range(1, m - 1):
        if screening.min_neighbors(rule, b) > topology.min_in_degree:
            break
        hi = b
    return hi if b_cap is None else min(hi, b_cap)


class BreakdownEngine:
    """Certifies b* for every (rule, adversary) pair over one topology.

    ``grad_fn`` / ``init_fn`` / ``batches`` are exactly the `GridEngine`
    contract; ``eval_fn(params, honest_mask)``, when given, scores one cell's
    final ``[M, ...]`` params host-side (higher = better, e.g. honest test
    accuracy).

    ``scenario`` moves every probe from the synchronous broadcast path onto
    the unreliable-network runtime (a `repro.net.scenarios` name; ``"ideal"``
    = mailbox exchange over a perfect channel).  This is what the
    equivocation study needs — per-receiver lies only exist at message
    granularity.  ``trust`` compiles a `repro.trust.TrustSpec` into every
    probe, so the engine can certify *detect-and-expel* breakdown points:
    the trust arm of ``benchmarks/trust_bench.py`` runs the same ladder
    twice — static rule vs reputation-weighted rule + trust — and gates on
    the b* gap.

    Minimal usage::

        eng = BreakdownEngine(topo, ["trimmed_mean"], ["alie_online"],
                              grad_fn, init_fn, batches)
        result = eng.run()          # result["rules"][rule]["adversaries"][adv]["bstar"]
    """

    def __init__(self, topology, rules: Sequence[str], adversaries: Sequence[str],
                 grad_fn: Callable, init_fn: Callable, batches, *,
                 lam: float = 1.0, t0: float = 30.0,
                 config: BreakdownConfig = BreakdownConfig(),
                 eval_fn: Callable | None = None,
                 engine_chunk: int | None = None,
                 trace=_DEFAULT_TRACE, trust=None,
                 scenario: str | None = None, events=None):
        if "none" in adversaries:
            raise ValueError("'none' is the reference, not a certifiable adversary")
        self.topology = topology
        self.rules = tuple(rules)
        self.adversaries = tuple(adversaries)
        self.grad_fn = grad_fn
        self.init_fn = init_fn
        self.batches = batches
        self.lam, self.t0 = lam, t0
        self.config = config
        self.eval_fn = eval_fn
        self.engine_chunk = engine_chunk
        # sentinel-only trace by default: divergence is *located* (first bad
        # tick per probe) instead of inferred from NaNs in the loss trace;
        # bit-inert, so certification verdicts are unchanged
        self.trace = (TraceSpec(forensics=False, sentinel=True)
                      if trace is _DEFAULT_TRACE else trace)
        self.trust = trust
        self.scenario = scenario
        # net-mode grids need the schedule length up front
        self.num_ticks = int(jax.tree_util.tree_leaves(batches)[0].shape[0])
        self.events = events
        self.compiles = 0
        self.cells_run = 0
        self.compile_s = 0.0
        self.steady_state_s = 0.0
        self.feasible = {r: feasible_b(r, topology, config.b_max) for r in self.rules}
        # probe ledger: (rule, adversary, b) -> record dict
        self.probes: dict[tuple[str, str, int], dict] = {}
        self.refs: dict[str, dict] = {}

    # -- one batched probe round ------------------------------------------

    def _grid(self) -> ExperimentGrid:
        return ExperimentGrid(
            self.topology, self.rules, ("none",), byzantine_counts=(0,),
            seeds=self.config.seeds,
            scenarios=None if self.scenario is None else (self.scenario,),
            adversaries=("none",) + self.adversaries,
            lam=self.lam, t0=self.t0,
        )

    def _run_round(self, keys: list[tuple[str, str, int]]) -> None:
        """Run every (rule, adversary, b) probe (x seeds) as one engine call
        and record per-probe aggregates in the ledger."""
        keys = [k for k in keys if k not in self.probes]
        if not keys:
            return
        cells = [Cell(rule, "none", b, s, scenario=self.scenario,
                      adversary=adv, mask_seed=s)
                 for (rule, adv, b) in keys for s in self.config.seeds]
        engine = GridEngine(self._grid(), self.grad_fn, cells=cells,
                            trace=self.trace, trust=self.trust,
                            num_ticks=self.num_ticks if self.scenario else None)
        state = engine.init(self.init_fn)
        t0 = time.perf_counter()
        final, metrics = engine.run(state, self.batches, chunk=self.engine_chunk)
        final = jax.block_until_ready(final)
        wall = time.perf_counter() - t0
        if self.config.measure_compile:
            # second call hits the jit cache: its wall IS the steady-state
            # round, the excess of the first call is compile time
            t1 = time.perf_counter()
            jax.block_until_ready(engine.run(state, self.batches, chunk=self.engine_chunk))
            steady = time.perf_counter() - t1
            self.compile_s += max(wall - steady, 0.0)
            self.steady_state_s += steady
        self.compiles += engine.trace_count
        self.cells_run += len(cells)
        loss = np.asarray(metrics["loss"], np.float64)  # [E, T]
        first_bad = (np.asarray(final.obs.first_bad)
                     if final.obs is not None else None)  # [E] or None
        ns = len(self.config.seeds)
        for j, key in enumerate(keys):
            rows = slice(j * ns, (j + 1) * ns)
            rec = {
                "final_loss": float(np.mean(loss[rows, -1])),
                "max_final_loss": float(np.max(loss[rows, -1])),
                "finite": bool(np.isfinite(loss[rows]).all()),
            }
            if first_bad is not None:
                bad = first_bad[rows][first_bad[rows] >= 0]
                rec["first_bad_tick"] = int(bad.min()) if bad.size else None
            if self.eval_fn is not None:
                # score only the seeds that stayed finite: a diverged run's
                # params are NaN and would poison the host-side score, hiding
                # *when* the cell broke behind an opaque NaN
                scores = []
                for i in range(j * ns, (j + 1) * ns):
                    if not np.isfinite(loss[i]).all():
                        continue
                    params_i = jax.tree_util.tree_map(lambda x: x[i], final.params)
                    scores.append(float(self.eval_fn(params_i, ~engine.byz_masks[i])))
                rec["score"] = float(np.mean(scores)) if scores else None
            self.probes[key] = rec
            if self.events is not None and rec.get("first_bad_tick") is not None:
                self.events.emit("obs.divergence", rule=key[0], adversary=key[1],
                                 b=key[2], first_bad_tick=rec["first_bad_tick"])
        if self.events is not None:
            self.events.emit("breakdown.round", probes=len(keys), cells=len(cells),
                             wall_s=wall, compiles=engine.trace_count)

    def _survived(self, rule: str, adv: str, b: int) -> bool:
        rec = self.probes[(rule, adv, b)]
        ref = self.refs[rule]
        ok = rec["finite"] and rec["max_final_loss"] <= (
            self.config.loss_ratio * max(ref["final_loss"], 1e-9) + 1e-6)
        if ok and self.eval_fn is not None and self.config.score_drop is not None:
            ok = rec["score"] >= ref["score"] - self.config.score_drop
        rec["survived"] = bool(ok)
        return rec["survived"]

    # -- certification ----------------------------------------------------

    def run(self) -> dict:
        t_start = time.time()
        # faultless references (b=0, adversary-free), one per rule
        self._run_round([(rule, "none", 0) for rule in self.rules])
        for rule in self.rules:
            self.refs[rule] = self.probes[(rule, "none", 0)]
            self.refs[rule]["survived"] = True
        pairs = [(r, a) for r in self.rules for a in self.adversaries]
        # the raw search answer per pair, before the prefix certificate;
        # a certified b* below it means the search overshot on a
        # non-monotone fluke (reported honestly via certified_monotone)
        search_bstar: dict[tuple[str, str], int] = {}
        if self.config.mode == "ladder":
            self._run_round([(r, a, b) for r, a in pairs
                             for b in range(1, self.feasible[r] + 1)])
        elif self.config.mode == "bisect":
            # batched binary search: one engine round serves every pair's probe
            lo = {p: 0 for p in pairs}  # largest b known surviving
            hi = {p: self.feasible[p[0]] + 1 for p in pairs}  # smallest diverging
            while any(hi[p] - lo[p] > 1 for p in pairs):
                mids = {p: (lo[p] + hi[p]) // 2 for p in pairs if hi[p] - lo[p] > 1}
                self._run_round([(r, a, m) for (r, a), m in mids.items()])
                for p, mid in mids.items():
                    if self._survived(p[0], p[1], mid):
                        lo[p] = mid
                    else:
                        hi[p] = mid
            search_bstar = dict(lo)
            # monotone certificate: probe the skipped prefix below each b*
            self._run_round([(r, a, b) for (r, a) in pairs
                             for b in range(1, lo[(r, a)] + 1)])
        else:
            raise ValueError(f"unknown breakdown mode {self.config.mode!r}")

        result = {"rules": {}, "meta": {
            "mode": self.config.mode, "seeds": list(self.config.seeds),
            "loss_ratio": self.config.loss_ratio,
            "adversaries": list(self.adversaries),
            "scenario": self.scenario,
            "trust": self.trust is not None,
        }}
        for rule in self.rules:
            rrec = {"feasible_b": self.feasible[rule],
                    "ref": dict(self.refs[rule]), "adversaries": {}}
            worst = self.feasible[rule]
            for adv in self.adversaries:
                # the FULL probed ladder (failures included — ladder mode has
                # every b, so downstream equal-b comparisons across tiers
                # never lose a point to another tier's early break)
                ladder = {}
                for b in range(1, self.feasible[rule] + 1):
                    if (rule, adv, b) in self.probes:
                        self._survived(rule, adv, b)
                        ladder[b] = dict(self.probes[(rule, adv, b)])
                bstar = 0
                for b in range(1, self.feasible[rule] + 1):
                    if b not in ladder or not ladder[b]["survived"]:
                        break
                    bstar = b
                # the actual certificate, computed from the ledger: every
                # b <= b* was probed and survived, AND the prefix walk agrees
                # with the raw search answer (a bisection that overshot on a
                # non-monotone fluke reports certified_monotone=False while
                # b* stays the conservative prefix)
                certified = all(
                    b in ladder and ladder[b]["survived"]
                    for b in range(1, bstar + 1)
                ) and bstar == search_bstar.get((rule, adv), bstar)
                rrec["adversaries"][adv] = {
                    "bstar": bstar,  # the longest all-surviving prefix
                    "certified_monotone": bool(certified),
                    "probes": {str(b): rec for b, rec in ladder.items()},
                }
                worst = min(worst, bstar)
            rrec["bstar_worst_adversary"] = worst
            result["rules"][rule] = rrec
        result["meta"].update({
            "wall_s": time.time() - t_start,
            "compiles": self.compiles,
            "cells_run": self.cells_run,
            "cells_per_sec": self.cells_run / max(time.time() - t_start, 1e-9),
        })
        if self.config.measure_compile:
            result["meta"]["compile_s"] = self.compile_s
            result["meta"]["steady_state_s"] = self.steady_state_s
        return result


def breakdown_curve(result: dict) -> list[tuple[str, str, int, float, float | None]]:
    """Flatten a certification result into figure rows:
    ``(rule, adversary, b, final_loss, score)`` sorted for plotting."""
    rows = []
    for rule, rrec in result["rules"].items():
        for adv, arec in rrec["adversaries"].items():
            for b_str, probe in sorted(arec["probes"].items(), key=lambda kv: int(kv[0])):
                rows.append((rule, adv, int(b_str),
                             probe["final_loss"], probe.get("score")))
    return rows
