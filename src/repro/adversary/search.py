"""Red-team hyperparameter search: find the theta that breaks a rule.

An adaptive adversary is only as strong as its hyperparameters (attack
scale, z, ascent steps, ...).  This module searches the registered
``theta_bounds`` box of one adversary against one (rule, b) defense with a
random + evolutionary loop whose *entire proposal population runs as grid
cells of one compiled program*:

* generation 0: the registered default plus uniform-random draws inside the
  bounds;
* every later generation: the elite (highest honest damage) survive, and
  the rest are gaussian mutations of random elites, clipped to the bounds;
* fitness is the mean final honest loss over the evaluation seeds
  (maximize — the red team's objective), with non-finite traces scored as
  +inf fitness (a total break);
* the population size and cell structure never change, so after the first
  generation compiles, `GridEngine.set_cells` swaps thetas as jit *data* —
  ``trace_count`` stays 1 across the whole search (asserted in tests).

    PYTHONPATH=src python -m repro.adversary.search --rule trimmed_mean \
        --adversary ipm --b 2 [--population 12] [--generations 4]
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable, Sequence

import numpy as np

from repro.adversary import protocols as adv_lib
from repro.sim import Cell, ExperimentGrid, GridEngine


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    population: int = 12
    generations: int = 4
    elite: int = 3
    mutation_scale: float = 0.15  # gaussian sigma as a fraction of each bound's width
    seeds: tuple[int, ...] = (0,)  # evaluation seeds per proposal
    seed: int = 0  # the search's own PRNG


def _sample_theta(rng: np.random.Generator, bounds) -> tuple[float, ...]:
    return tuple(
        0.0 if hi <= lo else float(rng.uniform(lo, hi)) for lo, hi in bounds
    )


def _mutate_theta(rng: np.random.Generator, theta, bounds, scale: float) -> tuple[float, ...]:
    out = []
    for x, (lo, hi) in zip(theta, bounds, strict=True):
        if hi <= lo:
            out.append(0.0)
            continue
        out.append(float(np.clip(x + rng.normal() * scale * (hi - lo), lo, hi)))
    return tuple(out)


def red_team_search(topology, rule: str, adversary: str, b: int,
                    grad_fn: Callable, init_fn: Callable, batches, *,
                    lam: float = 1.0, t0: float = 30.0,
                    config: SearchConfig = SearchConfig(),
                    engine_chunk: int | None = None) -> dict:
    """Search ``adversary``'s theta box against ``(rule, b)``.  Returns the
    ledger: best theta/fitness, per-generation history, and the engine's
    trace count (1 — the zero-retrace contract)."""
    adv = adv_lib.get_adversary(adversary)
    if all(hi <= lo for lo, hi in adv.theta_bounds):
        raise ValueError(f"adversary {adversary!r} has no searchable theta slots")
    rng = np.random.default_rng(config.seed)
    pop = max(config.population, 2)
    ns = len(config.seeds)

    def cells_for(thetas: Sequence[tuple]) -> list[Cell]:
        return [Cell(rule, "none", b, s, adversary=adversary, mask_seed=s, theta=th)
                for th in thetas for s in config.seeds]

    thetas = [tuple(map(float, adv.default_theta))]
    thetas += [_sample_theta(rng, adv.theta_bounds) for _ in range(pop - 1)]
    grid = ExperimentGrid(topology, (rule,), ("none",), byzantine_counts=(b,),
                          seeds=config.seeds, adversaries=(adversary,),
                          lam=lam, t0=t0)
    engine = GridEngine(grid, grad_fn, cells=cells_for(thetas))
    state0 = engine.init(init_fn)

    history, best_theta, best_fit = [], None, -np.inf
    default_fit = None
    t_start = time.time()
    for gen in range(config.generations):
        if gen > 0:
            engine.set_cells(cells_for(thetas))
        _, metrics = engine.run(state0, batches, chunk=engine_chunk)
        loss = np.asarray(metrics["loss"], np.float64)  # [pop*ns, T]
        fits = []
        for j in range(pop):
            tail = loss[j * ns:(j + 1) * ns, -1]
            # a non-finite honest trace is a total break: top fitness
            fits.append(np.inf if not np.isfinite(tail).all() else float(np.mean(tail)))
        if gen == 0:
            default_fit = fits[0]  # thetas[0] is the registered default
        order = np.argsort(fits)[::-1]
        if fits[order[0]] > best_fit:
            best_fit, best_theta = fits[order[0]], thetas[order[0]]
        history.append({
            "generation": gen,
            "best_fitness": fits[order[0]],
            "best_theta": list(thetas[order[0]]),
            "mean_fitness": float(np.mean([f for f in fits if np.isfinite(f)] or [np.inf])),
        })
        elite = [thetas[i] for i in order[:config.elite]]
        thetas = list(elite)
        while len(thetas) < pop:
            if rng.random() < 0.25:  # fresh random blood
                thetas.append(_sample_theta(rng, adv.theta_bounds))
            else:
                parent = elite[rng.integers(len(elite))]
                thetas.append(_mutate_theta(rng, parent, adv.theta_bounds,
                                            config.mutation_scale))
    return {
        "rule": rule, "adversary": adversary, "b": b,
        "best_theta": list(best_theta),
        "best_fitness": best_fit,
        "default_fitness": default_fit,
        "generations": history,
        "trace_count": engine.trace_count,
        "wall_s": time.time() - t_start,
        "proposals_evaluated": pop * config.generations,
    }


def main(argv=None):  # pragma: no cover - thin CLI smoke
    import argparse
    import json

    from repro.sim import default_topology
    from repro.sim.tasks import linear_task

    ap = argparse.ArgumentParser()
    ap.add_argument("--rule", default="trimmed_mean")
    ap.add_argument("--adversary", default="ipm")
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=10)
    ap.add_argument("--ticks", type=int, default=40)
    ap.add_argument("--population", type=int, default=12)
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--out", default=None, help="write the ledger JSON here")
    args = ap.parse_args(argv)

    topo = default_topology(args.nodes, (args.rule,), (args.b,), seed=0)
    task = linear_task(args.nodes, args.ticks, seed=0)
    ledger = red_team_search(
        topo, args.rule, args.adversary, args.b,
        task.grad_fn, task.init_fn, task.batches, lam=1.0, t0=30.0,
        config=SearchConfig(population=args.population, generations=args.generations))
    print(json.dumps({k: v for k, v in ledger.items() if k != "generations"}, indent=2,
                     default=str))
    for g in ledger["generations"]:
        print(f"  gen {g['generation']}: best={g['best_fitness']:.4g} "
              f"theta={[round(t, 3) for t in g['best_theta']]}")
    if ledger["trace_count"] != 1:
        raise SystemExit(f"expected one compile, got {ledger['trace_count']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(ledger, f, indent=2, default=str)


if __name__ == "__main__":  # pragma: no cover
    main()
