"""Omniscient adaptive attacks — adversaries that *optimize* each tick.

Four families, all jit/vmap-compatible (fixed shapes, no host control flow)
so rule x adversary x b grids still compile once:

* ``alie_online`` — ALIE with *tracked* statistics: the crafted value hides
  at ``mu - z * sigma`` like the static attack, but ``sigma`` is the running
  (EMA) estimate — robust to per-tick variance spikes an instantaneous
  estimate would chase — ``z`` defaults to the classic ALIE quantile bound
  computed from (M, b) instead of a fixed 1.5, and the lie is extrapolated
  along the tracked consensus velocity by the channel's expected latency, so
  on a laggy network it still sits inside the trimming band *on arrival*.
* ``ipm`` — inner-product-manipulation (Xie et al.) in iterate space: push
  the consensus *backwards* along its own tracked motion, clipped inside the
  per-coordinate trimming band so screening cannot rank it out.  Strictly
  more targeted than ALIE's fixed-sign shift: every surviving coordinate
  carries negative inner product with the honest descent direction.
* ``dissensus`` — time-coupled cluster splitting: track the principal honest
  deviation axis (EMA of the max-deviation node's offset, sign-aligned so it
  cannot cancel), then broadcast band-limited perturbations of *alternating
  sign* per Byzantine node — neighbors of different attackers get pulled to
  opposite sides of the axis, starving consensus instead of biasing it.  The
  message-granularity variant (network runtime) pushes each *receiver* along
  its own side of the axis.
* ``inner_max`` — the strongest: K steps of projected sign-gradient *ascent
  through the (differentiable, banked) screening step itself*, maximizing
  post-screen consensus displacement.  The perturbation warm-starts from the
  previous tick's optimum (carried in `AdvState.dir`), making the attack
  time-coupled: it keeps probing the screening rule's current blind spot.

Hyperparameter slots (``CellParams.adv_theta``, searched by
`repro.adversary.search`) are documented per registration below; slot value
0 selects the registered default, so an all-zeros theta is always valid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.adversary.protocols import (
    EMA,
    Adversary,
    observe,
    register,
)


def _pick(value, default):
    """theta slot semantics: 0 -> registered default.  Searchable bounds
    below keep their lower edge strictly above 0 so a clipped mutation can
    approach 'off' continuously without snapping onto this sentinel."""
    return jnp.where(value > 0, value, default)


def _substitute(w, byz_mask, crafted_rows):
    return jnp.where(byz_mask[:, None], crafted_rows, w)


# ---------------------------------------------------------------------------
# Online-sigma ALIE
# ---------------------------------------------------------------------------


def _auto_z(m: int, byz_mask):
    """The classic ALIE z bound: the largest z such that the crafted value
    still collects enough honest 'supporters' to survive coordinate-wise
    trimming — Phi^-1((n - s) / n) with n honest nodes and
    s = floor(M/2) + 1 - b supporters needed."""
    b = jnp.sum(byz_mask).astype(jnp.float32)
    n = jnp.maximum(m - b, 1.0)
    s = jnp.floor(m / 2.0) + 1.0 - b
    q = jnp.clip((n - s) / n, 0.05, 0.95)
    return jnp.asarray(jax.scipy.stats.norm.ppf(q), jnp.float32)


def _alie_online_fn(ctx, state, theta, w, byz_mask, key, t):
    state, mu, sigma, vel = observe(state, w, byz_mask)
    vel_ema = jnp.where(state.count > 1, EMA * state.dir + (1.0 - EMA) * vel, vel)
    state = state._replace(dir=vel_ema)
    # z floor: the classic quantile bound degenerates to ~0 at small (M, b);
    # the fixed-z regime (Baruch et al.'s empirical setting) dominates there
    z = _pick(theta[0], jnp.maximum(_auto_z(w.shape[0], byz_mask), 1.5))
    extrap = _pick(theta[1], 1.0)
    # band-hugging sigma: the *instantaneous* spread is what defines this
    # tick's trim band, but when consensus tightens faster than the attack
    # can bite, the tracked estimate keeps a minimum band open (static ALIE
    # starves as sigma -> 0); never exceed the instantaneous band by more
    # than the tracked one allows or trimming ranks the lie straight out
    sigma_eff = jnp.maximum(sigma, 0.5 * jnp.sqrt(state.var + 1e-12))
    crafted = mu + extrap * ctx.latency * vel_ema - z * sigma_eff
    return _substitute(w, byz_mask, crafted[None, :]), state


register(Adversary(
    "alie_online", _alie_online_fn, stateful=True,
    # theta: [z (0 = max(quantile bound, 1.5)), velocity-extrapolation gain]
    default_theta=(0.0, 1.0, 0.0, 0.0),
    theta_bounds=((0.05, 3.0), (0.01, 2.0), (0.0, 0.0), (0.0, 0.0)),
))


# ---------------------------------------------------------------------------
# Inner-product manipulation (iterate-space)
# ---------------------------------------------------------------------------


def _ipm_fn(ctx, state, theta, w, byz_mask, key, t):
    state, mu, sigma, vel = observe(state, w, byz_mask)
    vel_ema = jnp.where(state.count > 1, EMA * state.dir + (1.0 - EMA) * vel, vel)
    state = state._replace(dir=vel_ema)
    eps = _pick(theta[0], 6.0)
    clip_z = _pick(theta[1], 1.5)
    # reverse the tracked consensus motion, amplified by how stale the view
    # will be on arrival, but never leave the per-coordinate trimming band
    pert = -eps * (1.0 + ctx.latency) * vel_ema
    band = clip_z * sigma
    crafted = mu + jnp.clip(pert, -band, band)
    return _substitute(w, byz_mask, crafted[None, :]), state


register(Adversary(
    "ipm", _ipm_fn, stateful=True,
    # theta: [eps (motion-reversal gain), clip_z (band half-width in sigmas)]
    default_theta=(6.0, 1.5, 0.0, 0.0),
    theta_bounds=((0.5, 20.0), (0.5, 3.0), (0.0, 0.0), (0.0, 0.0)),
))


# ---------------------------------------------------------------------------
# Time-coupled dissensus
# ---------------------------------------------------------------------------


def _dissensus_core(state, theta, w, byz_mask):
    """Shared state tracking: returns (state', mu, band-limited perturbation
    along the tracked principal honest deviation axis)."""
    state, mu, sigma, _ = observe(state, w, byz_mask)
    honest = ~byz_mask
    dev = jnp.where(honest[:, None], w - mu[None, :], 0.0)
    j_star = jnp.argmax(jnp.sum(dev * dev, axis=1))
    u_inst = dev[j_star]
    # sign-align before averaging so the EMA cannot cancel across ticks
    align = jnp.where(jnp.vdot(u_inst, state.dir) < 0, -1.0, 1.0)
    u = jnp.where(state.count > 1, EMA * state.dir + (1.0 - EMA) * align * u_inst, u_inst)
    state = state._replace(dir=u)
    z = _pick(theta[0], 1.5)
    # per-coordinate bounded by z*sigma, directionally aligned with u
    pert = z * sigma * jnp.tanh(u / (sigma + 1e-6))
    return state, mu, pert


def _dissensus_fn(ctx, state, theta, w, byz_mask, key, t):
    state, mu, pert = _dissensus_core(state, theta, w, byz_mask)
    # alternating signs across the Byzantine ranks: different attackers pull
    # their neighborhoods to opposite sides of the axis
    rank = jnp.cumsum(byz_mask.astype(jnp.int32)) - 1
    sign = jnp.where(byz_mask, 1.0 - 2.0 * (rank % 2).astype(jnp.float32), 0.0)
    crafted = mu[None, :] + sign[:, None] * pert[None, :]
    return _substitute(w, byz_mask, crafted), state


def _dissensus_receiver_lies(ctx, state, theta, w, byz_mask):
    """Shared by the dense and sparse message variants: the advanced state
    and the per-RECEIVER crafted row (each receiver pushed outward along its
    own side of the tracked axis — only expressible at message granularity)."""
    state, mu, pert = _dissensus_core(state, theta, w, byz_mask)
    proj = (w - mu[None, :]) @ state.dir
    side = jnp.where(proj >= 0, 1.0, -1.0)
    crafted = mu[None, :] + side[:, None] * pert[None, :]  # [receiver, d]
    return state, crafted


def _dissensus_message_fn(ctx, state, theta, w, byz_mask, adjacency, key, t):
    state, crafted = _dissensus_receiver_lies(ctx, state, theta, w, byz_mask)
    m = w.shape[0]
    base = jnp.broadcast_to(w[None, :, :], (m,) + w.shape)
    lie = jnp.broadcast_to(crafted[:, None, :], (m,) + w.shape)
    if ctx.deliver_mask is not None:
        # waste nothing on coordinates the capped channel will backfill
        lie = jnp.where(ctx.deliver_mask[None, None, :], lie, base)
    msgs = jnp.where(byz_mask[None, :, None], lie, base)
    # no single broadcast value exists: Byzantine nodes screen truthfully
    return msgs, w, state


def _dissensus_sparse_message_fn(ctx, state, theta, w, byz_mask, nbr, live, key, t):
    del live
    state, crafted = _dissensus_receiver_lies(ctx, state, theta, w, byz_mask)
    base = nbr.gather_rows(w)  # [M, K, d]
    lie = jnp.broadcast_to(crafted[:, None, :], base.shape)
    if ctx.deliver_mask is not None:
        lie = jnp.where(ctx.deliver_mask[None, None, :], lie, base)
    msgs = jnp.where(nbr.gather_senders(byz_mask, fill=False)[:, :, None], lie, base)
    return msgs, w, state


register(Adversary(
    "dissensus", _dissensus_fn, stateful=True, message_fn=_dissensus_message_fn,
    sparse_message_fn=_dissensus_sparse_message_fn,
    # theta: [z (band half-width in sigmas)]
    default_theta=(1.5, 0.0, 0.0, 0.0),
    theta_bounds=((0.5, 3.0), (0.0, 0.0), (0.0, 0.0), (0.0, 0.0)),
))


# ---------------------------------------------------------------------------
# Inner maximization through the screening step
# ---------------------------------------------------------------------------

K_MAX = 8  # static unroll bound for the projected-ascent loop


def _inner_max_fn(ctx, state, theta, w, byz_mask, key, t):
    state, mu, sigma, vel = observe(state, w, byz_mask)
    radius = _pick(theta[0], 3.0)
    lr = _pick(theta[1], 0.75)
    k = jnp.clip(jnp.round(_pick(theta[2], 6.0)).astype(jnp.int32), 1, K_MAX)
    if ctx.screen is None:  # no screening oracle on this path: static fallback
        crafted = mu - radius * sigma
        return _substitute(w, byz_mask, crafted[None, :]), state

    honest = ~byz_mask
    cnt = jnp.maximum(jnp.sum(honest), 1)

    def post_screen_mean(wb):
        y = ctx.screen(wb)
        return jnp.sum(jnp.where(honest[:, None], y, 0.0), axis=0) / cnt

    y0_mean = post_screen_mean(w)  # what consensus would do unattacked
    # compounding term: damage accumulates only when successive ticks push
    # the consensus the SAME way, so reward displacement aligned with the
    # realized honest drift (which includes the drift this attack already
    # caused — a positive feedback the one-step objective alone misses)
    vnorm = jnp.sqrt(jnp.sum(vel * vel)) + 1e-12
    drift = vel / vnorm

    beta = _pick(theta[3], 1.0)

    def objective(delta):
        crafted = mu + delta * sigma
        wb = _substitute(w, byz_mask, crafted[None, :])
        disp = post_screen_mean(wb) - y0_mean
        along = jnp.vdot(disp, drift)
        # one-step displacement, plus signed alignment with the drift:
        # one-step-optimal zig-zags cancel across ticks, drift-aligned
        # pushes compound
        return jnp.sum(disp * disp) + jnp.where(
            state.count > 1, beta * along * jnp.abs(along), 0.0)

    grad = jax.grad(objective)
    # the ascent warm-starts from the previous tick's optimum (the attack
    # keeps probing the screening rule's current blind spot) and keeps the
    # best iterate seen: screening rules have large zero-gradient plateaus
    # (a candidate Krum never selects moves nothing), so a step off the
    # selected region must not strand the attack there
    alie_pt = -jnp.minimum(radius, 1.5) * jnp.ones_like(mu)
    warm = jnp.where(state.count > 1, jnp.clip(state.dir, -radius, radius), alie_pt)
    # the ALIE collusion point is a persistent fallback candidate: a crafted
    # cluster every rule demonstrably admits, so the optimized attack never
    # scores below plain ALIE on its own objective
    o_warm, o_alie = objective(warm), objective(alie_pt)
    best0 = jnp.where(o_alie > o_warm, alie_pt, warm)
    carry0 = (warm, best0, jnp.maximum(o_warm, o_alie))

    def ascend(_, carry):
        delta, best, best_obj = carry
        # sign ascent is scale-free per coordinate (the objective's gradient
        # magnitude varies over many orders across coordinates)
        delta = jnp.clip(delta + lr * jnp.sign(grad(delta)), -radius, radius)
        o = objective(delta)
        best = jnp.where(o > best_obj, delta, best)
        return delta, best, jnp.maximum(o, best_obj)

    _, delta, _ = jax.lax.fori_loop(0, k, ascend, carry0)
    state = state._replace(dir=delta)
    crafted = mu + delta * sigma
    return _substitute(w, byz_mask, crafted[None, :]), state


register(Adversary(
    "inner_max", _inner_max_fn, stateful=True,
    # theta: [radius (sigmas), lr (sigmas/step), K (ascent steps, <= K_MAX),
    #         beta (drift-compounding weight)]
    default_theta=(3.0, 0.75, 6.0, 1.0),
    theta_bounds=((1.0, 4.0), (0.1, 2.0), (1.0, float(K_MAX)), (0.01, 4.0)),
))
