"""Protocol-level adversaries: equivocators and slanderers.

The adaptive tier (`repro.adversary.adaptive`) attacks consensus *values* —
every lie is a single per-tick row the whole network sees.  Two strictly
nastier families attack the *protocol*:

* ``equivocate`` — the sender tells different receivers different lies:
  receiver j gets ``mu + sgn(j, i) * z * sigma`` (the ALIE collusion point,
  but on *alternating sides* of the honest spread by receiver/sender
  parity).  Each individual payload is band-hugging and survives value
  screening on its own; the inconsistency is only visible by comparing
  receptions — which is exactly what the commit-then-gossip echo protocol
  (`repro.trust.echo`) does.  On the broadcast path a sender physically has
  one payload, so the registration degrades to the one-sided ALIE point.
* ``slander`` — the dual attack, aimed at the trust layer itself: Byzantine
  nodes send *honest* values (value screening sees nothing, ever) but forge
  the digest rows they gossip (`Adversary.accuse_fn`), accusing every
  honest in-neighbor of equivocation.  The echo protocol's ``b + 1`` witness
  quorum is what defeats it — at most b forged votes can never confirm an
  accusation — and the trust bench asserts honest evictions stay at 0 under
  this attack.

Both register in the banked adversary dispatch like any other, under their
own `registry_tiers` tiers (``equivocator`` / ``slanderer``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.adversary.protocols import (
    Adversary,
    observe,
    register,
)
from repro.adversary.adaptive import _pick, _substitute


# ---------------------------------------------------------------------------
# Equivocation: per-receiver inconsistent ALIE
# ---------------------------------------------------------------------------


def _equiv_core(state, theta, w, byz_mask):
    """(state', mu, band): the tracked honest center and the per-coordinate
    half-width ``z * sigma`` the per-receiver lies sit at."""
    state, mu, sigma, _ = observe(state, w, byz_mask)
    z = _pick(theta[0], 1.5)
    return state, mu, z * sigma


def _sign_grid(m: int) -> jnp.ndarray:
    """``[receiver, sender]`` alternating-side matrix: +1 or -1 by
    receiver/sender parity, so each Byzantine sender splits its audience
    into two groups holding contradictory payloads (and two senders never
    split the audience identically)."""
    j = jnp.arange(m)
    return 1.0 - 2.0 * ((j[:, None] + j[None, :]) % 2).astype(jnp.float32)


def _equivocate_fn(ctx, state, theta, w, byz_mask, key, t):
    # broadcast path: one payload per sender by construction — equivocation
    # is structurally impossible, degrade to the minus-side collusion point
    state, mu, band = _equiv_core(state, theta, w, byz_mask)
    crafted = mu - band
    return _substitute(w, byz_mask, crafted[None, :]), state


def _equivocate_message_fn(ctx, state, theta, w, byz_mask, adjacency, key, t):
    state, mu, band = _equiv_core(state, theta, w, byz_mask)
    m = w.shape[0]
    sgn = _sign_grid(m)  # [receiver, sender]
    base = jnp.broadcast_to(w[None, :, :], (m,) + w.shape)
    lie = mu[None, None, :] + sgn[:, :, None] * band[None, None, :]
    if ctx.deliver_mask is not None:
        # waste nothing on coordinates the capped channel will backfill
        lie = jnp.where(ctx.deliver_mask[None, None, :], lie, base)
    msgs = jnp.where(byz_mask[None, :, None], lie, base)
    # no single broadcast value exists: Byzantine nodes screen truthfully
    return msgs, w, state


def _equivocate_sparse_message_fn(ctx, state, theta, w, byz_mask, nbr, live, key, t):
    del live
    state, mu, band = _equiv_core(state, theta, w, byz_mask)
    # the dense sign matrix gathered through the table — the bitwise gather
    # of the dense lie tensor (dense <-> sparse parity contract)
    sgn = nbr.gather_edges(_sign_grid(nbr.num_nodes))  # [M, K]
    base = nbr.gather_rows(w)  # [M, K, d]
    lie = mu[None, None, :] + sgn[:, :, None] * band[None, None, :]
    if ctx.deliver_mask is not None:
        lie = jnp.where(ctx.deliver_mask[None, None, :], lie, base)
    msgs = jnp.where(nbr.gather_senders(byz_mask, fill=False)[:, :, None], lie, base)
    return msgs, w, state


register(Adversary(
    "equivocate", _equivocate_fn, stateful=True, tier="equivocator",
    message_fn=_equivocate_message_fn,
    sparse_message_fn=_equivocate_sparse_message_fn,
    # theta: [z (band half-width in sigmas)]
    default_theta=(1.5, 0.0, 0.0, 0.0),
    theta_bounds=((0.5, 3.0), (0.0, 0.0), (0.0, 0.0), (0.0, 0.0)),
))


# ---------------------------------------------------------------------------
# Slander: honest values, forged gossip
# ---------------------------------------------------------------------------


def _slander_fn(ctx, state, theta, w, byz_mask, key, t):
    # values stay honest — the attack lives entirely in accuse_fn
    del ctx, theta, byz_mask, key, t
    return w, state


def _slander_accuse_fn(theta, digests, byz_mask, key, t):
    """Forge the rows Byzantine nodes report: shift every digest by a large
    constant so the forged row disagrees with every honest witness about
    every sender — the maximal framing attempt.  ``theta[0]`` scales the
    shift (0 selects the default)."""
    del key, t
    mag = _pick(theta[0], 1e3)
    return digests + jnp.where(byz_mask[:, None, None], mag, 0.0)


register(Adversary(
    "slander", _slander_fn, stateful=False, tier="slanderer",
    accuse_fn=_slander_accuse_fn,
    # theta: [digest forgery magnitude]
    default_theta=(1e3, 0.0, 0.0, 0.0),
    theta_bounds=((1.0, 1e6), (0.0, 0.0), (0.0, 0.0), (0.0, 0.0)),
))
