"""The stateful adversary protocol — Definition 1 upgraded to worst-case.

Every attack in `repro.core.byzantine` is *oblivious*: a pure function of the
current broadcast matrix (fixed-scale gaussian, fixed-z ALIE, ...).  The
Byzantine model permits far more — an omniscient adversary may observe the
whole honest trajectory and *adapt*.  This module makes that adversary a
first-class, grid-bankable object:

* `Adversary` — a named attack whose call carries an `AdvState` pytree
  through the training scan, so the adversary can track honest-node
  statistics across iterations (running mean/variance of broadcasts, the
  estimated consensus-motion direction, a warm-started perturbation).  The
  state is threaded through `repro.core.bridge.BridgeState` and the
  ``lax.scan`` carry exactly like the wire codec's error-feedback residual.
* `AdvCtx` — the omniscient observation surface the step hands the
  adversary: a differentiable closure over this cell's *banked* screening
  step (inner-maximization attacks ascend through it), the coordinate
  subset the channel will actually deliver this tick (bandwidth-capped
  links), and the channel's expected latency (stale-view extrapolation).
* banked dispatch — adversary selection is **data**: an int32
  ``CellParams.adv_idx`` into a static bank resolved by ``lax.switch``,
  exactly like rules/attacks/codecs, so a rule x adversary x b grid still
  compiles once.  Per-cell attack hyperparameters ride along as a
  ``THETA_DIM``-vector (``CellParams.adv_theta``), which is what lets
  `repro.adversary.search` run whole proposal populations as grid cells of
  one compiled program.

Every static broadcast attack is re-registered here as a *stateless*
adversary (its `AdvState` passes through untouched — all-zeros in, all-zeros
out, property-tested), so the adversary tier subsumes the broadcast tier and
one grid axis covers both.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable, Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import byzantine as byz_lib

# Per-cell adversary hyperparameter vector width (CellParams.adv_theta).
# Slots are adversary-specific (see each registration's docstring); unused
# slots are zero.  Fixed width keeps the stacked cell pytree uniform.
THETA_DIM = 4

# EMA decay for the tracked honest-broadcast statistics.
EMA = 0.8


class AdvState(NamedTuple):
    """The adversary's carried observations — one global (colluding) state.

    Uniform across every registered adversary so a mixed bank switches over
    one pytree: stateless entries ignore it and pass it through unchanged.

    ``mean``/``var``: EMA of the honest broadcasts' per-coordinate mean and
    variance.  ``dir``: an adversary-specific tracked direction — the
    consensus-motion estimate (IPM / online ALIE), the principal honest
    deviation axis (dissensus), or the warm-started perturbation
    (inner-maximization).  ``count``: observation ticks so far.
    """

    mean: jax.Array  # [d] f32
    var: jax.Array  # [d] f32
    dir: jax.Array  # [d] f32
    count: jax.Array  # [] f32


class AdvCtx(NamedTuple):
    """What the omniscient adversary is allowed to see beyond ``w`` itself.

    ``screen``: ``w_bcast [M, d] -> y [M, d]`` — this cell's banked
    screening step (differentiable; inner-maximization ascends through it).
    ``deliver_mask``: ``[d]`` bool — the coordinate subset a
    bandwidth-capped channel will deliver this tick (None when uncapped /
    unknowable): an adaptive adversary wastes no energy on coordinates the
    wire will replace with backfill.  ``latency``: the channel's expected
    delivery delay in ticks (0 on the synchronous path) — adversaries that
    track the consensus motion extrapolate their crafted values to *arrival*
    time, so the lie still sits inside the trimming band when it is screened.
    """

    screen: Callable | None = None
    deliver_mask: jax.Array | None = None
    latency: float = 0.0


def init_state(dim: int, *, lead: tuple[int, ...] = ()) -> AdvState:
    """All-zeros carried state (optionally with leading batch axes — the grid
    engine stacks one state row per experiment)."""
    return AdvState(
        mean=jnp.zeros(lead + (dim,), jnp.float32),
        var=jnp.zeros(lead + (dim,), jnp.float32),
        dir=jnp.zeros(lead + (dim,), jnp.float32),
        count=jnp.zeros(lead, jnp.float32),
    )


def honest_stats(w: jax.Array, byz_mask: jax.Array):
    """(mu [d], sigma [d], count) over the honest rows of ``w [M, d]``."""
    honest = ~byz_mask
    cnt = jnp.maximum(jnp.sum(honest), 1)
    mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
    var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
    return mu, jnp.sqrt(var + 1e-12), cnt


def observe(state: AdvState, w: jax.Array, byz_mask: jax.Array):
    """Advance the tracked running statistics with this tick's broadcasts.

    Returns ``(state', mu, sigma, vel)`` where ``mu``/``sigma`` are the
    *instantaneous* honest stats and ``vel`` is the estimated per-coordinate
    consensus motion (current honest mean minus the tracked one; zero on the
    first observation).
    """
    mu, sigma, _ = honest_stats(w, byz_mask)
    seen = state.count > 0
    vel = jnp.where(seen, mu - state.mean, jnp.zeros_like(mu))
    new_mean = jnp.where(seen, EMA * state.mean + (1.0 - EMA) * mu, mu)
    new_var = jnp.where(seen, EMA * state.var + (1.0 - EMA) * sigma**2, sigma**2)
    return state._replace(mean=new_mean, var=new_var, count=state.count + 1.0), mu, sigma, vel


@dataclasses.dataclass(frozen=True)
class Adversary:
    """A (possibly stateful) broadcast-substitution adversary.

    ``fn(ctx, state, theta, w [M,d], byz_mask [M], key, t)
    -> (w_bcast [M,d], state')`` substitutes the Byzantine rows; honest rows
    must pass through bitwise (``jnp.where(byz_mask[:, None], ...)``), which
    is what makes an empty mask exactly the `none` path.  ``message_fn``
    (``(ctx, state, theta, w, byz_mask, adjacency, key, t)
    -> (msgs [M,M,d], self_view [M,d], state')``) is the per-link variant the
    network runtime drives; `lift_message` derives it for broadcast-only
    adversaries.  ``stateful`` declares whether `AdvState` is read — a bank
    carries state iff any member needs it; stateless members pass it through
    untouched (the inertness contract the property tests pin).

    ``default_theta`` / ``theta_bounds`` describe the `THETA_DIM`
    hyperparameter slots (`repro.adversary.search` samples inside the
    bounds; ``(0, 0)`` marks an unused slot).

    ``tier`` places the adversary in the attack namespace taxonomy
    (`registry_tiers`): ``"adversary"`` for value-crafting attacks,
    ``"equivocator"`` for per-receiver inconsistent senders (only the echo
    protocol can catch them — see `repro.trust.echo`), ``"slanderer"`` for
    protocol-level liars whose *values* are honest but whose reported
    digests (``accuse_fn``) frame honest senders.  ``accuse_fn``
    (``(theta, digests [M, M, q], byz_mask [M], key, t) -> digests'``)
    forges the digest rows Byzantine nodes gossip in the echo protocol's
    cross-check stage; None reports honestly.
    """

    name: str
    fn: Callable
    stateful: bool = False
    tier: str = "adversary"
    accuse_fn: Callable | None = None
    message_fn: Callable | None = None
    # neighbor-indexed twin of message_fn (repro.core.neighbors):
    # ``(ctx, state, theta, w, byz_mask, nbr, live [M,K], key, t)
    # -> (msgs [M,K,d], self_view [M,d], state')`` — must be the bitwise
    # gather of the dense tensor.  Broadcast-only adversaries derive it via
    # `lift_message_sparse`; custom message_fn adversaries must supply it to
    # run on the sparse runtime.
    sparse_message_fn: Callable | None = None
    default_theta: tuple[float, ...] = (0.0,) * THETA_DIM
    theta_bounds: tuple[tuple[float, float], ...] = ((0.0, 0.0),) * THETA_DIM

    def __post_init__(self):
        if len(self.default_theta) != THETA_DIM or len(self.theta_bounds) != THETA_DIM:
            raise ValueError(f"adversary {self.name!r}: theta spec must have {THETA_DIM} slots")
        if self.tier not in ("adversary", "equivocator", "slanderer"):
            raise ValueError(f"adversary {self.name!r}: unknown tier {self.tier!r}")


def lift_message(adv: Adversary) -> Callable:
    """Message-granularity view of a broadcast adversary: every receiver gets
    the same crafted row, and the Byzantine self-view is the broadcast value
    (matching the synchronous path bit-for-bit over an ideal channel).  When
    the channel is bandwidth-capped (``ctx.deliver_mask``), the lie is
    confined to the coordinates the wire will actually deliver — off-mask
    coordinates revert to the sender's true iterate, so no adversarial energy
    rides coordinates the channel replaces with backfill anyway."""

    def mfn(ctx, state, theta, w, byz_mask, adjacency, key, t):
        w_bcast, new_state = adv.fn(ctx, state, theta, w, byz_mask, key, t)
        if ctx.deliver_mask is not None:
            w_bcast = jnp.where(ctx.deliver_mask[None, :], w_bcast, w)
        m = w.shape[0]
        msgs = jnp.broadcast_to(w_bcast[None, :, :], (m,) + w.shape)
        return msgs, w_bcast, new_state

    return mfn


def lift_message_sparse(adv: Adversary) -> Callable:
    """Neighbor-indexed `lift_message`: the crafted broadcast row, gathered
    into each receiver's ``[K, d]`` slots — the bitwise gather of the dense
    lift."""

    def mfn(ctx, state, theta, w, byz_mask, nbr, live, key, t):
        del live
        w_bcast, new_state = adv.fn(ctx, state, theta, w, byz_mask, key, t)
        if ctx.deliver_mask is not None:
            w_bcast = jnp.where(ctx.deliver_mask[None, :], w_bcast, w)
        return nbr.gather_rows(w_bcast), w_bcast, new_state

    return mfn


def from_attack(attack: byz_lib.Attack) -> Adversary:
    """Re-register a static broadcast attack as a stateless adversary."""

    def fn(ctx, state, theta, w, byz_mask, key, t):
        del ctx, theta
        return attack(w, byz_mask, key, t), state

    return Adversary(attack.name, fn, stateful=False)


# ---------------------------------------------------------------------------
# Registry — the single source of truth for the attack <-> adversary namespace
# ---------------------------------------------------------------------------

ADVERSARIES: dict[str, Adversary] = {}


def register(adv: Adversary) -> Adversary:
    if adv.name in ADVERSARIES:
        raise ValueError(f"adversary {adv.name!r} already registered")
    ADVERSARIES[adv.name] = adv
    return adv


# the static broadcast tier, subsumed as stateless adversaries
for _attack in byz_lib.ATTACKS.values():
    register(from_attack(_attack))


def get_adversary(name: str) -> Adversary:
    try:
        return ADVERSARIES[name]
    except KeyError:
        raise ValueError(
            f"unknown adversary {name!r}; options: {sorted(ADVERSARIES)} "
            f"(adaptive adversaries register via repro.adversary.adaptive)"
        ) from None


def registry_tiers() -> dict[str, frozenset[str]]:
    """The six attack-namespace tiers.  Every registered name belongs to
    exactly ONE tier (validated by ``tests/test_adversary.py``):

    * ``broadcast`` — static `byzantine.Attack`s (also usable as stateless
      adversaries; their adversary registration is *derived*, not a second
      home).
    * ``message`` — per-link-only `byzantine.MessageAttack`s (no broadcast
      equivalent, e.g. ``selective_victim``).
    * ``wire`` — codeword-domain `byzantine.WireAttack`s.
    * ``adversary`` — adaptive stateful adversaries (this package).
    * ``equivocator`` — per-receiver inconsistent senders: each receiver
      gets an individually plausible payload, so value screening alone
      cannot see the attack (the echo protocol can —
      `repro.trust.echo`).
    * ``slanderer`` — honest-valued protocol liars that forge the digest
      rows they gossip (`Adversary.accuse_fn`), attacking the trust layer
      itself rather than the consensus values.
    """
    adaptive = frozenset(ADVERSARIES) - frozenset(byz_lib.ATTACKS)
    by_tier = {
        tier: frozenset(n for n in adaptive if ADVERSARIES[n].tier == tier)
        for tier in ("adversary", "equivocator", "slanderer")
    }
    return {
        "broadcast": frozenset(byz_lib.ATTACKS),
        "message": frozenset(
            n for n, a in byz_lib.MESSAGE_ATTACKS.items() if a.broadcast is None
        ),
        "wire": frozenset(byz_lib.WIRE_ATTACKS) - {"none"},
        **by_tier,
    }


def attack_names() -> list[str]:
    """Every name in the full six-tier namespace (sorted, deduplicated)."""
    tiers = registry_tiers()
    return sorted(set().union(*tiers.values()))


# ---------------------------------------------------------------------------
# Banked (branchless) dispatch — adversary selection as data
# ---------------------------------------------------------------------------


def adversary_bank(names: Sequence[str]) -> tuple[Adversary, ...]:
    """Resolve names to a static bank (order preserved)."""
    return tuple(get_adversary(n) for n in names)


def bank_engaged(bank: Sequence[Adversary] | None) -> bool:
    """True when the bank can alter a broadcast (any non-`none` entry) —
    False lets the step skip the adversary stage structurally, keeping the
    default path bit-identical to the pre-adversary program."""
    return bank is not None and any(a.name != "none" for a in bank)

def bank_stateful(bank: Sequence[Adversary] | None) -> bool:
    """True when any bank entry reads `AdvState` — the carry is allocated
    iff so (stateless banks thread ``None``, costing nothing)."""
    return bank is not None and any(a.stateful for a in bank)


def bank_accuses(bank: Sequence[Adversary] | None) -> bool:
    """True when any bank entry forges gossiped digests (`accuse_fn`) — the
    echo protocol inserts its forging stage iff so, keeping slander-free
    banks on the exact honest-gossip program."""
    return bank is not None and any(a.accuse_fn is not None for a in bank)


def default_thetas(bank: Sequence[Adversary]) -> jnp.ndarray:
    """[len(bank), THETA_DIM] registered defaults (row per bank entry)."""
    return jnp.asarray([a.default_theta for a in bank], jnp.float32)


def cell_theta(bank: Sequence[Adversary], adv_idx, adv_theta):
    """The per-cell hyperparameter vector: the cell's own ``adv_theta`` when
    carried, else the selected bank entry's registered default."""
    if adv_theta is not None:
        return adv_theta
    return default_thetas(bank)[jnp.asarray(adv_idx, jnp.int32)]


def apply_adversary_bank(bank, adv_idx, ctx, state, theta, w, byz_mask, key, t):
    """Broadcast-path substitution by the bank entry selected by ``adv_idx``
    (single-entry banks elide the switch — the trainer path)."""
    if len(bank) == 1:
        return bank[0].fn(ctx, state, theta, w, byz_mask, key, t)
    branches = [
        (lambda fn: lambda st, th, ww, bm, k, tt: fn(ctx, st, th, ww, bm, k, tt))(a.fn)
        for a in bank
    ]
    return jax.lax.switch(adv_idx, branches, state, theta, w, byz_mask, key, t)


def apply_accuse_bank(bank, adv_idx, theta, digests, byz_mask, key, t):
    """Digest-forging stage of the echo protocol: the selected bank entry's
    `Adversary.accuse_fn` rewrites the rows Byzantine nodes gossip (entries
    without one report honestly — identity).  ``digests`` is the dense
    ``[M, M, q]`` reported-digest tensor from `repro.trust.echo`."""
    fns = [a.accuse_fn if a.accuse_fn is not None
           else (lambda th, dg, bm, k, tt: dg) for a in bank]
    if len(fns) == 1:
        return fns[0](theta, digests, byz_mask, key, t)
    branches = [
        (lambda fn: lambda th, dg, bm, k, tt: fn(th, dg, bm, k, tt))(fn)
        for fn in fns
    ]
    return jax.lax.switch(adv_idx, branches, theta, digests, byz_mask, key, t)


def apply_message_adversary_bank(bank, adv_idx, ctx, state, theta, w, byz_mask,
                                 adjacency, key, t):
    """Per-link substitution by the selected bank entry.  Returns
    ``(msgs, self_view, state')`` — the crafted message tensor, the self-view
    Byzantine nodes screen with, and the advanced adversary state."""
    fns = [a.message_fn if a.message_fn is not None else lift_message(a) for a in bank]
    if len(fns) == 1:
        return fns[0](ctx, state, theta, w, byz_mask, adjacency, key, t)
    branches = [
        (lambda fn: lambda st, th, ww, bm, adj, k, tt: fn(ctx, st, th, ww, bm, adj, k, tt))(fn)
        for fn in fns
    ]
    return jax.lax.switch(adv_idx, branches, state, theta, w, byz_mask, adjacency, key, t)


def apply_sparse_message_adversary_bank(bank, adv_idx, ctx, state, theta, w, byz_mask,
                                        nbr, live, key, t):
    """Neighbor-indexed `apply_message_adversary_bank`: per-slot lies on the
    ``[M, K]`` layout (``nbr`` a `repro.core.neighbors.NeighborTable``)."""
    fns = []
    for a in bank:
        if a.sparse_message_fn is not None:
            fns.append(a.sparse_message_fn)
        elif a.message_fn is None:
            fns.append(lift_message_sparse(a))
        else:
            raise ValueError(
                f"adversary {a.name!r} crafts per-link messages but has no "
                f"sparse_message_fn — required on the neighbor-indexed runtime path")
    if len(fns) == 1:
        return fns[0](ctx, state, theta, w, byz_mask, nbr, live, key, t)
    branches = [
        (lambda fn: lambda st, th, ww, bm, lv, k, tt: fn(ctx, st, th, ww, bm, nbr, lv, k, tt))(fn)
        for fn in fns
    ]
    return jax.lax.switch(adv_idx, branches, state, theta, w, byz_mask, live, key, t)


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "adversary.tiers.partition", "lint",
        "every name in the attack namespace belongs to exactly one of the "
        "six registry tiers (broadcast / message / wire / adversary / "
        "equivocator / slanderer)",
        params=(("check", "adversary_tiers"),),
    ),
)
