"""Minimal msgpack pytree checkpointing (offline environment; no orbax).

Layout: <dir>/step_<N>.msgpack holding {treedef_repr, leaves: [{dtype, shape,
bytes}]}.  Restore requires a template pytree with the same structure (the
standard init-then-restore pattern), which also guards against structure
drift between code versions.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    return {"dtype": str(arr.dtype), "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    return np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode())).reshape(d[b"shape"])


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    path = os.path.join(ckpt_dir, f"step_{step:08d}.msgpack")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic publish
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)\.msgpack", f))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None) -> tuple[Any, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    stored = payload[b"leaves"]
    if len(stored) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(stored)} leaves, template has {len(leaves)}"
        )
    new_leaves = []
    for tmpl, d in zip(leaves, stored, strict=True):
        arr = _unpack_leaf(d)
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"shape mismatch: ckpt {arr.shape} vs template {np.shape(tmpl)}")
        new_leaves.append(arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
