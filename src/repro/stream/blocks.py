"""Static coordinate-block partitioning of stacked parameter pytrees.

`BlockSpec` is the compile-time plan the chunk-streaming step iterates over:
every leaf of an ``[M, ...]`` pytree is viewed as an ``[M, s]`` coordinate
matrix and cut into blocks of at most ``chunk`` coordinates.  Blocks never
span leaves — a leaf's dtype, and the per-leaf error-feedback / mailbox
carries keyed off it, stay uniform within a block — so the partition is
"per-leaf, then per-``chunk``-columns", and the concatenation of all blocks
in global order visits exactly the coordinates of `repro.core.bridge.
stack_flatten`, in the same order (pinned by ``tests/test_stream.py``).

Everything here is host-side static: block starts/sizes are Python ints baked
into the jitted streaming step, which is what lets the tail block of each
leaf run at its exact (unpadded) size — no padded coordinates ever enter
screening, so per-block trim fractions and wire-bit counts are exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LeafPlan(NamedTuple):
    """One leaf's slice of the global coordinate space (all fields static)."""

    shape: tuple  # trailing (per-node) shape of the leaf
    dtype: Any  # per-leaf storage dtype, preserved on write-back
    size: int  # prod(shape) — coordinates per node in this leaf
    offset: int  # global coordinate offset (stack_flatten order)
    block0: int  # global index of this leaf's first block
    num_full: int  # number of chunk-sized blocks
    tail: int  # size of the final partial block (0 when size % chunk == 0)

    @property
    def num_blocks(self) -> int:
        return self.num_full + (1 if self.tail else 0)


class BlockSpec(NamedTuple):
    """The full partition: ``treedef`` + per-leaf plans + the chunk width."""

    treedef: Any
    leaves: tuple[LeafPlan, ...]
    chunk: int
    num_nodes: int

    @classmethod
    def from_params(cls, params: Any, chunk: int | None) -> "BlockSpec":
        """Plan the partition of a stacked ``[M, ...]`` pytree.  ``chunk`` is
        the maximum coordinates per block; ``None`` means one block per leaf
        (pure per-leaf streaming)."""
        leaves, treedef = jax.tree_util.tree_flatten(params)
        if not leaves:
            raise ValueError("empty parameter pytree")
        m = leaves[0].shape[0]
        plans, offset, block0 = [], 0, 0
        for leaf in leaves:
            if leaf.shape[:1] != (m,):
                raise ValueError(
                    f"leaf leading axis {leaf.shape[:1]} != node axis ({m},)")
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                raise ValueError(
                    f"non-float leaf dtype {leaf.dtype}: screening is defined "
                    f"over real coordinates only")
            size = int(np.prod(leaf.shape[1:])) if leaf.shape[1:] else 1
            c = size if chunk is None else min(int(chunk), size)
            if c < 1:
                raise ValueError(f"chunk must be >= 1, got {chunk}")
            plan = LeafPlan(shape=tuple(leaf.shape[1:]), dtype=leaf.dtype,
                            size=size, offset=offset, block0=block0,
                            num_full=size // c, tail=size % c)
            plans.append(plan)
            offset += size
            block0 += plan.num_blocks
        return cls(treedef=treedef, leaves=tuple(plans),
                   chunk=(max(p.size for p in plans) if chunk is None
                          else int(chunk)),
                   num_nodes=m)

    @property
    def total_dim(self) -> int:
        return sum(p.size for p in self.leaves)

    @property
    def num_blocks(self) -> int:
        return sum(p.num_blocks for p in self.leaves)

    @property
    def max_block(self) -> int:
        """Largest actual block width (<= chunk) — the streaming path's peak
        per-block coordinate count, the ``chunk`` of its [M, K, chunk] bound."""
        return max(min(self.chunk, p.size) for p in self.leaves)

    def block_sizes(self) -> tuple[int, ...]:
        """Per-block coordinate counts in global block order — what the
        per-block wire-bit accounting sums over."""
        out: list[int] = []
        for p in self.leaves:
            c = min(self.chunk, p.size)
            out.extend([c] * p.num_full)
            if p.tail:
                out.append(p.tail)
        return tuple(out)

    def leaf_mats(self, params: Any) -> list[jax.Array]:
        """The ``[M, s]`` coordinate-matrix views of a matching pytree (pure
        reshapes in the leaf's own dtype — no f32 upcast, no concatenation)."""
        leaves = jax.tree_util.tree_flatten(params)[0]
        if len(leaves) != len(self.leaves):
            raise ValueError("pytree does not match this BlockSpec")
        return [l.reshape(self.num_nodes, -1) for l in leaves]

    def unflatten(self, mats: list[jax.Array]) -> Any:
        """Per-leaf ``[M, s]`` matrices back to the original pytree (dtypes
        are whatever the matrices carry — the streaming step writes each
        leaf's buffer in its own storage dtype)."""
        outs = [mat.reshape((self.num_nodes,) + p.shape)
                for mat, p in zip(mats, self.leaves, strict=True)]
        return jax.tree_util.tree_unflatten(self.treedef, outs)


# BlockSpec is structural data (all-static NamedTuples): registering it as a
# zero-leaf pytree node would collide with NamedTuple flattening, so the
# streaming step simply closes over it — it is part of the program, never an
# operand, exactly like the rule/attack banks.
