"""`StreamBridgeTrainer` — the `BridgeTrainer` twin that screens parameter
pytrees block by block (`repro.stream.engine`) instead of flattening them.

It consumes the same `BridgeConfig`; ``screen_chunk`` is reinterpreted as the
streaming block width (coordinates per block, blocks never spanning leaves),
and ``sparse=True`` selects the neighbor-indexed gather exactly as on the
flat path.  The optional ``channel`` argument switches to the streaming
network path (per-edge drops + staleness over a per-block mailbox).

Because the block partition is a property of the parameter *pytree*, the
jitted step is built lazily on the first `init` call — unlike the flat
trainer, whose step only depends on the config.  Subsequent `init` calls
with a structurally different pytree rebuild the step.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm import codec as codec_lib
from repro.comm import exchange as comm_lib
from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core.bridge import BridgeConfig, BridgeState, BridgeTrainer
from repro.core.neighbors import NeighborTable
from repro.net import mailbox as mb
from repro.stream.blocks import BlockSpec
from repro.stream.engine import StreamChannelConfig, build_stream_cell_step


class StreamBridgeTrainer:
    """Chunk-streaming BRIDGE over parameter pytrees.  API-compatible with
    `BridgeTrainer` (``init`` / ``step`` / ``run`` / ``_raw_step`` /
    ``_cell``); bit-identity contracts vs the flat trainer are documented on
    `repro.stream.engine` and pinned by ``tests/test_stream.py``."""

    def __init__(self, config: BridgeConfig, grad_fn: Callable, *,
                 channel: StreamChannelConfig | None = None):
        config.topology.validate_for_rule(config.rule)
        screening.check_streamable((config.rule,))
        if config.adversary != "none":
            raise NotImplementedError(
                "adaptive adversaries observe the full flat trajectory and are "
                "not supported on the streaming path; use BridgeTrainer")
        if channel is not None and config.trust is not None and config.trust.echo:
            raise ValueError(
                "the echo protocol digests whole messages and cannot stream; "
                "use TrustSpec(echo=False) with the streaming network path")
        self.config = config
        self.grad_fn = grad_fn
        self.channel = channel
        m = config.topology.num_nodes
        nbyz = min(config.num_byzantine, m)
        if (config.attack == "none" and config.adversary == "none") or nbyz == 0:
            self.byz_mask = jnp.zeros((m,), dtype=bool)
        else:
            self.byz_mask = byz_lib.pick_byzantine_mask(m, nbyz, config.byzantine_seed)
        self.codec = codec_lib.get_codec(config.codec)
        # the network path is neighbor-indexed by construction; the broadcast
        # path follows the config's sparse flag like the flat trainer
        self.neighbors = None
        if channel is not None or config.sparse:
            self.neighbors = NeighborTable.from_adjacency(config.topology.adjacency)
        self._attack = byz_lib.get_attack(config.attack)
        self._wire_bank = byz_lib.wire_attack_bank((config.attack,))
        self._codec_bank = codec_lib.codec_bank((config.codec,))
        self._lossless = (comm_lib.bank_is_lossless(self._codec_bank)
                          and all(a.name == "none" for a in self._wire_bank))
        self._cell = BridgeTrainer.cell_params(self)  # same single-entry banks
        self.spec: BlockSpec | None = None
        self._raw_step = None
        self._jit_step = None

    # the flat trainer's cell_params reads self._adv_bank; streaming has none
    _adv_bank = None

    @property
    def honest_mask(self) -> jax.Array:
        return ~self.byz_mask

    def cell_params(self):
        return BridgeTrainer.cell_params(self)

    def _build(self, params: Any) -> None:
        spec = BlockSpec.from_params(params, self.config.screen_chunk)
        if self.spec is not None and spec == self.spec:
            return
        self.spec = spec
        self._chunk_scan_fn = None  # rebuilt with the step (run_chunks cache)
        self._raw_step = build_stream_cell_step(
            self.grad_fn, spec,
            None if self.neighbors is not None else self.config.topology.adjacency,
            (self.config.rule,), (self._attack,),
            codecs=(self.config.codec,), wire_attacks=self._wire_bank,
            neighbors=self.neighbors, channel=self.channel,
        )
        self._jit_step = jax.jit(self._raw_step)

    def init(self, params: Any, seed: int = 0) -> BridgeState:
        m = self.config.topology.num_nodes
        lead = jax.tree_util.tree_leaves(params)[0].shape[0]
        if lead != m:
            raise ValueError(f"params leading axis {lead} != num_nodes {m}")
        self._build(params)
        sizes = tuple(p.size for p in self.spec.leaves)
        comm = net = None
        if not self._lossless:
            # per-leaf EF carries: one codec state per sender per leaf (the
            # streaming wire is a broadcast codeword per sender, per block)
            comm = tuple(comm_lib.init_residual((m, s), (self.codec,))
                         for s in sizes)
        if self.channel is not None:
            net = mb.init_block_mailbox(m, sizes, width=self.neighbors.k)
        obs = trust = None
        width = m if self.neighbors is None else self.neighbors.k
        if self.config.trace is not None:
            from repro.obs import trace as obs_trace

            obs = obs_trace.init_state(self.config.trace, m, width)
        if self.config.trust is not None:
            from repro.trust import reputation as trust_lib

            trust = trust_lib.init_state(self.config.trust, m, width)
        mets = None
        if self.config.metrics is not None:
            from repro.obs import metrics as obs_metrics

            mets = obs_metrics.init_state(self.config.metrics)
        return BridgeState(params=params, t=jnp.zeros((), jnp.int32),
                           key=jax.random.PRNGKey(seed), net=net, comm=comm,
                           adv=None, obs=obs, trust=trust, mets=mets)

    def step(self, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        if self._jit_step is None:
            self._build(state.params)
        return self._jit_step(self._cell, state, batch)

    def run(self, state: BridgeState, batch_fn: Callable[[int], Any],
            num_steps: int, eval_fn: Callable | None = None,
            eval_every: int = 0) -> tuple[BridgeState, list[dict]]:
        history = []
        for i in range(num_steps):
            state, metrics = self.step(state, batch_fn(i))
            if eval_fn is not None and eval_every and (i + 1) % eval_every == 0:
                metrics = dict(metrics)
                metrics.update(eval_fn(state))
                metrics["step"] = i + 1
                history.append(jax.device_get(metrics))
        return state, history

    # the chunked host loop with donated carries + live-metric flushes; the
    # flat trainer's implementation duck-types on (_raw_step, _cell, config)
    _chunk_scan = BridgeTrainer._chunk_scan

    def run_chunks(self, state: BridgeState, batch_fn: Callable[[int], Any],
                   num_steps: int, **kw) -> tuple[BridgeState, dict]:
        if self._raw_step is None:
            self._build(state.params)
        return BridgeTrainer.run_chunks(self, state, batch_fn, num_steps, **kw)
