"""The chunk-streaming BRIDGE iteration: screen parameter pytrees block by
block, never materializing the flat ``[M, d]`` matrix.

One tick runs the same phases as `repro.core.bridge.build_cell_step` —
attack -> codec -> (exchange ->) screen -> apply -> obs/trust — but the
attack/codec/screen/apply phases execute *inside* a per-leaf loop over
coordinate blocks (`repro.stream.blocks.BlockSpec`): full-width blocks ride a
``lax.scan``, each leaf's tail block runs inline at its exact size, and every
block's screened update is written straight into that leaf's output buffer in
the leaf's own storage dtype.  Peak live state in the loop is ``[M, K, c]``
(one gathered block) plus the model's own leaves — at LLM ``d`` the flat
path's ``[M, d]`` f32 broadcast/screen tensors simply never exist.  ByRDiE
(arXiv:1708.08155) already updated coordinate-by-coordinate, so blockwise
BRIDGE screening is the algorithm family's native decomposition, not an
approximation: for the coordinate-wise rules (`screening.STREAMABLE_RULES`)
the result is *bitwise* the flat path's.

Bit-identity contract (pinned by ``tests/test_stream.py``):

* **Single block** (one leaf, ``chunk >= d``): the per-block PRNG key is the
  step subkey itself, so the full rule x attack x codec product — including
  stochastic attacks and stochastic-rounding codecs — matches the flat
  trainer bit-for-bit.
* **Many blocks**: block i folds ``i`` into the subkey (independent streams
  per block), so draws differ from the flat path's single full-width draw by
  construction; every *deterministic* attack/codec combination still matches
  bitwise, because the coordinate-wise rules, the per-coordinate attacks, and
  `screening.fence` all decompose exactly over blocks.  Stochastic combos are
  distributionally equivalent, not bitwise.

Codecs apply per block (`repro.comm.exchange.wire_bits_blocks`): each block
is an independent codeword with its own error-feedback slice, so top-k keeps
k coordinates *per block* and per-message overhead is paid per block — the
honest accounting for a chunked wire.

The optional network path replaces the ideal broadcast with a per-edge
drop/staleness channel over `repro.net.mailbox.BlockMailboxState`: one
arrival event per edge per tick (all blocks of a message travel together),
per-block payload writes, Table-II min-usable fallback.  With an ideal
channel (``drop_prob=0``) it reproduces the streaming broadcast path
bit-for-bit wherever every node clears the rule's usable minimum.

Not supported while streaming: vector rules (krum/bulyan/geomedian/
clipped_mean — their outputs depend on full-vector norms), adaptive
adversaries (omniscient crafting wants the full flat trajectory), and the
echo protocol (digests commit to whole messages); all three raise at build
time rather than silently changing semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm import codec as codec_lib
from repro.comm import exchange as comm_lib
from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core.bridge import (
    COMM_SALT,
    NET_SALT,
    WIRE_SALT,
    BridgeState,
    CellParams,
    _cell_codec_idx,
    _fold_metric_ring,
    cell_step_size,
)
from repro.core.neighbors import NeighborTable
from repro.net import mailbox as mb
from repro.stream.blocks import BlockSpec


@dataclasses.dataclass(frozen=True)
class StreamChannelConfig:
    """The streaming network path's channel: per-receiver message drops over
    a broadcast medium (every neighbor of a sender sees the *same* codeword;
    whether it arrives is per edge), with a staleness bound on what screening
    may still consume.  ``drop_prob=0`` is the ideal channel — bit-identical
    to the streaming broadcast path where in-degrees clear the rule minimum."""

    drop_prob: float = 0.0
    staleness_bound: int = 4


def build_stream_cell_step(grad_fn, spec: BlockSpec, adjacency, rules, attacks, *,
                           codecs=("identity",), wire_attacks=None,
                           neighbors: NeighborTable | None = None,
                           channel: StreamChannelConfig | None = None):
    """The streaming twin of `build_cell_step` (``channel=None``) and of the
    runtime path (``channel`` set): ``step(cell, state, batch)`` over the
    block partition ``spec``.  The network path requires ``neighbors`` (its
    mailbox width is K) and a `BlockMailboxState` in ``state.net``."""
    screening.check_streamable(rules)
    if channel is not None and neighbors is None:
        raise ValueError("the streaming network path is neighbor-indexed: "
                         "pass a NeighborTable")
    codec_bank = codec_lib.codec_bank(codecs)
    if wire_attacks is None:
        wire_attacks = (byz_lib.WIRE_ATTACKS["none"],) * len(attacks)
    skip_wire = (comm_lib.bank_is_lossless(codec_bank)
                 and all(a.name == "none" for a in wire_attacks))
    adjacency = None if adjacency is None else jnp.asarray(adjacency)
    n_edges = (jnp.sum(neighbors.valid_dev).astype(jnp.float32)
               if neighbors is not None
               else jnp.sum(adjacency).astype(jnp.float32))
    m = spec.num_nodes
    d = spec.total_dim
    single_block = spec.num_blocks == 1

    def step(cell: CellParams, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        tr_spec = cell.trace  # static: TraceSpec or None
        tspec = cell.trust  # static: TrustSpec or None
        decide = tspec is not None or (tr_spec is not None and tr_spec.forensics)
        cidx = _cell_codec_idx(cell)
        key, sub = jax.random.split(state.key)
        with jax.named_scope("stream.grad"):
            losses, grads = jax.vmap(grad_fn)(state.params, batch)
        rho = cell_step_size(cell, state.t)
        x_mats = spec.leaf_mats(state.params)
        g_mats = spec.leaf_mats(grads)
        hm = ~cell.byz_mask
        hcnt = jnp.sum(hm)

        weights = evicted = None
        stride = 1
        if tspec is not None:
            from repro.trust import reputation as trust_lib

            weights = trust_lib.edge_weights(tspec, state.trust)
            evicted = state.trust.evicted
            stride = (tr_spec.decide_stride
                      if tr_spec is not None and tr_spec.forensics
                      else tspec.decide_stride)
        elif decide:
            stride = tr_spec.decide_stride

        # live-edge structure (static topology on both paths)
        if neighbors is not None:
            valid = neighbors.valid_dev  # [M, K]
            byz_edge_all = neighbors.gather_senders(cell.byz_mask, fill=False)
        else:
            valid = jnp.asarray(adjacency, bool)  # [M, M]
            byz_edge_all = jnp.broadcast_to(cell.byz_mask[None, :], valid.shape)

        # network path: one channel event per edge per tick, shared by every
        # coordinate block of the tick's message
        arrived = send_tick = enough = None
        if channel is not None:
            net_key = jax.random.fold_in(sub, NET_SALT)
            u = jax.random.uniform(net_key, valid.shape)
            arrived = valid & (u >= channel.drop_prob)
            send_tick = mb.stamp(state.net.send_tick, arrived, state.t)
            usable = valid & (send_tick > mb.NEVER) & (
                send_tick >= state.t - channel.staleness_bound)
            mask_live = usable
        else:
            mask_live = valid
        mask_eff = mask_live if evicted is None else mask_live & ~evicted
        if channel is not None:
            need = screening.min_neighbors_banked(rules, cell.rule_idx, cell.b)
            enough = jnp.sum(mask_eff, axis=1) >= need  # [M]
            obs_live = mask_eff & enough[:, None]
        else:
            obs_live = valid
        obs_live_f = obs_live.astype(jnp.float32)
        # dense broadcast screening consumes the adjacency operand directly
        # (bitwise parity with build_cell_step's trust-on/off calls)
        dense_adj = None
        if neighbors is None:
            dense_adj = adjacency if evicted is None else valid & ~evicted

        def block_fn(x2d, g2d, carry, gid, start, size):
            """One coordinate block through attack -> codec -> (exchange ->)
            screen -> apply; ``start`` may be traced (scan) or static (tail),
            ``size`` is always static."""
            y_buf, comm_leaf, vals_leaf, trim_acc, cons_sq = carry
            kb = sub if single_block else jax.random.fold_in(sub, gid)
            xb = jax.lax.dynamic_slice(x2d, (0, start), (m, size)).astype(jnp.float32)
            with jax.named_scope("stream.attack"):
                wb = byz_lib.apply_attack_bank(
                    attacks, cell.attack_idx, xb, cell.byz_mask, kb, state.t)
            with jax.named_scope("stream.codec"):
                if skip_wire:
                    what, comm_new = wb, comm_leaf
                else:
                    comm_blk = None if comm_leaf is None else jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_slice(a, (0, start), (m, size)),
                        comm_leaf)
                    ck = jax.random.fold_in(kb, COMM_SALT)
                    wk = jax.random.fold_in(kb, WIRE_SALT)
                    msg, target = comm_lib.encode_bank(codec_bank, cidx, ck, wb, comm_blk)
                    msg = byz_lib.apply_wire_attack_bank(
                        wire_attacks, cell.attack_idx, msg, cell.byz_mask, wk,
                        state.t, size)
                    what, comm_blk_new = comm_lib.decode_bank(
                        codec_bank, cidx, msg, target, comm_blk, ck)
                    comm_new = comm_leaf if comm_leaf is None else jax.tree_util.tree_map(
                        lambda full, blk: jax.lax.dynamic_update_slice(full, blk, (0, start)),
                        comm_leaf, comm_blk_new)
            if channel is not None:
                with jax.named_scope("stream.exchange"):
                    msgs_blk = neighbors.gather_rows(what)  # [M, K, size]
                    vals_leaf = mb.push_block(vals_leaf, msgs_blk, arrived, start)
                    views = jax.lax.dynamic_slice(
                        vals_leaf, (0, 0, start), (m, neighbors.k, size))
            trim_b = None
            with jax.named_scope("stream.screen"):
                if channel is not None:
                    if decide:
                        y_b, trim_b = screening.screen_views_decide_banked(
                            views, mask_eff, wb, rules, cell.rule_idx, cell.b,
                            decide_stride=stride, weights=weights)
                    else:
                        y_b = screening.screen_views_banked(
                            views, mask_eff, wb, rules, cell.rule_idx, cell.b,
                            chunk=None)
                    # nodes starved below the Table-II minimum keep their own
                    # (broadcast) iterate this tick — same fallback, per block
                    y_b = jnp.where(enough[:, None], y_b, wb)
                elif neighbors is not None:
                    gathered = neighbors.gather_rows(what)
                    if decide:
                        y_b, trim_b = screening.screen_views_decide_banked(
                            gathered, mask_eff, wb, rules, cell.rule_idx, cell.b,
                            decide_stride=stride, weights=weights)
                    else:
                        y_b = screening.screen_views_banked(
                            gathered, mask_eff, wb, rules, cell.rule_idx, cell.b,
                            chunk=None)
                else:
                    if decide:
                        y_b, trim_b = screening.screen_all_decide_banked(
                            what, dense_adj, rules, cell.rule_idx, cell.b,
                            self_vals=wb, decide_stride=stride, weights=weights)
                    else:
                        y_b = screening.screen_all_banked(
                            what, dense_adj, rules, cell.rule_idx, cell.b,
                            chunk=None, self_vals=wb)
            with jax.named_scope("stream.apply"):
                gb = jax.lax.dynamic_slice(g2d, (0, start), (m, size)).astype(jnp.float32)
                w_new = y_b - screening.fence(rho * gb)
                y_buf = jax.lax.dynamic_update_slice(
                    y_buf, w_new.astype(y_buf.dtype), (0, start))
                mu = jnp.sum(jnp.where(hm[:, None], w_new, 0.0), axis=0) / hcnt
                dev = jnp.where(hm[:, None], w_new - mu[None, :], 0.0)
                cons_sq = cons_sq + jnp.sum(dev * dev, axis=1)
            ys = None
            if decide:
                from repro.trust import reputation as trust_lib

                trim_acc = trust_lib.accumulate_trim(trim_acc, trim_b, size / d)
                ys = (jnp.sum(trim_b * obs_live_f)
                      / jnp.maximum(jnp.sum(obs_live_f), 1.0))
            return (y_buf, comm_new, vals_leaf, trim_acc, cons_sq), ys

        width = valid.shape[1]
        trim_acc = jnp.zeros((m, width), jnp.float32) if decide else None
        cons_sq = jnp.zeros((m,), jnp.float32)
        comm_list = ((None,) * len(spec.leaves) if state.comm is None
                     else tuple(state.comm))
        vals_list = (tuple(state.net.values) if channel is not None
                     else (None,) * len(spec.leaves))
        mats_out, comm_out, vals_out, block_trims = [], [], [], []
        for li, plan in enumerate(spec.leaves):
            x2d, g2d = x_mats[li], g_mats[li]
            c = min(spec.chunk, plan.size)
            # every coordinate belongs to exactly one block, so the buffer is
            # fully overwritten; seeding it with the input keeps dtype/shape
            carry = (x2d, comm_list[li], vals_list[li], trim_acc, cons_sq)
            if plan.num_full == 1:
                carry, ys = block_fn(x2d, g2d, carry, plan.block0, 0, c)
                if decide:
                    block_trims.append(ys[None])
            elif plan.num_full > 1:
                gids = plan.block0 + jnp.arange(plan.num_full, dtype=jnp.int32)
                starts = jnp.arange(plan.num_full, dtype=jnp.int32) * c

                def body(cr, gs, x2d=x2d, g2d=g2d, c=c):
                    return block_fn(x2d, g2d, cr, gs[0], gs[1], c)

                carry, ys = jax.lax.scan(body, carry, (gids, starts))
                if decide:
                    block_trims.append(ys)
            if plan.tail:
                carry, ys = block_fn(x2d, g2d, carry,
                                     plan.block0 + plan.num_full,
                                     plan.num_full * c, plan.tail)
                if decide:
                    block_trims.append(ys[None])
            y_buf, comm_leaf, vals_leaf, trim_acc, cons_sq = carry
            mats_out.append(y_buf)
            comm_out.append(comm_leaf)
            vals_out.append(vals_leaf)

        new_params = spec.unflatten(mats_out)
        new_comm = None if state.comm is None else tuple(comm_out)
        new_net = state.net
        if channel is not None:
            new_net = mb.BlockMailboxState(send_tick=send_tick,
                                           values=tuple(vals_out))
        metrics = {
            "loss": jnp.sum(jnp.where(hm, losses, 0.0)) / hcnt,
            "consensus_dist": jnp.sqrt(jnp.max(cons_sq)),
            "rho": rho,
        }
        if cell.metrics is not None:
            # honest-mean per-node gradient norm for the live-metric ring;
            # summed leaf-wise so the flat [M, d] matrix never materializes.
            # Each leaf goes through the fence first: the squares would
            # otherwise CSE with the loss computation inside grad_fn and
            # re-fuse its reduction — ULP-shifting the loss stream and
            # breaking metrics-on bit-inertness
            gn_sq = sum(jnp.sum(jnp.square(screening.fence(
                            g.astype(jnp.float32))), axis=1)
                        for g in g_mats)
            gn = jnp.sqrt(gn_sq)
            metrics["grad_norm"] = jnp.sum(jnp.where(hm, gn, 0.0)) / hcnt
        bits = comm_lib.wire_bits_blocks(codec_bank, cidx, spec.block_sizes())
        live_edges = (jnp.sum(mask_live).astype(jnp.float32)
                      if channel is not None else n_edges)
        metrics["wire_bits_per_edge"] = jnp.asarray(bits, jnp.float32)
        metrics["wire_bytes_total"] = metrics["wire_bits_per_edge"] / 8.0 * live_edges
        metrics["ef_residual_norm"] = (
            jnp.zeros((), jnp.float32) if new_comm is None else jnp.sqrt(sum(
                jnp.sum(cst.resid * cst.resid) for cst in new_comm)))
        if channel is not None:
            metrics["delivered_frac"] = (jnp.sum(arrived.astype(jnp.float32))
                                         / jnp.maximum(n_edges, 1.0))
            stale = jnp.where(mask_live, state.t - send_tick, 0)
            metrics["mean_staleness"] = (jnp.sum(stale.astype(jnp.float32))
                                         / jnp.maximum(jnp.sum(mask_live), 1))
            metrics["screened_frac"] = jnp.mean(enough.astype(jnp.float32))
            metrics["usable_in"] = jnp.mean(jnp.sum(mask_eff, axis=1).astype(jnp.float32))
        if decide:
            from repro.obs import trace as obs_trace

            metrics["obs_trim_frac"] = (
                jnp.sum(trim_acc * obs_live_f)
                / jnp.maximum(jnp.sum(obs_live_f), 1.0))
            metrics[obs_trace.BLOCK_TRIM_STREAM] = jnp.concatenate(block_trims)
        new_obs = state.obs
        if tr_spec is not None:
            from repro.obs import trace as obs_trace

            with jax.named_scope("stream.obs"):
                trim_o = live_o = byz_o = None
                if decide:
                    live_o = obs_live
                    trim_o = (jnp.where(live_o, trim_acc, 0.0)
                              if channel is not None else trim_acc)
                    byz_o = (byz_edge_all & live_o if channel is not None
                             else byz_edge_all)
                stale_o = None
                if channel is not None:
                    stale_o = obs_trace.staleness_of(new_net, state.t)
                new_obs = obs_trace.update(
                    tr_spec, state.obs, t=state.t, loss=metrics["loss"],
                    consensus=metrics["consensus_dist"], trim_frac=trim_o,
                    live=live_o, byz_edge=byz_o, staleness=stale_o,
                    wire_bits=bits, live_edges=live_edges, d=d)
        new_trust = state.trust
        if tspec is not None:
            from repro.trust import reputation as trust_lib

            with jax.named_scope("stream.trust"):
                if channel is not None:
                    screened = mask_eff & enough[:, None]
                    new_trust = trust_lib.update(
                        tspec, state.trust, t=state.t,
                        trim_frac=jnp.where(screened, trim_acc, 0.0),
                        live=mask_eff)
                else:
                    new_trust = trust_lib.update(
                        tspec, state.trust, t=state.t,
                        trim_frac=jnp.where(mask_eff, trim_acc, 0.0),
                        live=mask_eff)
                metrics["trust_evicted_frac"] = jnp.mean(
                    new_trust.evicted.astype(jnp.float32))
        stale_m = live_m = None
        if cell.metrics is not None and channel is not None:
            stale_m = jnp.where(mask_live, state.t - send_tick, 0)
            live_m = mask_live
        new_mets = _fold_metric_ring(cell.metrics, state, metrics,
                                     staleness=stale_m, live=live_m)
        return BridgeState(new_params, state.t + 1, key, new_net, new_comm,
                           state.adv, new_obs, new_trust, new_mets), metrics

    return step


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "stream.peak_memory.flat_bound", "memory",
        "the streaming step's largest tensor is strictly smaller than the "
        "flat [M, d] float matrix it exists to avoid (peak live state is "
        "one gathered [M, K, c] block plus the model's own leaves)",
        params=(("programs", ("stream",)), ("budget", "flat_md")),
    ),
    Contract(
        "stream.prng.per_block_keys", "prng",
        "every block draws from its own folded key (block i folds i into "
        "the step subkey): no key feeds two distinct draws anywhere in the "
        "streaming program",
        params=(("programs", ("stream",)),),
    ),
)
