"""repro.stream — chunk-streaming BRIDGE over parameter pytrees.

Screens the real model zoo (``src/repro/models``) under attack without ever
materializing `stack_flatten`'s flat ``[M, d]`` matrix: a `BlockSpec`
partitions the stacked parameter pytree into per-leaf coordinate blocks, and
the tick loops attack -> codec -> (exchange ->) screen -> apply over blocks,
keeping peak live state at ``[M, K, chunk]`` even at LLM ``d``.  See
`repro.stream.engine` for the bit-identity contracts vs the flat path.
"""
from repro.stream.blocks import BlockSpec, LeafPlan
from repro.stream.engine import StreamChannelConfig, build_stream_cell_step
from repro.stream.trainer import StreamBridgeTrainer

__all__ = [
    "BlockSpec",
    "LeafPlan",
    "StreamChannelConfig",
    "StreamBridgeTrainer",
    "build_stream_cell_step",
]
