"""Repo-lint pass: the registry contracts, mechanized at the AST/import level.

Four invariant families that previously lived only as prose in docstrings or
scattered test assertions:

* **partition** — every screening rule sits in exactly one of
  ``STREAMABLE_RULES`` / ``STREAM_REJECTED_RULES``; every name in the attack
  namespace sits in exactly one of the six `adversary.protocols` tiers;
* **completeness** — the per-rule side tables (``MIN_NEIGHBORS``, the
  traceable twins, the decision-instrumented twins) cover exactly
  ``RULES``'s keys: a rule added to one registry but not the others would
  otherwise only fail at dispatch time, deep inside a compiled grid;
* **zero-leaf specs** — ``TraceSpec`` / ``MetricSpec`` / ``TrustSpec`` are
  jit *structure*: ``tree_leaves(spec) == []``, or a vmapped `CellParams`
  would try to batch them;
* **seed plumbing** — no naked ``jax.random.PRNGKey(...)`` in ``src/``
  outside declared seed-plumbing sites: every other key must descend from a
  plumbed seed via split/fold_in, or two entry points could silently share
  a stream.  A site is plumbed when its argument expression mentions a seed
  (``seed``, ``args.seed``, ``c.seed``...) or when it carries a waiver in
  the governing contract (each waiver names the file and enclosing
  function, so a moved call site invalidates loudly).

Checks import the live registries (not a parallel list that could itself go
stale) and parse source with ``ast`` — nothing here executes jax programs.
"""
from __future__ import annotations

import ast
import importlib
import pathlib

from repro.analysis.contracts import CheckResult, Contract


def _result(contract: Contract, ok: bool, ok_detail: str, bad_detail: str) -> CheckResult:
    return CheckResult(contract=contract.name, kind="lint",
                       status="PASS" if ok else "FAIL",
                       detail=ok_detail if ok else bad_detail)


# ---------------------------------------------------------------------------
# registry partitions / completeness
# ---------------------------------------------------------------------------


def check_stream_partition(contract: Contract) -> CheckResult:
    from repro.core import screening

    rules = set(screening.RULES)
    streamable = set(screening.STREAMABLE_RULES)
    rejected = set(screening.STREAM_REJECTED_RULES)
    overlap = streamable & rejected
    missing = rules - streamable - rejected
    phantom = (streamable | rejected) - rules
    ok = not overlap and not missing and not phantom
    return _result(
        contract, ok,
        f"{len(rules)} rules partitioned: {len(streamable)} streamable, "
        f"{len(rejected)} rejected",
        f"stream partition broken — overlap={sorted(overlap)}, "
        f"unassigned={sorted(missing)}, phantom={sorted(phantom)}")


def check_registry_completeness(contract: Contract) -> CheckResult:
    from repro.core import screening

    rules = set(screening.RULES)
    problems = []
    for label, table in (
        ("MIN_NEIGHBORS", screening.MIN_NEIGHBORS),
        ("_MIN_NEIGHBORS_TRACEABLE", screening._MIN_NEIGHBORS_TRACEABLE),
        ("RULES_WITH_DECISIONS", screening.RULES_WITH_DECISIONS),
    ):
        if set(table) != rules:
            problems.append(
                f"{label}: missing={sorted(rules - set(table))}, "
                f"extra={sorted(set(table) - rules)}")
    weighted = set(screening.WEIGHTED_RULES)
    if not weighted <= rules:
        problems.append(f"WEIGHTED_RULES outside RULES: {sorted(weighted - rules)}")
    return _result(
        contract, not problems,
        f"side tables cover all {len(rules)} rules",
        "; ".join(problems))


def check_adversary_tiers(contract: Contract) -> CheckResult:
    from repro.adversary import protocols

    tiers = protocols.registry_tiers()
    names: dict[str, list[str]] = {}
    for tier, members in tiers.items():
        for n in members:
            names.setdefault(n, []).append(tier)
    multi = {n: hs for n, hs in names.items() if len(hs) > 1}
    uncovered = set(protocols.attack_names()) - set(names)
    ok = not multi and not uncovered
    return _result(
        contract, ok,
        f"{len(names)} names across {len(tiers)} tiers, each in exactly one",
        f"tier partition broken — multi-homed={multi}, "
        f"uncovered={sorted(uncovered)}")


def check_zero_leaf_specs(contract: Contract) -> CheckResult:
    import jax

    bad = []
    for spec_path in contract.param("classes", ()):
        modname, clsname = spec_path.split(":")
        cls = getattr(importlib.import_module(modname), clsname)
        leaves = jax.tree_util.tree_leaves(cls())
        if leaves:
            bad.append(f"{spec_path} has {len(leaves)} leaves")
    return _result(
        contract, not bad,
        f"{len(contract.param('classes', ()))} spec classes are zero-leaf "
        f"pytrees (pure jit structure)",
        "; ".join(bad))


def check_salts_distinct(contract: Contract) -> CheckResult:
    from repro.core import bridge

    names = contract.param("salts", ())
    vals = {n: getattr(bridge, n) for n in names}
    dupes = {v: [n for n, vv in vals.items() if vv == v]
             for v in vals.values()
             if sum(vv == v for vv in vals.values()) > 1}
    return _result(
        contract, not dupes,
        f"{len(names)} stream salts pairwise distinct",
        f"colliding salts (streams would correlate): {dupes}")


def check_kernel_ref_twins(contract: Contract) -> CheckResult:
    """Every public dispatcher in kernels/ops.py routes to BOTH a `_pallas`
    implementation and a `ref.` twin — the parity contract that lets CPU CI
    stand in for the TPU path."""
    modname = contract.param("module", "repro.kernels.ops")
    mod = importlib.import_module(modname)
    tree = ast.parse(pathlib.Path(mod.__file__).read_text())
    bad = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        src = ast.unparse(node)
        if "_pallas" not in src or "ref." not in src:
            bad.append(node.name)
    return _result(
        contract, not bad,
        "every kernel dispatcher has a pallas path and a ref twin",
        f"dispatchers missing a pallas path or ref twin: {bad}")


# ---------------------------------------------------------------------------
# naked-PRNGKey scan
# ---------------------------------------------------------------------------


def _prngkey_sites(root: pathlib.Path) -> list[tuple[str, str, int, str]]:
    """Every ``PRNGKey(...)`` call under ``root`` as
    ``(relpath, enclosing_function, lineno, arg_source)``."""
    sites = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root.parent).as_posix()
        tree = ast.parse(path.read_text())
        # map each node to its enclosing function name
        parents: dict[ast.AST, str] = {}

        def visit(node, fname):
            for child in ast.iter_child_nodes(node):
                cf = fname
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    cf = child.name
                parents[child] = cf
                visit(child, cf)

        parents[tree] = "<module>"
        visit(tree, "<module>")
        for node, fname in parents.items():
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            if name != "PRNGKey":
                continue
            arg_src = ", ".join(ast.unparse(a) for a in node.args)
            sites.append((rel, fname, node.lineno, arg_src))
    return sites


def check_seed_plumbing(contract: Contract, src_root: str | pathlib.Path) -> CheckResult:
    root = pathlib.Path(src_root) / "repro"
    waivers = set(contract.param("waivers", ()))
    sites = _prngkey_sites(root)
    violations = []
    for rel, fname, lineno, arg in sites:
        if "seed" in arg.lower():
            continue  # plumbed: the key IS the seed argument
        if (rel, fname) in waivers:
            continue
        violations.append(f"{rel}:{lineno} in {fname}(PRNGKey({arg}))")
    unused = [w for w in waivers
              if not any((rel, fname) == w for rel, fname, _, _ in sites)]
    ok = not violations and not unused
    return _result(
        contract, ok,
        "every PRNGKey call is seed plumbing or carries a waiver",
        ("naked PRNGKey outside seed plumbing: " + "; ".join(violations)
         if violations else "")
        + (f" stale waivers (site moved/removed): {unused}" if unused else ""))


#: dispatch by the short check id each lint contract declares
CHECKS = {
    "stream_partition": check_stream_partition,
    "registry_completeness": check_registry_completeness,
    "adversary_tiers": check_adversary_tiers,
    "zero_leaf_specs": check_zero_leaf_specs,
    "salts_distinct": check_salts_distinct,
    "kernel_ref_twins": check_kernel_ref_twins,
}


def run_lint(contracts: list[Contract], src_root) -> list[CheckResult]:
    out = []
    for c in contracts:
        if c.kind != "lint":
            continue
        check_id = c.param("check")
        if check_id == "seed_plumbing":
            out.append(check_seed_plumbing(c, src_root))
        elif check_id in CHECKS:
            out.append(CHECKS[check_id](c))
        else:
            out.append(CheckResult(contract=c.name, kind="lint", status="SKIP",
                                   detail=f"no lint check registered for "
                                          f"{check_id!r}"))
    return out
