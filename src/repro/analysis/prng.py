"""PRNG-discipline pass: statically prove every random draw has its own key.

BRIDGE's resilience analysis assumes independent randomness per edge, per
block, per tick (Chen/Su/Xu; the survey's replay/correlation failure class).
In JAX that invariant is a *syntactic* property of the jaxpr: a key reaching
two distinct ``random_bits`` computations without an intervening
``random_split`` / ``random_fold_in`` yields correlated draws.  This pass
walks the jaxpr of a traced-but-not-run program and flags exactly that.

The walk is a local value-numbering pass, not a simple def-use scan, because
the jaxpr obscures key identity three ways:

* the same raw ``uint32[2]`` key is re-``random_wrap``-ed at every use site
  (distinct Vars, one key) — structural value numbering unifies them, since
  identical primitives over identical inputs get identical numbers;
* ``random_split`` outputs are unwrapped and then sliced per subkey — slices
  with different ``start_indices`` hash to different numbers and correctly
  stay distinct keys;
* the high-level samplers appear as ``pjit[name=_normal/...]`` sub-jaxprs —
  the walk recurses with the caller's value numbers bound to the callee's
  invars, so key identity crosses the call boundary.

Counting discipline (what is and is not a violation):

* a violation is one key value-number feeding **two or more distinct**
  ``random_bits`` value-numbers; two draws with *identical* numbers are
  identical values (value numbering's invariant) — that is the deliberate
  shared-randomness idiom (every node reading the same public coin, a
  loop-invariant draw equal to its hoisted form) and counts once.  The
  consumer's number includes the outermost sampler frame (the first
  ``pjit[name=_normal/_uniform/...]`` wrapper on the path — ``normal``
  *internally* calls ``_uniform``, so the innermost frame cannot tell the
  two apart), so two *distributions* drawing the same raw bits from one
  key — bitwise equal bits but statistically correlated samples — stay
  distinct and are flagged;
* ``cond``/``switch`` regions merge per key by keeping the **largest single
  branch's** consumer set — only one branch executes, so the same key
  consumed once in each of nine attack-bank branches is one use, not nine
  (this under-approximates across-branch/after-branch mixes, never
  over-approximates: no false positives from exclusive control flow);
* ``scan``/``while`` carries and xs bind fresh numbers per body (the carried
  key evolves), while closed-over consts keep the caller's numbers — a
  body draw from an un-split const key unifies with any outer draw from it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

#: sub-jaxpr-carrying params, recursed generically when not handled inline
_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")

#: key -> set-of-consumers tables; a region's analysis result
UseTable = dict[Any, frozenset]


@dataclasses.dataclass(frozen=True)
class KeyReuse:
    """One flagged key: feeds ``uses`` distinct random-bits computations."""

    key: str        # short rendering of the key's value number
    uses: int       # distinct-consumer count (>= 2)
    consumers: tuple[str, ...]  # distinct consumer renderings

    def __str__(self):
        return (f"key {self.key} consumed by {self.uses} distinct draws: "
                + "; ".join(self.consumers))


def _params_repr(params: dict) -> tuple:
    """Hashable, stable rendering of eqn params (sub-jaxprs by identity —
    they are interned per trace, and value numbers never cross traces)."""
    out = []
    for k in sorted(params):
        v = params[k]
        if k in _JAXPR_PARAMS or k == "branches":
            out.append((k, id(v)))
            continue
        try:
            hash(v)
            out.append((k, v))
        except TypeError:
            out.append((k, repr(v)))
    return tuple(out)


def _render(vn, depth: int = 0) -> str:
    if isinstance(vn, tuple):
        if depth >= 2:
            return "(..)"
        return "(" + ",".join(_render(x, depth + 1) for x in vn) + ")"
    return str(vn)


def _merge_seq(into: UseTable, region: UseTable) -> None:
    """Sequential composition: both regions execute — union consumer sets."""
    for key, cons in region.items():
        into[key] = into.get(key, frozenset()) | cons


def _merge_branches(regions: list[UseTable]) -> UseTable:
    """Exclusive composition: ONE region executes — per key, keep the
    largest single branch's consumer set (a sound lower bound on the worst
    path; unioning would fabricate cross-branch reuse)."""
    merged: UseTable = {}
    for region in regions:
        for key, cons in region.items():
            if len(cons) > len(merged.get(key, frozenset())):
                merged[key] = cons
    return merged


class _Walker:
    def __init__(self):
        self._n = 0
        self.uses: UseTable = {}
        self._frame: str | None = None  # outermost sampler (_-named pjit) frame

    def fresh(self, label: str):
        self._n += 1
        return ("fresh", self._n, label)

    # -- value environment ---------------------------------------------------

    def _get(self, env: dict, atom) -> Any:
        if hasattr(atom, "val"):  # Literal
            v = np.asarray(atom.val)
            return ("lit", v.tobytes(), str(v.dtype), v.shape)
        if atom not in env:  # DropVar or untracked
            env[atom] = self.fresh("untracked")
        return env[atom]

    def _bind(self, inner_jaxpr, outer_ids: list, label: str) -> dict:
        env: dict = {}
        for i, iv in enumerate(inner_jaxpr.invars):
            env[iv] = outer_ids[i] if i < len(outer_ids) else self.fresh(label)
        for cv in inner_jaxpr.constvars:
            env[cv] = self.fresh(f"{label}:const")
        return env

    # -- the walk ------------------------------------------------------------

    def run(self, jaxpr, env: dict) -> UseTable:
        """Walk one (sub-)jaxpr; returns the region's private use table so
        callers can branch-merge it before folding in."""
        saved, self.uses = self.uses, {}
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env)
        region, self.uses = self.uses, saved
        return region

    def _subregion(self, inner, outer_ids, label):
        env = self._bind(inner, outer_ids, label)
        return env, self.run(inner, env)

    def _eqn(self, eqn, env: dict):
        prim = eqn.primitive.name
        in_ids = [self._get(env, a) for a in eqn.invars]
        pr = _params_repr(eqn.params)

        if prim == "random_bits":
            consumer = ("random_bits", self._frame, tuple(in_ids), pr)
            key = in_ids[0]
            self.uses[key] = self.uses.get(key, frozenset()) | {consumer}
            # fall through to generic value numbering of the output

        elif prim == "pjit" or "call_jaxpr" in eqn.params or "fun_jaxpr" in eqn.params:
            closed = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                      or eqn.params.get("fun_jaxpr"))
            inner = closed.jaxpr if hasattr(closed, "jaxpr") else closed
            saved_frame = self._frame
            name = eqn.params.get("name")
            if saved_frame is None and isinstance(name, str) and name.startswith("_"):
                self._frame = name  # jax's samplers are _-named; first wins
            try:
                ienv, region = self._subregion(inner, in_ids, prim)
            finally:
                self._frame = saved_frame
            _merge_seq(self.uses, region)
            for ov, res in zip(eqn.outvars, inner.outvars, strict=True):
                env[ov] = self._get(ienv, res)
            return

        elif prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            nc = eqn.params["num_consts"]
            ids = list(in_ids[:nc]) + [self.fresh("scan") for _ in inner.invars[nc:]]
            _, region = self._subregion(inner, ids, "scan")
            _merge_seq(self.uses, region)
            for ov in eqn.outvars:
                env[ov] = self.fresh("scan:out")
            return

        elif prim == "while":
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            for closed, consts in (
                (eqn.params["cond_jaxpr"], in_ids[:cn]),
                (eqn.params["body_jaxpr"], in_ids[cn:cn + bn]),
            ):
                inner = closed.jaxpr
                ids = list(consts) + [self.fresh("while")
                                      for _ in inner.invars[len(consts):]]
                _, region = self._subregion(inner, ids, "while")
                _merge_seq(self.uses, region)
            for ov in eqn.outvars:
                env[ov] = self.fresh("while:out")
            return

        elif prim == "cond":
            regions = []
            for br in eqn.params["branches"]:
                inner = br.jaxpr if hasattr(br, "jaxpr") else br
                _, region = self._subregion(inner, in_ids[1:], "branch")
                regions.append(region)
            _merge_seq(self.uses, _merge_branches(regions))
            for ov in eqn.outvars:
                env[ov] = self.fresh("cond:out")
            return

        else:
            # any other higher-order primitive (remat, custom_jvp, ...):
            # recurse into every sub-jaxpr param with the operand bindings
            recursed = False
            for k in _JAXPR_PARAMS:
                closed = eqn.params.get(k)
                if closed is None:
                    continue
                inner = closed.jaxpr if hasattr(closed, "jaxpr") else closed
                _, region = self._subregion(inner, in_ids, prim)
                _merge_seq(self.uses, region)
                recursed = True
            if recursed:
                for ov in eqn.outvars:
                    env[ov] = self.fresh(f"{prim}:out")
                return

        for i, ov in enumerate(eqn.outvars):
            env[ov] = (prim, tuple(in_ids), pr, i)


def find_reuse(closed_jaxpr) -> list[KeyReuse]:
    """All keys in ``closed_jaxpr`` feeding >= 2 distinct random-bits
    computations.  Empty list == the program is PRNG-clean."""
    w = _Walker()
    jaxpr = closed_jaxpr.jaxpr
    env = {v: ("arg", i) for i, v in enumerate(jaxpr.invars)}
    for i, cv in enumerate(jaxpr.constvars):
        env[cv] = ("const", i)
    region = w.run(jaxpr, env)

    out = []
    for key_vn, cons in sorted(region.items(), key=lambda kv: -len(kv[1])):
        if len(cons) < 2:
            continue
        out.append(KeyReuse(key=_render(key_vn), uses=len(cons),
                            consumers=tuple(sorted(_render(c) for c in cons))))
    return out


def check(fn, *args, **kwargs) -> list[KeyReuse]:
    """Trace ``fn(*args)`` (abstractly — nothing runs) and report reuse."""
    import jax

    return find_reuse(jax.make_jaxpr(fn, **kwargs)(*args))
