"""Fence-integrity and memory-contract passes over optimized HLO.

Both passes read ``jit(...).lower(...).compile().as_text()`` — the program
XLA will actually run, *after* CSE, fusion, and loop simplification — so they
check what survived optimization, not what the tracer emitted.

**Fence integrity.**  `repro.core.screening.fence` rounds a value to storage
precision behind a length-2 ``lax.scan`` precisely because XLA's while-loop
simplifier unrolls trip-count-<=1 loops (which would re-fuse the producer and
void the fence).  Survival is therefore a checkable property of the optimized
program: each fence is a ``while`` whose condition bounds trip count 2.  The
pass counts trip-2 loops per program and asserts (a) every canonical program
keeps at least the declared floor, and (b) the metrics-on program keeps
exactly one MORE than its metrics-off twin — the grad-norm fence that severs
CSE between the metric's reduction and the loss reduction (PR 9's
bit-inertness condition: without it, XLA re-fuses the shared ``g*g``
subexpressions and ULP-shifts the loss stream).

**Memory contract.**  Declared per-program byte budgets over the largest
array typed anywhere in the HLO (`launch.hlo_analysis.largest_tensor_bytes`
— parameters, results, tuple elements): the sparse path must never
materialize a dense ``[M, M, d]`` float tensor, the streaming path must stay
under the flat ``[M, d]`` it exists to avoid, and ``donate_argnums`` on the
chunk-scan carry must survive into the module's ``input_output_alias`` table
(jax silently warns-and-copies when a donation is dropped — the table is the
ground truth).
"""
from __future__ import annotations

from repro.analysis.contracts import CheckResult
from repro.launch import hlo_analysis

#: a surviving screening fence == a while loop with this trip count
FENCE_TRIP_COUNT = 2


def count_fences(hlo_text: str) -> int:
    """Trip-count-2 while loops in the optimized program (nested computations
    included)."""
    return sum(1 for w in hlo_analysis.while_loops(hlo_text)
               if w.trip_count == FENCE_TRIP_COUNT)


def check_fence_floor(contract, program_name: str, hlo_text: str,
                      min_fences: int = 1) -> CheckResult:
    """Every canonical program must keep >= ``min_fences`` surviving fences."""
    n = count_fences(hlo_text)
    ok = n >= min_fences
    return CheckResult(
        contract=contract.name, kind="fence", program=program_name,
        status="PASS" if ok else "FAIL",
        detail=(f"{n} trip-2 while loop(s) survive optimization"
                if ok else
                f"only {n} trip-2 while loop(s) survive (declared floor "
                f"{min_fences}) — a fence was stripped or unrolled"))


def check_metrics_fence_delta(contract, flat_hlo: str, metrics_hlo: str,
                              delta: int = 1) -> CheckResult:
    """metrics-on keeps exactly ``delta`` more fences than metrics-off: the
    grad-norm fence exists, and turning metrics on did not strip any."""
    n_flat, n_met = count_fences(flat_hlo), count_fences(metrics_hlo)
    ok = n_met == n_flat + delta
    return CheckResult(
        contract=contract.name, kind="fence", program="metrics",
        status="PASS" if ok else "FAIL",
        detail=(f"fences: metrics-off {n_flat}, metrics-on {n_met} "
                f"(grad-norm reduction stays un-CSE'd from the loss)"
                if ok else
                f"fences: metrics-off {n_flat}, metrics-on {n_met}, expected "
                f"+{delta} — the grad-norm fence is missing or a rule fence "
                f"was lost when metrics engaged"))


def check_budget(contract, program_name: str, hlo_text: str,
                 budget_bytes: int, label: str) -> CheckResult:
    """Largest single tensor in the program strictly under ``budget_bytes``."""
    largest = hlo_analysis.largest_tensor_bytes(hlo_text)
    ok = largest < budget_bytes
    top = hlo_analysis.largest_tensors(hlo_text, top=1)
    shape = f"{top[0][1]}{list(top[0][2])}" if top else "?"
    return CheckResult(
        contract=contract.name, kind="memory", program=program_name,
        status="PASS" if ok else "FAIL",
        detail=(f"largest tensor {shape} = {largest} B < {label} "
                f"budget {budget_bytes} B"
                if ok else
                f"largest tensor {shape} = {largest} B >= {label} "
                f"budget {budget_bytes} B — a dense intermediate "
                f"materialized on a path that promises not to"))


def check_donation(contract, program_name: str, chunk_hlo: str,
                   backend_supports: bool) -> CheckResult:
    """The chunk-scan's donated state carry appears in the aliasing table."""
    if not backend_supports:
        return CheckResult(
            contract=contract.name, kind="memory", program=program_name,
            status="SKIP",
            detail="backend emits no input_output_alias for donated "
                   "buffers (donation unsupported here); not checkable")
    aliased = hlo_analysis.donated_params(chunk_hlo)
    ok = len(aliased) > 0
    return CheckResult(
        contract=contract.name, kind="memory", program=program_name,
        status="PASS" if ok else "FAIL",
        detail=(f"{len(aliased)} output(s) alias donated parameters "
                f"(state carry reuses its buffers)"
                if ok else
                "input_output_alias table is empty: the donated scan carry "
                "was silently copied, doubling peak state memory"))


def donation_supported() -> bool:
    """Probe once whether this backend honors donation at all (an identity
    add with a donated same-shape operand must alias)."""
    import jax
    import jax.numpy as jnp

    txt = (jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
           .lower(jnp.zeros((4,), jnp.float32)).compile().as_text())
    return len(hlo_analysis.donated_params(txt)) > 0
