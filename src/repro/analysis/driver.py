"""Driver: collect every CONTRACTS declaration, build the canonical
programs, route each contract to the pass that can discharge it, and
aggregate verdicts.

Routing is by contract ``kind`` plus pass-specific params:

* ``prng``    — runs `prng.find_reuse` on the jaxpr of every program the
  contract names in its ``("programs", ...)`` param;
* ``fence``   — a ``min_fences`` param checks the floor on every selected
  program; a ``delta`` param compares the metrics-on/off twins;
* ``memory``  — a ``budget`` param resolves against the byte ceilings the
  programs declare (`programs.Program.budgets`); ``("check", "donation")``
  reads the chunk-scan's aliasing table;
* ``retrace`` — a ``max_traces`` param drives `run_chunks` on a FRESH flat
  program (trace counters must start cold); otherwise the grid
  `set_cells` zero-retrace check;
* ``lint``    — dispatched wholesale to `lint.run_lint` over the source tree.

A contract whose inputs were deselected (``--programs``/``--passes``)
reports SKIP, never silently disappears — the summary line counts it.
"""
from __future__ import annotations

import pathlib

from repro.analysis import hlo as hlo_pass
from repro.analysis import lint as lint_pass
from repro.analysis import prng as prng_pass
from repro.analysis import programs as programs_lib
from repro.analysis import retrace as retrace_pass
from repro.analysis.contracts import KINDS, CheckResult, collect

#: source root (the directory holding ``repro/``) for the lint pass
SRC_ROOT = pathlib.Path(__file__).resolve().parents[2]


def _skip(c, detail: str, program: str = "") -> CheckResult:
    return CheckResult(contract=c.name, kind=c.kind, status="SKIP",
                       detail=detail, program=program)


def _run_prng(c, programs) -> list[CheckResult]:
    out = []
    for pname in c.param("programs", tuple(programs)):
        prog = programs.get(pname)
        if prog is None:
            out.append(_skip(c, "program not selected", pname))
            continue
        reuse = prng_pass.find_reuse(prog.jaxpr)
        out.append(CheckResult(
            contract=c.name, kind="prng", program=pname,
            status="PASS" if not reuse else "FAIL",
            detail=(f"every key feeds exactly one draw "
                    f"({len(prog.jaxpr.eqns)} top-level eqns walked)"
                    if not reuse else
                    f"{len(reuse)} reused key(s): "
                    + " | ".join(str(r) for r in reuse[:3]))))
    return out


def _run_fence(c, programs) -> list[CheckResult]:
    delta = c.param("delta")
    if delta is not None:
        flat, met = programs.get("flat"), programs.get("metrics")
        if flat is None or met is None:
            return [_skip(c, "needs both the flat and metrics programs")]
        return [hlo_pass.check_metrics_fence_delta(c, flat.hlo, met.hlo,
                                                   delta=int(delta))]
    floor = int(c.param("min_fences", 1))
    return [hlo_pass.check_fence_floor(c, p.name, p.hlo, min_fences=floor)
            for p in programs.values()]


def _run_memory(c, programs) -> list[CheckResult]:
    if c.param("check") == "donation":
        prog = programs.get("flat")
        if prog is None:
            return [_skip(c, "needs the flat program")]
        return [hlo_pass.check_donation(c, prog.name, prog.chunk_hlo,
                                        hlo_pass.donation_supported())]
    budget_id = c.param("budget")
    out = []
    governed = [p for p in programs.values() if budget_id in p.budgets]
    if not governed:
        return [_skip(c, f"no selected program declares budget {budget_id!r}")]
    for prog in governed:
        byte_ceiling, label = prog.budgets[budget_id]
        out.append(hlo_pass.check_budget(c, prog.name, prog.hlo,
                                         byte_ceiling, label))
    return out


def _run_retrace(c, programs) -> list[CheckResult]:
    if c.param("max_traces") is not None:
        if "flat" not in programs:
            return [_skip(c, "needs the flat program")]
        # a FRESH trainer: the shared flat program's jit caches are already
        # warm from the fence/memory passes, which would mask a retrace
        prog = programs_lib.build_flat()
        return [retrace_pass.check_run_chunks(
            c, prog.trainer, prog.state, prog.batch_fn, num_steps=8, chunk=4)]
    engine, state_fn, batches = programs_lib.build_grid()
    return [retrace_pass.check_grid_set_cells(c, engine, state_fn, batches)]


def run_all(program_names=None, kinds=None, src_root=SRC_ROOT,
            log=None) -> list[CheckResult]:
    """Run the selected passes over the selected canonical programs.

    ``program_names``/``kinds`` default to everything; ``log`` (optional
    callable) receives progress lines."""
    say = log or (lambda *_: None)
    kinds = tuple(kinds) if kinds else KINDS
    names = tuple(program_names) if program_names else programs_lib.PROGRAM_NAMES
    contracts = collect()
    say(f"{len(contracts)} contracts collected from governed modules")

    needs_programs = any(k in kinds for k in ("prng", "fence", "memory"))
    programs = {}
    if needs_programs:
        for n in names:
            say(f"building canonical program: {n}")
            programs[n] = programs_lib.BUILDERS[n]()

    results: list[CheckResult] = []
    for c in contracts:
        if c.kind not in kinds:
            results.append(_skip(c, f"pass {c.kind!r} not selected"))
            continue
        say(f"checking {c.name} [{c.kind}]")
        if c.kind == "prng":
            results.extend(_run_prng(c, programs))
        elif c.kind == "fence":
            results.extend(_run_fence(c, programs))
        elif c.kind == "memory":
            results.extend(_run_memory(c, programs))
        elif c.kind == "retrace":
            results.extend(_run_retrace(c, programs))
    lint_contracts = [c for c in contracts if c.kind == "lint"]
    if "lint" in kinds and lint_contracts:
        results.extend(lint_pass.run_lint(lint_contracts, src_root))
    return results
