"""The five canonical programs the static-analysis CLI checks.

Small enough to trace and compile in seconds on CPU, but each one exercises a
distinct compiled shape of the BRIDGE stack:

* ``flat``    — dense broadcast path, a *drawing* attack (``random``) plus the
  int8 wire codec, so the step's key tree is maximally populated;
* ``sparse``  — neighbor-indexed layout on a genuinely sparse graph
  (max degree + 1 < M), where the dense ``[M, M, d]`` budget has headroom
  and any dense materialization is a real violation, not the gather;
* ``stream``  — the chunk-streaming trainer over a two-leaf model, whose
  peak tensor must stay under the flat ``[M, d]`` it replaces;
* ``net``     — the unreliable-runtime path (drops + staleness), whose
  per-edge channel draws stress the PRNG discipline hardest;
* ``metrics`` — the flat program with the live-metric ring compiled in; its
  optimized HLO must keep exactly one more fence than ``flat``'s (the
  grad-norm/loss CSE sever).

Everything derived from a program (jaxpr, optimized HLO, chunk-scan HLO) is
computed lazily and cached — passes share one trace/compile per program.
"""
from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.bridge import (
    BridgeConfig,
    BridgeTrainer,
    replicate,
    stack_batches,
)
from repro.core.graph import erdos_renyi

#: bytes per f32 element
_F32 = 4


def quad_grad_fn(params, batch):
    """The analysis workload: per-node quadratic pull toward ``batch``.

    Shares ``w - c`` between loss and gradient on purpose — exactly the
    subexpression sharing that makes the grad-norm fence necessary."""
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


def two_leaf_grad_fn(params, batch):
    """Stream-path workload: two leaves so the block schedule is nontrivial."""
    loss = 0.0
    grads = {}
    for name, w in params.items():
        c = batch[name]
        loss = loss + 0.5 * jnp.sum((w - c) ** 2)
        grads[name] = w - c
    return loss, grads


@dataclasses.dataclass
class Program:
    """One canonical program: a trainer plus everything the passes read."""

    name: str
    trainer: Any
    state: Any
    batch: Any
    batch_fn: Callable[[int], Any]
    #: budget id -> (byte ceiling, human label); referenced by memory
    #: contracts via their ("budget", "<id>") param
    budgets: dict[str, tuple[int, str]] = dataclasses.field(default_factory=dict)

    @functools.cached_property
    def jaxpr(self):
        """Closed jaxpr of the raw (unjitted) step — the PRNG pass input."""
        return jax.make_jaxpr(self.trainer._raw_step)(
            self.trainer._cell, self.state, self.batch)

    @functools.cached_property
    def hlo(self) -> str:
        """Optimized HLO of the jitted step — fence + memory pass input."""
        return (jax.jit(self.trainer._raw_step)
                .lower(self.trainer._cell, self.state, self.batch)
                .compile().as_text())

    @functools.cached_property
    def chunk_hlo(self) -> str:
        """Optimized HLO of the donated chunk scan (4 ticks) — donation pass
        input."""
        xs = stack_batches(self.batch_fn, 4)
        return (self.trainer._chunk_scan()
                .lower(self.trainer._cell, self.state, xs)
                .compile().as_text())


def _const_batch_fn(batch):
    return lambda i: batch


def _flat_pieces(metrics=None, runtime=None):
    m, d = 8, 5
    topo = erdos_renyi(m, 0.9, 1, seed=1)
    cfg = BridgeConfig(topology=topo, rule="median", num_byzantine=1,
                      attack="random", codec="int8", lam=1.0, t0=10.0,
                      metrics=metrics)
    trainer = BridgeTrainer(cfg, quad_grad_fn, runtime=runtime)
    init_seed = 0
    params = replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                       key=jax.random.PRNGKey(init_seed))
    state = trainer.init(params, seed=0)
    batch = jnp.linspace(-1.0, 1.0, m * d, dtype=jnp.float32).reshape(m, d)
    return trainer, state, batch


def build_flat() -> Program:
    trainer, state, batch = _flat_pieces()
    return Program("flat", trainer, state, batch, _const_batch_fn(batch))


def build_metrics() -> Program:
    from repro.obs.metrics import MetricSpec

    trainer, state, batch = _flat_pieces(metrics=MetricSpec(capacity=8))
    return Program("metrics", trainer, state, batch, _const_batch_fn(batch))


def build_net() -> Program:
    from repro.net import ChannelConfig, UnreliableRuntime

    m, d = 8, 5
    topo = erdos_renyi(m, 0.9, 1, seed=1)
    rt = UnreliableRuntime(topo, ChannelConfig(drop_prob=0.2),
                           staleness_bound=5)
    cfg = BridgeConfig(topology=topo, rule="median", num_byzantine=1,
                      attack="sign_flip", codec="int8", lam=1.0, t0=10.0)
    trainer = BridgeTrainer(cfg, quad_grad_fn, runtime=rt)
    init_seed = 0
    params = replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                       key=jax.random.PRNGKey(init_seed))
    state = trainer.init(params, seed=0)
    batch = jnp.linspace(-1.0, 1.0, m * d, dtype=jnp.float32).reshape(m, d)
    return Program("net", trainer, state, batch, _const_batch_fn(batch))


def build_sparse() -> Program:
    m, d = 12, 16
    topo = erdos_renyi(m, 0.45, 1, seed=3)
    # the budget only means something on a genuinely sparse graph: the
    # screening gather is [M, K+1, d], and K+1 must be < M for "no dense
    # [M, M, d]" to be distinguishable from the gather itself
    kp1 = int(topo.adjacency.sum(axis=1).max()) + 1
    if kp1 >= m:
        raise AssertionError(
            f"canonical sparse graph degenerated: max degree+1 = {kp1} >= "
            f"M = {m}; pick a sparser topology")
    cfg = BridgeConfig(topology=topo, rule="median", num_byzantine=1,
                      attack="sign_flip", codec="identity", lam=1.0, t0=10.0,
                      sparse=True)
    trainer = BridgeTrainer(cfg, quad_grad_fn)
    init_seed = 0
    params = replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                       key=jax.random.PRNGKey(init_seed))
    state = trainer.init(params, seed=0)
    batch = jnp.linspace(-1.0, 1.0, m * d, dtype=jnp.float32).reshape(m, d)
    prog = Program("sparse", trainer, state, batch, _const_batch_fn(batch))
    prog.budgets["dense_mmd"] = (m * m * d * _F32, f"dense [M,M,d]=[{m},{m},{d}]")
    return prog


def build_stream() -> Program:
    from repro.stream.trainer import StreamBridgeTrainer

    m, leaves = 8, {"w1": 512, "w2": 256}
    d = sum(leaves.values())
    topo = erdos_renyi(m, 0.9, 1, seed=1)
    cfg = BridgeConfig(topology=topo, rule="median", num_byzantine=1,
                      attack="sign_flip", codec="int8", lam=1.0, t0=10.0,
                      screen_chunk=64)
    trainer = StreamBridgeTrainer(cfg, two_leaf_grad_fn)
    init_seed = 0
    params = replicate({k: jnp.zeros(n) for k, n in leaves.items()}, m,
                       perturb=0.1, key=jax.random.PRNGKey(init_seed))
    state = trainer.init(params, seed=0)
    batch = {k: jnp.linspace(-1.0, 1.0, m * n, dtype=jnp.float32).reshape(m, n)
             for k, n in leaves.items()}
    prog = Program("stream", trainer, state, batch, _const_batch_fn(batch))
    prog.budgets["flat_md"] = (m * d * _F32, f"flat [M,d]=[{m},{d}]")
    return prog


BUILDERS: dict[str, Callable[[], Program]] = {
    "flat": build_flat,
    "sparse": build_sparse,
    "stream": build_stream,
    "net": build_net,
    "metrics": build_metrics,
}

PROGRAM_NAMES = tuple(BUILDERS)


def build(names=PROGRAM_NAMES) -> dict[str, Program]:
    return {n: BUILDERS[n]() for n in names}


# -- the grid fixture for the set_cells retrace contract --------------------


def build_grid():
    """A small two-rule grid plus its init/batches, for the zero-retrace
    check (`analysis.retrace.check_grid_set_cells`)."""
    from repro.sim.engine import GridEngine
    from repro.sim.grid import ExperimentGrid

    m, d, ticks = 8, 5, 6
    topo = erdos_renyi(m, 0.9, 1, seed=1)
    grid = ExperimentGrid(topo, ("median", "trimmed_mean"), ("sign_flip",),
                          (1,), (0,), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)

    def state_fn():
        return engine.init(
            lambda seed: replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                                   key=jax.random.PRNGKey(seed)))

    batch = jnp.linspace(-1.0, 1.0, m * d, dtype=jnp.float32).reshape(m, d)
    batches = stack_batches(lambda i: batch, ticks)
    return engine, state_fn, batches
