"""Contract manifests for the static-analysis passes.

A *contract* is a machine-checkable invariant declared NEXT TO the code it
governs: each governed module exposes a module-level ``CONTRACTS`` tuple, and
the analysis CLI (`python -m repro.analysis`) collects them all, matches each
against the pass that can discharge it, and reports PASS / FAIL / SKIP per
contract per program.  Keeping the declaration in the governed module (not in
the analysis package) means a refactor that breaks an invariant also has the
contract text in the same diff — reviewers see both sides.

This module is deliberately dependency-light (stdlib only, no jax): the core
modules import it at module load, so it must never import them back.
"""
from __future__ import annotations

import dataclasses
import importlib

# The five pass kinds.  ``kind`` routes a contract to the pass that can
# discharge it; a contract whose pass is not selected reports SKIP.
KINDS = ("prng", "fence", "memory", "retrace", "lint")

#: modules that declare CONTRACTS — the collection roots for the CLI.
GOVERNED_MODULES: tuple[str, ...] = (
    "repro.core.bridge",
    "repro.core.screening",
    "repro.sim.engine",
    "repro.stream.engine",
    "repro.kernels.ops",
    "repro.launch.train",
    "repro.adversary.protocols",
)


@dataclasses.dataclass(frozen=True)
class Contract:
    """One statically checkable invariant.

    ``params`` carries the pass-specific payload as a hashable tuple of
    ``(key, value)`` pairs (budgets, trip counts, waiver site lists...), so
    Contract instances can live in frozenset registries and hash into jit
    caches without dragging arrays along."""

    name: str  # globally unique, dotted: "<module-nick>.<invariant>"
    kind: str  # one of KINDS
    description: str
    params: tuple[tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"contract {self.name!r}: unknown kind {self.kind!r} "
                f"(must be one of {KINDS})")

    def param(self, key: str, default=None):
        for k, v in self.params:
            if k == key:
                return v
        return default


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One pass's verdict on one contract (possibly per program)."""

    contract: str   # Contract.name
    kind: str       # Contract.kind (pass that produced the verdict)
    status: str     # "PASS" | "FAIL" | "SKIP"
    detail: str = ""
    program: str = ""  # canonical program name, "" for tree-level checks

    @property
    def ok(self) -> bool:
        return self.status != "FAIL"


def collect(modules: tuple[str, ...] = GOVERNED_MODULES) -> list[Contract]:
    """Import every governed module and gather its CONTRACTS declarations.

    Raises on duplicate contract names across modules — each invariant has
    exactly one home (the same exactly-one-tier discipline the adversary
    registry enforces)."""
    out: list[Contract] = []
    seen: dict[str, str] = {}
    for modname in modules:
        mod = importlib.import_module(modname)
        declared = getattr(mod, "CONTRACTS", ())
        for c in declared:
            if not isinstance(c, Contract):
                raise TypeError(
                    f"{modname}.CONTRACTS holds a non-Contract entry: {c!r}")
            if c.name in seen:
                raise ValueError(
                    f"contract {c.name!r} declared in both {seen[c.name]} "
                    f"and {modname}; contracts have exactly one home")
            seen[c.name] = modname
            out.append(c)
    return out


def by_kind(contracts: list[Contract], kind: str) -> list[Contract]:
    return [c for c in contracts if c.kind == kind]


def summarize(results: list[CheckResult]) -> str:
    """Render a verdict table (stable order: kind, contract, program)."""
    rows = sorted(results, key=lambda r: (KINDS.index(r.kind), r.contract, r.program))
    lines = []
    npass = sum(r.status == "PASS" for r in rows)
    nfail = sum(r.status == "FAIL" for r in rows)
    nskip = sum(r.status == "SKIP" for r in rows)
    for r in rows:
        where = f" [{r.program}]" if r.program else ""
        detail = f" — {r.detail}" if r.detail else ""
        lines.append(f"{r.status:4s} {r.kind:7s} {r.contract}{where}{detail}")
    lines.append(f"{npass} passed, {nfail} failed, {nskip} skipped")
    return "\n".join(lines)
