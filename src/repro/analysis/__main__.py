"""``python -m repro.analysis`` — the static-analysis gate.

Collects every governed module's CONTRACTS, builds the five canonical
programs, runs the selected passes, prints the verdict table, and exits
nonzero on any FAIL (the CI contract).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import driver
from repro.analysis.contracts import KINDS, summarize
from repro.analysis.programs import PROGRAM_NAMES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract-driven static analysis of the compiled "
                    "BRIDGE program")
    ap.add_argument("--programs", nargs="+", choices=PROGRAM_NAMES,
                    metavar="PROG",
                    help=f"canonical programs to build (default: all of "
                         f"{', '.join(PROGRAM_NAMES)})")
    ap.add_argument("--passes", nargs="+", choices=KINDS, metavar="PASS",
                    help=f"passes to run (default: all of {', '.join(KINDS)})")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress progress lines (verdict table only)")
    args = ap.parse_args(argv)

    log = None if args.quiet else lambda msg: print(f"  .. {msg}", flush=True)
    results = driver.run_all(program_names=args.programs, kinds=args.passes,
                             log=log)
    print(summarize(results))
    return 1 if any(not r.ok for r in results) else 0


if __name__ == "__main__":
    sys.exit(main())
