"""repro.analysis — contract-driven static analysis of the BRIDGE stack.

Five passes over three artifact levels:

* **prng** (`repro.analysis.prng`)       — jaxpr: no key feeds two draws;
* **fence** (`repro.analysis.hlo`)       — optimized HLO: fences survive CSE;
* **memory** (`repro.analysis.hlo`)      — optimized HLO: byte budgets,
  donation aliasing;
* **retrace** (`repro.analysis.retrace`) — runtime counters: compiled-program
  caches stay warm across the promised update patterns;
* **lint** (`repro.analysis.lint`)       — AST/registries: partitions,
  completeness, zero-leaf specs, seed plumbing.

Contracts live NEXT TO governed code as module-level ``CONTRACTS`` tuples
(see `repro.analysis.contracts.GOVERNED_MODULES`); the CLI is
``python -m repro.analysis``.

This package's top level re-exports only the dependency-light contract
vocabulary: governed modules import `repro.analysis.contracts` at module
load, so importing programs/driver here would recreate the cycle the
layering avoids.
"""
from repro.analysis.contracts import (
    GOVERNED_MODULES,
    KINDS,
    CheckResult,
    Contract,
    by_kind,
    collect,
    summarize,
)

__all__ = [
    "GOVERNED_MODULES", "KINDS", "CheckResult", "Contract",
    "by_kind", "collect", "summarize",
]
