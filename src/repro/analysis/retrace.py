"""Retrace guard: statically prove the compiled-program caches stay warm.

The grid engine's whole value proposition is ONE compiled program per group
reused across generations of cell data (`sim.engine.GridEngine.set_cells`),
and `BridgeTrainer.run_chunks`'s is one trace per distinct chunk length.
Both promises are Python-side-effect observable: the traced functions bump a
counter that only executes while tracing (``GridEngine.trace_count``,
``BridgeTrainer.chunk_trace_count``), so "no retrace" is an exact, cheap
assertion — not a heuristic over timings.

This pass drives the canonical programs through the update patterns the
promises cover (cell swaps at fixed structure; uniform and ragged chunk
schedules) and asserts the counters land exactly on the contract's budget.
`guard` is the reusable context-manager form for embedding the same
assertion in drivers and tests.
"""
from __future__ import annotations

import contextlib

from repro.analysis.contracts import CheckResult


class RetraceError(AssertionError):
    """A compiled-program cache went cold inside a `guard` block."""


@contextlib.contextmanager
def guard(obj, attr: str = "trace_count", budget: int = 0):
    """Assert ``obj.<attr>`` grows by at most ``budget`` inside the block.

    ``budget=0`` (the default) is the zero-retrace contract: every call in
    the block must hit an existing compilation."""
    before = getattr(obj, attr, 0)
    yield
    after = getattr(obj, attr, 0)
    grew = after - before
    if grew > budget:
        raise RetraceError(
            f"{type(obj).__name__}.{attr} grew by {grew} (budget {budget}): "
            f"a compiled-program cache went cold — some jit structure "
            f"(shape, dtype, static arg, spec) changed between calls")


def check_run_chunks(contract, trainer, state, batch_fn, *, num_steps: int,
                     chunk: int) -> CheckResult:
    """Uniform-chunk `run_chunks` compiles exactly once; a second run with a
    fresh state stays on the cached program (trace budget from the
    contract, default 1)."""
    budget = int(contract.param("max_traces", 1))
    trainer.chunk_trace_count = 0
    import jax

    # the chunk scan DONATES its carry: the second run needs its own copy of
    # the buffers, taken before the first run consumes them
    state2 = jax.tree_util.tree_map(lambda x: x.copy(), state)
    state, _ = trainer.run_chunks(state, batch_fn, num_steps, chunk=chunk)
    first = trainer.chunk_trace_count
    trainer.run_chunks(state2, batch_fn, num_steps, chunk=chunk)
    total = trainer.chunk_trace_count
    ok = first <= budget and total == first
    return CheckResult(
        contract=contract.name, kind="retrace", program="flat",
        status="PASS" if ok else "FAIL",
        detail=(f"{first} trace(s) for {num_steps} steps in chunks of "
                f"{chunk}; re-run added {total - first}"
                if ok else
                f"{first} trace(s) on first run (budget {budget}), "
                f"{total - first} more on an identically-shaped re-run — "
                f"the chunk scan is retracing"))


def check_grid_set_cells(contract, engine, state_fn, batches) -> CheckResult:
    """A generation update (`set_cells` at fixed structure) must not retrace:
    `trace_count` is identical before and after the swapped-cell run."""
    state = state_fn()
    engine.run(state, batches)
    baseline = engine.trace_count
    # a new generation: same structure, different per-cell data
    swapped = [c._replace(seed=c.seed + 100) for c in engine.cells]
    engine.set_cells(swapped)
    try:
        with guard(engine, "trace_count", budget=0):
            engine.run(state_fn(), batches)
    except RetraceError as e:
        return CheckResult(contract=contract.name, kind="retrace",
                           program="grid", status="FAIL", detail=str(e))
    return CheckResult(
        contract=contract.name, kind="retrace", program="grid",
        status="PASS",
        detail=f"trace_count stayed {baseline} across a set_cells "
               f"generation swap ({engine.num_cells} cells)")
