"""Banked codec application: delta tracking + per-link error feedback.

The grid engine runs experiments with *different* codecs inside one jitted
program, so — like rules and attacks — codec selection is a ``lax.switch``
over a static bank, indexed by the int32 ``codec_idx`` carried in the
experiment's `CellParams`.  All branches of a bank return one uniform
`WireMsg` layout (payload/scale/idx padded to the bank maxima), keeping
shapes switch-compatible; a single-entry bank elides the switch entirely,
which is how `BridgeTrainer` drives these helpers — per-experiment and
batched paths stay bit-identical.

Lossy codecs do NOT compress the raw iterate.  BRIDGE gossips *iterates*, so
a sparse codeword decoded as "zero at unsent coordinates" would average
literal zeros into consensus and a quantized one would carry noise
proportional to ``|w|`` forever.  Instead the carry (`CommState`, living in
``BridgeState.comm``) implements the compressed-gossip scheme of the
CHOCO-SGD / robust-gossip line (Koloskova et al.; Gaucher & Dieuleveut):

* ``est`` — the *public copy*: the running decoded estimate every receiver
  holds of this sender(-link)'s iterate.  What travels is the compressed
  **delta** ``x - est``; receivers apply it, so sparse codewords *update*
  coordinates instead of zeroing them, and quantization noise scales with
  the shrinking delta instead of the iterate.
* ``resid`` — error feedback on the transmitted delta: the codec sends
  ``compress(delta + resid)`` and carries the *in-support* reconstruction
  error forward, so quantization error on what WAS sent is corrected the
  next tick.  Coordinates a sparse codec did not transmit are excluded: the
  untransmitted mass already persists in the next delta (``est`` did not
  move there), and accumulating it in the residual too would double-count
  it — an unstable positive feedback loop (the reason CHOCO-style schemes
  carry no separate EF term at all).

On the broadcast path the state is per sender (``[M, d]`` — every receiver
sees the same codeword); on the network-runtime path it is per link
(``[M, M, d]`` — a Byzantine sender tells different lies on different links,
so its codewords, estimates, and residuals diverge per link).  Lossless
codecs pass everything through *structurally untouched* (no ``x + 0.0``
anywhere), which is what keeps identity-codec runs bit-identical to the
uncompressed trainer even for ``-0.0`` payloads.

The state update is masked by the tick's live-edge set on the runtime path
(a sender advances a link's public copy only for messages it actually put on
the wire — channel drops are downstream, invisible to it, and correctly not
fed back; the dropped *reconstruction* simply never reaches the mailbox).
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.comm.codec import Codec, WireMsg, _scatter_last


class CommState(NamedTuple):
    """Wire-codec carry for one message tensor (see module docstring)."""

    est: jax.Array  # receivers' running decoded estimate (public copy)
    resid: jax.Array  # error-feedback accumulator on the transmitted delta


def bank_is_lossless(bank: Sequence[Codec]) -> bool:
    """True when no codec in the bank needs a delta/error-feedback carry."""
    return all(c.lossless for c in bank)


def bank_sizes(bank: Sequence[Codec], d: int) -> tuple[int, int, int]:
    """(payload bytes P, index slots K, scale pairs S) every bank message is
    padded to."""
    p = max(c.payload_bytes(d) for c in bank)
    k = max((c.kept(d) for c in bank if c.mode != "dense"), default=0)
    s = max(c.nscales(d) for c in bank)
    return p, k, s


def _pad_axis(x: jax.Array, size: int, axis: int = -1) -> jax.Array:
    axis = axis % x.ndim
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def init_residual(shape: tuple[int, ...], bank: Sequence[Codec]):
    """The codec carry for a message tensor of ``shape`` (zero estimate +
    zero residual), or ``None`` for an all-lossless bank — the default
    identity path carries no extra state at all."""
    if bank_is_lossless(bank):
        return None
    return CommState(est=jnp.zeros(shape, jnp.float32),
                     resid=jnp.zeros(shape, jnp.float32))


def encode_bank(
    bank: Sequence[Codec],
    codec_idx,
    key: jax.Array,
    x: jax.Array,
    state,
) -> tuple[WireMsg, jax.Array]:
    """Encode ``x [..., d]`` with the codec selected by ``codec_idx``: lossy
    codecs transmit ``compress((x - est) + resid)``.  Returns ``(msg,
    target)`` where ``target`` is what the codec tried to send — `decode_bank`
    needs it to close the feedback loop."""
    d = x.shape[-1]
    p, k, s = bank_sizes(bank, d)

    def branch(c: Codec):
        def run(key, x, st):
            if c.lossless or st is None:
                target = x
            else:
                target = (x - st.est) + st.resid
            m = c.encode(key, target)
            return WireMsg(_pad_axis(m.payload, p), _pad_axis(m.scale, s, axis=-2),
                           _pad_axis(m.idx, k)), target

        return run

    branches = [branch(c) for c in bank]
    if len(branches) == 1:
        return branches[0](key, x, state)
    return jax.lax.switch(codec_idx, branches, key, x, state)


def decode_bank(
    bank: Sequence[Codec],
    codec_idx,
    msg: WireMsg,
    target: jax.Array,
    state,
    key: jax.Array | None = None,
):
    """Decode the (possibly wire-attacked) ``msg`` with the selected codec
    and advance the carry: receivers see ``x_hat = est + decoded_delta``, the
    public copy moves to ``x_hat``, and the EF residual becomes ``target -
    decoded_delta``.  Returns ``(x_hat [..., d], state')``.  ``key`` must be
    the encode-side comm key — shared-randomness codecs (randk) re-derive
    their index sets from it instead of trusting the attackable ``idx``
    field.  Honest senders' codewords are never wire-attacked, so their
    carries correctly track their own decodes (a corrupted Byzantine
    estimate only poisons what that sender's receivers screen — which is
    the point)."""
    d = target.shape[-1]

    def branch(c: Codec):
        def run(msg, target, st):
            dec = c.decode(msg, d, key)
            if c.lossless or st is None:
                return dec, (jnp.zeros(()) if st is None else st)
            x_hat = st.est + dec
            # NOTE: XLA may contract the dequant multiply feeding this
            # subtraction into an FMA in one program shape but not another,
            # so a lossy codec inside a *multi-codec banked* program can
            # drift from its single-codec twin by ~1 ULP per step through
            # the feedback loop.  Grouped grid execution (the default) uses
            # single-codec banks and stays bit-identical to the trainer;
            # identity cells are exactly equal on every path.
            err = target - dec
            if c.mode != "dense":
                # in-support only: untransmitted mass stays in the delta.
                # The support must match what decode actually scattered —
                # randk re-derives its set via the same Codec.randk_indices
                # draw decode makes (XLA CSEs the duplicate).
                if c.mode == "randk" and key is not None:
                    sidx = c.randk_indices(key, msg.payload.shape[:-1], d)
                else:
                    sidx = msg.idx[..., : c.kept(d)]
                support = _scatter_last(sidx, jnp.ones(sidx.shape, bool), d)
                err = jnp.where(support, err, 0.0)
            return x_hat, CommState(est=x_hat, resid=err)

        return run

    branches = [branch(c) for c in bank]
    if len(branches) == 1:
        x_hat, st = branches[0](msg, target, state)
    else:
        x_hat, st = jax.lax.switch(codec_idx, branches, msg, target, state)
    return x_hat, (None if state is None else st)


def wire_bits_bank(bank: Sequence[Codec], codec_idx, d: int):
    """Exact bits-on-wire per message for the selected codec: a python int
    for single-entry banks (static — channel ring sizing uses it), an int32
    scalar selected by ``lax.switch`` otherwise."""
    if len(bank) == 1:
        return bank[0].wire_bits(d)
    branches = [
        (lambda b: lambda _: jnp.asarray(b, jnp.int32))(c.wire_bits(d)) for c in bank
    ]
    return jax.lax.switch(codec_idx, branches, 0)


def max_wire_bits(bank: Sequence[Codec], d: int) -> int:
    """The largest message in the bank — what mailbox rings must be sized
    for when channels charge serialization ticks from wire bits."""
    return max(c.wire_bits(d) for c in bank)


def wire_bits_blocks(bank: Sequence[Codec], codec_idx, sizes: Sequence[int]):
    """Total bits on the wire for one logical message streamed as independent
    per-block codewords (`repro.stream`): each coordinate block is encoded on
    its own, so per-message overhead — scale factors, top-k index headers —
    is paid once per block, and sparsifying codecs keep their budget per
    block rather than globally.  Summing `wire_bits_bank` over the true
    (unpadded) block sizes is therefore the exact accounting for the chunked
    path, not an approximation of the flat one."""
    total = 0
    for s in sizes:
        total = total + wire_bits_bank(bank, codec_idx, s)
    return total
