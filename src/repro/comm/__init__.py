"""repro.comm — compressed Byzantine-resilient exchange (wire formats).

What travels on an edge is a first-class design axis: a `Codec` maps the
flattened iterate to an attackable `WireMsg` codeword (quantized / sparsified
/ both) and back, with exact bits-on-wire accounting; `exchange` applies
codecs as banked ``lax.switch`` data with per-link error feedback so
compressed BRIDGE still converges.  `repro.core.bridge` threads the codec
through both the broadcast and network-runtime steps, `repro.net` charges
serialization latency from ``wire_bits()``, `repro.sim` sweeps codec as a
grid axis, and `repro.kernels.dequant_screen` screens int8 codewords without
materializing ``float32[n, d]``.
"""
from repro.comm.codec import SCALE_BLOCK, Codec, WireMsg, codec_bank, codec_names, get_codec
from repro.comm.exchange import (
    CommState,
    bank_is_lossless,
    bank_sizes,
    decode_bank,
    encode_bank,
    init_residual,
    max_wire_bits,
    wire_bits_bank,
)

__all__ = [
    "SCALE_BLOCK", "Codec", "CommState", "WireMsg", "codec_bank", "codec_names",
    "get_codec",
    "bank_is_lossless", "bank_sizes", "decode_bank", "encode_bank",
    "init_residual", "max_wire_bits", "wire_bits_bank",
]
