"""Wire codecs: what actually travels on an edge per tick.

BRIDGE's scalability argument (Sec. V) is about large model dimension ``d``,
but a simulated exchange that ships ``float32[d]`` per edge per tick makes
*communication* the binding constraint long before compute.  A `Codec` turns
the flattened iterate into a compact `WireMsg` — the attackable codeword the
network moves — and back:

* ``encode(key, x) -> WireMsg`` — quantize / sparsify ``x [..., d]`` into a
  byte payload plus dequantization metadata.  Stochastic rounding draws from
  ``key``, so a fixed seed reproduces the exact wire trace.
* ``decode(msg, d) -> x_hat`` — what receivers actually see.  Decoders are
  total functions of the codeword: malicious payload bytes, abused scale
  fields, or lying sparse indices (`repro.core.byzantine` wire attacks) decode
  to *something*, and screening is evaluated against that something.
* ``wire_bits(d)`` — the exact bits-on-wire per message, the unit `repro.net`
  channels charge serialization latency in and benchmarks account bytes with.

Every codec in a bank encodes to one uniform `WireMsg` layout (payload /
scale / idx padded to the bank maxima), so codec selection is banked
``lax.switch`` *data* exactly like screening rules and attacks — a codec ×
rule × attack grid still compiles once.  Lossy codecs compose with per-link
error feedback (`repro.comm.exchange`); the ``identity`` codec is an exact
float32 bitcast round-trip, which is what makes the default path bit-identical
to the uncompressed trainer.

Registry names: ``identity``, ``int8``, ``int4`` (dense stochastic
quantization), ``topk<P>`` / ``randk<P>`` (keep P percent of coordinates,
float32 values), and quantized-sparse combos ``topk<P>_int8`` etc.  ``randk``
draws its surviving set from the shared per-tick PRNG, so it ships **no index
bits** — the receiver re-derives the indices (classic shared-randomness
trick); ``topk`` ships its k-subset as a combinatorial-number-system rank —
``ceil(log2 C(d, k))`` bits exactly, the subset's information content (a
fixed-size enumerative code, ~2.5x tighter than naive per-index addressing
at k/d = 1/2).  Sparsifiers are *contractive*, not unbiased (no ``d/k``
rescale) — the delta/error-feedback carry (`repro.comm.exchange`), not
inflation, recovers what they drop.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import re
from collections.abc import Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp


# Coordinates per quantization-scale block.  A single per-message scale
# couples every coordinate's quantization step to the payload's GLOBAL
# dynamic range — at d ~ 10^4 a handful of large coordinates (bias terms)
# inflate the noise on every small one until error feedback can't keep up.
# One affine pair per 128 coordinates keeps the step locally adaptive for
# ~0.25 bits/coordinate of overhead; for top-k payloads (magnitude-sorted)
# the blocks are naturally range-graded.
SCALE_BLOCK = 128


class WireMsg(NamedTuple):
    """One codeword: the unit the simulated network transmits.

    ``payload`` is the quantized byte stream (raw float bits for lossless
    codecs, one int8 per coordinate for ``int8``, two packed nibbles per byte
    for ``int4``), ``scale`` the per-block affine dequantization pairs
    ``(scale, zero)`` — one per `SCALE_BLOCK` payload coordinates — applied
    as ``q * scale + zero``, and ``idx`` the surviving coordinate indices of
    sparse codecs (empty trailing axis for dense banks).  Leading axes are
    free: ``[M, ...]`` per-sender on the broadcast path, ``[M, M, ...]``
    per-link on the network-runtime path.
    """

    payload: jax.Array  # int8 [..., P]
    scale: jax.Array  # f32 [..., S, 2]
    idx: jax.Array  # int32 [..., K]


def _bitcast_f32_to_i8(x: jax.Array) -> jax.Array:
    """f32 [..., k] -> int8 [..., 4k] (exact, invertible)."""
    b = jax.lax.bitcast_convert_type(x, jnp.int8)  # [..., k, 4]
    return b.reshape(x.shape[:-1] + (x.shape[-1] * 4,))

def _bitcast_i8_to_f32(b: jax.Array, k: int) -> jax.Array:
    """int8 [..., 4k] -> f32 [..., k] (inverse of `_bitcast_f32_to_i8`)."""
    return jax.lax.bitcast_convert_type(b.reshape(b.shape[:-1] + (k, 4)), jnp.float32)


def _stochastic_round(key: jax.Array, q: jax.Array, levels: int) -> jax.Array:
    """Unbiased rounding of ``q`` in [-levels, levels] to integers: E[out] = q
    (floor(q + U[0,1)) — the mean-preserving property `tests/test_comm.py`
    asserts, and what lets compressed BRIDGE average away quantization noise).
    """
    u = jax.random.uniform(key, q.shape, q.dtype)
    return jnp.clip(jnp.floor(q + u), -levels, levels)


def _blocked(x: jax.Array) -> jax.Array:
    """[..., k] -> [..., S, SCALE_BLOCK] (zero-padded ragged tail)."""
    k = x.shape[-1]
    s = -(-k // SCALE_BLOCK)
    pad = s * SCALE_BLOCK - k
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (s, SCALE_BLOCK))


def _quantize(key: jax.Array, x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric stochastic quantization to ``bits`` (<= 8) signed levels,
    one scale per `SCALE_BLOCK` coordinates.  Returns (q int8 in
    [-levels, levels] [..., k], scale f32 [..., S, 2])."""
    levels = (1 << (bits - 1)) - 1
    k = x.shape[-1]
    xb = _blocked(x)
    s = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)  # [..., S, 1]
    safe = jnp.where(s > 0, s, 1.0)
    q = _stochastic_round(key, xb / safe * levels, levels)
    q = q.reshape(q.shape[:-2] + (-1,))[..., :k].astype(jnp.int8)
    scale0 = (safe / levels)[..., 0]  # [..., S]
    scale = jnp.stack([scale0, jnp.zeros_like(scale0)], axis=-1)
    return q, scale


def apply_scales(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Decode int codes ``q [..., k]`` with per-block affine pairs
    ``scale [..., S, 2]`` (shared by codec decode and the kernel oracles)."""
    k = q.shape[-1]
    qb = _blocked(q.astype(jnp.float32))
    v = qb * scale[..., 0:1] + scale[..., 1:2]
    return v.reshape(v.shape[:-2] + (-1,))[..., :k]


def _pack_nibbles(q: jax.Array) -> jax.Array:
    """int8 [..., k] values in [-7, 7] -> int8 [..., ceil(k/2)] packed pairs."""
    k = q.shape[-1]
    if k % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
    lo = q[..., 0::2].astype(jnp.int32) & 0xF
    hi = q[..., 1::2].astype(jnp.int32) & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)

def _unpack_nibbles(b: jax.Array, k: int) -> jax.Array:
    """Inverse of `_pack_nibbles` (sign-extends each 4-bit field)."""
    w = b.astype(jnp.int32)
    lo = ((w & 0xF) ^ 8) - 8
    hi = (((w >> 4) & 0xF) ^ 8) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(b.shape[:-1] + (2 * b.shape[-1],))
    return out[..., :k].astype(jnp.int8)


@functools.lru_cache(maxsize=None)
def _subset_rank_bits(d: int, k: int) -> int:
    """ceil(log2 C(d, k)): the exact size of a combinatorial-number-system
    rank of a k-subset of d coordinates (ranks live in [0, C(d, k)))."""
    c = math.comb(d, k)
    return max(1, (c - 1).bit_length())


def _scatter_last(idx: jax.Array, vals: jax.Array, d: int) -> jax.Array:
    """Scatter ``vals [..., k]`` at ``idx [..., k]`` into zeros ``[..., d]``."""
    lead = idx.shape[:-1]
    k = idx.shape[-1]
    n = int(math.prod(lead)) if lead else 1
    flat = jnp.zeros((n, d), vals.dtype).at[
        jnp.arange(n)[:, None], idx.reshape(n, k)
    ].set(vals.reshape(n, k))
    return flat.reshape(lead + (d,))


@dataclasses.dataclass(frozen=True)
class Codec:
    """One wire format: ``mode`` in {dense, topk, randk}, value precision
    ``bits`` in {32, 8, 4}, kept fraction ``k_frac`` (sparse modes only)."""

    name: str
    mode: str = "dense"
    bits: int = 32
    k_frac: float = 1.0

    def __post_init__(self):
        if self.mode not in ("dense", "topk", "randk"):
            raise ValueError(f"unknown codec mode {self.mode!r}")
        if self.bits not in (32, 8, 4):
            raise ValueError(f"codec bits must be 32, 8, or 4, got {self.bits}")
        if not 0.0 < self.k_frac <= 1.0:
            raise ValueError(f"codec k_frac must be in (0, 1], got {self.k_frac}")

    @property
    def lossless(self) -> bool:
        """True when decode(encode(x)) == x bit-for-bit (no error feedback
        needed; the carry stays structurally untouched)."""
        return self.mode == "dense" and self.bits == 32

    def kept(self, d: int) -> int:
        """Coordinates that survive encoding a [d] message."""
        if self.mode == "dense":
            return d
        return max(1, min(d, round(self.k_frac * d)))

    def index_bits(self, d: int) -> int:
        """TOTAL wire bits for the surviving index set.  ``randk`` indices
        are re-derived from the shared per-tick PRNG — zero bits on the wire;
        ``topk`` ships the exact combinatorial rank of its k-subset:
        ``ceil(log2 C(d, k))`` bits (enumerative code)."""
        if self.mode != "topk":
            return 0
        return _subset_rank_bits(d, self.kept(d))

    def payload_bytes(self, d: int) -> int:
        """Bytes of the simulated payload buffer (value bytes only)."""
        k = self.kept(d)
        if self.bits == 32:
            return 4 * k
        if self.bits == 8:
            return k
        return (k + 1) // 2  # packed nibbles

    def nscales(self, d: int) -> int:
        """Per-block dequantization pairs on the wire (1 unit pair, not
        transmitted, for float32 values)."""
        if self.bits == 32:
            return 1
        return -(-self.kept(d) // SCALE_BLOCK)

    def wire_bits(self, d: int) -> int:
        """EXACT bits on the wire per message: value bits + the index set's
        enumerative rank + one 32-bit scale per `SCALE_BLOCK` quantized
        coordinates (the nibble-packing pad byte is a simulation artifact
        and is not charged)."""
        k = self.kept(d)
        bits = k * self.bits + self.index_bits(d)
        if self.bits < 32:
            bits += 32 * self.nscales(d)  # per-block dequantization scales
        return bits

    # -- encode / decode ----------------------------------------------------

    def encode(self, key: jax.Array, x: jax.Array) -> WireMsg:
        """``x [..., d] -> WireMsg`` at this codec's natural sizes (the bank
        helpers in `repro.comm.exchange` pad to the bank maxima)."""
        d = x.shape[-1]
        lead = x.shape[:-1]
        k_sel, k_q = jax.random.split(key)
        unit_scale = jnp.broadcast_to(
            jnp.asarray([[1.0, 0.0]], jnp.float32), lead + (1, 2))
        if self.mode == "dense":
            idx = jnp.zeros(lead + (0,), jnp.int32)
            vals = x
        else:
            k = self.kept(d)
            if self.mode == "topk":
                _, idx = jax.lax.top_k(jnp.abs(x), k)
                idx = idx.astype(jnp.int32)
            else:  # randk: surviving set from the shared PRNG, not the data
                del k_sel  # randk_indices re-splits `key` identically
                idx = self.randk_indices(key, lead, d)
            vals = jnp.take_along_axis(x, idx, axis=-1)
        if self.bits == 32:
            return WireMsg(_bitcast_f32_to_i8(vals), unit_scale, idx)
        q, scale = _quantize(k_q, vals, self.bits)
        payload = q if self.bits == 8 else _pack_nibbles(q)
        return WireMsg(payload, scale, idx)

    def decode(self, msg: WireMsg, d: int, key: jax.Array | None = None) -> jax.Array:
        """``WireMsg -> x_hat [..., d]``.  Reads only this codec's own prefix
        of the (possibly bank-padded) payload/idx, so banked messages decode
        identically to dedicated ones.

        ``key`` is the shared per-tick PRNG key the encoder drew from.  For
        ``randk`` the surviving indices are *re-derived* from it — they are
        exactly what ``wire_bits`` says never travels, so the simulated
        ``msg.idx`` field is untrusted and codeword attacks cannot forge
        them (a key-less call, e.g. a unit test poking a raw codec, falls
        back to the carried field)."""
        k = self.kept(d)
        if self.bits == 32:
            vals = _bitcast_i8_to_f32(msg.payload[..., : 4 * k], k)
        else:
            raw = msg.payload[..., : self.payload_bytes(d)]
            q = raw if self.bits == 8 else _unpack_nibbles(raw, k)
            vals = apply_scales(q, msg.scale[..., : self.nscales(d), :])
        if self.mode == "dense":
            return vals
        idx = msg.idx[..., :k]
        if self.mode == "randk" and key is not None:
            idx = self.randk_indices(key, msg.payload.shape[:-1], d)
        return _scatter_last(idx, vals, d)

    def randk_indices(self, key: jax.Array, lead: tuple[int, ...], d: int) -> jax.Array:
        """The shared-randomness index draw both sides of a randk link make:
        split -> k_sel -> top_k over per-coordinate uniforms.  The SINGLE
        definition encode, decode, and the error-feedback support all use —
        the EF-support invariant (residual only on decoded coordinates)
        depends on these draws being identical."""
        if self.mode != "randk":
            raise ValueError(f"codec {self.name!r} has no shared-randomness indices")
        k_sel, _ = jax.random.split(key)
        _, idx = jax.lax.top_k(jax.random.uniform(k_sel, lead + (d,)), self.kept(d))
        return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_SPARSE_RE = re.compile(r"^(topk|randk)(\d{1,2})(?:_int(8|4))?$")


@functools.lru_cache(maxsize=None)
def get_codec(name: str) -> Codec:
    """Resolve a codec name: ``identity``, ``int8``, ``int4``, or the
    parameterized sparse family ``topk<P>`` / ``randk<P>`` (P = percent of
    coordinates kept, 1-99) with an optional ``_int8`` / ``_int4`` value-
    quantization suffix — e.g. ``topk25_int8``."""
    if name == "identity":
        return Codec(name)
    if name == "int8":
        return Codec(name, bits=8)
    if name == "int4":
        return Codec(name, bits=4)
    m = _SPARSE_RE.match(name)
    if m:
        mode, pct, bits = m.group(1), int(m.group(2)), m.group(3)
        if not 1 <= pct <= 99:
            raise ValueError(f"codec {name!r}: kept percentage must be 1-99")
        return Codec(name, mode=mode, bits=int(bits) if bits else 32,
                     k_frac=pct / 100.0)
    raise ValueError(
        f"unknown codec {name!r}; options: identity, int8, int4, "
        f"topk<P>[_int8|_int4], randk<P>[_int8|_int4] (P = percent kept)"
    )


def codec_bank(names: Sequence[str]) -> tuple[Codec, ...]:
    """Resolve codec names to a static bank (order preserved)."""
    return tuple(get_codec(n) for n in names)


def codec_names() -> list[str]:
    """The fixed registry names (the sparse family is parameterized and
    validated by `get_codec`, not enumerable)."""
    return ["identity", "int8", "int4", "topk25", "randk25", "topk25_int8"]
