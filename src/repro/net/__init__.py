"""repro.net — event-driven unreliable-network runtime for asynchronous BRIDGE.

Layers (each usable standalone):

* `channel` — per-link stochastic models: drop probability, integer latency
  distributions, bandwidth-capped payload truncation.
* `dynamic` — ``[T, M, M]`` time-varying topology schedules: edge churn, node
  join/leave, partition-and-heal, built from `repro.core.graph.Topology`.
* `mailbox` — fixed-capacity per-node mailboxes with an in-flight ring buffer
  (scan-over-ticks friendly; no Python event loop inside jit).
* `runtime` — `SynchronousRuntime` (the trivial ideal network) and
  `UnreliableRuntime` (channel + schedule + mailboxes), pluggable into
  `BridgeTrainer` via its ``runtime=`` hook.
* `async_bridge` — `AsyncBridgeTrainer`: BRIDGE screening whatever messages
  have arrived, with a configurable staleness bound and a jitted
  ``lax.scan``-over-ticks hot path.
* `scenarios` — the canonical named network conditions (channel x dynamics x
  staleness) shared by benchmarks, sweeps, and the batched grid engine.
"""
from repro.net.async_bridge import AsyncBridgeConfig, AsyncBridgeTrainer
from repro.net.channel import ChannelConfig
from repro.net.dynamic import (
    edge_churn,
    node_join_leave,
    node_presence_schedule,
    partition_and_heal,
    scenario_schedule,
    schedule_stats,
    static_schedule,
)
from repro.net.mailbox import MailboxState, deliver, init_mailbox, push, staleness, usable_mask
from repro.net.runtime import SparseUnreliableRuntime, SynchronousRuntime, UnreliableRuntime
from repro.net.scenarios import NET_SCENARIOS, NetScenario, build_schedule, get_scenario

__all__ = [
    "AsyncBridgeConfig", "AsyncBridgeTrainer",
    "ChannelConfig",
    "edge_churn", "node_join_leave", "node_presence_schedule",
    "partition_and_heal", "scenario_schedule", "schedule_stats", "static_schedule",
    "MailboxState", "deliver", "init_mailbox", "push", "staleness", "usable_mask",
    "SparseUnreliableRuntime", "SynchronousRuntime", "UnreliableRuntime",
    "NET_SCENARIOS", "NetScenario", "build_schedule", "get_scenario",
]
