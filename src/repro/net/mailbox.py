"""Fixed-capacity per-node mailboxes with in-flight message tracking.

State is a pytree of fixed-shape arrays so the whole exchange threads through
``lax.scan`` as a carry — no Python queues, no dynamic allocation:

* ``values[j, i]`` / ``send_tick[j, i]`` — the *mailbox*: the most recent
  payload node j has received from sender i, tagged with the tick it was
  sent (staleness at tick t is ``t - send_tick``; `NEVER` marks empty slots).
* ``ring_*[j, i, s]`` — in-flight messages.  A message sent at tick t with
  delay δ is written to ring slot ``(t + δ) mod L`` where ``L = max_delay + 1``;
  at tick t the runtime delivers slot ``t mod L``.  One slot per (edge,
  arrival tick) suffices because a sender emits at most one message per tick,
  and L bounds how far ahead any message can land (a later send to the same
  slot would be delivered first).

Memory is ``O(M * W * L * d)`` where ``W`` is the mailbox width: ``M`` on the
dense per-link layout (every node a potential sender — what makes
selective-victim attacks and per-edge loss expressible at simulation scale),
or ``K = max in-degree`` on the neighbor-indexed layout
(`repro.core.neighbors.NeighborTable`), where slot (j, k) belongs to j's k-th
static in-neighbor.  All state transforms here are elementwise over the
leading ``[M, W]`` axes, so the two layouts share every function below —
only `init_mailbox`'s ``width`` differs.  Padded sparse slots are never
pushed to, so they stay at `NEVER` forever and `usable_mask` keeps them out
of screening by construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Sentinel send-tick for "nothing ever delivered on this edge"; large negative
# so staleness comes out huge (and any finite bound masks it) without risking
# int32 overflow when ticks are added.
NEVER = -(2**30)


class MailboxState(NamedTuple):
    values: jax.Array  # [M, W, d] newest delivered payload per (receiver, slot)
    send_tick: jax.Array  # [M, W] int32 tick the stored payload was sent
    ring_vals: jax.Array  # [M, W, L, d] in-flight payloads by arrival slot
    ring_send: jax.Array  # [M, W, L] int32 send ticks of in-flight payloads
    ring_valid: jax.Array  # [M, W, L] bool slot occupancy

    @property
    def capacity(self) -> int:
        return self.ring_vals.shape[2]


def init_mailbox(num_nodes: int, dim: int, max_delay: int, dtype=jnp.float32,
                 *, width: int | None = None) -> MailboxState:
    """``width`` is the sender-slot axis: ``num_nodes`` (default — the dense
    per-link layout) or a `NeighborTable`'s ``k`` (the sparse layout)."""
    m, L = num_nodes, max_delay + 1
    w = num_nodes if width is None else int(width)
    return MailboxState(
        values=jnp.zeros((m, w, dim), dtype),
        send_tick=jnp.full((m, w), NEVER, jnp.int32),
        ring_vals=jnp.zeros((m, w, L, dim), dtype),
        ring_send=jnp.full((m, w, L), NEVER, jnp.int32),
        ring_valid=jnp.zeros((m, w, L), bool),
    )


def push(
    state: MailboxState,
    msgs: jax.Array,
    send_mask: jax.Array,
    delay: jax.Array,
    tick: jax.Array,
) -> MailboxState:
    """Enqueue this tick's transmissions.  ``msgs[j, i]`` is the payload from
    i to j, sent iff ``send_mask[j, i]`` (edge live and not dropped), arriving
    ``delay[j, i]`` ticks later."""
    L = state.capacity
    slot = (tick + delay) % L  # [M, M]
    hit = send_mask[:, :, None] & (slot[:, :, None] == jnp.arange(L)[None, None, :])
    return state._replace(
        ring_vals=jnp.where(hit[..., None], msgs[:, :, None, :], state.ring_vals),
        ring_send=jnp.where(hit, tick, state.ring_send),
        ring_valid=state.ring_valid | hit,
    )


def deliver(state: MailboxState, tick: jax.Array) -> tuple[MailboxState, jax.Array]:
    """Move every message whose arrival slot is ``tick`` into the mailbox.
    Returns the updated state and the ``[M, M]`` arrival mask."""
    L = state.capacity
    cur = (tick % L) == jnp.arange(L)  # [L]
    hit = state.ring_valid & cur[None, None, :]  # [M, M, L]
    arrived = jnp.any(hit, axis=2)
    payload = jnp.sum(jnp.where(hit[..., None], state.ring_vals, 0.0), axis=2)
    sent_at = jnp.sum(jnp.where(hit, state.ring_send, 0), axis=2)
    # Variable latency reorders messages; keep only arrivals *sent* later than
    # the current mailbox entry (send_tick doubles as a sequence number), so a
    # delayed stale copy never clobbers a fresher one.
    newer = arrived & (sent_at > state.send_tick)
    return (
        state._replace(
            values=jnp.where(newer[..., None], payload, state.values),
            send_tick=jnp.where(newer, sent_at, state.send_tick),
            ring_valid=state.ring_valid & ~hit,
        ),
        arrived,
    )


def staleness(state: MailboxState, tick: jax.Array) -> jax.Array:
    """[M, W] ticks since each mailbox entry was *sent*; empty slots saturate
    to INT32_MAX instead of computing ``tick - NEVER`` (which overflows int32
    once ``tick`` exceeds ``2**30``, silently turning never-filled slots into
    "fresh" zero payloads — pinned by ``tests/test_sparse.py``)."""
    return jnp.where(state.send_tick > NEVER, tick - state.send_tick,
                     jnp.iinfo(jnp.int32).max)


def generation_match(send_tick_a: jax.Array, send_tick_b: jax.Array) -> jax.Array:
    """True where two mailbox entries hold payloads from the *same send
    tick* (and both hold one at all — `NEVER` never matches).  The echo
    protocol (`repro.trust.echo`) only cross-checks digests across matching
    generations, so drops and variable latency — which leave receivers
    holding different-aged copies — are excluded from comparison instead of
    being miscounted as equivocation."""
    return (send_tick_a > NEVER) & (send_tick_a == send_tick_b)


def usable_mask(state: MailboxState, tick: jax.Array, bound: int) -> jax.Array:
    """[M, W] entries that have ever arrived and are at most ``bound`` ticks
    stale — the mask asynchronous screening feeds to the rules.  Written as a
    bound on ``send_tick`` (never as ``tick - NEVER``), so it stays exact at
    arbitrary tick counts.  Duck-typed on ``send_tick`` so the chunk-streaming
    `BlockMailboxState` shares it (as does `staleness` above)."""
    return (state.send_tick > NEVER) & (state.send_tick >= tick - bound)


# ---------------------------------------------------------------------------
# Per-block mailbox (repro.stream)
# ---------------------------------------------------------------------------
#
# The chunk-streaming runtime stores payloads per parameter *leaf* instead of
# one [M, W, d] matrix, and updates them one coordinate block at a time inside
# the scan-over-chunks loop — the only payload tensors live at any point of
# the streaming screen are [M, W, chunk] slices.  Metadata stays a single
# shared [M, W] ``send_tick``: all blocks of a tick's message travel the same
# (broadcast) channel together, so there is exactly one arrival event per
# edge per tick and `staleness` / `usable_mask` / `generation_match` apply
# unchanged.  Total resident payload memory still sums to O(M * W * d) — a
# mailbox must hold the newest copy of every coordinate — the win is that no
# *transient* full-d tensor (flat views, screening temporaries) exists.


class BlockMailboxState(NamedTuple):
    send_tick: jax.Array  # [M, W] int32 tick the stored payload was sent
    values: tuple  # per-leaf [M, W, s_l] f32 newest delivered payloads


def init_block_mailbox(num_nodes: int, sizes: tuple[int, ...], *,
                       width: int | None = None) -> BlockMailboxState:
    """``sizes`` are the per-leaf coordinate counts (`BlockSpec` leaf sizes);
    ``width`` as in `init_mailbox`."""
    m = num_nodes
    w = num_nodes if width is None else int(width)
    return BlockMailboxState(
        send_tick=jnp.full((m, w), NEVER, jnp.int32),
        values=tuple(jnp.zeros((m, w, s), jnp.float32) for s in sizes),
    )


def stamp(send_tick: jax.Array, arrived: jax.Array, tick: jax.Array) -> jax.Array:
    """Advance the shared metadata for this tick's arrivals (once per tick,
    outside the block loop)."""
    return jnp.where(arrived, tick, send_tick)


def push_block(values_leaf: jax.Array, msgs_blk: jax.Array, arrived: jax.Array,
               start) -> jax.Array:
    """Write one coordinate block of this tick's arrivals into a leaf's
    payload store: ``msgs_blk [M, W, c]`` lands at column ``start`` of
    ``values_leaf [M, W, s]`` on edges where ``arrived [M, W]``; dropped
    edges keep the previous (now stale) payload.  Slot columns update in
    place, so the peak live tensor of the push is the block itself."""
    m, w, c = msgs_blk.shape
    cur = jax.lax.dynamic_slice(values_leaf, (0, 0, start), (m, w, c))
    blk = jnp.where(arrived[:, :, None], msgs_blk, cur)
    return jax.lax.dynamic_update_slice(values_leaf, blk, (0, 0, start))
