"""Fixed-capacity per-node mailboxes with in-flight message tracking.

State is a pytree of fixed-shape arrays so the whole exchange threads through
``lax.scan`` as a carry — no Python queues, no dynamic allocation:

* ``values[j, i]`` / ``send_tick[j, i]`` — the *mailbox*: the most recent
  payload node j has received from sender i, tagged with the tick it was
  sent (staleness at tick t is ``t - send_tick``; `NEVER` marks empty slots).
* ``ring_*[j, i, s]`` — in-flight messages.  A message sent at tick t with
  delay δ is written to ring slot ``(t + δ) mod L`` where ``L = max_delay + 1``;
  at tick t the runtime delivers slot ``t mod L``.  One slot per (edge,
  arrival tick) suffices because a sender emits at most one message per tick,
  and L bounds how far ahead any message can land (a later send to the same
  slot would be delivered first).

Memory is ``O(M^2 * L * d)`` — the price of per-link payloads, which is what
makes selective-victim attacks and per-edge loss expressible.  At simulation
scale (M tens, d up to ~10^4, L a few ticks) this is tens of MB.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Sentinel send-tick for "nothing ever delivered on this edge"; large negative
# so staleness comes out huge (and any finite bound masks it) without risking
# int32 overflow when ticks are added.
NEVER = -(2**30)


class MailboxState(NamedTuple):
    values: jax.Array  # [M, M, d] newest delivered payload per (receiver, sender)
    send_tick: jax.Array  # [M, M] int32 tick the stored payload was sent
    ring_vals: jax.Array  # [M, M, L, d] in-flight payloads by arrival slot
    ring_send: jax.Array  # [M, M, L] int32 send ticks of in-flight payloads
    ring_valid: jax.Array  # [M, M, L] bool slot occupancy

    @property
    def capacity(self) -> int:
        return self.ring_vals.shape[2]


def init_mailbox(num_nodes: int, dim: int, max_delay: int, dtype=jnp.float32) -> MailboxState:
    m, L = num_nodes, max_delay + 1
    return MailboxState(
        values=jnp.zeros((m, m, dim), dtype),
        send_tick=jnp.full((m, m), NEVER, jnp.int32),
        ring_vals=jnp.zeros((m, m, L, dim), dtype),
        ring_send=jnp.full((m, m, L), NEVER, jnp.int32),
        ring_valid=jnp.zeros((m, m, L), bool),
    )


def push(
    state: MailboxState,
    msgs: jax.Array,
    send_mask: jax.Array,
    delay: jax.Array,
    tick: jax.Array,
) -> MailboxState:
    """Enqueue this tick's transmissions.  ``msgs[j, i]`` is the payload from
    i to j, sent iff ``send_mask[j, i]`` (edge live and not dropped), arriving
    ``delay[j, i]`` ticks later."""
    L = state.capacity
    slot = (tick + delay) % L  # [M, M]
    hit = send_mask[:, :, None] & (slot[:, :, None] == jnp.arange(L)[None, None, :])
    return state._replace(
        ring_vals=jnp.where(hit[..., None], msgs[:, :, None, :], state.ring_vals),
        ring_send=jnp.where(hit, tick, state.ring_send),
        ring_valid=state.ring_valid | hit,
    )


def deliver(state: MailboxState, tick: jax.Array) -> tuple[MailboxState, jax.Array]:
    """Move every message whose arrival slot is ``tick`` into the mailbox.
    Returns the updated state and the ``[M, M]`` arrival mask."""
    L = state.capacity
    cur = (tick % L) == jnp.arange(L)  # [L]
    hit = state.ring_valid & cur[None, None, :]  # [M, M, L]
    arrived = jnp.any(hit, axis=2)
    payload = jnp.sum(jnp.where(hit[..., None], state.ring_vals, 0.0), axis=2)
    sent_at = jnp.sum(jnp.where(hit, state.ring_send, 0), axis=2)
    # Variable latency reorders messages; keep only arrivals *sent* later than
    # the current mailbox entry (send_tick doubles as a sequence number), so a
    # delayed stale copy never clobbers a fresher one.
    newer = arrived & (sent_at > state.send_tick)
    return (
        state._replace(
            values=jnp.where(newer[..., None], payload, state.values),
            send_tick=jnp.where(newer, sent_at, state.send_tick),
            ring_valid=state.ring_valid & ~hit,
        ),
        arrived,
    )


def staleness(state: MailboxState, tick: jax.Array) -> jax.Array:
    """[M, M] ticks since each mailbox entry was *sent* (huge where empty)."""
    return tick - state.send_tick


def usable_mask(state: MailboxState, tick: jax.Array, bound: int) -> jax.Array:
    """[M, M] entries that have ever arrived and are at most ``bound`` ticks
    stale — the mask asynchronous screening feeds to the rules."""
    return (state.send_tick > NEVER) & (staleness(state, tick) <= bound)
