"""Canonical network-condition scenario registry.

A *scenario* names one point on the network-condition axis of the experiment
cube: a `ChannelConfig` (drop / latency / bandwidth), an optional topology
dynamics kind (`repro.net.dynamic.scenario_schedule`), and the staleness bound
asynchronous screening tolerates.  `benchmarks.net_bench`, the batched grid
engine (`repro.sim`), and `launch.sweep --mode grid` all resolve scenario
labels here, so "lossy" means the same channel everywhere a result is
recorded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.net.channel import ChannelConfig
from repro.net.dynamic import scenario_schedule, static_schedule


@dataclasses.dataclass(frozen=True)
class NetScenario:
    """One named network condition (channel x topology dynamics).

    ``topology`` optionally names a `repro.core.graph.TOPOLOGIES` spec —
    large-graph scenarios (small-world / geometric / torus at M >= 512,
    where only the sparse [M, K] layout fits) bundle the graph family with
    the channel so one label reproduces the whole condition; resolve it with
    `build_topology`.  ``None`` means the caller supplies the graph (all
    paper-scale scenarios)."""

    name: str
    channel: ChannelConfig = ChannelConfig.ideal()
    schedule_kind: str | None = None  # dynamic.scenario_schedule kind; None = static
    staleness_bound: int = 5
    churn_prob: float = 0.3
    topology: str | None = None  # repro.core.graph.make_topology spec


NET_SCENARIOS: dict[str, NetScenario] = {
    s.name: s
    for s in (
        NetScenario("ideal", ChannelConfig.ideal(), None, 0),
        NetScenario("lossy", ChannelConfig(drop_prob=0.2)),
        NetScenario("laggy", ChannelConfig(latency_max=3)),
        NetScenario("lossy_laggy", ChannelConfig(drop_prob=0.2, latency_max=3)),
        NetScenario("bandwidth64", ChannelConfig(bandwidth_cap=64)),
        # serialization-limited link: latency is charged from the codec's
        # exact wire_bits — a float32 payload of d ~ 8k coords spends extra
        # ticks on the wire that int8/top-k codewords do not
        NetScenario("narrowband64k", ChannelConfig(bits_per_tick=1 << 16)),
        NetScenario("churn", schedule_kind="churn"),
        NetScenario("partition", schedule_kind="partition"),
        # large-graph scenarios (ISSUE 5): bounded-degree families at
        # M >= 512 — run these through the sparse neighbor-indexed layout
        # (dense [M, M, d] state does not fit)
        NetScenario("smallworld_lossy", ChannelConfig(drop_prob=0.1),
                    topology="small_world:6"),
        NetScenario("geometric_churn", schedule_kind="churn", churn_prob=0.2,
                    topology="geometric"),
        NetScenario("torus_laggy", ChannelConfig(latency_max=2),
                    topology="torus"),
    )
}


def build_topology(scenario: NetScenario, num_nodes: int, num_byzantine: int,
                   *, seed: int = 0):
    """Resolve the scenario's bundled topology spec (see `NetScenario`);
    raises for paper-scale scenarios that leave the graph to the caller."""
    if scenario.topology is None:
        raise ValueError(
            f"scenario {scenario.name!r} does not bundle a topology; "
            f"construct one via repro.core.graph")
    from repro.core.graph import make_topology

    return make_topology(scenario.topology, num_nodes, num_byzantine, seed=seed)


def get_scenario(name: str) -> NetScenario:
    try:
        return NET_SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown net scenario {name!r}; options: {sorted(NET_SCENARIOS)}") from None


def build_schedule(scenario: NetScenario, topology, num_ticks: int, *, seed: int = 0) -> np.ndarray:
    """The scenario's full-length ``[num_ticks, M, M]`` topology schedule
    (static scenarios are expanded so schedules of different scenarios stack
    into one ``[S, T, M, M]`` array for the grid runtime)."""
    sched = scenario_schedule(
        scenario.schedule_kind, topology, num_ticks, seed=seed, churn_prob=scenario.churn_prob
    )
    if sched is None:
        sched = static_schedule(topology, num_ticks)
    return sched
