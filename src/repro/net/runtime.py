"""Network runtimes: how messages move between BRIDGE nodes each tick.

A runtime is the pluggable object `repro.core.bridge.BridgeTrainer` accepts
via its ``runtime=`` hook.  The contract (duck-typed, jit-traceable):

* ``init(num_nodes, dim) -> net_state`` — pytree carried through the step/scan.
* ``adjacency_at(t) -> [M, M] bool`` — the tick's live edges.
* ``exchange(net_state, msgs, self_vals, adjacency, key, t)
  -> (net_state, views [M, M, d], mask [M, M], stats dict)`` — moves this
  tick's message tensor ``msgs[receiver, sender]`` through the network and
  returns each node's current view of its senders plus the usable-entry mask.

`SynchronousRuntime` is the trivial instance — every edge delivers instantly,
every tick — and reproduces the classic broadcast simulation exactly.
`UnreliableRuntime` composes a `ChannelConfig` (drop/latency/bandwidth), a
``[T, M, M]`` topology schedule (`repro.net.dynamic`), and per-node mailboxes
(`repro.net.mailbox`), exposing stale-but-bounded views for asynchronous
screening.  Both are scan-over-ticks friendly: fixed shapes, no host control
flow inside the step.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.neighbors import NeighborTable
from repro.net import mailbox as mb
from repro.net.channel import ChannelConfig
from repro.net.dynamic import static_schedule


def _as_schedule(topology_or_schedule) -> jnp.ndarray:
    """Accept a Topology, a [M, M] adjacency, or a [T, M, M] schedule."""
    arr = getattr(topology_or_schedule, "adjacency", topology_or_schedule)
    arr = np.asarray(arr, dtype=bool)
    if arr.ndim == 2:
        arr = static_schedule(arr, 1)
    if arr.ndim != 3 or arr.shape[1] != arr.shape[2]:
        raise ValueError(f"schedule must be [T, M, M], got {arr.shape}")
    return jnp.asarray(arr)


class SynchronousRuntime:
    """The ideal network: every live edge delivers the fresh message within
    the tick.  ``BridgeTrainer(cfg, fn, runtime=SynchronousRuntime(topo))``
    matches ``BridgeTrainer(cfg, fn)`` bit-for-bit (same rules, same masks);
    it exists so dynamic topologies can be driven without any channel noise.
    """

    def __init__(self, topology_or_schedule):
        self._schedule = _as_schedule(topology_or_schedule)

    def describe(self) -> dict:
        """JSON-able summary for run manifests (`repro.obs.manifest`)."""
        return {"runtime": "synchronous", "num_nodes": int(self._schedule.shape[1]),
                "num_ticks": self.num_ticks}

    @property
    def num_ticks(self) -> int:
        return self._schedule.shape[0]

    def adjacency_at(self, t: jax.Array) -> jax.Array:
        return self._schedule[t % self.num_ticks]

    def init(self, num_nodes: int, dim: int, max_wire_bits: int | None = None):
        del num_nodes, dim, max_wire_bits
        return None

    def exchange(self, net_state, msgs, self_vals, adjacency, key, t, *, wire_bits=None):
        del self_vals, key, t, wire_bits
        m = adjacency.shape[0]
        links = jnp.sum(adjacency).astype(jnp.float32) / max(m, 1)
        stats = {
            "delivered_frac": jnp.ones((), jnp.float32),
            "mean_staleness": jnp.zeros((), jnp.float32),
            "active_links": links,  # live in-edges per node this tick
            "usable_in": links,  # usable mailbox entries per node (== links here)
        }
        return net_state, msgs, adjacency, stats


class UnreliableRuntime:
    """Lossy, delayed, bandwidth-capped, time-varying message exchange.

    Per tick: (1) sample per-edge drop/delay from the channel, (2) enqueue the
    surviving messages into the in-flight ring, (3) deliver everything whose
    arrival tick is now, (4) expose mailbox contents no staler than
    ``staleness_bound`` ticks (sender-side timestamps) as the screening views.
    Untransmitted coordinates under a bandwidth cap are backfilled, at send
    time, with the receiver's iterate of the send tick.
    """

    def __init__(
        self,
        topology_or_schedule,
        channel: ChannelConfig = ChannelConfig.ideal(),
        *,
        staleness_bound: int = 5,
    ):
        if staleness_bound < 0:
            raise ValueError(f"staleness_bound must be >= 0, got {staleness_bound}")
        self._schedule = _as_schedule(topology_or_schedule)
        self.channel = channel
        self.staleness_bound = staleness_bound

    def describe(self) -> dict:
        """JSON-able summary for run manifests (`repro.obs.manifest`)."""
        return {"runtime": "unreliable", "num_nodes": int(self._schedule.shape[1]),
                "num_ticks": self.num_ticks, "staleness_bound": self.staleness_bound,
                "channel": dataclasses.asdict(self.channel)}

    @property
    def num_ticks(self) -> int:
        return self._schedule.shape[0]

    def adjacency_at(self, t: jax.Array) -> jax.Array:
        return self._schedule[t % self.num_ticks]

    def init(self, num_nodes: int, dim: int, max_wire_bits: int | None = None) -> mb.MailboxState:
        if num_nodes != self._schedule.shape[1]:
            raise ValueError(
                f"runtime schedule is for {self._schedule.shape[1]} nodes, "
                f"trainer has {num_nodes}"
            )
        # ring sized for the worst case: propagation latency plus the
        # serialization ticks of the largest codeword the run can emit
        # (32*dim — a raw float32 payload — when no codec bound is given)
        if max_wire_bits is None:
            max_wire_bits = 32 * dim
        return mb.init_mailbox(num_nodes, dim, self.channel.max_total_latency(max_wire_bits))

    def delivered_coord_mask(self, key: jax.Array, d: int) -> jax.Array | None:
        """The coordinate subset `exchange` will deliver for this tick's
        ``key`` (None when uncapped).  Mirrors the internal PRNG derivation
        exactly — an *omniscient* adversary (`repro.adversary`) can therefore
        concentrate its lies on the coordinates that will actually cross the
        wire; honest nodes cannot (the draw happens channel-side)."""
        if self.channel.bandwidth_cap is None:
            return None
        return self.channel.coord_mask(jax.random.split(key)[1], d)

    def exchange(self, net_state, msgs, self_vals, adjacency, key, t, *, wire_bits=None):
        m = adjacency.shape[0]
        # the coord-subset stream splits off only when a cap is set, so
        # uncapped channels keep their historical drop/latency traces
        if self.channel.bandwidth_cap is not None:
            key, k_coord = jax.random.split(key)
        else:
            k_coord = key
        delay, drop = self.channel.sample(key, m)
        # serialization: a wire_bits-bit codeword occupies the link for
        # ceil(wire_bits / bits_per_tick) ticks; compression buys ticks back
        delay = delay + self.channel.serial_ticks(wire_bits)
        send_mask = adjacency & ~drop
        # the bandwidth cap bites at SEND time: the in-flight payload carries
        # this tick's transmitted subset, untransmitted coordinates backfilled
        # with the receiver's iterate as of the send tick.  Masking at read
        # time instead would re-draw the subset per tick and let a stale
        # mailbox entry leak almost every coordinate of a message of which
        # only `cap` per tick ever crossed the wire.
        cm = self.channel.coord_mask(k_coord, msgs.shape[-1])
        if cm is not None:
            msgs = jnp.where(cm[None, None, :], msgs, self_vals[:, None, :])
        net_state = mb.push(net_state, msgs, send_mask, delay, t)
        net_state, arrived = mb.deliver(net_state, t)
        mask = mb.usable_mask(net_state, t, self.staleness_bound)
        views = net_state.values
        n_edges = jnp.maximum(jnp.sum(adjacency), 1)
        n_usable = jnp.maximum(jnp.sum(mask), 1)
        stats = {
            "delivered_frac": jnp.sum(arrived & adjacency) / n_edges.astype(jnp.float32),
            "mean_staleness": jnp.sum(
                jnp.where(mask, mb.staleness(net_state, t), 0)
            ) / n_usable.astype(jnp.float32),
            "active_links": jnp.sum(adjacency).astype(jnp.float32) / max(m, 1),
            # usable entries can exceed active_links: fresh mailbox values from
            # edges that churned away still count until they go stale
            "usable_in": jnp.sum(mask).astype(jnp.float32) / max(m, 1),
        }
        return net_state, views, mask, stats


class SparseUnreliableRuntime:
    """`UnreliableRuntime` on the neighbor-indexed ``[M, K]`` layout.

    A static `NeighborTable` built from the *union* of the topology schedule
    keys every per-link structure: the mailbox ring is ``[M, K, L, d]``, the
    per-tick live/usable masks are ``[M, K]``, and `exchange` consumes/emits
    ``[M, K, d]`` message tensors — nothing of size ``M^2 * d`` exists on
    this path (asserted over the jitted step's HLO by
    ``benchmarks/scale_bench.py``).  Channel *events* are still drawn on the
    dense ``[M, M]`` scalar grid and gathered through the table: per-edge
    scalars are microscopic next to payloads, and reusing the dense draw
    keeps the drop/latency trace — and therefore the whole trajectory —
    bit-identical to the dense `UnreliableRuntime` oracle at equal seed
    (property-tested in ``tests/test_sparse.py``).

    ``adjacency_at`` returns the pre-gathered ``[M, K]`` live-slot mask (the
    schedule is collapsed through the table once, on the host).
    """

    def __init__(
        self,
        topology_or_schedule,
        channel: ChannelConfig = ChannelConfig.ideal(),
        *,
        staleness_bound: int = 5,
        k: int | None = None,
        neighbors: NeighborTable | None = None,
    ):
        if staleness_bound < 0:
            raise ValueError(f"staleness_bound must be >= 0, got {staleness_bound}")
        schedule = _as_schedule(topology_or_schedule)
        self.channel = channel
        self.staleness_bound = staleness_bound
        sched_np = np.asarray(schedule)
        self.neighbors = (
            neighbors if neighbors is not None
            else NeighborTable.from_schedule(sched_np, k=k)
        )
        if self.neighbors.num_nodes != sched_np.shape[1]:
            raise ValueError(
                f"neighbor table is for {self.neighbors.num_nodes} nodes, "
                f"schedule has {sched_np.shape[1]}")
        self._live = jnp.asarray(self.neighbors.live_schedule(sched_np))  # [T, M, K]

    def describe(self) -> dict:
        """JSON-able summary for run manifests (`repro.obs.manifest`)."""
        return {"runtime": "sparse_unreliable", "num_nodes": self.neighbors.num_nodes,
                "num_ticks": self.num_ticks, "staleness_bound": self.staleness_bound,
                "k": self.neighbors.k, "channel": dataclasses.asdict(self.channel)}

    @property
    def num_ticks(self) -> int:
        return self._live.shape[0]

    def adjacency_at(self, t: jax.Array) -> jax.Array:
        return self._live[t % self.num_ticks]  # [M, K]

    def init(self, num_nodes: int, dim: int, max_wire_bits: int | None = None) -> mb.MailboxState:
        if num_nodes != self.neighbors.num_nodes:
            raise ValueError(
                f"runtime table is for {self.neighbors.num_nodes} nodes, "
                f"trainer has {num_nodes}")
        if max_wire_bits is None:
            max_wire_bits = 32 * dim
        return mb.init_mailbox(
            num_nodes, dim, self.channel.max_total_latency(max_wire_bits),
            width=self.neighbors.k)

    def delivered_coord_mask(self, key: jax.Array, d: int) -> jax.Array | None:
        """See `UnreliableRuntime.delivered_coord_mask` (identical stream)."""
        if self.channel.bandwidth_cap is None:
            return None
        return self.channel.coord_mask(jax.random.split(key)[1], d)

    def exchange(self, net_state, msgs, self_vals, live, key, t, *, wire_bits=None):
        m = self.neighbors.num_nodes
        if self.channel.bandwidth_cap is not None:
            key, k_coord = jax.random.split(key)
        else:
            k_coord = key
        # dense scalar event grid, gathered to slots — see class docstring
        delay_d, drop_d = self.channel.sample(key, m)
        delay = self.neighbors.gather_edges(delay_d)
        drop = self.neighbors.gather_edges(drop_d, fill=True)
        delay = delay + self.channel.serial_ticks(wire_bits)
        send_mask = live & ~drop
        cm = self.channel.coord_mask(k_coord, msgs.shape[-1])
        if cm is not None:
            msgs = jnp.where(cm[None, None, :], msgs, self_vals[:, None, :])
        net_state = mb.push(net_state, msgs, send_mask, delay, t)
        net_state, arrived = mb.deliver(net_state, t)
        mask = mb.usable_mask(net_state, t, self.staleness_bound)
        views = net_state.values
        n_edges = jnp.maximum(jnp.sum(live), 1)
        n_usable = jnp.maximum(jnp.sum(mask), 1)
        stats = {
            "delivered_frac": jnp.sum(arrived & live) / n_edges.astype(jnp.float32),
            "mean_staleness": jnp.sum(
                jnp.where(mask, mb.staleness(net_state, t), 0)
            ) / n_usable.astype(jnp.float32),
            "active_links": jnp.sum(live).astype(jnp.float32) / max(m, 1),
            "usable_in": jnp.sum(mask).astype(jnp.float32) / max(m, 1),
        }
        return net_state, views, mask, stats
