"""Asynchronous BRIDGE over an unreliable network.

`AsyncBridgeTrainer` is BRIDGE (Algorithm 1) with the message exchange routed
through an `UnreliableRuntime`: at every tick each node screens *whatever
messages have arrived* — the newest mailbox entry per sender, provided it is
at most ``staleness_bound`` ticks old — instead of assuming a synchronous
lossless broadcast round.  Nodes that momentarily hold fewer usable messages
than their screening rule's Table-II minimum skip the combine and keep their
own iterate (pure local SGD for that tick), which keeps the update well
defined through partitions, churn, and burst loss.

With an ideal channel (zero latency, zero drop, no bandwidth cap) and a
static schedule, the trainer reproduces `repro.core.bridge.BridgeTrainer`
bit-for-bit — asserted by ``tests/test_net.py`` — so every existing
rule × attack experiment extends to a rule × attack × network-condition
matrix by flipping channel/schedule knobs only.

The hot path is a single ``lax.scan`` over ticks (`run_scan`): mailbox ring
buffers, channel sampling, screening, and the gradient step all live inside
one jitted scan body — no Python event loop, no per-tick dispatch.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import numpy as np

from repro.core.bridge import BridgeConfig, BridgeState, BridgeTrainer, stack_batches
from repro.net.channel import ChannelConfig
from repro.net.runtime import UnreliableRuntime


@dataclasses.dataclass(frozen=True)
class AsyncBridgeConfig(BridgeConfig):
    """`BridgeConfig` plus the network scenario.

    ``schedule`` is an optional ``[T, M, M]`` time-varying adjacency
    (`repro.net.dynamic` generators); ``None`` runs the static topology.
    """

    channel: ChannelConfig = ChannelConfig.ideal()
    staleness_bound: int = 5
    schedule: np.ndarray | None = None


class AsyncBridgeTrainer(BridgeTrainer):
    """BRIDGE through an `UnreliableRuntime` built from an `AsyncBridgeConfig`.

    ``config.sparse`` swaps in the neighbor-indexed `SparseUnreliableRuntime`
    — ``[M, K]`` mailbox/channel/codec state keyed by the schedule-union
    `NeighborTable`, bit-identical to the dense runtime at equal seed and the
    only layout that fits large-M graphs (see `repro.core.neighbors`).
    """

    def __init__(self, config: AsyncBridgeConfig, grad_fn: Callable):
        from repro.net.runtime import SparseUnreliableRuntime

        cls = SparseUnreliableRuntime if config.sparse else UnreliableRuntime
        runtime = cls(
            config.schedule if config.schedule is not None else config.topology,
            config.channel,
            staleness_bound=config.staleness_bound,
        )
        super().__init__(config, grad_fn, runtime=runtime)
        self._scan = None

    def run_scan(self, state: BridgeState, batches: Any) -> tuple[BridgeState, dict]:
        """Run one tick per leading-axis slice of ``batches`` (a pytree of
        ``[T, ...]`` arrays) as a single jitted ``lax.scan``.  Returns the
        final state and the per-tick metrics stacked to ``[T]`` arrays."""
        if self._scan is None:
            # the cell is a scan-invariant operand (not a closure constant)
            # for program-shape parity with the grid engine — see BridgeTrainer
            self._scan = jax.jit(
                lambda cell, st, xs: jax.lax.scan(
                    lambda s, x: self._raw_step(cell, s, x), st, xs
                )
            )
        return self._scan(self._cell, state, batches)

    def run_ticks(
        self,
        state: BridgeState,
        batch_fn: Callable[[int], Any],
        num_ticks: int,
    ) -> tuple[BridgeState, dict]:
        """`run_scan` convenience: materialize ``num_ticks`` batches from
        ``batch_fn`` (stacked on a new leading axis) and scan over them."""
        return self.run_scan(state, stack_batches(batch_fn, num_ticks))
