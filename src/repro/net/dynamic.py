"""Time-varying topology schedules.

A *schedule* is a ``[T, M, M]`` bool array: ``schedule[t, j, i]`` marks edge
i -> j live at tick t.  Generators here start from a static
`repro.core.graph.Topology` (so Assumption-4 style validation applies to the
base graph) and overlay temporal structure: independent edge churn, node
join/leave, and partition-and-heal events.  The runtime indexes the schedule
with ``t mod T``, so a finite schedule repeats — build it as long as the run
when that matters.

Schedules are plain numpy on the host (they are built once, before jit) and
converted to device arrays by the runtime.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Topology


def _base_adjacency(topo) -> np.ndarray:
    adj = topo.adjacency if isinstance(topo, Topology) else np.asarray(topo)
    adj = np.asarray(adj, dtype=bool)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    return adj


def static_schedule(topo, num_ticks: int) -> np.ndarray:
    """The trivial schedule: the same graph every tick."""
    adj = _base_adjacency(topo)
    return np.broadcast_to(adj, (num_ticks,) + adj.shape).copy()


def edge_churn(
    topo,
    num_ticks: int,
    churn_prob: float,
    *,
    seed: int = 0,
    symmetric: bool = True,
) -> np.ndarray:
    """Each base edge is independently absent with probability ``churn_prob``
    at each tick (a memoryless on/off link model).  ``symmetric=True`` churns
    both directions of a link together, matching radio-style connectivity."""
    if not 0.0 <= churn_prob < 1.0:
        raise ValueError(f"churn_prob must be in [0, 1), got {churn_prob}")
    adj = _base_adjacency(topo)
    rng = np.random.default_rng(seed)
    draw = rng.random((num_ticks,) + adj.shape)
    if symmetric:
        # one draw per undirected pair, so the pair-level churn probability is
        # exactly churn_prob (AND-ing two independent draws would double it)
        upper = np.triu(draw, 1)
        draw = upper + np.swapaxes(upper, 1, 2)
    return adj[None] & (draw >= churn_prob)


def node_presence_schedule(topo, presence: np.ndarray) -> np.ndarray:
    """Derive an edge schedule from per-node presence: ``presence[t, m]`` is
    False while node m has left the network; all its edges (both directions)
    vanish for those ticks."""
    adj = _base_adjacency(topo)
    presence = np.asarray(presence, dtype=bool)
    if presence.ndim != 2 or presence.shape[1] != adj.shape[0]:
        raise ValueError(
            f"presence must be [T, {adj.shape[0]}], got {presence.shape}"
        )
    both = presence[:, :, None] & presence[:, None, :]
    return adj[None] & both


def node_join_leave(
    topo,
    num_ticks: int,
    leave_windows: dict[int, tuple[int, int]],
) -> np.ndarray:
    """Nodes leave and rejoin: ``leave_windows[node] = (t_leave, t_rejoin)``
    removes the node's edges for ticks in ``[t_leave, t_rejoin)``."""
    adj = _base_adjacency(topo)
    presence = np.ones((num_ticks, adj.shape[0]), dtype=bool)
    for node, (lo, hi) in leave_windows.items():
        presence[lo:hi, node] = False
    return node_presence_schedule(topo, presence)


def partition_and_heal(
    topo,
    num_ticks: int,
    groups: np.ndarray,
    *,
    cut_start: int,
    cut_end: int,
) -> np.ndarray:
    """Partition event: every cross-group edge is severed during ticks
    ``[cut_start, cut_end)``, then the network heals back to the base graph.
    ``groups[m]`` assigns each node to a partition component."""
    adj = _base_adjacency(topo)
    groups = np.asarray(groups)
    if groups.shape != (adj.shape[0],):
        raise ValueError(f"groups must be [{adj.shape[0]}], got {groups.shape}")
    if not 0 <= cut_start <= cut_end <= num_ticks:
        raise ValueError(
            f"need 0 <= cut_start <= cut_end <= {num_ticks}, got "
            f"[{cut_start}, {cut_end})"
        )
    same = groups[:, None] == groups[None, :]
    sched = static_schedule(adj, num_ticks)
    sched[cut_start:cut_end] &= same[None]
    return sched


SCENARIO_KINDS = ("static", "churn", "partition", "join_leave")


def scenario_schedule(
    kind: str | None,
    topo,
    num_ticks: int,
    *,
    seed: int = 0,
    churn_prob: float = 0.3,
) -> np.ndarray | None:
    """Named *schedule* presets — the single topology-dynamics definition
    behind `launch.train --net-schedule`, `launch.sweep --mode net`, and
    `benchmarks.net_bench`, so e.g. the partition window is identical
    everywhere.  (Channel conditions — drop/latency — are orthogonal and
    composed on top by each caller.)

    ``static`` (or None) returns None (run the base topology); ``churn``
    drops each undirected pair with ``churn_prob`` per tick; ``partition``
    severs the network into index-parity halves for ticks [T/4, T/2);
    ``join_leave`` removes the last node for the same window.
    """
    T = max(num_ticks, 1)
    if kind in (None, "static"):
        return None
    if kind == "churn":
        return edge_churn(topo, T, churn_prob, seed=seed)
    lo, hi = max(T // 4, 1), max(T // 2, 2)
    if kind == "partition":
        adj = _base_adjacency(topo)
        groups = np.arange(adj.shape[0]) % 2
        return partition_and_heal(topo, T, groups, cut_start=lo, cut_end=hi)
    if kind == "join_leave":
        adj = _base_adjacency(topo)
        return node_join_leave(topo, T, {adj.shape[0] - 1: (lo, hi)})
    raise ValueError(f"unknown scenario {kind!r}; options: {list(SCENARIO_KINDS)}")


def schedule_stats(schedule: np.ndarray) -> dict:
    """Diagnostics for a schedule: worst-case / mean in-degree over time and
    the fraction of base edges live on average.  Useful for checking that a
    scenario hasn't starved a screening rule of its Table-II minimum degree
    for longer than the configured staleness bound can bridge."""
    schedule = np.asarray(schedule, dtype=bool)
    in_deg = schedule.sum(axis=2)  # [T, M]
    union = schedule.any(axis=0)
    return {
        "num_ticks": int(schedule.shape[0]),
        "min_in_degree": int(in_deg.min()),
        "mean_in_degree": float(in_deg.mean()),
        "edge_uptime": float(schedule.sum() / max(union.sum() * schedule.shape[0], 1)),
    }
