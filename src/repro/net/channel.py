"""Per-link channel models for the unreliable-network runtime.

A channel decides, for every directed edge (i -> j) at every tick, whether the
message is dropped, how many ticks it spends in flight, and how much of the
payload survives a bandwidth cap.  Everything is sampled from a per-tick PRNG
key, so a fixed seed reproduces the exact same loss/latency trace — the
determinism the repro benchmarks and tests rely on.

All sampling is shape-static (``[M, M]`` tensors regardless of how many edges
are live), so channels compose with ``lax.scan`` over ticks without any
Python-level event loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Stochastic properties of every link.

    ``drop_prob``: i.i.d. per-edge per-tick probability the message is lost.
    ``latency_min``/``latency_max``: message delay in ticks, sampled uniformly
    from the inclusive integer range (0 means delivery the same tick it was
    sent, i.e. the synchronous ideal).
    ``bandwidth_cap``: if set, only the first ``bandwidth_cap`` coordinates of
    a payload are transmitted; the receiver substitutes its own current value
    for the untransmitted tail at screening time (partial-update semantics).
    """

    drop_prob: float = 0.0
    latency_min: int = 0
    latency_max: int = 0
    bandwidth_cap: int | None = None

    def __post_init__(self):
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob must be in [0, 1], got {self.drop_prob}")
        if self.latency_min < 0 or self.latency_max < self.latency_min:
            raise ValueError(
                f"need 0 <= latency_min <= latency_max, got "
                f"[{self.latency_min}, {self.latency_max}]"
            )
        if self.bandwidth_cap is not None and self.bandwidth_cap < 1:
            raise ValueError(f"bandwidth_cap must be >= 1, got {self.bandwidth_cap}")

    @classmethod
    def ideal(cls) -> "ChannelConfig":
        """Zero latency, zero drop, unlimited bandwidth — the channel under
        which the async runtime reproduces the synchronous path bit-for-bit."""
        return cls()

    @property
    def is_ideal(self) -> bool:
        return (
            self.drop_prob == 0.0
            and self.latency_max == 0
            and self.bandwidth_cap is None
        )

    @property
    def max_latency(self) -> int:
        return self.latency_max

    def sample(self, key: jax.Array, num_nodes: int) -> tuple[jax.Array, jax.Array]:
        """Draw one tick of channel events: ``(delay [M,M] int32, drop [M,M]
        bool)``.  Entries for non-edges are sampled too (shape-static) and
        simply never used."""
        k_delay, k_drop = jax.random.split(key)
        if self.latency_max > self.latency_min:
            delay = jax.random.randint(
                k_delay, (num_nodes, num_nodes), self.latency_min, self.latency_max + 1,
                dtype=jnp.int32,
            )
        else:
            delay = jnp.full((num_nodes, num_nodes), self.latency_min, jnp.int32)
        if self.drop_prob > 0.0:
            drop = jax.random.uniform(k_drop, (num_nodes, num_nodes)) < self.drop_prob
        else:
            drop = jnp.zeros((num_nodes, num_nodes), bool)
        return delay, drop

    def coord_mask(self, d: int) -> jax.Array | None:
        """[d] bool marking transmitted coordinates, or None when uncapped."""
        if self.bandwidth_cap is None or self.bandwidth_cap >= d:
            return None
        return jnp.arange(d) < self.bandwidth_cap
