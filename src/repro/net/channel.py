"""Per-link channel models for the unreliable-network runtime.

A channel decides, for every directed edge (i -> j) at every tick, whether the
message is dropped, how many ticks it spends in flight, and how much of the
payload survives a bandwidth cap.  Everything is sampled from a per-tick PRNG
key, so a fixed seed reproduces the exact same loss/latency trace — the
determinism the repro benchmarks and tests rely on.

All sampling is shape-static (``[M, M]`` tensors regardless of how many edges
are live), so channels compose with ``lax.scan`` over ticks without any
Python-level event loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Stochastic properties of every link.

    ``drop_prob``: i.i.d. per-edge per-tick probability the message is lost.
    ``latency_min``/``latency_max``: message delay in ticks, sampled uniformly
    from the inclusive integer range (0 means delivery the same tick it was
    sent, i.e. the synchronous ideal).
    ``bandwidth_cap``: if set, only ``bandwidth_cap`` coordinates of a payload
    are transmitted — a subset resampled from the per-tick PRNG, so no
    coordinate is systematically starved; the in-flight payload backfills the
    untransmitted rest with the receiver's iterate as of the send tick
    (partial-update semantics, fixed when the message leaves the sender).
    ``bits_per_tick``: if set, the link's serialization capacity — a message
    of ``wire_bits`` (the `repro.comm` codec's exact bits-on-wire) occupies
    the link for ``ceil(wire_bits / bits_per_tick)`` ticks, the excess over
    one tick added to the sampled propagation latency.  This is what makes
    compression *visible* to the simulated clock: an int8/top-k codeword
    clears a narrowband link ticks earlier than the float32 payload.
    """

    drop_prob: float = 0.0
    latency_min: int = 0
    latency_max: int = 0
    bandwidth_cap: int | None = None
    bits_per_tick: int | None = None

    def __post_init__(self):
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob must be in [0, 1], got {self.drop_prob}")
        if self.latency_min < 0 or self.latency_max < self.latency_min:
            raise ValueError(
                f"need 0 <= latency_min <= latency_max, got "
                f"[{self.latency_min}, {self.latency_max}]"
            )
        if self.bandwidth_cap is not None and self.bandwidth_cap < 1:
            raise ValueError(f"bandwidth_cap must be >= 1, got {self.bandwidth_cap}")
        if self.bits_per_tick is not None and self.bits_per_tick < 1:
            raise ValueError(f"bits_per_tick must be >= 1, got {self.bits_per_tick}")

    @classmethod
    def ideal(cls) -> "ChannelConfig":
        """Zero latency, zero drop, unlimited bandwidth — the channel under
        which the async runtime reproduces the synchronous path bit-for-bit."""
        return cls()

    @property
    def is_ideal(self) -> bool:
        return (
            self.drop_prob == 0.0
            and self.latency_max == 0
            and self.bandwidth_cap is None
            and self.bits_per_tick is None
        )

    @property
    def max_latency(self) -> int:
        return self.latency_max

    def serial_ticks(self, wire_bits):
        """EXTRA delay ticks a ``wire_bits``-bit message spends serializing
        onto the link (0 when uncapped or it fits in one tick).  ``wire_bits``
        may be a traced int32 (grid cells select codecs as data)."""
        if self.bits_per_tick is None or wire_bits is None:
            return 0
        if isinstance(wire_bits, int):
            return max((wire_bits + self.bits_per_tick - 1) // self.bits_per_tick - 1, 0)
        bpt = jnp.int32(self.bits_per_tick)
        return jnp.maximum((jnp.asarray(wire_bits, jnp.int32) + bpt - 1) // bpt - 1, 0)

    def max_total_latency(self, max_wire_bits: int | None) -> int:
        """Worst-case delivery delay — propagation plus serialization of the
        largest codeword the run can emit.  Sizes the mailbox ring."""
        wb = 0 if max_wire_bits is None else int(max_wire_bits)
        return self.latency_max + int(self.serial_ticks(wb) or 0)

    def sample(self, key: jax.Array, num_nodes: int) -> tuple[jax.Array, jax.Array]:
        """Draw one tick of channel events: ``(delay [M,M] int32, drop [M,M]
        bool)``.  Entries for non-edges are sampled too (shape-static) and
        simply never used."""
        k_delay, k_drop = jax.random.split(key)
        if self.latency_max > self.latency_min:
            delay = jax.random.randint(
                k_delay, (num_nodes, num_nodes), self.latency_min, self.latency_max + 1,
                dtype=jnp.int32,
            )
        else:
            delay = jnp.full((num_nodes, num_nodes), self.latency_min, jnp.int32)
        if self.drop_prob > 0.0:
            drop = jax.random.uniform(k_drop, (num_nodes, num_nodes)) < self.drop_prob
        else:
            drop = jnp.zeros((num_nodes, num_nodes), bool)
        return delay, drop

    def coord_mask(self, key: jax.Array, d: int) -> jax.Array | None:
        """[d] bool marking this tick's transmitted coordinates (exactly
        ``bandwidth_cap`` of them), or None when uncapped.

        The surviving subset is sampled fresh from the per-tick PRNG.  The
        previous implementation masked the *first* ``bandwidth_cap``
        coordinates every tick — a deterministic prefix that silently biased
        learning toward low-index coordinates (high-index ones never traveled
        and were permanently backfilled with the receiver's own value);
        ``tests/test_comm.py`` keeps the regression pinned.

        Implementation note: top-k over per-coordinate uniforms is a uniform
        k-subset draw, and ``lax.top_k``'s partial selection beats the full
        sort a ``random.permutation`` pays per tick at large d."""
        if self.bandwidth_cap is None or self.bandwidth_cap >= d:
            return None
        _, idx = jax.lax.top_k(jax.random.uniform(key, (d,)), self.bandwidth_cap)
        return jnp.zeros((d,), bool).at[idx].set(True)
