"""Run manifests: every run bracket writes a self-describing ``manifest.json``.

A run directory that outlives its process (a killed sweep, a CI artifact, a
months-old benchmark) is only as useful as the provenance it carries.  The
manifest records what produced the artifacts next to it: the exact argv, the
config (plus a stable digest for cheap equality checks across runs), the git
commit, the jax/jaxlib versions and backend/device kind, and wall-clock
brackets.  `write_manifest` is called at run *start* (so even a killed run is
self-describing) and again at run *end* with ``extra={"ended": ...}`` fields
merged in; `read_manifest` is the monitor/report/perfetto input.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import subprocess
import sys
import time
from typing import Any

MANIFEST_NAME = "manifest.json"


def _jsonable_config(config: Any):
    """Config -> JSON-able structure (dataclasses unpacked, everything else
    stringified) — stable enough to digest."""
    if config is None:
        return None
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    try:
        json.dumps(config)
        return config
    except TypeError:
        if isinstance(config, dict):
            return {str(k): _jsonable_config(v) for k, v in config.items()}
        if isinstance(config, (list, tuple)):
            return [_jsonable_config(v) for v in config]
        return repr(config)


def config_digest(config: Any) -> str | None:
    """sha256 of the stable-JSON config rendering (None config -> None)."""
    if config is None:
        return None
    blob = json.dumps(_jsonable_config(config), sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _git_sha() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], capture_output=True,
                             text=True, timeout=5,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def _environment() -> dict:
    env: dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    try:
        import jax

        env["jax"] = jax.__version__
        try:
            import jaxlib

            env["jaxlib"] = jaxlib.__version__
        except Exception:
            env["jaxlib"] = None
        env["backend"] = jax.default_backend()
        devs = jax.devices()
        env["device_kind"] = devs[0].device_kind if devs else None
        env["device_count"] = len(devs)
    except Exception:
        env["jax"] = None
    return env


def write_manifest(run_dir: str, *, kind: str | None = None, config: Any = None,
                   extra: dict | None = None) -> str:
    """Write (or update) ``run_dir/manifest.json``.  Re-writing merges on top
    of an existing manifest, so a run-end bracket extends the run-start one
    instead of erasing it (``kind=None`` keeps the start bracket's kind)."""
    os.makedirs(run_dir, exist_ok=True)
    path = os.path.join(run_dir, MANIFEST_NAME)
    manifest = read_manifest(run_dir) or {}
    if kind is not None:
        manifest["kind"] = kind
    else:
        manifest.setdefault("kind", "run")
    manifest.update({
        "argv": list(sys.argv),
        "time": time.time(),
        "time_iso": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "git_sha": _git_sha(),
        "environment": _environment(),
    })
    if config is not None:
        manifest["config"] = _jsonable_config(config)
        manifest["config_digest"] = config_digest(config)
    if extra:
        manifest.update(extra)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True, default=repr)
    os.replace(tmp, path)  # atomic: a killed run never leaves a torn manifest
    return path


def read_manifest(run_dir: str) -> dict | None:
    """``run_dir/manifest.json`` as a dict, or None when absent/torn."""
    path = os.path.join(run_dir, MANIFEST_NAME)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
