"""repro.obs — observability for the BRIDGE stack.

`TraceSpec`-driven in-graph forensics (`repro.obs.trace`), the async JSONL
event log (`repro.obs.events`), live per-tick metric rings + threshold
alerting (`repro.obs.metrics`), run manifests (`repro.obs.manifest`), the
Perfetto/Chrome-trace exporter (``python -m repro.obs.perfetto``), the live
run monitor (``python -m repro.obs.monitor``), and the report renderer
(``python -m repro.obs.report``).  Tracing AND metrics are OFF by default
everywhere (``trace=None`` / ``metrics=None``) and bit-inert when on — see
``tests/test_obs.py`` / ``tests/test_metrics.py``.
"""
from repro.obs.events import EventLog, read_events
from repro.obs.manifest import read_manifest, write_manifest
from repro.obs.metrics import (
    AlertEngine,
    AlertRules,
    MetricSpec,
    MetricState,
    MetricWriter,
    read_metrics,
)
from repro.obs.trace import (
    TraceSpec,
    TraceState,
    init_state,
    ranking_auc,
    sender_grid,
    staleness_of,
    summarize,
    update,
)

__all__ = [
    "EventLog",
    "read_events",
    "AlertEngine",
    "AlertRules",
    "MetricSpec",
    "MetricState",
    "MetricWriter",
    "read_metrics",
    "read_manifest",
    "write_manifest",
    "TraceSpec",
    "TraceState",
    "init_state",
    "ranking_auc",
    "sender_grid",
    "staleness_of",
    "summarize",
    "update",
]
