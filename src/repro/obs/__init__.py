"""repro.obs — observability for the BRIDGE stack.

`TraceSpec`-driven in-graph forensics (`repro.obs.trace`), the async JSONL
event log (`repro.obs.events`), and the report renderer
(``python -m repro.obs.report``).  Tracing is OFF by default everywhere
(``trace=None``) and bit-inert when on — see ``tests/test_obs.py``.
"""
from repro.obs.events import EventLog, read_events
from repro.obs.trace import (
    TraceSpec,
    TraceState,
    init_state,
    ranking_auc,
    sender_grid,
    staleness_of,
    summarize,
    update,
)

__all__ = [
    "EventLog",
    "read_events",
    "TraceSpec",
    "TraceState",
    "init_state",
    "ranking_auc",
    "sender_grid",
    "staleness_of",
    "summarize",
    "update",
]
