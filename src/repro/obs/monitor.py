"""Live run monitor: ``python -m repro.obs.monitor RUN_DIR``.

A stdlib-only (http.server) dashboard over the artifacts a running — or
killed — run leaves in its directory: it tails ``metrics.jsonl`` for the
per-tick scalar streams, re-evaluates the same `repro.obs.metrics.AlertEngine`
the writer runs (so alerts fire even for runs that died before emitting
them), and serves a single-file dark HTML dashboard plus three JSON
endpoints:

* ``/``                               — the dashboard
* ``/api/run``                        — manifest, tags, alert list, totals
* ``/api/metrics?after=T&tag=X``      — metric rows (incremental by tick)
* ``/api/events?offset=N``            — event records (incremental by index)

The tailer remembers its file offset, so each poll reads only appended
bytes; a ``metrics.jsonl`` being written concurrently is safe to tail
(truncated final lines are skipped and re-read on the next poll).

``--once`` prints a JSON snapshot and exits — the CI smoke path and a quick
"is it diverging?" check over ssh without holding a port open.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.obs.manifest import read_manifest
from repro.obs.metrics import AlertEngine, AlertRules


class RunTail:
    """Incremental reader over a run directory's JSONL artifacts.

    ``refresh()`` reads bytes appended since the last call, parses complete
    lines, feeds new metric rows through the alert engine, and leaves a
    partial trailing line in the offset for the next round.
    """

    def __init__(self, run_dir: str, *, rules: AlertRules | None = None,
                 max_rows: int = 200_000):
        self.run_dir = run_dir
        self.metrics_path = os.path.join(run_dir, "metrics.jsonl")
        self.events_path = os.path.join(run_dir, "events.jsonl")
        self.rows: list[dict] = []
        self.events: list[dict] = []
        self.alerts: list[dict] = []
        self._offsets = {self.metrics_path: 0, self.events_path: 0}
        self._engine = AlertEngine(rules)
        self._max_rows = max_rows
        self._lock = threading.Lock()

    def _read_new_lines(self, path: str) -> list[dict]:
        if not os.path.exists(path):
            return []
        out = []
        with open(path, "rb") as f:
            f.seek(self._offsets[path])
            while True:
                pos = f.tell()
                line = f.readline()
                if not line:
                    break
                if not line.endswith(b"\n"):
                    # torn tail of a live writer: re-read it next refresh
                    f.seek(pos)
                    break
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
            self._offsets[path] = f.tell()
        return out

    def refresh(self) -> None:
        with self._lock:
            for row in self._read_new_lines(self.metrics_path):
                self.rows.append(row)
                self.alerts.extend(
                    self._engine.feed(row.get("tag", "train"), row))
            if len(self.rows) > self._max_rows:
                self.rows = self.rows[-self._max_rows:]
            for rec in self._read_new_lines(self.events_path):
                self.events.append(rec)
                # alerts the run emitted itself (writer-side engine); its
                # stream tag rides in `stream` (the record's "tag" field is
                # the event name "obs.alert")
                if rec.get("tag") == "obs.alert":
                    key = (rec.get("stream", ""), rec.get("kind", ""))
                    if key not in {(a.get("tag", ""), a.get("kind", ""))
                                   for a in self.alerts}:
                        a = {k: v for k, v in rec.items()
                             if k not in ("wall", "time", "tag")}
                        a["tag"] = rec.get("stream", "")
                        a.pop("stream", None)
                        self.alerts.append(a)

    def tags(self) -> list[str]:
        return sorted({r.get("tag", "train") for r in self.rows})

    def snapshot(self) -> dict:
        self.refresh()
        last = self.rows[-1] if self.rows else None
        return {
            "run_dir": self.run_dir,
            "manifest": read_manifest(self.run_dir),
            "tags": self.tags(),
            "rows": len(self.rows),
            "events": len(self.events),
            "alerts": self.alerts,
            "last": last,
        }

    def metrics_since(self, after: int, tag: str | None) -> list[dict]:
        self.refresh()
        return [r for r in self.rows
                if int(r.get("tick", -1)) > after
                and (tag is None or r.get("tag") == tag)]

    def events_since(self, offset: int) -> tuple[list[dict], int]:
        self.refresh()
        return self.events[offset:], len(self.events)


def _handler_for(tail: RunTail):
    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):  # quiet
            pass

        def _send(self, body: bytes, ctype: str, code: int = 200) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _json(self, obj, code: int = 200) -> None:
            self._send(json.dumps(obj).encode(), "application/json", code)

        def do_GET(self):
            url = urlparse(self.path)
            q = parse_qs(url.query)
            try:
                if url.path == "/":
                    self._send(DASHBOARD_HTML.encode(), "text/html; charset=utf-8")
                elif url.path == "/api/run":
                    self._json(tail.snapshot())
                elif url.path == "/api/metrics":
                    after = int(q.get("after", ["-1"])[0])
                    tag = q.get("tag", [None])[0]
                    self._json({"rows": tail.metrics_since(after, tag)})
                elif url.path == "/api/events":
                    offset = int(q.get("offset", ["0"])[0])
                    events, total = tail.events_since(offset)
                    self._json({"events": events, "total": total})
                else:
                    self._json({"error": "not found"}, 404)
            except Exception as e:  # keep the monitor alive over bad input
                self._json({"error": str(e)}, 500)

    return Handler


def serve(run_dir: str, *, host: str = "127.0.0.1", port: int = 8765,
          rules: AlertRules | None = None) -> ThreadingHTTPServer:
    """Build (but do not run) the monitor server — ``serve_forever`` it, or
    drive it from a test thread and ``shutdown()`` when done."""
    tail = RunTail(run_dir, rules=rules)
    tail.refresh()
    server = ThreadingHTTPServer((host, port), _handler_for(tail))
    server.tail = tail  # for tests / callers
    return server


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        description="Live dashboard over a run directory's metrics.jsonl / "
                    "events.jsonl / manifest.json")
    p.add_argument("run_dir")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8765)
    p.add_argument("--once", action="store_true",
                   help="print a JSON snapshot and exit (no server)")
    p.add_argument("--wire-budget-bytes", type=float, default=None,
                   help="alert when a tag's cumulative wire bytes cross this")
    args = p.parse_args(argv)
    rules = AlertRules(wire_budget_bytes=args.wire_budget_bytes)
    if args.once:
        tail = RunTail(args.run_dir, rules=rules)
        # BrokenPipeError: `--once | head` is a legitimate use
        with contextlib.suppress(BrokenPipeError):
            print(json.dumps(tail.snapshot(), indent=2, default=repr))
        return 0
    server = serve(args.run_dir, host=args.host, port=args.port, rules=rules)
    print(f"monitoring {args.run_dir} at http://{args.host}:{server.server_address[1]}/")
    try:
        # ctrl-C is the supported shutdown; fall through to close
        with contextlib.suppress(KeyboardInterrupt):
            server.serve_forever()
    finally:
        server.server_close()
    return 0


# ---------------------------------------------------------------------------
# The dashboard: one dark-mode HTML file, inline vanilla JS + SVG.
#
# Colors are the reference dataviz palette's dark-mode values (first three
# categorical slots — the subset documented to validate all-pairs on the
# dark surface), status colors reserved for the alert feed, chart chrome
# from the same reference (surface #1a1a19, page #0d0d0d, muted ink
# #898781, hairline grid #2c2c2a).  Each chart draws at most three series;
# identity is carried by the legend + direct labels, not color alone.
# ---------------------------------------------------------------------------

DASHBOARD_HTML = """<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<title>repro run monitor</title>
<style>
  :root {
    color-scheme: dark;
    --page: #0d0d0d; --surface: #1a1a19;
    --ink: #ffffff; --ink-2: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --axis: #383835;
    --s1: #3987e5; --s2: #d95926; --s3: #199e70;
    --crit: #d03b3b; --warn: #fab219; --good: #0ca30c; --serious: #ec835a;
    --ring: rgba(255,255,255,0.10);
  }
  body { background: var(--page); color: var(--ink-2); margin: 0;
         font: 13px/1.5 system-ui, -apple-system, "Segoe UI", sans-serif; }
  header { padding: 12px 20px; border-bottom: 1px solid var(--ring);
           display: flex; gap: 16px; align-items: baseline; flex-wrap: wrap; }
  header h1 { font-size: 15px; color: var(--ink); margin: 0; font-weight: 600; }
  header .meta { color: var(--muted); font-size: 12px; }
  .filters { padding: 10px 20px; display: flex; gap: 12px; align-items: center; }
  .filters select { background: var(--surface); color: var(--ink-2);
                    border: 1px solid var(--ring); border-radius: 6px; padding: 4px 8px; }
  main { display: grid; grid-template-columns: repeat(auto-fit, minmax(380px, 1fr));
         gap: 14px; padding: 8px 20px 20px; }
  .card { background: var(--surface); border: 1px solid var(--ring);
          border-radius: 10px; padding: 12px 14px; }
  .card h2 { font-size: 12px; font-weight: 600; color: var(--ink);
             margin: 0 0 2px; }
  .card .sub { color: var(--muted); font-size: 11px; margin: 0 0 8px; }
  .legend { display: flex; gap: 14px; font-size: 11px; color: var(--ink-2);
            margin: 4px 0 0; }
  .legend .sw { display: inline-block; width: 10px; height: 10px;
                border-radius: 3px; margin-right: 5px; vertical-align: -1px; }
  svg text { fill: var(--muted); font: 10px system-ui, sans-serif; }
  svg .tick-label { font-variant-numeric: tabular-nums; }
  .tooltip { position: fixed; pointer-events: none; background: #222221;
             border: 1px solid var(--ring); border-radius: 6px; padding: 6px 9px;
             font-size: 11px; color: var(--ink); display: none; z-index: 10;
             font-variant-numeric: tabular-nums; }
  #alerts .alert { display: flex; gap: 8px; align-items: baseline;
                   padding: 5px 0; border-bottom: 1px solid var(--grid); }
  #alerts .alert:last-child { border-bottom: none; }
  .badge { font-weight: 600; font-size: 11px; }
  .badge::before { margin-right: 4px; }
  .badge.critical { color: var(--crit); } .badge.critical::before { content: "\\2716"; }
  .badge.warning  { color: var(--warn); } .badge.warning::before  { content: "\\26A0"; }
  .badge.ok       { color: var(--good); } .badge.ok::before       { content: "\\2714"; }
  .empty { color: var(--muted); font-size: 12px; padding: 8px 0; }
</style></head><body>
<header>
  <h1>repro run monitor</h1>
  <span class="meta" id="run-meta">loading…</span>
</header>
<div class="filters">
  <label for="tag">stream</label>
  <select id="tag"></select>
  <span class="meta" id="row-count"></span>
</div>
<main>
  <div class="card"><h2>Loss</h2><p class="sub">honest-mean loss per tick</p>
    <div id="c-loss"></div></div>
  <div class="card"><h2>Gradient norm</h2><p class="sub">honest-mean per-node l2</p>
    <div id="c-grad"></div></div>
  <div class="card"><h2>Consensus distance</h2>
    <p class="sub">max honest deviation from the honest mean</p>
    <div id="c-cons"></div></div>
  <div class="card"><h2>Message staleness</h2>
    <p class="sub">delivered-message age quantiles (net paths)</p>
    <div id="c-stale"></div>
    <div class="legend">
      <span><span class="sw" style="background:var(--s1)"></span>p50</span>
      <span><span class="sw" style="background:var(--s2)"></span>p90</span>
    </div></div>
  <div class="card"><h2>Screening</h2>
    <p class="sub">trim + trust-eviction fractions</p>
    <div id="c-screen"></div>
    <div class="legend">
      <span><span class="sw" style="background:var(--s1)"></span>trim_frac</span>
      <span><span class="sw" style="background:var(--s2)"></span>evicted_frac</span>
    </div></div>
  <div class="card"><h2>Alerts</h2>
    <p class="sub">threshold rules over the metric stream</p>
    <div id="alerts"><div class="empty">none</div></div></div>
</main>
<div class="tooltip" id="tip"></div>
<script>
"use strict";
const COLORS = ["var(--s1)", "var(--s2)", "var(--s3)"];
const state = { rows: [], tag: null, tags: [] };

function fmt(v) {
  if (v === null || v === undefined) return "–";
  const a = Math.abs(v);
  if (a !== 0 && (a < 1e-3 || a >= 1e5)) return v.toExponential(2);
  return +v.toFixed(4);
}

// Minimal SVG line chart: series = [{name, color, pts: [[x, y], ...]}].
// Hover layer: vertical crosshair + nearest-tick tooltip (interaction.md).
function lineChart(el, series, width, height) {
  el.innerHTML = "";
  const pad = { l: 44, r: 10, t: 8, b: 20 };
  const live = series.filter(s => s.pts.length > 0);
  if (!live.length) { el.innerHTML = '<div class="empty">no data</div>'; return; }
  const xs = live.flatMap(s => s.pts.map(p => p[0]));
  const ys = live.flatMap(s => s.pts.map(p => p[1]));
  let x0 = Math.min(...xs), x1 = Math.max(...xs);
  let y0 = Math.min(...ys), y1 = Math.max(...ys);
  if (x0 === x1) x1 = x0 + 1;
  if (y0 === y1) { y0 -= 0.5; y1 += 0.5; }
  const X = x => pad.l + (x - x0) / (x1 - x0) * (width - pad.l - pad.r);
  const Y = y => height - pad.b - (y - y0) / (y1 - y0) * (height - pad.t - pad.b);
  const ns = "http://www.w3.org/2000/svg";
  const svg = document.createElementNS(ns, "svg");
  svg.setAttribute("viewBox", `0 0 ${width} ${height}`);
  svg.style.width = "100%";
  // recessive grid: 3 hairlines + tick labels
  for (let i = 0; i <= 2; i++) {
    const yv = y0 + (y1 - y0) * i / 2, gy = Y(yv);
    const ln = document.createElementNS(ns, "line");
    ln.setAttribute("x1", pad.l); ln.setAttribute("x2", width - pad.r);
    ln.setAttribute("y1", gy); ln.setAttribute("y2", gy);
    ln.setAttribute("stroke", i === 0 ? "var(--axis)" : "var(--grid)");
    svg.appendChild(ln);
    const tx = document.createElementNS(ns, "text");
    tx.setAttribute("x", pad.l - 6); tx.setAttribute("y", gy + 3);
    tx.setAttribute("text-anchor", "end"); tx.setAttribute("class", "tick-label");
    tx.textContent = fmt(yv);
    svg.appendChild(tx);
  }
  [x0, x1].forEach((xv, i) => {
    const tx = document.createElementNS(ns, "text");
    tx.setAttribute("x", X(xv)); tx.setAttribute("y", height - 6);
    tx.setAttribute("text-anchor", i ? "end" : "start");
    tx.setAttribute("class", "tick-label");
    tx.textContent = Math.round(xv);
    svg.appendChild(tx);
  });
  for (const s of live) {
    const path = document.createElementNS(ns, "path");
    path.setAttribute("d", s.pts.map((p, i) =>
      `${i ? "L" : "M"}${X(p[0]).toFixed(1)},${Y(p[1]).toFixed(1)}`).join(""));
    path.setAttribute("fill", "none");
    path.setAttribute("stroke", s.color);
    path.setAttribute("stroke-width", "2");
    path.setAttribute("stroke-linejoin", "round");
    svg.appendChild(path);
  }
  // crosshair + tooltip
  const cross = document.createElementNS(ns, "line");
  cross.setAttribute("y1", pad.t); cross.setAttribute("y2", height - pad.b);
  cross.setAttribute("stroke", "var(--muted)"); cross.setAttribute("stroke-dasharray", "3 3");
  cross.style.display = "none";
  svg.appendChild(cross);
  const tip = document.getElementById("tip");
  svg.addEventListener("mousemove", ev => {
    const r = svg.getBoundingClientRect();
    const mx = (ev.clientX - r.left) / r.width * width;
    const tickX = x0 + (mx - pad.l) / (width - pad.l - pad.r) * (x1 - x0);
    let best = null, bd = Infinity;
    for (const s of live) for (const p of s.pts) {
      const d = Math.abs(p[0] - tickX);
      if (d < bd) { bd = d; best = p[0]; }
    }
    if (best === null) return;
    cross.setAttribute("x1", X(best)); cross.setAttribute("x2", X(best));
    cross.style.display = "";
    const lines = [`tick ${best}`];
    for (const s of live) {
      const p = s.pts.find(p => p[0] === best);
      if (p) lines.push(`${s.name}: ${fmt(p[1])}`);
    }
    tip.innerHTML = lines.join("<br>");
    tip.style.display = "block";
    tip.style.left = (ev.clientX + 14) + "px";
    tip.style.top = (ev.clientY + 10) + "px";
  });
  svg.addEventListener("mouseleave", () => {
    cross.style.display = "none"; tip.style.display = "none";
  });
  el.appendChild(svg);
}

function pts(rows, col) {
  return rows.filter(r => r[col] !== null && r[col] !== undefined)
             .map(r => [r.tick, r[col]]);
}

function redraw() {
  const rows = state.rows.filter(r => r.tag === state.tag);
  document.getElementById("row-count").textContent =
    rows.length ? `${rows.length} ticks (last: ${rows[rows.length - 1].tick})` : "no rows yet";
  const W = 420, H = 170;
  lineChart(document.getElementById("c-loss"),
    [{ name: "loss", color: COLORS[0], pts: pts(rows, "loss") }], W, H);
  lineChart(document.getElementById("c-grad"),
    [{ name: "grad_norm", color: COLORS[0], pts: pts(rows, "grad_norm") }], W, H);
  lineChart(document.getElementById("c-cons"),
    [{ name: "consensus_dist", color: COLORS[0], pts: pts(rows, "consensus_dist") }], W, H);
  lineChart(document.getElementById("c-stale"), [
    { name: "p50", color: COLORS[0], pts: pts(rows, "stale_p50") },
    { name: "p90", color: COLORS[1], pts: pts(rows, "stale_p90") },
  ], W, H);
  lineChart(document.getElementById("c-screen"), [
    { name: "trim_frac", color: COLORS[0], pts: pts(rows, "trim_frac") },
    { name: "evicted_frac", color: COLORS[1], pts: pts(rows, "evicted_frac") },
  ], W, H);
}

function renderAlerts(alerts) {
  const el = document.getElementById("alerts");
  if (!alerts.length) { el.innerHTML = '<div class="empty">none</div>'; return; }
  el.innerHTML = alerts.map(a => {
    const sev = a.kind === "divergence" ? "critical" : "warning";
    return `<div class="alert"><span class="badge ${sev}">${a.kind}</span>` +
           `<span>${a.tag} @ tick ${a.tick}</span></div>`;
  }).join("");
}

async function poll() {
  try {
    const run = await (await fetch("/api/run")).json();
    const m = run.manifest || {};
    const env = m.environment || {};
    document.getElementById("run-meta").textContent =
      `${run.run_dir} · ${m.kind || "run"} · git ${(m.git_sha || "?").slice(0, 10)}` +
      ` · jax ${env.jax || "?"} on ${env.backend || "?"}` +
      ` · ${run.rows} rows · ${run.alerts.length} alerts`;
    renderAlerts(run.alerts);
    const sel = document.getElementById("tag");
    if (run.tags.join() !== state.tags.join()) {
      state.tags = run.tags;
      sel.innerHTML = run.tags.map(t => `<option>${t}</option>`).join("");
      if (!state.tag || !run.tags.includes(state.tag)) state.tag = run.tags[0] || null;
      sel.value = state.tag;
    }
    if (state.tag) {
      const res = await (await fetch(`/api/metrics?tag=${encodeURIComponent(state.tag)}`)).json();
      state.rows = res.rows;
      redraw();
    }
  } catch (e) { /* server restarting: retry on the next tick */ }
}

document.getElementById("tag").addEventListener("change", ev => {
  state.tag = ev.target.value;
  poll();
});
poll();
setInterval(poll, 2000);
</script></body></html>
"""


if __name__ == "__main__":
    raise SystemExit(main())
