"""Structured host-side event log: an async JSONL writer (repro.obs).

The jitted scan cannot write files; the host-side loops around it can — the
grid engine's chunk boundaries, the breakdown engine's probe rounds, and the
launch CLIs' run brackets all emit here.  Writes go through a queue drained
by a daemon thread so emitting never blocks the dispatch loop.

Every record is one JSON line ``{"tag": ..., "wall": <s since log open>,
"time": <unix>, **fields}``.  Stable tags (the report renderer and CI
artifacts key on these):

* ``run.start`` / ``run.end``      — one run bracket (engine or CLI)
* ``grid.chunk``                   — one compiled chunk of a chunked grid run
* ``breakdown.round``              — one (rule, adversary, b) probe round
* ``obs.divergence``               — a cell's NaN sentinel fired (first tick)
* ``profile.capture``              — a jax.profiler trace was written
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time

import numpy as np

_SENTINEL = object()


def _jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    return str(x)


class EventLog:
    """Append-only JSONL event stream; safe to emit from any thread.

    Writes are batched: the drain thread flushes at most every
    ``flush_interval`` seconds (and whenever its queue runs dry, and on
    close), so a high-rate chunk-event stream costs one buffered ``write``
    per record instead of one ``fsync``-ish flush each — the dispatch loop
    never serializes on the log.
    """

    def __init__(self, path: str, *, flush_interval: float = 0.2):
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self.path = path
        self._f = open(path, "a")  # noqa: SIM115  (lives until .close())
        self._t0 = time.perf_counter()
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        self._flush_interval = max(float(flush_interval), 0.0)
        self._thread = threading.Thread(target=self._drain, daemon=True,
                                        name="obs-eventlog")
        self._thread.start()

    def emit(self, tag: str, **fields) -> None:
        if self._closed:
            return
        rec = {"tag": str(tag), "wall": round(time.perf_counter() - self._t0, 6),
               "time": time.time()}
        rec.update(fields)
        self._q.put(rec)

    def _drain(self) -> None:
        last_flush = time.perf_counter()
        while True:
            try:
                rec = self._q.get(timeout=self._flush_interval or 0.05)
            except queue.Empty:
                self._f.flush()
                last_flush = time.perf_counter()
                continue
            if rec is _SENTINEL:
                break
            self._f.write(json.dumps(rec, sort_keys=True, default=_jsonable) + "\n")
            now = time.perf_counter()
            if self._q.empty() or now - last_flush >= self._flush_interval:
                self._f.flush()
                last_flush = now
        self._f.flush()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(_SENTINEL)
        self._thread.join(timeout=10.0)
        if self._thread.is_alive():
            # the drain thread is still writing (slow disk, huge backlog):
            # closing the file here would race it into "I/O operation on
            # closed file" — leave the fd to the daemon thread instead
            return
        self._f.close()

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: str) -> list[dict]:
    """Parse an event log back into records (report input); tolerates a
    truncated final line from an interrupted run."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return records
