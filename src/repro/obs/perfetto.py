"""Perfetto / Chrome-trace export: one ``trace.json`` per run directory.

``chrome.tracing`` and https://ui.perfetto.dev render the Trace Event
Format — a flat list of timestamped events.  This module converts the run
artifacts the obs layer already writes (``events.jsonl`` host-side event
records, ``metrics.jsonl`` per-tick scalar rows, ``manifest.json``) into
that format, so a whole run — chunk dispatches, grid compilation chunks,
alerts, divergences, and every metric stream as a counter track — lands on
one zoomable timeline:

* events carrying a duration (``wall_s`` from blocking grid chunks and run
  brackets, ``dispatch_s`` from non-blocking ``train.chunk`` dispatches)
  become complete ("X") slices ending at their record's wall time;
* all other events become instants ("i") on their source track;
* metric rows become counter ("C") tracks named ``<tag>/<column>``;
* the manifest rides in ``otherData`` (what run is this, exactly?).

Timestamps are each record's ``wall`` field (seconds since its log opened)
scaled to microseconds.  The event log and metric writer are opened at the
same run bracket, so their clocks agree to within process-startup noise —
good enough for a timeline whose slices are milliseconds wide.

CLI: ``python -m repro.obs.perfetto RUN_DIR [--out trace.json]``.
"""
from __future__ import annotations

import argparse
import json
import os
from collections.abc import Iterable
from typing import Any

# event tags -> the field holding their duration in seconds (everything
# else renders as an instant)
_DURATION_FIELDS = ("wall_s", "dispatch_s")
# record fields that are identity/timing, not interesting args
_META_FIELDS = {"tag", "wall", "time"}

_PID = 1


def _track_of(rec: dict) -> str:
    """The thread-track an event record belongs to."""
    tag = rec.get("tag", "event")
    if tag == "train.chunk":
        # run_chunks events carry the metric stream's tag as `train_tag`
        # (the record's own "tag" field is the event name)
        return f"train/{rec.get('train_tag', 'train')}"
    if tag.startswith("grid."):
        return "grid"
    if tag.startswith("breakdown."):
        return "breakdown"
    if tag.startswith("obs.") or tag.startswith("profile."):
        return "alerts" if tag == "obs.alert" else "obs"
    return "run"


def _event_entries(events: Iterable[dict], tids: dict) -> list[dict]:
    out = []
    for rec in events:
        tag = rec.get("tag", "event")
        wall = float(rec.get("wall", 0.0))
        track = _track_of(rec)
        tid = tids.setdefault(track, len(tids) + 1)
        args = {k: v for k, v in rec.items() if k not in _META_FIELDS}
        dur = None
        for f in _DURATION_FIELDS:
            if f in rec:
                try:
                    dur = float(rec[f])
                except (TypeError, ValueError):
                    dur = None
                break
        if dur is not None and dur >= 0.0:
            out.append({
                "name": tag, "ph": "X", "pid": _PID, "tid": tid,
                "ts": (wall - dur) * 1e6, "dur": dur * 1e6, "args": args,
            })
        else:
            out.append({
                "name": tag, "ph": "i", "s": "t", "pid": _PID, "tid": tid,
                "ts": wall * 1e6, "args": args,
            })
    return out


def _counter_entries(rows: Iterable[dict]) -> list[dict]:
    out = []
    for rec in rows:
        tag = rec.get("tag", "train")
        wall = float(rec.get("wall", 0.0))
        for col, v in rec.items():
            if col in _META_FIELDS or col == "tick" or v is None:
                continue
            if not isinstance(v, (int, float)):
                continue
            out.append({
                "name": f"{tag}/{col}", "ph": "C", "pid": _PID, "tid": 0,
                "ts": wall * 1e6, "args": {col: v},
            })
    return out


def chrome_trace(events: Iterable[dict] | None = None,
                 metrics_rows: Iterable[dict] | None = None,
                 manifest: dict | None = None) -> dict:
    """Assemble a Trace Event Format dict from parsed run artifacts."""
    tids: dict[str, int] = {}
    trace_events: list[dict] = []
    if events:
        trace_events.extend(_event_entries(events, tids))
    if metrics_rows:
        trace_events.extend(_counter_entries(metrics_rows))
    # metadata: name the process and each thread track
    meta: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": "repro"},
    }]
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": track},
        })
    trace: dict[str, Any] = {
        "traceEvents": meta + sorted(trace_events, key=lambda e: e["ts"]),
        "displayTimeUnit": "ms",
    }
    if manifest:
        trace["otherData"] = manifest
    return trace


def export(run_dir: str, out: str | None = None) -> str:
    """Convert a run directory's artifacts into ``trace.json`` (returns the
    written path).  Missing inputs are skipped — a killed run with only a
    partial ``metrics.jsonl`` still renders."""
    from repro.obs.events import read_events
    from repro.obs.manifest import read_manifest
    from repro.obs.metrics import read_metrics

    events_path = os.path.join(run_dir, "events.jsonl")
    events = read_events(events_path) if os.path.exists(events_path) else []
    rows = read_metrics(os.path.join(run_dir, "metrics.jsonl"))
    trace = chrome_trace(events, rows, read_manifest(run_dir))
    out = out or os.path.join(run_dir, "trace.json")
    with open(out, "w") as f:
        json.dump(trace, f)
    return out


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        description="Export a run directory's events/metrics/manifest as a "
                    "Perfetto/chrome-tracing trace.json")
    p.add_argument("run_dir", help="directory holding events.jsonl / metrics.jsonl")
    p.add_argument("--out", default=None, help="output path (default RUN_DIR/trace.json)")
    args = p.parse_args(argv)
    path = export(args.run_dir, args.out)
    with open(path) as f:
        n = len(json.load(f).get("traceEvents", []))
    print(f"wrote {path} ({n} trace events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
