"""Forensics report renderer: ``python -m repro.obs.report RUN_DIR``.

Consumes what a traced run leaves on disk — ``obs_summary.json`` (per-cell
`repro.obs.trace.summarize` records) and/or ``events.jsonl`` (the
`repro.obs.events.EventLog` stream) — and renders the per-run summary the
ISSUE asks for: top-suspect edges, survival-rate-by-rule tables, divergence
sentinels, and the phase/wall-time breakdown.  Pure host-side text; the CI
obs-smoke job uploads its output next to the raw artifacts.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.obs.events import read_events


def _fmt_row(cols, widths):
    return "  ".join(str(c).ljust(w) for c, w in zip(cols, widths, strict=True))


def _table(header, rows) -> list[str]:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(header)]
    lines = [_fmt_row(header, widths), _fmt_row(["-" * w for w in widths], widths)]
    lines += [_fmt_row(r, widths) for r in rows]
    return lines


def render(summary: dict | None = None, events: list[dict] | None = None,
           *, top: int = 10, manifest: dict | None = None,
           metrics_rows: list[dict] | None = None) -> str:
    """The full text report; every input may be None."""
    out: list[str] = ["== BRIDGE observability report =="]

    if manifest:
        env = manifest.get("environment") or {}
        out.append("-- run manifest --")
        out.append(f"kind: {manifest.get('kind', '?')}  "
                   f"git: {(manifest.get('git_sha') or '?')[:12]}  "
                   f"config: {manifest.get('config_digest', '?')}")
        out.append(f"jax {env.get('jax', '?')} / jaxlib {env.get('jaxlib', '?')} "
                   f"on {env.get('backend', '?')} "
                   f"({env.get('device_kind', '?')} x{env.get('device_count', '?')})")
        argv = manifest.get("argv")
        if argv:
            out.append("argv: " + " ".join(str(a) for a in argv))
        out.append("")

    if metrics_rows:
        out.append("-- live metric streams (metrics.jsonl) --")
        by_tag: dict[str, list[dict]] = {}
        for r in metrics_rows:
            by_tag.setdefault(r.get("tag", "train"), []).append(r)
        mrows = []
        for tag, rows in sorted(by_tag.items()):
            last = rows[-1]
            bad = sum(1 for r in rows if (r.get("nonfinite") or 0.0) > 0.0)
            mrows.append((
                tag, len(rows), last.get("tick"),
                "n/a" if last.get("loss") is None else f"{last['loss']:.4g}",
                "n/a" if last.get("consensus_dist") is None
                else f"{last['consensus_dist']:.4g}",
                bad,
            ))
        out += _table(("stream", "rows", "last_tick", "last_loss",
                       "last_consensus", "nonfinite_rows"), mrows)
        out.append("")

    if summary is not None:
        cells = summary.get("cells", [])
        out.append(f"cells traced: {len(cells)}")

        diverged = [(c.get("tag", f"cell{i}"), c["first_bad_tick"])
                    for i, c in enumerate(cells) if c.get("first_bad_tick") is not None]
        out.append("")
        if diverged:
            out.append("-- divergence sentinel (first non-finite tick) --")
            out += _table(("cell", "first_bad_tick"), diverged)
        else:
            out.append("-- divergence sentinel: all traced cells stayed finite --")

        surv_rows = []
        for i, c in enumerate(cells):
            s = c.get("survival")
            if not s:
                continue
            auc = c.get("auc_byzantine_edges")
            surv_rows.append((
                c.get("tag", f"cell{i}"), c.get("rule", "?"),
                f"{s['byz_trim_freq']:.3f}", f"{s['honest_trim_freq']:.3f}",
                "n/a" if auc is None else f"{auc:.3f}",
            ))
        if surv_rows:
            out.append("")
            out.append("-- screening survival by cell (trim frequency; higher = more suspected) --")
            out += _table(("cell", "rule", "byz_trim", "honest_trim", "auc"), surv_rows)

        edge_rows = []
        for i, c in enumerate(cells):
            for e in c.get("top_edges", []):
                edge_rows.append((e["trim_freq"], c.get("tag", f"cell{i}"),
                                  e["receiver"], e["sender"], e["seen"],
                                  e.get("byzantine")))
        if edge_rows:
            edge_rows.sort(key=lambda r: -r[0])
            out.append("")
            out.append(f"-- top {top} suspect edges (by trim frequency) --")
            out += _table(
                ("trim_freq", "cell", "receiver", "sender", "seen", "byzantine"),
                [(f"{f:.3f}", tag, r, s, int(n), b)
                 for f, tag, r, s, n, b in edge_rows[:top]])

    if events:
        out.append("")
        out.append("-- event stream / wall-time breakdown --")
        by_tag: dict[str, dict] = {}
        for rec in events:
            agg = by_tag.setdefault(rec["tag"], {"count": 0, "wall_s": 0.0})
            agg["count"] += 1
            agg["wall_s"] += float(rec.get("wall_s", 0.0))
        rows = [(tag, a["count"], f"{a['wall_s']:.3f}")
                for tag, a in sorted(by_tag.items())]
        out += _table(("tag", "count", "sum wall_s"), rows)
        ends = [r for r in events if r["tag"] == "run.end"]
        for r in ends:
            compile_s, steady = r.get("compile_s"), r.get("steady_state_s")
            if compile_s is not None and steady is not None:
                out.append(f"compile {compile_s:.3f}s vs steady-state {steady:.3f}s "
                           f"({r.get('label', 'run')})")
        div = [r for r in events if r["tag"] == "obs.divergence"]
        if div:
            out.append("")
            out.append("-- divergence events --")
            out += _table(("cell", "first_bad_tick"),
                          [(r.get("cell", "?"), r.get("first_bad_tick")) for r in div])
        alerts = [r for r in events if r["tag"] == "obs.alert"]
        if alerts:
            out.append("")
            out.append("-- alerts (threshold rules over the live metric stream) --")
            out += _table(("kind", "stream", "tick"),
                          [(r.get("kind", "?"), r.get("stream", "?"), r.get("tick"))
                           for r in alerts])

    return "\n".join(out) + "\n"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("run_dir", nargs="?", default=None,
                    help="directory holding obs_summary.json / events.jsonl")
    ap.add_argument("--summary", default=None, help="explicit obs_summary.json path")
    ap.add_argument("--events", default=None, help="explicit events.jsonl path")
    ap.add_argument("--top", type=int, default=10, help="suspect edges to show")
    ap.add_argument("--out", default=None, help="write the report here too")
    args = ap.parse_args(argv)

    spath = args.summary or (args.run_dir and os.path.join(args.run_dir, "obs_summary.json"))
    epath = args.events or (args.run_dir and os.path.join(args.run_dir, "events.jsonl"))
    summary = None
    if spath and os.path.exists(spath):
        with open(spath) as f:
            summary = json.load(f)
    events = read_events(epath) if epath and os.path.exists(epath) else None
    manifest = metrics_rows = None
    if args.run_dir:
        from repro.obs.manifest import read_manifest
        from repro.obs.metrics import read_metrics

        manifest = read_manifest(args.run_dir)
        metrics_rows = read_metrics(os.path.join(args.run_dir, "metrics.jsonl")) or None
    if summary is None and events is None and manifest is None and metrics_rows is None:
        raise SystemExit(f"no obs_summary.json, events.jsonl, manifest.json or "
                         f"metrics.jsonl found (looked at {spath!r}, {epath!r})")
    text = render(summary, events, top=args.top, manifest=manifest,
                  metrics_rows=metrics_rows)
    print(text, end="")
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
