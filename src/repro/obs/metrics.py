"""Live per-tick metric rings: in-graph scalar streams for running runs.

`repro.obs.trace` answers *post-mortem* questions — its aggregates only
leave the device when the whole scan returns.  This module is the *live*
layer: a `MetricSpec` compiles a small ``[C, S]`` ring buffer of per-tick
scalar streams (loss, grad norm, trim fraction, eviction fraction, wire
bits, staleness quantiles, non-finite sentinel) into the step, the tick
loop runs as a host loop over jitted scan *chunks* with donated carries
(`repro.core.bridge.BridgeTrainer.run_chunks`), and after each chunk a
`MetricWriter` background thread ``device_get``s the ring and appends one
JSON line per tick to ``metrics.jsonl`` — without ever blocking dispatch.

The spec follows the `TraceSpec`/`TrustSpec` pattern exactly: a frozen
zero-leaf pytree riding `CellParams`/`BridgeConfig` as jit *structure*.
``metrics=None`` (the default everywhere) keeps each step builder's exact
pre-metrics program shape, and metrics ON is bit-inert for the trajectory —
the ring only *reads* values the step already computes (property-tested in
``tests/test_metrics.py``).

Ring semantics: ``buf[count % capacity]`` is overwritten round-robin, so a
chunk of up to ``capacity`` ticks survives intact between flushes (the
chunked runners default their chunk length to the spec's capacity).  Columns
a configuration does not produce (staleness on the synchronous path, the
eviction fraction without a trust spec) hold NaN and render as ``null``.

Threshold alerting (`AlertRules`/`AlertEngine`) is shared host-side logic:
the writer evaluates it on every flushed row and emits ``obs.alert`` events
into the run's `EventLog`; the live monitor (`repro.obs.monitor`) runs the
same engine over a tailed ``metrics.jsonl`` so a killed run still alerts.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import queue
import threading
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# The fixed column schema of the ring (S = len(COLUMNS)).  Order is the
# on-device layout AND the JSONL field order; appending a column is a
# compatible change (old readers index by name), reordering is not.
COLUMNS = (
    "tick",                # written from state.t — the ring's dedup key
    "loss",                # honest-mean loss
    "consensus_dist",      # max honest deviation from the honest mean
    "grad_norm",           # honest-mean per-node gradient l2 norm
    "rho",                 # step size
    "trim_frac",           # live-edge-mean screening trim fraction (decide path)
    "wire_bits_per_edge",  # codec codeword size
    "wire_bytes_total",    # bytes put on the wire this tick
    "evicted_frac",        # trust-layer evicted edge fraction
    "stale_p50",           # delivered-message age median (net paths)
    "stale_p90",           # delivered-message age 90th percentile
    "nonfinite",           # 1.0 the tick loss/consensus went non-finite
)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """What the compiled step streams.  Hashable and frozen: jit *structure*
    (a zero-leaf pytree), exactly like `repro.obs.trace.TraceSpec`."""

    # ring slots; the chunked runners flush once per chunk and default the
    # chunk length to this, so no tick is overwritten before it is read
    capacity: int = 64

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"invalid MetricSpec: {self}")


jax.tree_util.register_pytree_node(MetricSpec, lambda s: ((), s), lambda aux, _: aux)


class MetricState(NamedTuple):
    """The scanned metric carry (one per cell; grids stack a leading [E])."""

    buf: jax.Array    # [capacity, S] f32, NaN = slot never written
    count: jax.Array  # i32 scalar — ticks folded so far


def init_state(spec: MetricSpec | None, *, lead: tuple = ()) -> MetricState | None:
    """A fresh NaN-filled ring (``lead=(E,)`` stacks a grid's worth)."""
    if spec is None:
        return None
    return MetricState(
        buf=jnp.full(lead + (spec.capacity, len(COLUMNS)), jnp.nan, jnp.float32),
        count=jnp.zeros(lead, jnp.int32),
    )


def update(spec: MetricSpec, st: MetricState, *, t, vals: dict) -> MetricState:
    """Fold one tick's scalars into the ring.  ``vals`` maps column names to
    this tick's traced scalars; absent columns stay NaN.  Every op is
    vmap-safe (the grid maps this over [E])."""
    row = []
    for name in COLUMNS:
        if name == "tick":
            row.append(jnp.asarray(t, jnp.float32))
        elif name == "nonfinite":
            bad = ~(jnp.isfinite(jnp.asarray(vals["loss"], jnp.float32))
                    & jnp.isfinite(jnp.asarray(vals["consensus_dist"], jnp.float32)))
            row.append(bad.astype(jnp.float32))
        else:
            v = vals.get(name)
            row.append(jnp.full((), jnp.nan, jnp.float32) if v is None
                       else jnp.asarray(v, jnp.float32))
    return MetricState(
        buf=st.buf.at[st.count % spec.capacity].set(jnp.stack(row)),
        count=st.count + 1,
    )


def stale_quantiles(staleness, live) -> dict:
    """The ``stale_p50``/``stale_p90`` columns from a ``[M, W]`` delivered-
    message age tensor and its live mask (NaN quantiles over dead slots)."""
    vals = jnp.where(live, jnp.asarray(staleness, jnp.float32), jnp.nan)
    return {"stale_p50": jnp.nanquantile(vals, 0.5),
            "stale_p90": jnp.nanquantile(vals, 0.9)}


def rows_of(buf, count, *, after: int = -1) -> list[dict]:
    """Host-side ring decode: tick-ordered JSON-ready rows, skipping ticks
    ``<= after`` (the writer's per-tag dedup across overlapping flushes) and
    rendering NaN columns as None."""
    buf = np.asarray(buf)
    count = int(count)
    c = buf.shape[0]
    rows = []
    for i in range(max(count - c, 0), count):
        row = buf[i % c]
        if not np.isfinite(row[0]):
            continue  # slot never written (short first chunk)
        tick = int(row[0])
        if tick <= after:
            continue
        rec: dict[str, Any] = {"tick": tick}
        for name, v in zip(COLUMNS[1:], row[1:], strict=True):
            rec[name] = float(v) if math.isfinite(float(v)) else None
        rows.append(rec)
    return rows


# ---------------------------------------------------------------------------
# Threshold alert rules (shared by the writer and the live monitor)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AlertRules:
    """Host-side thresholds evaluated on every metric row.  Each kind latches
    per (tag, kind) so a persistent condition alerts once, not per tick."""

    divergence: bool = True  # the nonfinite sentinel fired
    # loss > factor * the running minimum (a blow-up, not normal noise)
    loss_spike_factor: float = 100.0
    # evicted_frac rose by more than this between consecutive rows
    evict_spike: float = 0.25
    # cumulative wire_bytes_total crossed this budget (None = unmetered)
    wire_budget_bytes: float | None = None


class AlertEngine:
    """Stateful evaluator: ``feed(tag, row) -> [alert dicts]``."""

    def __init__(self, rules: AlertRules | None = None):
        self.rules = rules or AlertRules()
        self._loss_min: dict[str, float] = {}
        self._evicted: dict[str, float] = {}
        self._wire: dict[str, float] = {}
        self._fired: set[tuple[str, str]] = set()

    def _fire(self, tag: str, kind: str, tick: int, **fields) -> dict | None:
        if (tag, kind) in self._fired:
            return None
        self._fired.add((tag, kind))
        return {"kind": kind, "tag": tag, "tick": tick, **fields}

    def feed(self, tag: str, row: dict) -> list[dict]:
        r = self.rules
        tick = int(row.get("tick", -1))
        out = []
        if r.divergence and (row.get("nonfinite") or 0.0) > 0.0:
            a = self._fire(tag, "divergence", tick)
            if a:
                out.append(a)
        loss = row.get("loss")
        if loss is not None and math.isfinite(loss):
            lo = self._loss_min.get(tag)
            if (lo is not None and lo > 0.0
                    and loss > r.loss_spike_factor * lo):
                a = self._fire(tag, "loss_spike", tick, loss=loss, running_min=lo)
                if a:
                    out.append(a)
            self._loss_min[tag] = loss if lo is None else min(lo, loss)
        ev = row.get("evicted_frac")
        if ev is not None:
            prev = self._evicted.get(tag, 0.0)
            if ev - prev > r.evict_spike:
                a = self._fire(tag, "eviction_spike", tick,
                               evicted_frac=ev, previous=prev)
                if a:
                    out.append(a)
            self._evicted[tag] = ev
        wire = row.get("wire_bytes_total")
        if r.wire_budget_bytes is not None and wire is not None:
            tot = self._wire.get(tag, 0.0) + wire
            self._wire[tag] = tot
            if tot > r.wire_budget_bytes:
                a = self._fire(tag, "wire_budget", tick, wire_bytes_cumulative=tot,
                               budget=r.wire_budget_bytes)
                if a:
                    out.append(a)
        return out


# ---------------------------------------------------------------------------
# The background writer
# ---------------------------------------------------------------------------

_SENTINEL = object()


class MetricWriter:
    """Appends flushed rings to ``metrics.jsonl`` from a daemon thread.

    ``flush(mstate, tag=...)`` enqueues a *device-side copy* of the ring and
    returns immediately: the chunked runners donate their carries, so the
    original buffer is invalidated at the very next dispatch — the copy is
    what makes the overlap safe.  The thread's blocking ``device_get`` then
    overlaps device compute instead of stalling it.

    One JSON line per tick: ``{"tag", "wall", <COLUMNS...>}``.  Overlapping
    flushes of the same tag are deduped by tick; per-row walls are
    interpolated between consecutive flush walls (the Perfetto counter
    track's timestamps).  ``alerts``/``events`` wire the flushed rows
    through an `AlertEngine` into ``obs.alert`` event records.
    """

    def __init__(self, path: str, *, alerts: AlertRules | None = None,
                 events=None, flush_interval: float = 0.2):
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self.path = path
        self._f = open(path, "a")  # noqa: SIM115  (lives until .close())
        self._t0 = time.perf_counter()
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        self._flush_interval = flush_interval
        self._last_tick: dict[str, int] = {}
        self._last_wall: dict[str, float] = {}
        self._alerts = None if alerts is None else AlertEngine(alerts)
        self._events = events
        self.rows_written = 0
        self._thread = threading.Thread(target=self._drain, daemon=True,
                                        name="obs-metricwriter")
        self._thread.start()

    def flush(self, mstate, *, tag: str = "train", tags=None) -> None:
        """Enqueue one ring (``[C, S]`` buf) or a stacked batch of rings
        (``[E, C, S]`` buf with ``tags`` naming each row)."""
        if mstate is None or self._closed:
            return
        # device-side copy BEFORE the caller's next (donating) dispatch
        buf = jnp.copy(mstate.buf)
        count = jnp.copy(mstate.count)
        self._q.put((tag, tags, buf, count, time.perf_counter() - self._t0))

    def _write_rows(self, tag: str, buf, count, wall: float) -> None:
        rows = rows_of(buf, count, after=self._last_tick.get(tag, -1))
        if not rows:
            return
        w0 = self._last_wall.get(tag, wall)
        for i, rec in enumerate(rows):
            rec_wall = w0 + (wall - w0) * (i + 1) / len(rows)
            line = {"tag": tag, "wall": round(rec_wall, 6), **rec}
            self._f.write(json.dumps(line) + "\n")
            self.rows_written += 1
            if self._alerts is not None:
                for alert in self._alerts.feed(tag, rec):
                    if self._events is not None:
                        # `stream`, not `tag`: the event record's "tag" field
                        # is the event name and fields must not collide
                        a = dict(alert)
                        a["stream"] = a.pop("tag")
                        self._events.emit("obs.alert", **a)
        self._last_tick[tag] = rows[-1]["tick"]
        self._last_wall[tag] = wall

    def _drain(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=self._flush_interval)
            except queue.Empty:
                self._f.flush()
                continue
            if item is _SENTINEL:
                break
            tag, tags, buf, count, wall = item
            # the blocking transfer happens HERE, overlapping device compute
            buf = jax.device_get(buf)
            count = jax.device_get(count)
            if tags is not None:
                for i, t in enumerate(tags):
                    self._write_rows(str(t), buf[i], count[i], wall)
            else:
                self._write_rows(tag, buf, count, wall)
            if self._q.empty():
                self._f.flush()
        self._f.flush()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(_SENTINEL)
        self._thread.join(timeout=30.0)
        if self._thread.is_alive():
            # a wedged transfer: leave the file to the daemon thread rather
            # than closing it out from under an in-flight write
            return
        self._f.close()

    def __enter__(self) -> "MetricWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_metrics(path: str, *, after: int = -1, tag: str | None = None) -> list[dict]:
    """Parse ``metrics.jsonl`` back into row dicts (monitor/report/perfetto
    input); tolerates a truncated final line from a killed run."""
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if tag is not None and rec.get("tag") != tag:
                continue
            if int(rec.get("tick", -1)) <= after:
                continue
            rows.append(rec)
    return rows


# Metric streams the metrics-on step adds to the engine metrics dict,
# registered with the grid result reducers so `repro.sim.results.collect`
# folds them instead of warning (satellite: reducer coverage for obs_*).
def _register_reducers() -> None:
    from repro.sim import results as results_lib

    results_lib.register_mean("grad_norm")


_register_reducers()
