"""In-graph screening forensics: bounded-memory trace aggregates (repro.obs).

BRIDGE's whole defense happens inside a jitted ``lax.scan`` — which neighbor
values landed in the trim window, which Byzantine coordinates survived
screening, how stale each delivered message was — and none of it escapes the
graph as ``[E, T]`` scalar streams.  A `TraceSpec` compiles the missing
telemetry *into* the scan:

* **per-edge trim-frequency counters** ``[M, W]`` (W = M dense / K sparse) —
  who keeps landing in the trim window, the ROADMAP trust layer's suspicion
  statistic;
* **Byzantine-vs-honest survival rates** — scalar totals against the known
  attacker mask, the "did screening actually screen" check;
* **staleness / wire-bits histograms** — fixed-bin ``segment_sum``, so the
  distribution survives without carrying per-tick tensors;
* **a strided raw-trace reservoir** — ``reservoir`` slots of (tick, loss,
  trim matrix) snapshots, written every ``stride`` ticks, overwriting
  round-robin (bounded HBM at M=512 x T);
* **a NaN/divergence sentinel** — the first tick where the honest loss or
  consensus distance went non-finite, surfaced as an obs event instead of
  silently propagating NaN into downstream scoring.

The spec rides on `repro.core.bridge.CellParams` as *structural* auxiliary
data — `TraceSpec` is registered as a zero-leaf pytree node, so it is jit
cache key, not operand.  ``trace=None`` (the default everywhere) keeps every
step builder's exact pre-obs program shape; tracing on is bit-inert for the
trajectory itself (property-tested in ``tests/test_obs.py``).

Aggregate counters are float32: exact integer accumulation holds to 2**24
(~16.7M edge observations per counter), far beyond any tick budget here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """What the compiled step traces.  Hashable and frozen: it is jit
    *structure* (a zero-leaf pytree), so changing any field retraces — which
    is correct, the program genuinely differs."""

    # per-edge trim counters + survival rates + histograms (needs the
    # decision-instrumented screening twins; incompatible with coordinate
    # streaming — the step raises if `screen_chunk` would engage)
    forensics: bool = True
    # coordinate subsampling for the per-edge membership pass: the twins
    # estimate trim fractions on every `decide_stride`-th coordinate (1 =
    # exact).  The aggregate y and its sort stay exact and bit-inert either
    # way; > 1 trades counter variance (which tick-accumulation averages
    # out) for dropping the one extra O(M*K*d) sweep tracing would add —
    # the knob that holds the <10% overhead budget at large d
    decide_stride: int = 1
    # raw-trace reservoir slots (0 disables); slot i holds the (tick, loss,
    # trim matrix) snapshot of the latest tick with t % stride == 0, written
    # round-robin
    reservoir: int = 0
    stride: int = 1
    # fixed histogram bins (staleness in ticks, wire bits as a fraction of
    # the uncompressed 32*d payload)
    hist_bins: int = 16
    stale_max: int = 32
    # loss_trace smoothing: 0 keeps the last tick's loss, else EMA weight on
    # the carried value
    ema: float = 0.0
    # first-non-finite-tick sentinel on (loss, consensus_dist)
    sentinel: bool = True

    def __post_init__(self):
        if (self.reservoir < 0 or self.stride < 1 or self.hist_bins < 1
                or self.decide_stride < 1):
            raise ValueError(f"invalid TraceSpec: {self}")


# Zero-leaf pytree registration: the spec flattens to no children and rides
# in the treedef.  This is what lets it sit on CellParams/vmapped stacks
# without contributing a mapped axis.
jax.tree_util.register_pytree_node(TraceSpec, lambda s: ((), s), lambda aux, _: aux)


class TraceState(NamedTuple):
    """The scanned obs carry (one per cell; the grid stacks a leading [E])."""

    edge_seen: jax.Array  # [M, W] f32 live-edge observation counts
    edge_trim: jax.Array  # [M, W] f32 accumulated trim fractions
    byz_seen: jax.Array  # f32 scalar
    byz_trim: jax.Array  # f32 scalar
    hon_seen: jax.Array  # f32 scalar
    hon_trim: jax.Array  # f32 scalar
    stale_hist: jax.Array  # [hist_bins] f32
    bits_hist: jax.Array  # [hist_bins] f32
    loss_trace: jax.Array  # f32 scalar (last or EMA, per spec.ema)
    res_tick: jax.Array  # [R] i32, -1 = slot never written
    res_loss: jax.Array  # [R] f32
    res_trim: jax.Array  # [R, M, W] f32 (R or M/W zero-sized when disabled)
    first_bad: jax.Array  # i32 scalar, -1 = finite so far


def init_state(spec: TraceSpec | None, num_nodes: int, width: int, *,
               lead: tuple = ()) -> TraceState | None:
    """Fresh aggregates for one cell (``lead=(E,)`` stacks a grid's worth).
    ``width`` is the per-node edge-slot count: M dense, K neighbor-indexed."""
    if spec is None:
        return None
    mw = (num_nodes, width) if spec.forensics else (0, 0)
    r = spec.reservoir
    z = lambda shape, dt=jnp.float32: jnp.zeros(lead + shape, dt)
    return TraceState(
        edge_seen=z(mw), edge_trim=z(mw),
        byz_seen=z(()), byz_trim=z(()), hon_seen=z(()), hon_trim=z(()),
        stale_hist=z((spec.hist_bins,)), bits_hist=z((spec.hist_bins,)),
        loss_trace=z(()),
        res_tick=jnp.full(lead + (r,), -1, jnp.int32),
        res_loss=z((r,)),
        res_trim=z((r,) + mw),
        first_bad=jnp.full(lead, -1, jnp.int32),
    )


def update(spec: TraceSpec, st: TraceState, *, t, loss, consensus,
           trim_frac=None, live=None, byz_edge=None, staleness=None,
           wire_bits=None, live_edges=None, d: int | None = None) -> TraceState:
    """Fold one tick into the aggregates.  All inputs are this tick's values
    inside the step: ``trim_frac``/``live``/``byz_edge`` are ``[M, W]``
    (trim fractions already zeroed outside ``live``), ``staleness`` the
    ``[M, W]`` delivered-message ages (None on the synchronous path),
    ``wire_bits`` the per-edge codeword size and ``live_edges`` the tick's
    live-edge count.  Every op is vmap-safe (the grid maps this over [E])."""
    kw: dict[str, Any] = {}
    loss32 = jnp.asarray(loss, jnp.float32)
    if spec.forensics and trim_frac is not None:
        live_f = live.astype(jnp.float32)
        byz_f = byz_edge.astype(jnp.float32)
        kw["edge_seen"] = st.edge_seen + live_f
        kw["edge_trim"] = st.edge_trim + trim_frac
        kw["byz_seen"] = st.byz_seen + jnp.sum(live_f * byz_f)
        kw["byz_trim"] = st.byz_trim + jnp.sum(trim_frac * byz_f)
        kw["hon_seen"] = st.hon_seen + jnp.sum(live_f * (1.0 - byz_f))
        kw["hon_trim"] = st.hon_trim + jnp.sum(trim_frac * (1.0 - byz_f))
        if staleness is not None:
            bin_w = max(1, -(-spec.stale_max // spec.hist_bins))
            bins = jnp.clip(jnp.asarray(staleness, jnp.int32) // bin_w,
                            0, spec.hist_bins - 1)
            kw["stale_hist"] = st.stale_hist + jax.ops.segment_sum(
                live_f.reshape(-1), bins.reshape(-1), num_segments=spec.hist_bins)
        if wire_bits is not None and d is not None:
            # bits binned as a fraction of the uncompressed 32*d payload
            frac_bin = jnp.clip(
                (jnp.asarray(wire_bits, jnp.int32) * spec.hist_bins) // (32 * d + 1),
                0, spec.hist_bins - 1)
            le = (jnp.asarray(live_edges, jnp.float32) if live_edges is not None
                  else jnp.ones((), jnp.float32))
            kw["bits_hist"] = st.bits_hist.at[frac_bin].add(le)
    if spec.ema > 0.0:
        kw["loss_trace"] = jnp.where(
            t == 0, loss32, spec.ema * st.loss_trace + (1.0 - spec.ema) * loss32)
    else:
        kw["loss_trace"] = loss32
    if spec.reservoir > 0:
        write = (t % spec.stride) == 0
        slot = (t // spec.stride) % spec.reservoir
        kw["res_tick"] = st.res_tick.at[slot].set(
            jnp.where(write, jnp.asarray(t, jnp.int32), st.res_tick[slot]))
        kw["res_loss"] = st.res_loss.at[slot].set(
            jnp.where(write, loss32, st.res_loss[slot]))
        if spec.forensics and trim_frac is not None:
            kw["res_trim"] = st.res_trim.at[slot].set(
                jnp.where(write, trim_frac, st.res_trim[slot]))
    if spec.sentinel:
        bad = ~(jnp.isfinite(loss32) & jnp.isfinite(jnp.asarray(consensus, jnp.float32)))
        kw["first_bad"] = jnp.where((st.first_bad < 0) & bad,
                                    jnp.asarray(t, jnp.int32), st.first_bad)
    return st._replace(**kw)


# Metric key of the per-block trim-fraction stream the chunk-streaming step
# (`repro.stream`) emits alongside the scalar ``obs_trim_frac``: one [NB]
# vector per tick — the live-edge-mean trim fraction of each coordinate block
# in global block order.  A layer whose block suddenly trims everything while
# the others stay quiet is a *localized* payload attack the scalar would
# dilute away; `repro.sim.results` registers a mean reducer for the key so
# grid collection folds the [T, NB] stream without warning.
BLOCK_TRIM_STREAM = "stream_block_trim_frac"


def staleness_of(net, t):
    """Delivered-message ages ``[M, W]`` of a mailbox-style net state (duck
    typed on ``send_tick``), or None when the runtime carries none."""
    if getattr(net, "send_tick", None) is None:
        return None
    from repro.net import mailbox as mb

    return jnp.where(net.send_tick > mb.NEVER, t - net.send_tick, 0)


# ---------------------------------------------------------------------------
# Host-side summaries (report inputs)
# ---------------------------------------------------------------------------


def sender_grid(num_nodes: int, *, adjacency=None, neighbors=None) -> np.ndarray:
    """``[M, W]`` sender node id per edge slot (-1 = never a live edge):
    neighbor-indexed tables map slots through ``idx``/``valid``; dense
    layouts map slot i to sender i, masked by the adjacency when the slot
    set is static (synchronous broadcast)."""
    if neighbors is not None:
        return np.where(np.asarray(neighbors.valid),
                        np.asarray(neighbors.idx, np.int64), -1)
    grid = np.broadcast_to(np.arange(num_nodes, dtype=np.int64)[None, :],
                           (num_nodes, num_nodes))
    if adjacency is None:
        return grid.copy()
    return np.where(np.asarray(adjacency, bool), grid, -1)


def ranking_auc(scores, labels) -> float | None:
    """Mann-Whitney AUC (average ranks on ties) of ``scores`` ranking
    ``labels`` (True = positive class).  None when a class is empty."""
    scores = np.asarray(scores, np.float64).reshape(-1)
    labels = np.asarray(labels, bool).reshape(-1)
    npos = int(labels.sum())
    nneg = int(labels.size - npos)
    if npos == 0 or nneg == 0:
        return None
    order = np.argsort(scores, kind="mergesort")
    s = scores[order]
    r = np.empty(s.size, np.float64)
    i = 0
    while i < s.size:
        j = i
        while j + 1 < s.size and s[j + 1] == s[i]:
            j += 1
        r[i:j + 1] = 0.5 * (i + j) + 1.0  # average 1-based rank of the tie run
        i = j + 1
    ranks = np.empty(s.size, np.float64)
    ranks[order] = r
    return float((ranks[labels].sum() - npos * (npos + 1) / 2.0) / (npos * nneg))


def summarize(spec: TraceSpec, state: TraceState, *, byz_mask=None,
              senders: np.ndarray | None = None, top: int = 20) -> dict:
    """One cell's aggregates as a JSON-ready forensics record: suspicion-
    ranked edges, Byzantine-vs-honest survival, histograms, sentinel tick,
    and (when the true mask is known) the AUC of the trim-frequency counters
    ranking Byzantine in-edges — the acceptance metric."""
    out: dict[str, Any] = {"spec": dataclasses.asdict(spec)}
    fb = int(np.asarray(state.first_bad))
    out["first_bad_tick"] = None if fb < 0 else fb
    out["loss_trace"] = float(np.asarray(state.loss_trace))
    byz = None if byz_mask is None else np.asarray(byz_mask, bool)
    if spec.forensics and state.edge_seen.size:
        seen = np.asarray(state.edge_seen, np.float64)
        trim = np.asarray(state.edge_trim, np.float64)
        freq = trim / np.maximum(seen, 1.0)
        bs = float(np.asarray(state.byz_seen))
        ht = float(np.asarray(state.hon_seen))
        out["survival"] = {
            "byz_edges_seen": bs,
            "byz_trim_freq": float(np.asarray(state.byz_trim)) / max(bs, 1.0),
            "honest_edges_seen": ht,
            "honest_trim_freq": float(np.asarray(state.hon_trim)) / max(ht, 1.0),
        }
        out["stale_hist"] = [float(x) for x in np.asarray(state.stale_hist)]
        out["bits_hist"] = [float(x) for x in np.asarray(state.bits_hist)]
        if senders is not None:
            recv, slot = np.nonzero((seen > 0) & (senders >= 0))
            send = senders[recv, slot]
            if byz is not None:
                # forensics are the honest nodes' view of their in-edges
                keep = ~byz[recv]
                recv, slot, send = recv[keep], slot[keep], send[keep]
            f = freq[recv, slot]
            order = np.argsort(-f, kind="mergesort")[:top]
            out["top_edges"] = [
                {"receiver": int(recv[k]), "sender": int(send[k]),
                 "trim_freq": float(f[k]), "seen": float(seen[recv[k], slot[k]]),
                 "byzantine": None if byz is None else bool(byz[send[k]])}
                for k in order
            ]
            if byz is not None:
                out["auc_byzantine_edges"] = ranking_auc(f, byz[send])
    if spec.reservoir > 0:
        ticks = np.asarray(state.res_tick)
        live = ticks >= 0
        out["reservoir"] = {
            "ticks": [int(x) for x in ticks[live]],
            "loss": [float(x) for x in np.asarray(state.res_loss)[live]],
        }
    return out


# Obs metric streams registered with the grid result reducers (satellite:
# `sim.results` warns on unregistered streams instead of dropping silently).
def _register_reducers() -> None:
    from repro.sim import results as results_lib

    results_lib.register_mean("obs_trim_frac")


_register_reducers()
