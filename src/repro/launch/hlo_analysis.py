"""Loop-aware cost analysis of optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` reports the FLOPs/bytes of ONE
iteration of each ``while`` loop (verified in this environment: a 10-step
scanned matmul reports 1 matmul of FLOPs).  Every model here scans over
layers / KV chunks / recurrence chunks, so the built-in numbers undercount by
10-100x.  This module parses ``compiled.as_text()`` (the per-device SPMD
program), extracts scan trip counts from while-loop conditions, and
recursively multiplies body costs — giving faithful per-chip totals for

* FLOPs (dot/convolution exactly from dot_dimension_numbers; elementwise and
  reduce ops as 1 flop/element),
* HBM bytes (fusion/dot/conv/copy/collective boundaries: operands + result —
  the XLA fusion model of HBM traffic),
* collective bytes per category (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), with ring-algorithm wire multipliers.

Everything is computed per chip (SPMD module == per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$"
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sine", "cosine",
    "compare", "select", "and", "or", "xor", "not", "clamp", "atan2",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "reduce", "sort", "transpose",
    "broadcast", "reshape", "concatenate", "slice", "pad", "reverse",
    "reduce-window", "select-and-scatter", "iota", "convert",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(segment: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    coll_wire: float = 0.0

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        self.coll_wire += other.coll_wire
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {a: b * k for a, b in self.coll.items()}, self.coll_wire * k)


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    rest: str  # operand list + attributes


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> dict[str, list[Instruction]]:
    """Split HLO text into computations -> instruction lists."""
    comps: dict[str, list[Instruction]] = {}
    cur: list[Instruction] | None = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # strip /*index=N*/ tuple comments
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if header and not stripped.startswith("ROOT") and "=" not in stripped.split("(")[0]:
            cur = []
            comps[header.group(1)] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instruction(m.group(1), m.group(2).strip(), m.group(3), m.group(4)))
    return comps


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_segment(instr: Instruction) -> str:
    """The operand list of the instruction line (before attributes)."""
    depth = 0
    for i, ch in enumerate(instr.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                return instr.rest[:i]
            depth -= 1
    return instr.rest


def _operand_names(instr: Instruction) -> list[str]:
    return _OPERAND_NAME_RE.findall(_operand_segment(instr))


def _operand_bytes(instr: Instruction, symbols: dict[str, str]) -> int:
    return sum(_shape_bytes(symbols.get(n, "")) for n in _operand_names(instr))


def _dot_flops(instr: Instruction, symbols: dict[str, str]) -> float:
    out = _first_shape_dims(instr.result_type)
    if out is None:
        return 0.0
    _, out_dims = out
    names = _operand_names(instr)
    lhs_dims: list[int] = []
    if names:
        lhs = _first_shape_dims(symbols.get(names[0], ""))
        if lhs:
            lhs_dims = lhs[1]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contract = 1
    if mc and mc.group(1) and lhs_dims:
        for d in mc.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    return 2.0 * math.prod(out_dims or [1]) * contract


def _conv_flops(instr: Instruction, symbols: dict[str, str]) -> float:
    out = _first_shape_dims(instr.result_type)
    names = _operand_names(instr)
    if out is None or len(names) < 2:
        return 0.0
    _, out_dims = out
    kshape = _first_shape_dims(symbols.get(names[1], ""))
    kdims = kshape[1] if kshape else []
    mg = re.search(r"feature_group_count=(\d+)", instr.rest)
    groups = int(mg.group(1)) if mg else 1
    out_elems = math.prod(out_dims or [1])
    kernel_elems = math.prod(kdims or [1])
    oc = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * kernel_elems / max(oc, 1) / max(groups, 1)


def _called(instr: Instruction) -> dict[str, list[str]]:
    refs: dict[str, list[str]] = {}
    for key in ("body", "condition", "calls", "to_apply"):
        m = re.search(rf"{key}=%?([\w\.\-]+)", instr.rest)
        if m:
            refs.setdefault(key, []).append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.rest)
    if m:
        refs["branches"] = [s.strip().lstrip("%") for s in m.group(1).split(",")]
    return refs


def _trip_count(cond_instrs: list[Instruction]) -> int:
    """Largest s32 constant in the while condition — the scan trip count."""
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "constant" and "s32" in ins.result_type:
            m = re.search(r"constant\((-?\d+)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_WIRE_MULT = {
    # ring-algorithm wire bytes per chip, as a multiple of the payload
    "all-gather": 1.0,      # receives (n-1)/n of the result ~ result bytes
    "all-reduce": 2.0,      # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def analyze(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        # fall back: the computation named like the module main
        entry = next(iter(comps))

    symtabs: dict[str, dict[str, str]] = {
        name: {ins.name: ins.result_type for ins in instrs}
        for name, instrs in comps.items()
    }
    memo: dict[tuple[str, bool], Cost] = {}

    def comp_cost(name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        for ins in comps.get(name, []):
            total += instr_cost(ins, name, in_fusion)
        memo[key] = total
        return total

    def instr_cost(ins: Instruction, comp: str, in_fusion: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        sym = symtabs.get(comp, {})
        refs = _called(ins)
        if op == "while":
            body = refs.get("body", [None])[0]
            cond = refs.get("condition", [None])[0]
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            inner = Cost()
            if body:
                inner += comp_cost(body, in_fusion)
            if cond:
                inner += comp_cost(cond, in_fusion)
            return inner.scaled(max(trips, 1))
        if op == "conditional":
            branches = refs.get("branches", [])
            if branches:
                costs = [comp_cost(b, in_fusion) for b in branches]
                return max(costs, key=lambda x: x.flops + x.bytes)
            return c
        if op == "fusion":
            for sub in refs.get("calls", []):
                sub_cost = comp_cost(sub, True)  # FLOPs inside; bytes at boundary
                c.flops += sub_cost.flops
                c.coll = {**c.coll, **sub_cost.coll}
                c.coll_wire += sub_cost.coll_wire
            if not in_fusion:
                c.bytes += _shape_bytes(ins.result_type) + _operand_bytes(ins, sym)
            return c
        if op in ("call", "custom-call", "async-start"):
            for sub in refs.get("calls", []) + refs.get("to_apply", []):
                c += comp_cost(sub, in_fusion)
            if not in_fusion and op == "custom-call":
                c.bytes += _shape_bytes(ins.result_type) + _operand_bytes(ins, sym)
            return c

        # FLOPs
        if op == "dot":
            c.flops += _dot_flops(ins, sym)
        elif op == "convolution":
            c.flops += _conv_flops(ins, sym)
        elif op in _ELEMENTWISE:
            c.flops += _shape_elems(ins.result_type)
        elif op in ("reduce", "reduce-window"):
            c.flops += sum(_shape_elems(sym.get(n, "")) for n in _operand_names(ins))

        # collectives (also *-start async forms)
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES:
            operand_b = _operand_bytes(ins, sym)
            result_b = _shape_bytes(ins.result_type)
            payload = max(operand_b, result_b)
            c.coll[base_op] = c.coll.get(base_op, 0.0) + payload
            c.coll_wire += _WIRE_MULT[base_op] * (result_b if base_op == "all-gather" else operand_b)

        # HBM bytes at fusion-equivalent boundaries
        if not in_fusion and op in _MEM_OPS:
            c.bytes += _shape_bytes(ins.result_type) + _operand_bytes(ins, sym)
        return c

    return comp_cost(entry, False)


# ---------------------------------------------------------------------------
# structural queries (the static-analysis surface: repro.analysis.hlo)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WhileLoop:
    """One while instruction in the optimized program."""

    name: str        # "<computation>/<instruction>"
    trip_count: int  # largest s32 constant in the condition (scan trip count)
    carry_type: str  # the loop-carried tuple's type string


def while_loops(text: str) -> list[WhileLoop]:
    """Catalog every while loop with its trip count and carry type.

    The fence-integrity pass counts trip-count-2 loops here: a
    `repro.core.screening.fence` site that survived optimization is exactly
    a while whose condition bounds a length-2 scan (XLA's simplifier unrolls
    trip-count-<=1 loops, which would void the fence — so survival IS the
    property being checked)."""
    comps = parse_hlo(text)
    out = []
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode != "while":
                continue
            refs = _called(ins)
            cond = refs.get("condition", [None])[0]
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            out.append(WhileLoop(f"{cname}/{ins.name}", trips, ins.result_type))
    return out


def donated_params(text: str) -> list[tuple[tuple[int, ...], int]]:
    """``(output_index, parameter_number)`` pairs from the module header's
    ``input_output_alias`` table — empty when the compiler honored no
    donation.  This is the ground truth for ``donate_argnums``: jax warns-
    and-copies when donation is dropped, so the analysis pass asserts the
    alias survived END-TO-END rather than trusting the python-level flag."""
    m = re.search(r"input_output_alias=\{", text)
    if m is None:
        return []
    start = m.end() - 1
    depth = 0
    end = start
    for end in range(start, len(text)):
        if text[end] == "{":
            depth += 1
        elif text[end] == "}":
            depth -= 1
            if depth == 0:
                break
    segment = text[start:end + 1]
    out = []
    for out_idx, pnum in re.findall(r"\{([\d,\s]*)\}\s*:\s*\((\d+)", segment):
        idx = tuple(int(x) for x in out_idx.replace(" ", "").split(",") if x)
        out.append((idx, int(pnum)))
    return out


def largest_tensors(text: str, top: int = 5) -> list[tuple[int, str, tuple[int, ...]]]:
    """The ``top`` largest distinct array types in the HLO text as
    ``(bytes, dtype, dims)``, descending — the memory-contract pass's
    evidence when a budget is exceeded (*which* tensor blew it)."""
    seen: dict[tuple[str, tuple[int, ...]], int] = {}
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        n = 1
        for d in shape:
            n *= d
        seen[(dt, shape)] = n * _DTYPE_BYTES[dt]
    ranked = sorted(((b, dt, shape) for (dt, shape), b in seen.items()),
                    key=lambda t: -t[0])
    return ranked[:top]


def largest_tensor_bytes(text: str) -> int:
    """The largest single array (in bytes) typed anywhere in the HLO text —
    parameters, instruction results, tuple elements.

    This is the memory-layout assertion surface for the sparse
    neighbor-indexed runtime (`repro.core.neighbors`): a jitted step whose
    largest tensor is below ``M * M * d * 4`` bytes provably never
    materializes a dense ``[M, M, d]`` float tensor (``benchmarks/
    scale_bench.py`` and ``tests/test_sparse.py`` gate on it)."""
    best = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        best = max(best, n * _DTYPE_BYTES[dt])
    return best


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per chip, one direction)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    coll_wire_bytes: float
    coll_detail: dict

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect overlap) step-time estimate."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_from_cost(cost: Cost) -> Roofline:
    return Roofline(
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.bytes / HBM_BW,
        collective_s=cost.coll_wire / ICI_BW,
        flops=cost.flops,
        bytes=cost.bytes,
        coll_wire_bytes=cost.coll_wire,
        coll_detail=dict(cost.coll),
    )
