"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis, and derive the per-chip roofline terms with the
loop-aware HLO analyzer.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
        --shape train_4k [--multi-pod] [--gossip all_gather] [--remat] \
        [--json experiments/dryrun]

One (arch, shape, mesh) per invocation — the sweep script
(launch/sweep.py) fans out subprocesses and aggregates the table.
"""
# The VERY FIRST jax-visible action: force 512 placeholder devices BEFORE any
# other import (jax locks the device count on first backend init).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import math
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.shapes import (
    decode_token_specs,
    prefill_specs,
    shape_applicable,
    train_specs,
)
from repro.core.graph import erdos_renyi
from repro.launch import hlo_analysis, sharding
from repro.launch.mesh import make_production_mesh, node_axes, num_nodes
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import api as model_api


def _tree_sds(tree):
    return jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def model_flops_per_chip(cfg, shape, kind: str, n_chips: int, n_nodes: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (active params for
    MoE), per chip."""
    n_total = model_api.param_count(cfg)
    n_active = n_total
    if cfg.num_experts:
        ff_mult = 3  # swiglu experts
        n_moe_layers = cfg.num_layers - cfg.first_dense_layers
        routed = ff_mult * cfg.d_model * cfg.moe_d_ff * cfg.num_experts * n_moe_layers
        n_active = n_total - routed + routed * cfg.top_k / cfg.num_experts
    if kind == "train":
        # global_batch is split across BRIDGE nodes; total trained tokens per
        # step is global_batch*seq regardless of M.
        tokens = shape.global_batch * shape.seq_len
        per_model = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_model = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        per_model = 2.0 * n_active * shape.global_batch
    return per_model / n_chips


def build_lowerable(cfg, shape, mesh, args):
    """Returns (fn, example_args, in_shardings) ready for jit().lower()."""
    nax = node_axes(mesh)
    kind = shape.kind
    key = jax.random.PRNGKey(0)
    api = model_api.build(cfg)

    if kind == "train":
        m = num_nodes(mesh)
        from repro.core.bridge import replicate

        pshapes = jax.eval_shape(lambda k: replicate(api.init_params(k, cfg), m), key)
        pspecs = sharding.param_specs(cfg, pshapes, node_axes=nax, layout=args.layout)
        # gossip always exchanges model-sharded coordinate shards (each chip
        # screens distinct coordinates even under the dp layout)
        gspecs = (pspecs if args.layout == "tp"
                  else sharding.param_specs(cfg, pshapes, node_axes=nax, layout="tp"))
        batch = train_specs(cfg, shape, m)
        bspecs = sharding.train_batch_specs(batch, nax, layout=args.layout)
        topo = None
        for p in (0.6, 0.7, 0.8, 0.9):
            try:
                topo = erdos_renyi(m, p, args.byzantine, seed=0)
                break
            except RuntimeError:
                continue
        assert topo is not None, "could not build Assumption-4 graph"
        adjacency = jnp.asarray(topo.adjacency)
        step = make_train_step(
            cfg, mesh, nax, gspecs, adjacency,
            rule=args.rule, num_byzantine=args.byzantine,
            gossip_schedule=args.gossip, gossip_first=not args.no_overlap,
            gossip_quantize=args.gossip_quant,
        )
        in_sh = (sharding.named(mesh, pspecs), sharding.named(mesh, bspecs), None)
        ex = (pshapes, batch, jax.ShapeDtypeStruct((), jnp.float32))
        return step, ex, in_sh

    if kind == "prefill":
        pshapes = jax.eval_shape(lambda k: api.init_params(k, cfg), key)
        pspecs = sharding.param_specs(cfg, pshapes, node_axes=None)
        batch = prefill_specs(cfg, shape)
        bspecs = sharding.serve_batch_specs(batch, nax, shape.global_batch, mesh)
        step = make_prefill_step(cfg)
        in_sh = (sharding.named(mesh, pspecs), sharding.named(mesh, bspecs))
        return step, (pshapes, batch), in_sh

    # decode
    pshapes = jax.eval_shape(lambda k: api.init_params(k, cfg), key)
    pspecs = sharding.param_specs(cfg, pshapes, node_axes=None)
    b = shape.global_batch
    if cfg.family == "encdec":
        cshapes = jax.eval_shape(lambda: api.init_cache(cfg, b, shape.seq_len))
    else:
        cshapes = jax.eval_shape(lambda: api.init_cache(cfg, b, shape.seq_len))
    cspecs = sharding.cache_specs(cfg, cshapes, node_axes=nax, mesh=mesh,
                                  batch=b, seq_len=shape.seq_len)
    batch = decode_token_specs(cfg, shape)
    bspecs = sharding.serve_batch_specs(batch, nax, b, mesh)
    step = make_serve_step(cfg)
    in_sh = (sharding.named(mesh, pspecs), sharding.named(mesh, cspecs),
             sharding.named(mesh, bspecs))
    return step, (pshapes, cshapes, batch), in_sh


def run_one(arch: str, shape_name: str, multi_pod: bool, args) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())
    shape = SHAPES[shape_name]
    cfg = get_config(arch, dtype=args.dtype, remat=args.remat)
    ok, why = shape_applicable(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "gossip": args.gossip if shape.kind == "train" else None,
        "rule": args.rule if shape.kind == "train" else None,
        "remat": args.remat,
        "layout": args.layout,
        "gossip_quant": args.gossip_quant,
    }
    if not ok:
        result.update(status="skipped", reason=why)
        print(json.dumps(result, indent=2))
        return result

    t0 = time.time()
    fn, ex, in_sh = build_lowerable(cfg, shape, mesh, args)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*ex)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cost = hlo_analysis.analyze(compiled.as_text())
    rl = hlo_analysis.roofline_from_cost(cost)
    n_nodes_ = num_nodes(mesh)
    mflops = model_flops_per_chip(cfg, shape, shape.kind, n_chips, n_nodes_)

    result.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        # memory analysis (per device)
        mem_argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        mem_output_bytes=getattr(mem, "output_size_in_bytes", None),
        mem_temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        mem_peak_gb=round(
            (getattr(mem, "argument_size_in_bytes", 0)
             + getattr(mem, "temp_size_in_bytes", 0)) / 1e9, 3),
        # built-in (loop-UNAWARE) numbers for reference
        xla_cost_flops=ca.get("flops"),
        # loop-aware per-chip totals
        hlo_flops=cost.flops,
        hlo_bytes=cost.bytes,
        collective_bytes=cost.coll,
        collective_wire_bytes=cost.coll_wire,
        # roofline terms (seconds, per chip per step)
        compute_s=rl.compute_s,
        memory_s=rl.memory_s,
        collective_s=rl.collective_s,
        dominant=rl.dominant,
        step_time_s=rl.step_time_s,
        model_flops_per_chip=mflops,
        useful_flops_ratio=round(mflops / cost.flops, 4) if cost.flops else None,
    )
    print(json.dumps(result, indent=2))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gossip", default="all_gather", choices=["all_gather", "all_to_all"])
    ap.add_argument("--rule", default="trimmed_mean")
    ap.add_argument("--byzantine", type=int, default=2)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--no-overlap", action="store_true",
                    help="issue gossip after backward (no compute overlap)")
    ap.add_argument("--gossip-quant", action="store_true",
                    help="int8-quantized gossip payloads (beyond-paper)")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"],
                    help="within-node parallelism: tensor (tp) or data (dp)")
    ap.add_argument("--json", default=None, help="directory to write result json")
    args = ap.parse_args(argv)

    result = run_one(args.arch, args.shape, args.multi_pod, args)
    if args.json:
        os.makedirs(args.json, exist_ok=True)
        tag = f"{args.arch}_{args.shape}_{result['mesh']}"
        if args.gossip != "all_gather":
            tag += f"_{args.gossip}"
        if args.remat:
            tag += "_remat"
        if args.no_overlap:
            tag += "_nooverlap"
        if args.gossip_quant:
            tag += "_quant"
        if args.layout != "tp":
            tag += f"_{args.layout}"
        with open(os.path.join(args.json, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
