"""Production mesh construction (TPU v5e pods; host-device placeholders in
the dry-run).  Defined as functions so importing never touches jax device
state."""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes, **kwargs):
    """``jax.make_mesh`` across jax versions: ``axis_types`` only exists from
    jax 0.5; older versions treat every axis as Auto already."""
    if hasattr(jax.sharding, "AxisType"):
        kwargs.setdefault("axis_types", (jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def node_axes(mesh) -> tuple:
    """Mesh axes hosting the BRIDGE node dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_nodes(mesh) -> int:
    import math

    return math.prod(mesh.shape[a] for a in node_axes(mesh))


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh over host CPU devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return make_mesh_compat((data, model), ("data", "model"))
