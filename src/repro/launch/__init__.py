from repro.launch.mesh import make_host_mesh, make_production_mesh, node_axes, num_nodes

__all__ = ["make_production_mesh", "make_host_mesh", "node_axes", "num_nodes"]
