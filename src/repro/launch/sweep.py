"""Fan out experiment matrices — batched in one compiled program, or as
subprocesses.  All modes are resumable (existing results are skipped).

* ``--mode grid`` — the rule x attack x b x seed (x network scenario) matrix
  through the batched grid engine (`repro.sim`): every pending cell runs
  inside ONE jitted vmapped ``lax.scan`` on the paper's MNIST-like linear
  task — no per-cell subprocess, retrace, or recompile.  Per-cell JSONs land
  in the result store exactly like the subprocess modes, so interrupted
  sweeps resume at the missing cells:

    PYTHONPATH=src python -m repro.launch.sweep --mode grid \
        --out experiments/grid [--rules trimmed_mean,median] \
        [--attacks random,alie] [--byz 1,2] [--seeds 0,1,2,3] \
        [--scenarios sync | ideal,lossy,...] [--codecs identity,int8,...] \
        [--grid-chunk 16]

* ``--mode dryrun`` (default) — the arch x shape x mesh lowering matrix as
  subprocesses:

    PYTHONPATH=src python -m repro.launch.sweep --out experiments/dryrun \
        [--jobs 4] [--archs a,b] [--shapes s1,s2] [--single-pod-only]

* ``--mode net`` — the legacy subprocess path for the scenario matrix via
  `repro.launch.train --net` (full training CLI per cell; prefer ``grid``
  for paper-scale sweeps):

    PYTHONPATH=src python -m repro.launch.sweep --mode net \
        --out experiments/net [--rules trimmed_mean,median] \
        [--attacks random,alie,selective_victim] [--scenarios ideal,lossy]

* ``--mode breakdown`` — breakdown-point certification (`repro.adversary`):
  binary-search / ladder the largest tolerated b per (rule, adversary) with
  batched probe rounds, writing ``BENCH_breakdown.json``-shaped output:

    PYTHONPATH=src python -m repro.launch.sweep --mode breakdown \
        --out experiments/breakdown [--rules trimmed_mean,median] \
        [--adversaries random,alie,ipm,inner_max] [--breakdown-mode ladder]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS = [
    "starcoder2-3b", "zamba2-1.2b", "qwen3-4b", "whisper-medium",
    "qwen2-vl-2b", "rwkv6-3b", "mistral-nemo-12b", "deepseek-v2-236b",
    "deepseek-v3-671b", "gemma3-12b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def tag_for(arch, shape, multi_pod, extra=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}_{shape}_{mesh}{extra}"


def run_job(arch, shape, multi_pod, out_dir, timeout, extra_args=()):
    tag = tag_for(arch, shape, multi_pod, "".join(f"_{a.lstrip('-').replace('-','_')}" for a in extra_args if not a.startswith("--json")))
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        return tag, "cached"
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--json", out_dir,
    ]
    if shape == "train_4k":
        cmd.append("--remat")
    if multi_pod:
        cmd.append("--multi-pod")
    cmd.extend(extra_args)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
        if proc.returncode != 0:
            fail = {"arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": "failed", "stderr": proc.stderr[-3000:]}
            with open(path, "w") as f:
                json.dump(fail, f, indent=2)
            return tag, f"FAILED ({time.time()-t0:.0f}s)"
        return tag, f"ok ({time.time()-t0:.0f}s)"
    except subprocess.TimeoutExpired:
        fail = {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "timeout"}
        with open(path, "w") as f:
            json.dump(fail, f, indent=2)
        return tag, "TIMEOUT"


# Network-condition axis of the scenario matrix (--mode net); each maps to
# repro.launch.train --net flags.
NET_SCENARIOS = {
    "ideal": ["--net"],
    "lossy": ["--net", "--net-drop", "0.2"],
    "laggy": ["--net", "--net-latency", "3"],
    "lossy_laggy": ["--net", "--net-drop", "0.2", "--net-latency", "3"],
    "bandwidth64": ["--net", "--net-cap", "64"],
    "churn": ["--net", "--net-schedule", "churn", "--net-churn-prob", "0.3"],
    "partition": ["--net", "--net-schedule", "partition"],
}


def run_net_job(rule, attack, scenario, out_dir, timeout, arch, steps):
    tag = f"net_{rule}_{attack}_{scenario}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        return tag, "cached"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", arch, "--reduce", "--nodes", "6", "--byzantine", "1",
        "--rule", rule, "--attack", attack, "--steps", str(steps),
        "--batch", "2", "--seq", "32", "--log-every", str(steps),
    ] + NET_SCENARIOS[scenario]
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
        status = "ok" if proc.returncode == 0 else "failed"
        with open(path, "w") as f:
            json.dump({"rule": rule, "attack": attack, "scenario": scenario,
                       "status": status, "stdout": proc.stdout[-3000:],
                       "stderr": proc.stderr[-3000:] if status == "failed" else ""},
                      f, indent=2)
        return tag, f"{status.upper() if status != 'ok' else status} ({time.time()-t0:.0f}s)"
    except subprocess.TimeoutExpired:
        with open(path, "w") as f:
            json.dump({"rule": rule, "attack": attack, "scenario": scenario,
                       "status": "timeout"}, f, indent=2)
        return tag, "TIMEOUT"


def run_grid_mode(args) -> None:
    """One-compile batched sweep over rule x attack x b x seed (x scenario) on
    the paper's MNIST-like linear task, resuming from the per-cell store."""
    import jax
    import jax.numpy as jnp

    from repro.core import replicate
    from repro.data import make_mnist_like, partition_iid
    from repro.data.partition import stack_node_batches
    from repro.models import small
    from repro.sim import ExperimentGrid, GridEngine, default_topology
    from repro.sim import results as results_lib
    from repro.sim.engine import stack_batches

    rules = args.rules.split(",")
    attacks = args.attacks.split(",")
    byz = [int(x) for x in args.byz.split(",")]
    seeds = [int(x) for x in args.seeds.split(",")]
    codecs = args.codecs.split(",")
    adversaries = args.adversaries.split(",") if args.adversaries else ["none"]
    scenarios = None
    if args.scenarios not in ("sync", "none", ""):
        scenarios = args.scenarios.split(",")
    m, ticks = args.grid_nodes, args.grid_ticks
    topo = default_topology(m, rules, byz, seed=0)
    grid = ExperimentGrid(topo, rules, attacks, byz, seeds, scenarios=scenarios,
                          codecs=codecs, adversaries=adversaries, lam=1.0, t0=30.0)
    done = results_lib.existing_tags(args.out)
    pending = [c for c in grid.cells() if c.tag not in done]
    print(f"{grid.num_cells} grid cells ({len(done & {c.tag for c in grid.cells()})} cached) "
          f"-> {args.out}")
    if not pending:
        return

    def grad_fn(params, batch):
        return jax.value_and_grad(lambda p: small.linear_loss(p, batch))(params)

    x, y, xt, yt = make_mnist_like(args.grid_train, args.grid_test, seed=0)
    shards = partition_iid(x, y, m, seed=0)
    batch_fn = stack_node_batches(shards, args.grid_batch, seed=0)
    batches = stack_batches(lambda i: jax.tree_util.tree_map(jnp.asarray, batch_fn(i)), ticks)

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return replicate(small.init_linear(key), m, perturb=0.01, key=key)

    trace_spec, events = None, None
    run_dir = args.trace or args.metrics
    if run_dir is not None:
        from repro.obs import EventLog, write_manifest

        os.makedirs(run_dir, exist_ok=True)
        write_manifest(run_dir, kind="sweep-grid", config=vars(args))
        events = EventLog(os.path.join(run_dir, "events.jsonl"))
    if args.trace is not None:
        from repro.obs import TraceSpec
        from repro.obs import trace as obs_trace

        trace_spec = TraceSpec()
    metric_spec, mwriter = None, None
    if args.metrics is not None:
        from repro.obs import AlertRules, MetricSpec, MetricWriter

        metric_spec = MetricSpec(capacity=args.metrics_capacity)
        mwriter = MetricWriter(os.path.join(args.metrics, "metrics.jsonl"),
                               alerts=AlertRules(), events=events)
    if args.profile is not None:
        os.makedirs(args.profile, exist_ok=True)
        jax.profiler.start_trace(args.profile)
    engine = GridEngine(grid, grad_fn, cells=pending,
                        num_ticks=ticks if scenarios else None, sparse=args.sparse,
                        trace=trace_spec, trust=_trust_spec(args),
                        metrics=metric_spec, events=events)
    t0 = time.time()
    state = engine.init(init_fn)
    state, metrics = engine.run(state, batches, chunk=args.grid_chunk,
                                metric_writer=mwriter)
    jax.block_until_ready(state.params)
    wall = time.time() - t0
    if mwriter is not None:
        mwriter.close()
        print(f"metric stream -> {os.path.join(args.metrics, 'metrics.jsonl')}  "
              f"(watch: python -m repro.obs.monitor {args.metrics})")
    if args.profile is not None:
        jax.profiler.stop_trace()
        if events is not None:
            events.emit("profile.capture", dir=args.profile)
        print(f"profiler trace -> {args.profile}")
    result = results_lib.collect(pending, metrics, meta={
        "num_nodes": m, "ticks": ticks, "wall_s": wall,
        "cells_per_sec": len(pending) / wall, "us_per_cell": wall / len(pending) * 1e6,
        "trace_count": engine.trace_count, "chunk": args.grid_chunk,
        "rules": engine.rule_bank, "attacks": engine.attack_bank,
        "scenarios": engine.scenario_bank, "codecs": engine.codec_bank,
        "adversaries": engine.adversary_bank,
    })
    # per-cell honest test accuracy (the paper's metric), evaluated host-side
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)
    for i, rec in enumerate(result.cells):
        hm = ~engine.byz_masks[i]
        accs = [
            float(small.linear_accuracy(
                jax.tree_util.tree_map(lambda leaf: leaf[i, j], state.params), xt, yt))
            for j in hm.nonzero()[0]
        ]
        rec["accuracy"] = float(sum(accs) / max(len(accs), 1))
    if events is not None:
        events.close()
    if run_dir is not None:
        from repro.obs import write_manifest

        write_manifest(run_dir, extra={"ended": True, "wall_s": wall,
                                       "cells": len(pending)})
    if trace_spec is not None:
        senders = engine.sender_grid()
        cells_out = []
        for i, c in enumerate(pending):
            obs_i = jax.tree_util.tree_map(lambda leaf: leaf[i], state.obs)
            rec = {"tag": c.tag, "rule": c.rule,
                   **obs_trace.summarize(trace_spec, obs_i,
                                         byz_mask=engine.byz_masks[i], senders=senders)}
            cells_out.append(rec)
        summary_path = os.path.join(args.trace, "obs_summary.json")
        with open(summary_path, "w") as f:
            json.dump({"meta": {"mode": "grid", "num_nodes": m, "ticks": ticks},
                       "cells": cells_out}, f, indent=2, sort_keys=True)
        print(f"obs summary -> {summary_path}  "
              f"(render: python -m repro.obs.report {args.trace})")
    result.save_cells(args.out)
    # the aggregate covers the WHOLE store (earlier runs' cells included),
    # so a resumed sweep never truncates GridResult.json to the tail run
    full = results_lib.load_cell_store(args.out)
    full.meta.update(result.meta)
    full.meta["computed_this_run"] = len(pending)
    full.save(os.path.join(args.out, "GridResult.json"))
    print(f"{len(pending)} cells in {wall:.1f}s "
          f"({result.meta['cells_per_sec']:.2f} cells/s, "
          f"{engine.trace_count} compilation(s))")
    for rec, row in zip(result.cells, result.rows(), strict=True):
        print(f"  {row[0]:60s} acc={rec['accuracy']:.4f} loss={rec['final_loss']:.4f}")


def _trust_spec(args):
    """The `repro.trust.TrustSpec` the --trust flags describe (None when
    --trust is off — the trust-free program, bit-identical to PR 6)."""
    if not args.trust:
        return None
    from repro.trust import TrustSpec

    return TrustSpec(evict_threshold=args.trust_evict, warmup=args.trust_warmup)


def run_breakdown_mode(args) -> None:
    """Breakdown-point certification on the paper's MNIST-like linear task
    (extreme non-iid partition — consensus is *required* for honest test
    accuracy, which is what adaptive adversaries break)."""
    from repro.adversary.breakdown import BreakdownConfig, BreakdownEngine
    from repro.sim import default_topology
    from repro.sim.tasks import linear_task

    rules = args.rules.split(",")
    adversaries = (args.adversaries or "random,alie,ipm,inner_max").split(",")
    m, ticks = args.grid_nodes, args.grid_ticks
    # the topology must admit the whole probed ladder, not just b=1
    if args.trust:
        # echo quorums need gossip triangles: witnesses of a sender must be
        # adjacent to the receiver, so trust runs get the complete graph
        from repro.core import complete_graph

        topo = complete_graph(m, max(args.breakdown_b_max, 1))
    else:
        topo = default_topology(m, rules, [max(args.breakdown_b_max, 1)], seed=0)
    task = linear_task(m, ticks, batch=args.grid_batch,
                       num_train=args.grid_train, num_test=args.grid_test, seed=0)
    events = None
    if args.trace is not None:
        from repro.obs import EventLog

        os.makedirs(args.trace, exist_ok=True)
        events = EventLog(os.path.join(args.trace, "events.jsonl"))
    engine = BreakdownEngine(
        topo, rules, adversaries, task.grad_fn, task.init_fn, task.batches,
        lam=1.0, t0=30.0,
        config=BreakdownConfig(mode=args.breakdown_mode,
                               seeds=tuple(int(s) for s in args.seeds.split(",")),
                               b_max=args.breakdown_b_max,
                               loss_ratio=args.breakdown_loss_ratio,
                               score_drop=args.breakdown_score_drop),
        eval_fn=task.eval_accuracy, engine_chunk=args.grid_chunk,
        trust=_trust_spec(args), scenario=args.breakdown_scenario, events=events)
    result = engine.run()
    if events is not None:
        events.close()
    path = os.path.join(args.out, "BENCH_breakdown.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"breakdown certification ({result['meta']['cells_run']} cells, "
          f"{result['meta']['compiles']} compiles, "
          f"{result['meta']['wall_s']:.1f}s) -> {path}")
    for rule, rrec in result["rules"].items():
        stars = "  ".join(f"{a}:b*={arec['bstar']}"
                          for a, arec in rrec["adversaries"].items())
        print(f"  {rule:14s} feasible_b={rrec['feasible_b']}  {stars}  "
              f"worst={rrec['bstar_worst_adversary']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="dryrun",
                    choices=["dryrun", "net", "grid", "breakdown"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--rules", default="trimmed_mean,median")
    # None sentinels: the per-mode defaults differ (net sweeps every scenario,
    # grid defaults to the broadcast path) and an explicitly-passed value must
    # never be second-guessed
    ap.add_argument("--attacks", default=None,
                    help="default: random,alie,selective_victim (net) / random,alie (sync grid)")
    ap.add_argument("--scenarios", default=None,
                    help=f"default: all of {','.join(NET_SCENARIOS)} (net) / sync (grid)")
    ap.add_argument("--net-arch", default="qwen3-4b")
    ap.add_argument("--net-steps", type=int, default=30)
    # --mode grid knobs (batched engine on the MNIST-like linear task)
    ap.add_argument("--byz", default="1", help="comma-separated Byzantine counts (grid mode)")
    ap.add_argument("--seeds", default="0", help="comma-separated seeds (grid mode)")
    ap.add_argument("--codecs", default="identity",
                    help="comma-separated wire codecs (repro.comm) — a grid "
                         "axis like rules/attacks (grid mode)")
    ap.add_argument("--adversaries", default=None,
                    help="comma-separated repro.adversary names — a grid axis "
                         "(grid mode; default none) and the certified attack "
                         "suite (breakdown mode; default "
                         "random,alie,ipm,inner_max)")
    # --mode breakdown knobs (repro.adversary.breakdown)
    ap.add_argument("--breakdown-mode", default="ladder", choices=["ladder", "bisect"])
    ap.add_argument("--breakdown-b-max", type=int, default=3,
                    help="deepest Byzantine count probed (topology is built "
                         "dense enough to admit it)")
    ap.add_argument("--breakdown-loss-ratio", type=float, default=4.0,
                    help="diverged when final honest loss exceeds this "
                         "multiple of the faultless reference")
    ap.add_argument("--breakdown-score-drop", type=float, default=0.15,
                    help="diverged when honest test accuracy drops this far "
                         "below the faultless reference")
    ap.add_argument("--breakdown-scenario", default=None,
                    help="run breakdown probes through the net runtime on this "
                         "repro.net scenario (e.g. ideal) — required for "
                         "equivocators, whose lies only exist per message")
    ap.add_argument("--grid-nodes", type=int, default=12)
    ap.add_argument("--grid-ticks", type=int, default=60)
    ap.add_argument("--grid-batch", type=int, default=32)
    ap.add_argument("--grid-train", type=int, default=2000)
    ap.add_argument("--grid-test", type=int, default=400)
    ap.add_argument("--grid-chunk", type=int, default=None,
                    help="max experiments per compiled call (memory bound); "
                         "default runs the whole grid in one call")
    ap.add_argument("--sparse", action="store_true",
                    help="neighbor-indexed [M, K] state layout "
                         "(repro.core.neighbors) — bit-identical to dense, "
                         "required past a few hundred nodes")
    # observability flags (repro.obs; grid + breakdown modes)
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="compile screening forensics into the grid (bit-inert) "
                         "and write DIR/events.jsonl + DIR/obs_summary.json "
                         "(render with `python -m repro.obs.report DIR`)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the grid run into DIR")
    ap.add_argument("--metrics", default=None, metavar="DIR",
                    help="compile the live metric ring into every cell (grid "
                         "mode, bit-inert) and stream per-tick rows tagged by "
                         "cell to DIR/metrics.jsonl; watch with "
                         "`python -m repro.obs.monitor DIR`")
    ap.add_argument("--metrics-capacity", type=int, default=64,
                    help="on-device metric ring slots per cell; grids stream "
                         "the last `capacity` ticks of each chunk")
    # trust flags (repro.trust; grid + breakdown modes)
    ap.add_argument("--trust", action="store_true",
                    help="compile reputation-weighted screening + eviction "
                         "into every cell (repro.trust) — pair with rep_* "
                         "rules for soft down-weighting")
    ap.add_argument("--trust-evict", type=float, default=0.5,
                    help="suspicion threshold that latches an edge out")
    ap.add_argument("--trust-warmup", type=int, default=8,
                    help="ticks before evictions can latch")
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = {"net": "experiments/net", "grid": "experiments/grid",
                    "breakdown": "experiments/breakdown"}.get(
            args.mode, "experiments/dryrun")
    os.makedirs(args.out, exist_ok=True)
    if args.mode == "breakdown":
        run_breakdown_mode(args)
        return
    if args.mode == "grid":
        if args.scenarios is None:
            args.scenarios = "sync"  # default grid mode is the broadcast path
        if args.attacks is None:
            # selective_victim needs the net runtime; default per path
            sync = args.scenarios in ("sync", "none", "")
            args.attacks = "random,alie" if sync else "random,alie,selective_victim"
        run_grid_mode(args)
        return
    if args.scenarios is None:
        args.scenarios = ",".join(NET_SCENARIOS)
    if args.attacks is None:
        args.attacks = "random,alie,selective_victim"
    if args.mode == "net":
        jobs = [(r, a, s)
                for r in args.rules.split(",")
                for a in args.attacks.split(",")
                for s in args.scenarios.split(",")]
        print(f"{len(jobs)} net-scenario jobs -> {args.out}")
        with ThreadPoolExecutor(max_workers=args.jobs) as ex:
            futs = [ex.submit(run_net_job, r, a, s, args.out, args.timeout,
                              args.net_arch, args.net_steps) for r, a, s in jobs]
            for fut in futs:
                tag, status = fut.result()
                print(f"  {tag:60s} {status}", flush=True)
        return
    archs = args.archs.split(",") if args.archs else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPES
    jobs = []
    for arch in archs:
        for shape in shapes:
            jobs.append((arch, shape, False))
            if not args.single_pod_only:
                jobs.append((arch, shape, True))
    print(f"{len(jobs)} jobs -> {args.out}")
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_job, a, s, mp, args.out, args.timeout): (a, s, mp)
                for a, s, mp in jobs}
        for fut in __import__("concurrent.futures", fromlist=["as_completed"]).as_completed(futs):
            tag, status = fut.result()
            print(f"  {tag:60s} {status}", flush=True)


if __name__ == "__main__":
    main()
