"""Fan out experiment matrices as subprocesses.

Two modes, both resumable (existing results are skipped):

* ``--mode dryrun`` (default) — the arch x shape x mesh lowering matrix:

    PYTHONPATH=src python -m repro.launch.sweep --out experiments/dryrun \
        [--jobs 4] [--archs a,b] [--shapes s1,s2] [--single-pod-only]

* ``--mode net`` — the rule x attack x network-condition scenario matrix via
  `repro.launch.train --net` (reduced configs, CPU-runnable):

    PYTHONPATH=src python -m repro.launch.sweep --mode net \
        --out experiments/net [--rules trimmed_mean,median] \
        [--attacks random,alie,selective_victim] [--scenarios ideal,lossy]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS = [
    "starcoder2-3b", "zamba2-1.2b", "qwen3-4b", "whisper-medium",
    "qwen2-vl-2b", "rwkv6-3b", "mistral-nemo-12b", "deepseek-v2-236b",
    "deepseek-v3-671b", "gemma3-12b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def tag_for(arch, shape, multi_pod, extra=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}_{shape}_{mesh}{extra}"


def run_job(arch, shape, multi_pod, out_dir, timeout, extra_args=()):
    tag = tag_for(arch, shape, multi_pod, "".join(f"_{a.lstrip('-').replace('-','_')}" for a in extra_args if not a.startswith("--json")))
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        return tag, "cached"
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--json", out_dir,
    ]
    if shape == "train_4k":
        cmd.append("--remat")
    if multi_pod:
        cmd.append("--multi-pod")
    cmd.extend(extra_args)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
        if proc.returncode != 0:
            fail = {"arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": "failed", "stderr": proc.stderr[-3000:]}
            with open(path, "w") as f:
                json.dump(fail, f, indent=2)
            return tag, f"FAILED ({time.time()-t0:.0f}s)"
        return tag, f"ok ({time.time()-t0:.0f}s)"
    except subprocess.TimeoutExpired:
        fail = {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "timeout"}
        with open(path, "w") as f:
            json.dump(fail, f, indent=2)
        return tag, "TIMEOUT"


# Network-condition axis of the scenario matrix (--mode net); each maps to
# repro.launch.train --net flags.
NET_SCENARIOS = {
    "ideal": ["--net"],
    "lossy": ["--net", "--net-drop", "0.2"],
    "laggy": ["--net", "--net-latency", "3"],
    "lossy_laggy": ["--net", "--net-drop", "0.2", "--net-latency", "3"],
    "bandwidth64": ["--net", "--net-cap", "64"],
    "churn": ["--net", "--net-schedule", "churn", "--net-churn-prob", "0.3"],
    "partition": ["--net", "--net-schedule", "partition"],
}


def run_net_job(rule, attack, scenario, out_dir, timeout, arch, steps):
    tag = f"net_{rule}_{attack}_{scenario}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        return tag, "cached"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", arch, "--reduce", "--nodes", "6", "--byzantine", "1",
        "--rule", rule, "--attack", attack, "--steps", str(steps),
        "--batch", "2", "--seq", "32", "--log-every", str(steps),
    ] + NET_SCENARIOS[scenario]
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
        status = "ok" if proc.returncode == 0 else "failed"
        with open(path, "w") as f:
            json.dump({"rule": rule, "attack": attack, "scenario": scenario,
                       "status": status, "stdout": proc.stdout[-3000:],
                       "stderr": proc.stderr[-3000:] if status == "failed" else ""},
                      f, indent=2)
        return tag, f"{status.upper() if status != 'ok' else status} ({time.time()-t0:.0f}s)"
    except subprocess.TimeoutExpired:
        with open(path, "w") as f:
            json.dump({"rule": rule, "attack": attack, "scenario": scenario,
                       "status": "timeout"}, f, indent=2)
        return tag, "TIMEOUT"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="dryrun", choices=["dryrun", "net"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--rules", default="trimmed_mean,median")
    ap.add_argument("--attacks", default="random,alie,selective_victim")
    ap.add_argument("--scenarios", default=",".join(NET_SCENARIOS))
    ap.add_argument("--net-arch", default="qwen3-4b")
    ap.add_argument("--net-steps", type=int, default=30)
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = "experiments/net" if args.mode == "net" else "experiments/dryrun"
    os.makedirs(args.out, exist_ok=True)
    if args.mode == "net":
        jobs = [(r, a, s)
                for r in args.rules.split(",")
                for a in args.attacks.split(",")
                for s in args.scenarios.split(",")]
        print(f"{len(jobs)} net-scenario jobs -> {args.out}")
        with ThreadPoolExecutor(max_workers=args.jobs) as ex:
            futs = [ex.submit(run_net_job, r, a, s, args.out, args.timeout,
                              args.net_arch, args.net_steps) for r, a, s in jobs]
            for fut in futs:
                tag, status = fut.result()
                print(f"  {tag:60s} {status}", flush=True)
        return
    archs = args.archs.split(",") if args.archs else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPES
    jobs = []
    for arch in archs:
        for shape in shapes:
            jobs.append((arch, shape, False))
            if not args.single_pod_only:
                jobs.append((arch, shape, True))
    print(f"{len(jobs)} jobs -> {args.out}")
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_job, a, s, mp, args.out, args.timeout): (a, s, mp)
                for a, s, mp in jobs}
        for fut in __import__("concurrent.futures", fromlist=["as_completed"]).as_completed(futs):
            tag, status = fut.result()
            print(f"  {tag:60s} {status}", flush=True)


if __name__ == "__main__":
    main()
