"""Jittable step functions for the production mesh.

* ``make_train_step`` — one BRIDGE iteration (Algorithm 1) over the mesh:
  per-node local grads (vmap over the sharded node axis), gossip + screening
  over the node axis (the paper's technique), plain GD update with rho(t).
* ``make_prefill_step`` — inference prefill: forward, last-position logits
  (whisper: encoder + cross-KV build).
* ``make_serve_step`` — single-token decode against a KV cache/SSM state.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.gossip import gossip_screen_params
from repro.models import api as model_api
from repro.models import dense, encdec, hybrid, moe, ssm, vlm
from repro.models.config import ModelConfig


def make_train_step(
    cfg: ModelConfig,
    mesh,
    node_axes: tuple,
    param_specs: Any,
    adjacency: jnp.ndarray,
    *,
    rule: str = "trimmed_mean",
    num_byzantine: int = 0,
    gossip_schedule: str = "all_gather",
    lam: float = 1.0,
    t0: float = 200.0,
    gossip_first: bool = True,
    gossip_quantize: bool = False,
) -> Callable:
    """Returns train_step(params, batch, t) -> (new_params, metrics).

    ``gossip_first`` controls collective/compute overlap (§Perf): the screen
    of w(t) only depends on w(t), so issuing the gossip before the backward
    pass lets XLA's latency-hiding scheduler overlap ICI with the MXU.
    """
    api = model_api.build(cfg)

    def local_grads(params, batch):
        def one(p, bt):
            return jax.value_and_grad(lambda pp: api.train_loss(pp, bt, cfg))(p)

        return jax.vmap(one)(params, batch)

    def gossip(params, t):
        return gossip_screen_params(
            params, param_specs, mesh=mesh, node_axes=node_axes, rule=rule,
            b=num_byzantine, adjacency=adjacency, schedule=gossip_schedule, t=t,
            quantize=gossip_quantize,
        )

    def train_step(params, batch, t):
        if gossip_first:
            y = gossip(params, t)
            losses, grads = local_grads(params, batch)
        else:
            losses, grads = local_grads(params, batch)
            y = gossip(params, t)
        rho = (1.0 / (lam * (t0 + t))).astype(jnp.float32)

        def upd(yy, gg):
            return (yy.astype(jnp.float32) - rho * gg.astype(jnp.float32)).astype(yy.dtype)

        new_params = jax.tree_util.tree_map(upd, y, grads)
        return new_params, {"loss": jnp.mean(losses)}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill_step(params, batch) -> last-token logits [B, 1, V]
    (whisper: encoder output + cross-KV; see DESIGN.md)."""
    if cfg.family == "dense":
        def step(params, batch):
            return dense.forward(params, batch["tokens"], cfg, last_only=True)
    elif cfg.family == "vlm":
        def step(params, batch):
            tokens = batch["tokens"]
            x = vlm.merge_embeds(params, tokens, batch["image_embeds"], cfg)
            mpos = vlm.make_mrope_positions(tokens.shape[0], tokens.shape[1],
                                            batch["image_embeds"].shape[1])
            return dense.forward(params, tokens, cfg, input_embeds=x,
                                 mrope_positions=mpos, last_only=True)
    elif cfg.family == "moe":
        def step(params, batch):
            logits, _ = moe.forward(params, batch["tokens"], cfg, last_only=True)
            return logits
    elif cfg.family == "rwkv":
        def step(params, batch):
            return ssm.forward(params, batch["tokens"], cfg, last_only=True)
    elif cfg.family == "hybrid":
        def step(params, batch):
            return hybrid.forward(params, batch["tokens"], cfg, last_only=True)
    elif cfg.family == "encdec":
        def step(params, batch):
            enc_out = encdec.encode(params, batch["audio_embeds"], cfg)
            logits = encdec.decode_train(params, enc_out, batch["tokens"], cfg)
            return logits[:, -1:]
    else:
        raise ValueError(cfg.family)
    return step


def make_serve_step(cfg: ModelConfig) -> Callable:
    api = model_api.build(cfg)

    def serve_step(params, cache, batch):
        return api.decode_step(params, cache, batch["tokens"], cfg)

    return serve_step
