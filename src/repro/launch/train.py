"""End-to-end decentralized training driver (runs for real on local devices).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduce \
        --nodes 6 --byzantine 1 --attack random --rule trimmed_mean \
        --steps 100 --batch 4 --seq 128

``--reduce`` swaps in the reduced config (CPU-runnable); without it the full
config is used (requires a real cluster).  Supports checkpoint save/resume.

Network scenarios (repro.net): ``--net`` routes training through the
unreliable-network runtime; combine with ``--net-drop 0.2 --net-latency 3
--net-schedule churn`` etc.  Message-granularity attacks (selective_victim)
imply ``--net``.

Observability (repro.obs): ``--trace DIR`` compiles screening forensics into
the step (bit-inert), streams a JSONL event log to ``DIR/events.jsonl``, and
dumps ``DIR/obs_summary.json`` for ``python -m repro.obs.report DIR``.
``--profile DIR`` captures a ``jax.profiler`` trace of the training loop
(named scopes mark the gather/screen/apply/codec phases).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.core.byzantine import ATTACKS
from repro.data.tokens import TokenPipeline
from repro.models import api as model_api


def build_trainer(args, topo, grad_fn):
    """BridgeTrainer (synchronous) or AsyncBridgeTrainer (--net scenarios)."""
    from repro.core.byzantine import WIRE_ATTACKS

    trace = None
    if args.trace is not None:
        from repro.obs import TraceSpec

        trace = TraceSpec(reservoir=args.trace_reservoir)
    trust = None
    if args.trust:
        from repro.trust import TrustSpec

        trust = TrustSpec(evict_threshold=args.trust_evict,
                          warmup=args.trust_warmup,
                          echo=not args.trust_no_echo)
    mspec = None
    if args.metrics is not None:
        from repro.obs import MetricSpec

        mspec = MetricSpec(capacity=args.metrics_capacity)
    use_net = args.net or (args.attack not in ATTACKS and args.attack not in WIRE_ATTACKS)
    if not use_net:
        bcfg = BridgeConfig(
            topology=topo, rule=args.rule, num_byzantine=args.byzantine,
            attack=args.attack, adversary=args.adversary, codec=args.codec,
            lam=args.lam, t0=args.t0, lr=args.lr, sparse=args.sparse,
            trace=trace, trust=trust, metrics=mspec,
        )
        return BridgeTrainer(bcfg, grad_fn)
    from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
    from repro.net.dynamic import scenario_schedule

    channel = ChannelConfig(
        drop_prob=args.net_drop,
        latency_min=args.net_latency_min,
        latency_max=args.net_latency,
        bandwidth_cap=args.net_cap,
    )
    acfg = AsyncBridgeConfig(
        topology=topo, rule=args.rule, num_byzantine=args.byzantine,
        attack=args.attack, adversary=args.adversary, codec=args.codec,
        lam=args.lam, t0=args.t0, lr=args.lr, sparse=args.sparse,
        channel=channel, staleness_bound=args.net_staleness,
        schedule=scenario_schedule(args.net_schedule, topo, args.steps,
                                   seed=args.seed, churn_prob=args.net_churn_prob),
        trace=trace, trust=trust, metrics=mspec,
    )
    return AsyncBridgeTrainer(acfg, grad_fn)


def dump_obs(args, trainer, state, topo, events_path) -> str:
    """Render the final `TraceState` into ``obs_summary.json`` (the input of
    ``python -m repro.obs.report``)."""
    import json

    from repro.obs import trace as obs_trace

    m = args.nodes
    nbr = (trainer.neighbors if trainer.runtime is None
           else getattr(trainer.runtime, "neighbors", None))
    if nbr is not None:
        senders = obs_trace.sender_grid(m, neighbors=nbr)
    else:
        # net schedules vary per tick, so the mailbox width is the full grid
        senders = obs_trace.sender_grid(
            m, adjacency=None if trainer.runtime is not None else topo.adjacency)
    rec = obs_trace.summarize(trainer.config.trace, state.obs,
                              byz_mask=np.asarray(trainer.byz_mask), senders=senders)
    tag = f"{args.rule}_{args.attack}_b{args.byzantine}_s{args.seed}"
    summary = {"meta": {"nodes": m, "steps": args.steps, "rule": args.rule,
                        "attack": args.attack, "adversary": args.adversary,
                        "codec": args.codec, "events": events_path},
               "cells": [{"tag": tag, "rule": args.rule, **rec}]}
    path = os.path.join(args.trace, "obs_summary.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--nodes", type=int, default=6)
    ap.add_argument("--byzantine", type=int, default=1)
    ap.add_argument("--attack", default="none")
    ap.add_argument("--adversary", default="none",
                    help="adaptive adversary (repro.adversary): ipm, "
                         "alie_online, dissensus, inner_max, or any static "
                         "attack name (stateless tier)")
    ap.add_argument("--rule", default="trimmed_mean")
    ap.add_argument("--codec", default="identity",
                    help="wire codec (repro.comm): identity, int8, int4, "
                         "topk<P>[_int8|_int4], randk<P>[_int8|_int4]")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4, help="per-node batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--t0", type=float, default=100.0)
    ap.add_argument("--lr", type=float, default=0.0, help="constant lr override")
    ap.add_argument("--graph-p", type=float, default=0.8)
    ap.add_argument("--topology", default=None,
                    help="named topology spec (repro.core.graph.TOPOLOGIES): "
                         "erdos_renyi[:p], small_world[:nearest], "
                         "geometric[:radius], torus[:rows], complete; "
                         "default builds ER from --graph-p")
    ap.add_argument("--sparse", action="store_true",
                    help="neighbor-indexed [M, K] state layout "
                         "(repro.core.neighbors) — bit-identical to dense, "
                         "required past a few hundred nodes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    # network-scenario flags (repro.net)
    ap.add_argument("--net", action="store_true",
                    help="route training through the unreliable-network runtime")
    ap.add_argument("--net-drop", type=float, default=0.0, help="per-link drop probability")
    ap.add_argument("--net-latency", type=int, default=0, help="max link latency (ticks)")
    ap.add_argument("--net-latency-min", type=int, default=0)
    ap.add_argument("--net-cap", type=int, default=None, help="bandwidth cap (coordinates)")
    ap.add_argument("--net-staleness", type=int, default=5,
                    help="max usable message age (ticks)")
    ap.add_argument("--net-schedule", default="static",
                    choices=["static", "churn", "partition", "join_leave"])
    ap.add_argument("--net-churn-prob", type=float, default=0.2)
    # observability flags (repro.obs)
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="compile screening forensics into the step (bit-inert) "
                         "and write DIR/events.jsonl + DIR/obs_summary.json "
                         "(render with `python -m repro.obs.report DIR`)")
    ap.add_argument("--trace-reservoir", type=int, default=0,
                    help="raw-trace reservoir slots kept on device (0: "
                         "bounded aggregates only)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the training loop "
                         "into DIR (phases are jax.named_scope-annotated)")
    ap.add_argument("--metrics", default=None, metavar="DIR",
                    help="compile the live metric ring into the step "
                         "(bit-inert) and stream per-tick scalar rows to "
                         "DIR/metrics.jsonl via the chunked runner; watch "
                         "with `python -m repro.obs.monitor DIR`, export "
                         "with `python -m repro.obs.perfetto DIR`; pass the "
                         "same DIR as --trace to keep all artifacts together")
    ap.add_argument("--metrics-capacity", type=int, default=64,
                    help="on-device metric ring slots (= the chunked "
                         "runner's scan chunk length)")
    ap.add_argument("--wire-budget-bytes", type=float, default=None,
                    help="alert (obs.alert event) when cumulative wire bytes "
                         "cross this budget")
    # trust flags (repro.trust)
    ap.add_argument("--trust", action="store_true",
                    help="reputation-weighted screening + eviction "
                         "(repro.trust); pair with --rule rep_trimmed_mean / "
                         "rep_median for soft down-weighting, any rule gets "
                         "hard eviction")
    ap.add_argument("--trust-evict", type=float, default=0.5,
                    help="suspicion threshold that latches an edge out")
    ap.add_argument("--trust-warmup", type=int, default=8,
                    help="ticks before evictions can latch")
    ap.add_argument("--trust-no-echo", action="store_true",
                    help="disable the equivocation echo protocol (net path)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    api = model_api.build(cfg)
    print(f"arch={cfg.name} family={cfg.family} params(single)="
          f"{model_api.param_count(cfg):,}")

    if args.topology:
        from repro.core.graph import make_topology

        topo = make_topology(args.topology, args.nodes, args.byzantine, seed=args.seed)
    else:
        topo = erdos_renyi(args.nodes, args.graph_p, args.byzantine, seed=args.seed)
    trainer = build_trainer(args, topo, api.grad_fn())
    key = jax.random.PRNGKey(args.seed)
    params = replicate(api.init_params(key, cfg), args.nodes, perturb=0.01, key=key)
    state = trainer.init(params, seed=args.seed)
    start = 0
    if args.ckpt and checkpoint.latest_step(args.ckpt) is not None:
        # Checkpoint the *full* BridgeState — including the PRNG key and any
        # network-runtime state (in-flight mailboxes) — so a resumed lossy run
        # replays the exact channel/attack trace of an uninterrupted one.
        try:
            restored, start = checkpoint.restore(args.ckpt, tuple(state))
            state = type(state)(*jax.tree_util.tree_map(jnp.asarray, restored))
        except ValueError:
            # legacy (params, t) checkpoints: resume params but warn that the
            # PRNG/network state restarts (loss trace won't replay exactly)
            (p, t), start = checkpoint.restore(args.ckpt, (state.params, state.t))
            state = state._replace(params=jax.tree_util.tree_map(jnp.asarray, p),
                                   t=jnp.asarray(t))
            print("legacy checkpoint format: PRNG key / network state reinitialized")
        print(f"resumed from step {start}")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, args.nodes, seed=args.seed)

    # run-bracket artifacts (repro.obs): one directory holds the event log,
    # the live metric stream, and the manifest — pass the same DIR to both
    # --trace and --metrics to keep everything together
    run_dir = args.trace or args.metrics
    events = None
    if run_dir is not None:
        from repro.obs import EventLog, write_manifest

        os.makedirs(run_dir, exist_ok=True)
        extra = {}
        if trainer.runtime is not None:
            extra["network"] = trainer.runtime.describe()
        write_manifest(run_dir, kind="train", config=vars(args), extra=extra)
        events = EventLog(os.path.join(run_dir, "events.jsonl"))
        events.emit("run.start", kind="train", arch=cfg.name, nodes=args.nodes,
                    steps=args.steps, rule=args.rule, attack=args.attack,
                    net=bool(trainer.runtime is not None), resumed_at=start)
    mwriter = None
    if args.metrics is not None:
        from repro.obs import AlertRules, MetricWriter

        os.makedirs(args.metrics, exist_ok=True)
        mwriter = MetricWriter(
            os.path.join(args.metrics, "metrics.jsonl"),
            alerts=AlertRules(wire_budget_bytes=args.wire_budget_bytes),
            events=events)
    if args.profile is not None:
        os.makedirs(args.profile, exist_ok=True)
        jax.profiler.start_trace(args.profile)

    t_run = time.time()
    compile_s = 0.0
    t_last = time.time()
    if mwriter is not None:
        # chunked tick loop: jitted scan chunks with donated carries, the
        # metric ring flushed to the writer thread after each chunk (the
        # blocking device_get overlaps the next chunk's compute)
        def batch_at(i):
            return jax.tree_util.tree_map(jnp.asarray, pipe.batch(i))

        seg = args.ckpt_every if args.ckpt else max(args.steps - start, 1)
        done = start
        while done < args.steps:
            n = min(seg, args.steps - done)
            state, ms = trainer.run_chunks(state, batch_at, n, writer=mwriter,
                                           events=events, start=done)
            if done == start:
                # the first segment's wall is compile + n steps: close
                # enough that the steady-state remainder is honest
                jax.block_until_ready(state.params)
                compile_s = time.time() - t_run
            done += n
            if args.ckpt:
                checkpoint.save(args.ckpt, done, tuple(state))
            dt = time.time() - t_last
            t_last = time.time()
            print(f"step {done:5d}  loss {float(ms['loss'][-1]):.4f}  "
                  f"consensus {float(ms['consensus_dist'][-1]):.4f}  "
                  f"rho {float(ms['rho'][-1]):.5f}  {dt/n:.2f}s/step",
                  flush=True)
    else:
        for step in range(start, args.steps):
            batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(step))
            state, metrics = trainer.step(state, batch)
            if step == start:
                # the first step's wall is compile + one step: close enough to
                # the compile cost that the steady-state remainder is honest
                jax.block_until_ready(state.params)
                compile_s = time.time() - t_run
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t_last
                t_last = time.time()
                net = ""
                if "delivered_frac" in metrics:
                    net = (f"  delivered {float(metrics['delivered_frac']):.2f}"
                           f"  stale {float(metrics['mean_staleness']):.1f}")
                if args.codec != "identity" and "wire_bits_per_edge" in metrics:
                    net += f"  wire {float(metrics['wire_bits_per_edge'])/8:.0f}B/edge"
                print(
                    f"step {step+1:5d}  loss {float(metrics['loss']):.4f}  "
                    f"consensus {float(metrics['consensus_dist']):.4f}  "
                    f"rho {float(metrics['rho']):.5f}{net}  {dt/args.log_every:.2f}s/step",
                    flush=True,
                )
            if args.ckpt and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt, step + 1, tuple(state))
    state = jax.block_until_ready(state)
    wall = time.time() - t_run
    if args.profile is not None:
        jax.profiler.stop_trace()
        if events is not None:
            events.emit("profile.capture", dir=args.profile)
        print(f"profiler trace -> {args.profile}")
    if mwriter is not None:
        mwriter.close()
        print(f"metric stream -> {os.path.join(args.metrics, 'metrics.jsonl')}  "
              f"(watch: python -m repro.obs.monitor {args.metrics})")
    if events is not None:
        events.emit("run.end", steps=args.steps - start, wall_s=wall,
                    compile_s=compile_s, steady_state_s=max(wall - compile_s, 0.0))
        if state.obs is not None:
            first_bad = int(np.asarray(state.obs.first_bad))
            if first_bad >= 0:
                events.emit("obs.divergence", cell="train", first_bad_tick=first_bad)
        events.close()
    if args.trace is not None:
        path = dump_obs(args, trainer, state, topo,
                        os.path.join(run_dir, "events.jsonl"))
        print(f"obs summary -> {path}  "
              f"(render: python -m repro.obs.report {args.trace})")
    if run_dir is not None:
        from repro.obs import write_manifest

        write_manifest(run_dir, extra={"ended": True, "wall_s": wall,
                                       "steps": args.steps})
    if args.trust:
        from repro.obs import trace as obs_trace
        from repro.trust import summarize as trust_summarize

        nbr = (trainer.neighbors if trainer.runtime is None
               else getattr(trainer.runtime, "neighbors", None))
        if nbr is not None:
            senders = obs_trace.sender_grid(args.nodes, neighbors=nbr)
        else:
            senders = obs_trace.sender_grid(
                args.nodes,
                adjacency=None if trainer.runtime is not None else topo.adjacency)
        rec = trust_summarize(trainer.config.trust, state.trust,
                              byz_mask=np.asarray(trainer.byz_mask), senders=senders)
        print(f"trust: evicted {rec['edges_evicted']} edges "
              f"(byz {rec.get('byz_evicted', 0)}, honest {rec.get('honest_evicted', 0)}, "
              f"max suspicion {rec['max_suspicion']:.2f})")
    print("done.")


if __name__ == "__main__":
    main()


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "launch.prng.seed_plumbing", "lint",
        "no naked jax.random.PRNGKey in src/ outside seed plumbing: every "
        "key descends from a plumbed seed argument, or the site carries an "
        "explicit (file, function) waiver below",
        params=(
            ("check", "seed_plumbing"),
            ("waivers", (
                # documented default init key (the paper's common-ball init)
                ("repro/core/bridge.py", "replicate"),
                # keyless leaf screening falls back to a fixed public key
                ("repro/core/gossip.py", "coordwise_gossip_leaf"),
                # shape-only lowering: the key is never run
                ("repro/launch/dryrun.py", "build_lowerable"),
                # eval_shape parameter count: abstract, nothing drawn
                ("repro/models/api.py", "param_count"),
            )),
        ),
    ),
)
