"""Sharding-spec builders for parameters, batches and decode caches.

Rules (documented per DESIGN.md §5):

* Training params carry a leading node axis -> sharded over the mesh node
  axes (("pod","data") multi-pod, ("data",) single-pod).
* Within a replica, tensor parallelism over "model": MoE expert dims shard
  over "model" (expert parallelism); otherwise the last dim shards over
  "model" when it is large enough (>= 512).  Stack/scan leading dims are
  never sharded.  GSPMD handles non-divisible dims by padding.
* Serving params have no node axis; same inner rules.
* Serving caches: the batch dim shards over the node axes when divisible;
  otherwise the sequence dim does (long_500k B=1 -> sequence-parallel KV);
  the trailing head/latent dim shards over "model" when divisible.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

_MIN_SHARD = 512

# Row-parallel projections (Megatron pairing): these weights contract against
# an already-sharded activation, so we shard their INPUT dim; their outputs
# are then partial sums that XLA reduces with one all-reduce per block —
# instead of all-gathering the sharded activation before the matmul.
_ROW_PARALLEL = ("wo", "wd", "out_proj", "cm_v")


def _inner_spec(shape: tuple, cfg: ModelConfig, model_axis: str, *, skip_lead: int,
                row_parallel: bool = False) -> list:
    """Choose which (non-node) dim to shard over the model axis."""
    dims = [None] * len(shape)
    # expert parallelism: shard the expert dim
    if cfg.num_experts:
        for i in range(skip_lead, len(shape)):
            if shape[i] == cfg.num_experts:
                dims[i] = model_axis
                return dims
    if row_parallel and len(shape) - skip_lead >= 2 and shape[-2] >= _MIN_SHARD:
        dims[-2] = model_axis
        return dims
    # column-parallel default: last dim if large
    for i in reversed(range(skip_lead, len(shape))):
        if shape[i] >= _MIN_SHARD:
            dims[i] = model_axis
            return dims
    return dims


def _n_stack_dims(path: str, cfg: ModelConfig) -> int:
    """How many leading dims of this param leaf are layer-stack dims."""
    if "blocks" in path or "groups" in path or "rem" in path:
        # dense pattern groups are [G, P, ...]; others are [L, ...]
        return 2 if (cfg.pattern and "blocks" in path and cfg.family in ("dense", "vlm")) else 1
    if "mtp" in path or "shared_attn" in path:
        return 0
    return 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_specs(cfg: ModelConfig, params_shapes: Any, *, node_axes: tuple | None,
                model_axis: str = "model", layout: str = "tp") -> Any:
    """PartitionSpec pytree for a parameter tree (shapes from eval_shape).

    ``node_axes`` None -> serving layout (no node axis); otherwise training
    layout where every leaf's dim 0 is the node axis.

    ``layout``:
      * "tp" (default) — tensor parallelism over the model axis inside each
        node's replica (column/row-parallel pairing, expert parallelism).
      * "dp" — the replica is REPLICATED across the model axis and the
        node's batch is sharded over it instead (within-node data
        parallelism).  Only sensible when params+grads fit one chip; removes
        all per-layer TP collectives at the cost of per-step grad
        all-reduces (see EXPERIMENTS.md §Perf).
    """

    def leaf_spec(path, leaf):
        shape = tuple(leaf.shape)
        ps = _path_str(path)
        if layout == "dp":
            inner = [None] * (len(shape) - (1 if node_axes is not None else 0))
        else:
            rp = any(ps.endswith(k) or f"/{k}" in ps for k in _ROW_PARALLEL)
            lead = shape[1:] if node_axes is not None else shape
            inner = _inner_spec(lead, cfg, model_axis,
                                skip_lead=_n_stack_dims(ps, cfg), row_parallel=rp)
        if node_axes is not None:
            return P(node_axes, *inner)
        return P(*inner)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def train_batch_specs(batch_shapes: Any, node_axes: tuple, *, layout: str = "tp",
                      model_axis: str = "model") -> Any:
    """Training batches are [M, B/M, ...]: node axis sharded; under the "dp"
    layout the per-node batch dim additionally shards over the model axis."""
    inner0 = model_axis if layout == "dp" else None
    return jax.tree_util.tree_map(
        lambda l: P(node_axes, inner0, *([None] * (len(l.shape) - 2))), batch_shapes
    )


def serve_batch_specs(batch_shapes: Any, node_axes: tuple, global_batch: int,
                      mesh) -> Any:
    import math

    n = math.prod(mesh.shape[a] for a in node_axes)
    lead = node_axes if global_batch % n == 0 and global_batch >= n else None
    return jax.tree_util.tree_map(
        lambda l: P(lead, *([None] * (len(l.shape) - 1))), batch_shapes
    )


def cache_specs(cfg: ModelConfig, cache_shapes: Any, *, node_axes: tuple, mesh,
                batch: int, seq_len: int, model_axis: str = "model") -> Any:
    import math

    n_nodes = math.prod(mesh.shape[a] for a in node_axes)
    n_model = mesh.shape[model_axis]
    batch_ok = batch % n_nodes == 0 and batch >= n_nodes

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        dims: list = [None] * len(shape)
        placed_nodes = False
        for i, s in enumerate(shape):
            if not placed_nodes and batch_ok and s == batch:
                dims[i] = node_axes
                placed_nodes = True
                break
        if not placed_nodes:
            for i, s in enumerate(shape):
                if s == seq_len and s % n_nodes == 0:
                    dims[i] = node_axes
                    placed_nodes = True
                    break
        # model axis on the trailing dim when divisible (and not already used)
        if len(shape) >= 2 and dims[-1] is None and shape[-1] % n_model == 0 and shape[-1] >= n_model:
            dims[-1] = model_axis
        return P(*dims)

    return jax.tree_util.tree_map(leaf_spec, cache_shapes)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
