"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "starcoder2-3b", "zamba2-1.2b", "qwen3-4b", "whisper-medium",
    "qwen2-vl-2b", "rwkv6-3b", "mistral-nemo-12b", "deepseek-v2-236b",
    "deepseek-v3-671b", "gemma3-12b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str):
    rows = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        with open(f) as fh:
            d = json.load(fh)
        extra = ""
        base = os.path.basename(f)[:-5]
        parts = base.split("_")
        if base.count("_") > 3 or any(t in base for t in ("all_to_all", "remat_off", "nooverlap")):
            # variant runs (perf iterations) keyed separately
            rows[(d["arch"], d["shape"], d["mesh"], base)] = d
        rows[(d["arch"], d["shape"], d["mesh"])] = d
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1000:.1f}ms"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, k in [("TB", 1e12), ("GB", 1e9), ("MB", 1e6)]:
        if x >= k:
            return f"{x/k:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(rows, mesh="16x16"):
    lines = [
        "| arch | shape | peak mem/chip | compute | memory | collective | dominant | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape, mesh))
            if d is None:
                continue
            if d["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | skipped: {d['reason'][:40]} | — |")
                continue
            lines.append(
                f"| {arch} | {shape} | {d['mem_peak_gb']:.1f}GB | "
                f"{fmt_s(d['compute_s'])} | {fmt_s(d['memory_s'])} | "
                f"{fmt_s(d['collective_s'])} | **{d['dominant']}** | "
                f"{d['useful_flops_ratio'] if d['useful_flops_ratio'] else '-'} |"
            )
    return "\n".join(lines)


def dryrun_table(rows):
    lines = [
        "| arch | shape | mesh | status | compile | HLO flops/chip | HBM bytes/chip | collective wire/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                d = rows.get((arch, shape, mesh))
                if d is None:
                    continue
                if d["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {mesh} | skipped | — | — | — | — |")
                    continue
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {d['status']} | {d['compile_s']}s | "
                    f"{d['hlo_flops']:.2e} | {fmt_b(d['hlo_bytes'])} | "
                    f"{fmt_b(d['collective_wire_bytes'])} |"
                )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--which", default="both", choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.which in ("roofline", "both"):
        print("### Roofline (single-pod 16x16, per chip per step)\n")
        print(roofline_table(rows))
        print()
    if args.which in ("dryrun", "both"):
        print("### Dry-run matrix (both meshes)\n")
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
