"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent per-channel decay,
head size 64 (40 heads).  [arXiv:2404.05892]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    num_layers=32,
    d_model=2560,
    num_heads=40,        # informational; rwkv heads = d_model // mamba_headdim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    mamba_headdim=64,    # rwkv head size
)
