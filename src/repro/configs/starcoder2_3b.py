"""starcoder2-3b [dense] — GQA (kv=2), RoPE, GELU MLP, layernorm, attn bias.
[arXiv:2402.19173]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    act="gelu",
    norm="layernorm",
    attn_bias=True,
    rope_theta=1e5,
)
