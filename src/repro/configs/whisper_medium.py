"""whisper-medium [audio] — enc-dec transformer backbone; conv/mel frontend
is a stub (input_specs provides frame embeddings).  [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    attn_bias=True,
    max_target_len=448,
)
