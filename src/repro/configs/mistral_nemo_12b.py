"""mistral-nemo-12b [dense] — GQA (kv=8), 128k context (rope theta 1e6).
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1e6,
)
