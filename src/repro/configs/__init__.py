"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses

from repro.configs import shapes
from repro.configs.deepseek_v2_236b import CONFIG as _dsv2
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.zamba2_1p2b import CONFIG as _zamba2
from repro.models.config import ModelConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _starcoder2, _zamba2, _qwen3, _whisper, _qwen2vl,
        _rwkv6, _nemo, _dsv2, _dsv3, _gemma3,
    ]
}

SHAPES = shapes.SHAPES


def get_config(arch: str, **overrides) -> ModelConfig:
    try:
        cfg = ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}") from None
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


__all__ = ["ARCHS", "SHAPES", "get_config", "shapes"]
