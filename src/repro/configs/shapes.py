"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Shapes (from the assignment):
    train_4k       seq_len=  4,096  global_batch=256   (training)
    prefill_32k    seq_len= 32,768  global_batch= 32   (inference-prefill)
    decode_32k     seq_len= 32,768  global_batch=128   (inference-decode)
    long_500k      seq_len=524,288  global_batch=  1   (long-context-decode)

Training batches carry an explicit node axis [M, B/M, ...] (the BRIDGE
replica a sample belongs to).  Serving batches are flat [B, ...].

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — no
allocation; the dry-run lowers against them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

N_IMAGE_TOKENS = 256  # VLM stub: patch-embedding prefix length


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic path; see DESIGN.md)
LONG_OK = {"zamba2-1.2b", "rwkv6-3b", "gemma3-12b"}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_OK:
        return False, "pure full-attention arch; long_500k skipped (DESIGN.md)"
    if shape.kind == "decode" and cfg.family == "encdec" and shape.name == "long_500k":
        return False, "whisper: no 500k-frame use case"
    return True, ""


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_specs(cfg: ModelConfig, shape: InputShape, num_nodes: int):
    """Per-node training batch: dict of ShapeDtypeStructs, leading [M, B/M]."""
    assert shape.global_batch % num_nodes == 0, (shape.global_batch, num_nodes)
    b = shape.global_batch // num_nodes
    m, s = num_nodes, shape.seq_len
    dt = cfg.jdtype
    if cfg.family == "encdec":
        return {
            "audio_embeds": _sd((m, b, s, cfg.d_model), dt),
            "tokens": _sd((m, b, cfg.max_target_len + 1), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "tokens": _sd((m, b, s + 1), jnp.int32),
            "image_embeds": _sd((m, b, N_IMAGE_TOKENS, cfg.d_model), dt),
        }
    return {"tokens": _sd((m, b, s + 1), jnp.int32)}


def prefill_specs(cfg: ModelConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.jdtype
    if cfg.family == "encdec":
        return {"audio_embeds": _sd((b, s, cfg.d_model), dt),
                "tokens": _sd((b, cfg.max_target_len), jnp.int32)}
    if cfg.family == "vlm":
        return {"tokens": _sd((b, s), jnp.int32),
                "image_embeds": _sd((b, N_IMAGE_TOKENS, cfg.d_model), dt)}
    return {"tokens": _sd((b, s), jnp.int32)}


def decode_token_specs(cfg: ModelConfig, shape: InputShape):
    return {"tokens": _sd((shape.global_batch, 1), jnp.int32)}
