"""gemma3-12b [dense] — 5:1 local(1024-window):global attention pattern,
dual rope theta, 128k, head_dim=256, 262k vocab.  [hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    pattern=6,             # 5 local + 1 global per group
    sliding_window=1024,
    rope_theta=1e6,        # global layers
    rope_theta_local=1e4,  # local layers
    qk_norm=True,
)
