"""qwen2-vl-2b [vlm] — dense backbone + M-RoPE; ViT/projector is a stub
(input_specs provides patch embeddings).  [arXiv:2409.12191]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
)
