"""qwen3-4b [dense] — GQA (kv=8), qk_norm, SwiGLU, head_dim=128.
[hf:Qwen/Qwen3-8B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
)
