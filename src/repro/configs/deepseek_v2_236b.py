"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed experts
top-6, first layer dense.  [arXiv:2405.04434]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,            # dense-layer FFN
    moe_d_ff=1536,         # routed/shared expert hidden
    vocab_size=102400,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
)
