"""zamba2-1.2b [hybrid] — Mamba2 blocks + ONE shared attention block invoked
every 6 blocks (weights reused; per-invocation LoRA omitted, see DESIGN.md).
ssm_state=64.  [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,          # mamba2 blocks; 6 shared-attn invocations
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,              # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    mamba_headdim=64,
    mamba_expand=2,
    conv_kernel=4,
    attn_every=6,
    sliding_window=4096,    # decode-time window for long_500k (DESIGN.md)
)
