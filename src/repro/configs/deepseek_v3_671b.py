"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP,
first 3 layers dense.  [arXiv:2412.19437]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,            # dense-layer FFN
    moe_d_ff=2048,         # routed/shared expert hidden
    vocab_size=129280,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    first_dense_layers=3,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp=True,
)
