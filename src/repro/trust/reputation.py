"""Reputation-weighted screening: in-carry per-edge trust state (repro.trust).

BRIDGE screens *values* but never *identifies* attackers — a Byzantine node
can equivocate or keep landing in the trim window forever, and static 2b+1
redundancy pays the full degree tax at every tick.  `repro.obs` (PR 6) built
the detection statistic: per-edge trim-frequency counters rank true Byzantine
in-edges at AUC >= 0.95, in-scan and bit-inert.  This module closes the loop
and makes that statistic *act*:

* **suspicion** ``[M, W]`` — an EMA over per-tick evidence: the trim fraction
  each live in-edge contributed this tick (from the decision-instrumented
  screening twins, `repro.core.screening.RULES_WITH_DECISIONS`) plus any
  equivocation evidence from the echo protocol (`repro.trust.echo`);
* **reputation weights** — ``clip(1 - suspicion, 0, 1)``, consumed by the
  reputation-aware rules (``rep_trimmed_mean`` / ``rep_median``) registered
  in the banked rule dispatch;
* **eviction** — once suspicion crosses ``evict_threshold`` (after
  ``warmup`` ticks), the edge is latched out of the screening gather: its
  mask bit is cleared for the rest of the run, exactly as if the link had
  died.

The spec rides on `repro.core.bridge.CellParams` as *structural* auxiliary
data — `TrustSpec`, like `TraceSpec`, is a zero-leaf pytree, so it is jit
cache key, not operand.  ``trust=None`` (the default everywhere) keeps every
step builder's exact pre-trust program shape: trust off is bit-inert by
construction (property-tested in ``tests/test_trust.py``).

Minimal usage::

    from repro.core.bridge import BridgeConfig, BridgeTrainer
    from repro.trust import TrustSpec

    cfg = BridgeConfig(num_nodes=10, num_byzantine=2, rule="rep_trimmed_mean",
                       attack="sign_flip", trust=TrustSpec())
    trainer = BridgeTrainer(cfg, grad_fn, topology.adjacency)

Caveats stated once (see docs/ARCHITECTURE.md):

* honest edges get trimmed too — under trimmed-mean an honest edge's
  steady-state trim frequency is ~2b/n, and under median almost every edge
  is "trimmed" almost every tick (only the middle ranks survive).  Raw trim
  fractions would therefore evict honest edges; the trim evidence is
  **centered per receiver** — ``relu(trim_frac - mean over live in-edges)``
  — so only edges trimmed *more than their neighborhood's average* accrue
  suspicion.  Honest edges sit at or below the center and stay at ~0;
* per-edge *lossy* codecs (e.g. int8 with edge-keyed stochastic rounding)
  make honest payloads legitimately differ per receiver — raise
  ``echo_tol`` or keep the echo off under such codecs (the quorum rule
  already damps isolated false mismatches).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TrustSpec:
    """What the compiled step distrusts.  Hashable and frozen: it is jit
    *structure* (a zero-leaf pytree), so changing any field retraces — which
    is correct, the program genuinely differs."""

    # suspicion EMA: s' = decay * s + (1 - decay) * evidence, on live edges
    decay: float = 0.9
    # evidence = trim_weight * centered_trim + echo_weight * echo_evidence,
    # where centered_trim = relu(trim_frac - per-receiver live mean): edges
    # trimmed more than their neighborhood's average accrue suspicion (echo
    # evidence is 0/1 per edge — a confirmed equivocation quorum — so the
    # echo_weight default makes one confirmed equivocation evict within a
    # few ticks while trim evidence needs a sustained streak)
    trim_weight: float = 1.0
    echo_weight: float = 4.0
    # eviction latch: suspicion > evict_threshold after `warmup` ticks
    # permanently clears the edge's screening-mask bit
    evict_threshold: float = 0.5
    warmup: int = 8
    # commit-then-gossip echo protocol (net path only — the synchronous
    # broadcast path has a single per-sender payload, so equivocation is
    # structurally impossible there and the echo stage is elided)
    echo: bool = True
    # rolling random-projection digest width q (cheap commitment: q floats
    # per edge instead of d)
    digest_dim: int = 4
    # relative tolerance for digest comparison (0 would be exact; the
    # default absorbs benign reduction-order noise, and lossy per-edge
    # codecs need it raised — see module docstring)
    echo_tol: float = 1e-3
    # coordinate subsampling for the trim-membership pass, as TraceSpec
    decide_stride: int = 1

    def __post_init__(self):
        if (not 0.0 <= self.decay < 1.0 or self.trim_weight < 0.0
                or self.echo_weight < 0.0 or not 0.0 < self.evict_threshold <= 1.0
                or self.warmup < 0 or self.digest_dim < 1 or self.echo_tol < 0.0
                or self.decide_stride < 1):
            raise ValueError(f"invalid TrustSpec: {self}")


# Zero-leaf pytree registration: the spec flattens to no children and rides
# in the treedef — jit cache key, never a vmapped operand (TraceSpec idiom).
jax.tree_util.register_pytree_node(TrustSpec, lambda s: ((), s), lambda aux, _: aux)


class TrustState(NamedTuple):
    """The scanned trust carry (one per cell; the grid stacks a leading [E]).
    ``W`` is the per-node edge-slot count: M dense, K neighbor-indexed."""

    suspicion: jax.Array  # [M, W] f32 evidence EMA in [0, 1]
    evicted: jax.Array  # [M, W] bool latched eviction bits
    echo_mism: jax.Array  # [M, W] f32 accumulated confirmed-equivocation counts


def init_state(spec: TrustSpec | None, num_nodes: int, width: int, *,
               lead: tuple = ()) -> TrustState | None:
    """Fresh all-trusting state for one cell (``lead=(E,)`` stacks a grid's
    worth).  Every edge starts at suspicion 0 / weight 1 / not evicted."""
    if spec is None:
        return None
    mw = lead + (num_nodes, width)
    return TrustState(
        suspicion=jnp.zeros(mw, jnp.float32),
        evicted=jnp.zeros(mw, bool),
        echo_mism=jnp.zeros(mw, jnp.float32),
    )


def edge_weights(spec: TrustSpec, st: TrustState) -> jax.Array:
    """``[M, W]`` reputation weights the reputation-aware rules consume:
    ``clip(1 - suspicion, 0, 1)``, hard-zeroed on evicted edges."""
    w = jnp.clip(1.0 - st.suspicion, 0.0, 1.0)
    return jnp.where(st.evicted, 0.0, w)


def accumulate_trim(acc: jax.Array, trim_blk: jax.Array, frac: float) -> jax.Array:
    """Fold one coordinate block's ``[M, W]`` trim fractions into a tick's
    evidence accumulator (`repro.stream`): ``frac`` is the static weight
    ``block_size / d``, so the weights over a tick's blocks sum to 1 and the
    accumulated matrix is the all-coordinate trim fraction `update` expects —
    screening evidence is gathered *across* chunks but folded into the
    reputation carry exactly once per tick, keeping the carry one ``[M, W]``
    matrix regardless of d.  With a single block ``frac == 1.0`` and the fold
    is bitwise the identity (``x * 1.0 + 0.0``), which is what lets the
    streaming trust path match the flat decide path bit-for-bit at small d
    (pinned by ``tests/test_stream.py``)."""
    return acc + trim_blk * frac


def update(spec: TrustSpec, st: TrustState, *, t, trim_frac, live,
           echo_evidence=None) -> TrustState:
    """Fold one tick of evidence into the carry.  ``trim_frac``/``live`` are
    this tick's ``[M, W]`` trim fractions (already zeroed outside ``live``)
    and live-edge mask; ``echo_evidence`` the 0/1 confirmed-equivocation
    matrix from `repro.trust.echo` (None on the synchronous path).  Every op
    is vmap-safe (the grid maps this over [E])."""
    kw: dict[str, Any] = {}
    live_f = jnp.asarray(live, jnp.float32)
    trim32 = jnp.asarray(trim_frac, jnp.float32)
    # centered trim evidence: only trimming above the receiver's live-edge
    # average is suspicious (see module docstring — median-family rules trim
    # nearly everyone, and honest edges must stay at ~0 evidence)
    center = (jnp.sum(trim32 * live_f, axis=-1, keepdims=True)
              / jnp.maximum(jnp.sum(live_f, axis=-1, keepdims=True), 1.0))
    ev = spec.trim_weight * jnp.maximum(trim32 - center, 0.0)
    if echo_evidence is not None:
        ev = ev + spec.echo_weight * jnp.asarray(echo_evidence, jnp.float32)
        kw["echo_mism"] = st.echo_mism + echo_evidence
    susp = jnp.clip(spec.decay * st.suspicion + (1.0 - spec.decay) * ev, 0.0, 1.0)
    susp = jnp.where(live, susp, st.suspicion)
    kw["suspicion"] = susp
    kw["evicted"] = st.evicted | (
        (jnp.asarray(t) >= spec.warmup) & (susp > spec.evict_threshold))
    return st._replace(**kw)


# ---------------------------------------------------------------------------
# Host-side summaries (report / bench inputs)
# ---------------------------------------------------------------------------


def summarize(spec: TrustSpec, state: TrustState, *, byz_mask=None,
              senders: np.ndarray | None = None) -> dict:
    """One cell's trust state as a JSON-ready record: eviction counts split
    honest-vs-Byzantine against the known sender mask (the slander-bench
    acceptance metric is ``honest_evicted == 0``), plus the AUC of the
    suspicion scores ranking Byzantine in-edges."""
    from repro.obs.trace import ranking_auc

    susp = np.asarray(state.suspicion, np.float64)
    evicted = np.asarray(state.evicted, bool)
    mism = np.asarray(state.echo_mism, np.float64)
    out: dict[str, Any] = {
        "spec": dataclasses.asdict(spec),
        "edges_evicted": int(evicted.sum()),
        "echo_mismatch_total": float(mism.sum()),
        "max_suspicion": float(susp.max()) if susp.size else 0.0,
    }
    if senders is not None and byz_mask is not None:
        byz = np.asarray(byz_mask, bool)
        live_slot = senders >= 0
        recv, slot = np.nonzero(live_slot)
        send = senders[recv, slot]
        # trust, like forensics, is the honest nodes' view of their in-edges
        keep = ~byz[recv]
        recv, slot, send = recv[keep], slot[keep], send[keep]
        byz_edge = byz[send]
        ev = evicted[recv, slot]
        out["byz_edges"] = int(byz_edge.sum())
        out["honest_edges"] = int((~byz_edge).sum())
        out["byz_evicted"] = int(ev[byz_edge].sum())
        out["honest_evicted"] = int(ev[~byz_edge].sum())
        out["honest_eviction_rate"] = (
            float(ev[~byz_edge].mean()) if (~byz_edge).any() else 0.0)
        out["byz_eviction_rate"] = (
            float(ev[byz_edge].mean()) if byz_edge.any() else 0.0)
        out["auc_byzantine_edges"] = ranking_auc(susp[recv, slot], byz_edge)
    return out


# Trust metric streams registered with the grid result reducers (the sim
# layer warns on unregistered streams instead of dropping them silently).
def _register_reducers() -> None:
    from repro.sim import results as results_lib

    results_lib.register_mean("trust_evicted_frac")


_register_reducers()
