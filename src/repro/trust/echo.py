"""Commit-then-gossip echo protocol: equivocation detection (repro.trust).

An equivocator sends *different* payloads to different receivers — value
screening alone can never see this, because every individual receiver gets a
plausible message.  The echo protocol cross-checks receptions:

1. **commit** — each receiver digests what it currently holds from each
   in-neighbor with a cheap rolling random projection: ``h = payload @ R_t``
   where ``R_t`` is a fresh public ``[d, q]`` Gaussian drawn from the tick
   key (q = ``TrustSpec.digest_dim`` floats per edge instead of d — the
   commitment a sender implicitly makes by broadcasting);
2. **gossip** — one-hop neighbors exchange their digest rows over the
   tick's live links (the same links the payloads travelled);
3. **cross-check** — receivers j and l compare digests of a common sender i
   only when `repro.net.mailbox.generation_match` says both mailbox entries
   stem from the *same send tick* — drops and latency produce generation
   mismatches that are *excluded*, never counted as accusations;
4. **quorum** — an edge (j <- i) earns evidence 1.0 only when at least
   ``b + 1`` gossip witnesses disagree with j's digest.  At most b Byzantine
   witnesses exist, so slanderers forging their reported digest rows
   (`Adversary.accuse_fn`) can muster at most b votes and can never frame an
   honest sender — the slander bench asserts honest evictions stay at 0.
   An equivocator, by contrast, is contradicted by every honest witness in
   the *other* payload group at once, including at receivers it told the
   truth to.

The cross-check is computed in the dense ``[M, M]`` sender space on both
layouts (the sparse path scatters its ``[M, K]`` slots out and gathers the
evidence back), which keeps dense <-> sparse bitwise identical and costs
O(M^2 q + M^3) — fine at the study scales the trust layer targets (M <= ~64);
a neighborhood-local sparse gossip is future work (see docs/ARCHITECTURE.md).

Only the net/mailbox path runs the echo: the synchronous broadcast path has
one payload per sender by construction, so equivocation is structurally
impossible there and the trust layer falls back to trim evidence alone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.net import mailbox as mb


def digest_matrix(key: jax.Array, dim: int, digest_dim: int) -> jax.Array:
    """The tick's public random projection ``R_t [d, q]``.  Every node uses
    the same matrix (it is derived from the shared tick key, not a secret),
    so digests of identical payloads are identical floats."""
    return jax.random.normal(key, (dim, digest_dim), jnp.float32)


def digest_all(spec, values: jax.Array, key: jax.Array) -> jax.Array:
    """``[M, M, d] -> [M, M, q]`` honest digests of the mailbox contents."""
    r = digest_matrix(key, values.shape[-1], spec.digest_dim)
    return values @ r


def scatter_dense(neighbors, x: jax.Array, fill) -> jax.Array:
    """``[M, K, ...] -> [M, M, ...]``: slot (j, k) lands at column
    ``idx[j, k]``; padded slots are routed to a dropped out-of-range column,
    so they can never clobber a real sender's entry."""
    m = neighbors.num_nodes
    idx = jnp.where(neighbors.valid_dev, neighbors.safe_idx, m)  # m = drop
    rows = jnp.arange(m)[:, None]
    out = jnp.full((m, m) + x.shape[2:], fill, x.dtype)
    return out.at[rows, idx].set(x, mode="drop")


def equivocation_evidence(digests, gens, valid, gossip, b, *,
                          tol: float) -> tuple[jax.Array, jax.Array]:
    """Quorum cross-check in dense sender space.

    ``digests [M, M, q]`` — row j holds j's *reported* digests of what it
    received from each sender (slanderers have already forged their rows via
    `repro.adversary.protocols.apply_accuse_bank` by the time this runs);
    ``gens [M, M]`` the mailbox send-tick generations, ``valid [M, M]`` the
    usable-entry mask, ``gossip [M, M]`` the tick's live links
    (``gossip[j, l]`` = j hears l's digest row this tick), ``b`` the cell's
    Byzantine bound (traced int32), ``tol`` the spec's relative digest
    tolerance (a Python float — the spec is jit structure).  Returns
    ``(evidence [M, M] f32 in {0, 1}, mismatches [M, M] f32 witness counts)``.
    """
    # comparable (j, l, i): both j and l hold a usable entry from i, from the
    # SAME send generation, and l's row reached j this tick
    both = (valid[:, None, :] & valid[None, :, :]
            & mb.generation_match(gens[:, None, :], gens[None, :, :]))
    cmp = gossip[:, :, None] & both
    # relative digest comparison: exact payload copies digest to exact floats
    # (same public R_t), so tol only absorbs deliberate looseness (lossy
    # per-edge codecs — see repro.trust.reputation docstring)
    dj = digests[:, None, :, :]
    dl = digests[None, :, :, :]
    scale = 1.0 + jnp.maximum(jnp.abs(dj), jnp.abs(dl))
    differs = jnp.any(jnp.abs(dj - dl) > tol * scale, axis=-1)
    mism = jnp.sum(jnp.where(cmp & differs, 1.0, 0.0), axis=1)
    evidence = (mism >= (jnp.asarray(b, jnp.int32) + 1)).astype(jnp.float32)
    return evidence, mism
