"""repro.trust — reputation-weighted screening + equivocation echo protocol.

Turns the `repro.obs` suspicion statistic (per-edge trim frequency) into an
online robustness mechanism: an in-carry ``[M, W]`` reputation state decays
per-edge screening weights, a commit-then-gossip echo protocol surfaces
equivocation as quorum-confirmed mismatches, and an eviction threshold
zeroes confirmed attackers out of the screening gather.  Off by default and
bit-inert when off — see `repro.trust.reputation` for the full contract and
docs/ARCHITECTURE.md for where the trust stage sits in the tick.
"""
from repro.trust.reputation import (  # noqa: F401
    TrustSpec,
    TrustState,
    edge_weights,
    init_state,
    summarize,
    update,
)
from repro.trust import echo  # noqa: F401

__all__ = ["TrustSpec", "TrustState", "edge_weights", "init_state",
           "summarize", "update", "echo"]
