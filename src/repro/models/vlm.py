"""Qwen2-VL-style vision-language model (language backbone only).

Per the assignment carve-out, the ViT vision encoder + projector is a STUB:
``input_specs`` supplies precomputed patch embeddings [B, N_img, d] which are
prefix-injected in place of the first N_img token embeddings.  The backbone
is the dense decoder with M-RoPE — three rotary sections (t, h, w) driven by
3-component position ids (text tokens advance all three together; image
patches advance h/w over the grid at a constant t).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import dense, layers as L
from repro.models.config import ModelConfig

init_params = dense.init_params
init_cache = dense.init_cache


def make_mrope_positions(batch: int, seq: int, n_img: int, grid: int | None = None):
    """Default M-RoPE position ids [3, B, S]: image patches occupy a
    sqrt(N)xsqrt(N) grid at t=0; text follows with t=h=w advancing."""
    import math

    if grid is None:
        grid = max(int(math.isqrt(max(n_img, 1))), 1)
    t = jnp.concatenate([jnp.zeros((n_img,), jnp.int32), jnp.arange(seq - n_img, dtype=jnp.int32) + 1])
    hh = jnp.concatenate([jnp.arange(n_img, dtype=jnp.int32) // grid, jnp.arange(seq - n_img, dtype=jnp.int32) + grid])
    ww = jnp.concatenate([jnp.arange(n_img, dtype=jnp.int32) % grid, jnp.arange(seq - n_img, dtype=jnp.int32) + grid])
    pos = jnp.stack([t, hh, ww])  # [3, S]
    return jnp.broadcast_to(pos[:, None], (3, batch, seq))


def merge_embeds(params, tokens, image_embeds, cfg: ModelConfig):
    emb = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, cfg.jdtype)
    n_img = image_embeds.shape[1]
    return jnp.concatenate([image_embeds.astype(emb.dtype), emb[:, n_img:]], axis=1)


def forward(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    x = merge_embeds(params, tokens, batch["image_embeds"], cfg)
    mpos = batch.get("mrope_positions")
    if mpos is None:
        mpos = make_mrope_positions(tokens.shape[0], tokens.shape[1], batch["image_embeds"].shape[1])
    return dense.forward(params, tokens, cfg, input_embeds=x, mrope_positions=mpos)


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    n_img = batch["image_embeds"].shape[1]
    sub = dict(batch, tokens=inputs)
    if "mrope_positions" in batch:
        sub["mrope_positions"] = batch["mrope_positions"][:, :, :-1]
    logits = forward(params, sub, cfg)
    # only text positions contribute to the LM loss
    mask = (jnp.arange(labels.shape[1])[None, :] >= n_img).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, labels.shape)
    return L.softmax_xent(logits, labels, mask)


def decode_step(params, cache, tokens, cfg: ModelConfig):
    pos = cache["pos"]
    mpos = jnp.broadcast_to(pos[None, None, None], (3, tokens.shape[0], 1)).astype(jnp.int32)
    return dense.decode_step(params, cache, tokens, cfg, mrope_positions=mpos)
