"""Shared transformer building blocks (pure JAX, GSPMD-friendly).

Conventions:
* params are plain dicts of jnp arrays; init_* functions take a PRNG key.
* activations: x [B, S, D]; attention heads live in the last-but-one axis.
* attention is chunked (online-softmax over KV blocks) so [S, S] score
  matrices are never materialized; sliding-window attention additionally
  restricts compute to a static banded KV slice.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(d, dtype, *, with_bias=False):
    if with_bias:
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.zeros((d,), dtype)}  # rmsnorm stores (weight - 1)


def apply_norm(p, x, kind: str):
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE / M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(rot_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x, positions, theta: float, rot_dim: int | None = None):
    """x [..., S, H, Dh]; positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    rot = rot_dim or dh
    freqs = rope_freqs(rot, theta)  # [rot/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, rot/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # [..., S, 1, rot/2]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def apply_mrope(x, positions3, theta: float, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: positions3 [3, ..., S] = (t, h, w) position
    ids; the rotary half-dims are split into three sections, each rotated by
    its own position stream.  For pure text, t == h == w == position."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)  # [half]
    angs = []
    off = 0
    for i, sec in enumerate(sections):
        f = freqs[off : off + sec]
        angs.append(positions3[i][..., None].astype(jnp.float32) * f)
        off += sec
    ang = jnp.concatenate(angs, axis=-1)[..., None, :]  # [..., S, 1, half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked attention (flash-style online softmax, GQA)
# ---------------------------------------------------------------------------

_NEG = -1e30


def _expand_kv(k, heads_q):
    """GQA: repeat kv heads to match q heads."""
    hkv = k.shape[-2]
    if hkv == heads_q:
        return k
    rep = heads_q // hkv
    return jnp.repeat(k, rep, axis=-2)


def chunked_attention(q, k, v, *, causal=True, kv_chunk=1024, q_offset=None,
                      bias_mask=None):
    """Online-softmax attention over KV chunks.

    q [B, Sq, H, Dh]; k, v [B, Sk, Hkv, Dh].  ``q_offset`` gives the absolute
    position of q[:, 0] (for decode: Sk_done); default assumes q and k are
    aligned suffixes (training: q_offset = Sk - Sq = 0).
    [Sq, Sk] scores are never materialized — peak extra memory is
    O(Sq * kv_chunk) per head.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]  # may differ from dh (MLA)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(dh)
    nchunks = -(-sk // kv_chunk)
    pad = nchunks * kv_chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, nchunks, kv_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nchunks, kv_chunk, h, dv).transpose(1, 0, 2, 3, 4)
    qf = (q * scale).astype(jnp.float32)
    q_pos = jnp.arange(sq) + (q_offset if q_offset is not None else sk - sq)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, ci = inputs
        kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        valid = kv_pos[None, :] < sk
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        if bias_mask is not None:
            valid = valid & bias_mask(q_pos, kv_pos)
        s = jnp.where(valid[None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, Sq, H, Dh]


def sliding_window_attention(q, k, v, *, window: int, q_chunk: int = 512):
    """Causal attention restricted to a trailing window.  Scans q chunks and
    slices a static [q_chunk + window] KV band per chunk, so HLO FLOPs scale
    with S*window, not S^2.  Requires aligned q/k (training/prefill)."""
    b, s, h, dh = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(dh)
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    band = window + q_chunk
    # left-pad KV so every band slice is in range
    kp = jnp.pad(k, ((0, 0), (band - q_chunk, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band - q_chunk, 0), (0, 0), (0, 0)))
    nq = s // q_chunk

    def body(_, ci):
        q_start = ci * q_chunk
        qb = lax.dynamic_slice_in_dim(q, q_start, q_chunk, axis=1)
        kb = lax.dynamic_slice_in_dim(kp, q_start, band, axis=1)
        vb = lax.dynamic_slice_in_dim(vp, q_start, band, axis=1)
        qpos = q_start + jnp.arange(q_chunk)
        kpos = q_start - window + jnp.arange(band)
        valid = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window) & (kpos[None, :] >= 0)
        sco = jnp.einsum("bqhd,bkhd->bhqk", (qb * scale).astype(jnp.float32), kb.astype(jnp.float32))
        sco = jnp.where(valid[None, None], sco, _NEG)
        p = jax.nn.softmax(sco, axis=-1)
        ob = jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
        return None, ob.astype(q.dtype)

    _, chunks = lax.scan(body, None, jnp.arange(nq))
    return chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """Single-token attention against a [B, S, Hkv, Dh] cache.  ``cache_len``
    is the number of valid cache entries (scalar or [B])."""
    b, _, h, dh = q.shape
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * scale).astype(jnp.float32), k.astype(jnp.float32))
    pos = jnp.arange(k.shape[1])
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim == 1 else cl[None, None]
    valid = pos[None, :] < cl  # [B or 1, S]
    if window is not None:
        valid = valid & (pos[None, :] >= cl - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA + optional qk-norm + RoPE variants)
# ---------------------------------------------------------------------------


def init_attention(key, d_model, n_heads, n_kv, head_dim, dtype, *, qk_norm=False, bias=False):
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    if qk_norm:
        p["q_norm"] = init_norm(head_dim, dtype)
        p["k_norm"] = init_norm(head_dim, dtype)
    return p


def qkv_project(p, x, n_heads, n_kv, head_dim, *, qk_norm=False):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"]["w"])
        k = rms_norm(k, p["k_norm"]["w"])
    return q, k, v


def attn_output(p, o):
    b, s, h, dh = o.shape
    out = o.reshape(b, s, h * dh) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype, *, act="swiglu", bias=False):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        p = {
            "wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    else:
        p = {
            "wu": dense_init(ks[0], (d_model, d_ff), dtype),
            "wd": dense_init(ks[1], (d_ff, d_model), dtype),
        }
        if bias:
            p["bu"] = jnp.zeros((d_ff,), dtype)
            p["bd"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(p, x, act="swiglu"):
    if act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    h = x @ p["wu"]
    if "bu" in p:
        h = h + p["bu"]
    h = jax.nn.gelu(h)
    out = h @ p["wd"]
    if "bd" in p:
        out = out + p["bd"]
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
