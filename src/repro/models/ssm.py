"""State-space / linear-recurrence families: Mamba2 blocks and RWKV6 (Finch).

Both use chunked recurrences: the heavy intra-chunk work is expressed as
batched matmuls *outside* any sequential loop (vectorized over chunks), and
only the tiny inter-chunk state carry runs in a lax.scan — this keeps HLO
FLOPs attributable and makes the MXU do the work, which is the TPU-native
formulation of the SSD duality (Mamba2 paper) and of RWKV's WKV kernel.

Decode is O(1) in sequence length: the state tensor is the whole cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    dt = cfg.jdtype
    d = cfg.d_model
    din = cfg.mamba_expand * d
    nh = din // cfg.mamba_headdim
    n = cfg.ssm_state
    ks = jax.random.split(key, 4)
    conv_dim = din + 2 * n
    return {
        "norm": L.init_norm(d, dt),
        "in_proj": L.dense_init(ks[0], (d, 2 * din + 2 * n + nh), dt),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim), jnp.float32).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": L.init_norm(din, dt),
        "out_proj": L.dense_init(ks[2], (din, d), dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv along time.  x [B, S, C]; w [K, C].
    If ``state`` [B, K-1, C] is given (decode), uses it as left context and
    returns the updated state."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = xp[:, -(k - 1) :] if k > 1 else None
    return jax.nn.silu(out).astype(x.dtype), new_state


def ssd_chunked(xh, bmat, cmat, dt_a, chunk: int):
    """Chunked SSD linear recurrence.

    xh [B, S, H, P] inputs, bmat/cmat [B, S, N] (single group), dt_a [B, S, H]
    log-decay per step (negative).  Returns y [B, S, H, P].

    Within a chunk:   y_t = C_t . sum_{s<=t} (prod decay) B_s x_s
    expressed as a masked [c, c] attention-like matmul; across chunks the
    state h [B, H, P, N] carries with a tiny scan.
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, h, p)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)
    ac = dt_a.reshape(b, nc, chunk, h)  # log decay per step (<= 0)
    cum = jnp.cumsum(ac, axis=2)  # [B,nc,c,H] within-chunk cumulative log decay

    # intra-chunk (vectorized over chunks; mask = causal with decay ratios)
    li = cum[:, :, :, None, :]  # [B,nc,c,1,H] at t
    lj = cum[:, :, None, :, :]  # [B,nc,1,c,H] at s
    decay = jnp.exp(jnp.minimum(li - lj, 0.0))  # exp(cum_t - cum_s)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    g = jnp.einsum("bktn,bksn->bkts", cc, bc)  # [B,nc,c,c]
    w = g[..., None] * decay * causal[None, None, :, :, None]  # [B,nc,t,s,H]
    y_intra = jnp.einsum("bktsh,bkshp->bkthp", w, xc.astype(jnp.float32))

    # chunk-final states and inter-chunk carry
    tail = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from step to chunk end
    bx = jnp.einsum("bksn,bkshp,bksh->bkhpn", bc, xc.astype(jnp.float32), tail)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H] total chunk decay

    def carry_body(hstate, inp):
        bx_k, dec_k = inp  # [B,H,P,N], [B,H]
        h_in = hstate
        hstate = hstate * dec_k[..., None, None] + bx_k
        return hstate, h_in

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, h_prev = lax.scan(
        carry_body, h0,
        (bx.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N] state entering chunk

    # inter-chunk contribution: y_t += C_t . (decay to t) h_prev
    head_decay = jnp.exp(cum)  # [B,nc,c,H] decay from chunk start to t
    y_inter = jnp.einsum("bktn,bkhpn,bkth->bkthp", cc, h_prev, head_decay)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def mamba2_forward(p, x, cfg: ModelConfig, chunk: int = 128):
    b, s, d = x.shape
    din = cfg.mamba_expand * d
    nh = din // cfg.mamba_headdim
    hp = cfg.mamba_headdim
    n = cfg.ssm_state
    h = L.rms_norm(x, p["norm"]["w"])
    proj = h @ p["in_proj"]
    z, xi, bmat, cmat, dt = jnp.split(proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xi, bmat, cmat = jnp.split(conv_out, [din, din + n], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H] negative
    dt_a = dtv * a  # log decay
    xh = (xi.astype(jnp.float32) * dtv[..., None].repeat(hp, axis=-1).reshape(b, s, din)).reshape(b, s, nh, hp)
    y = ssd_chunked(xh, bmat.astype(jnp.float32), cmat.astype(jnp.float32), dt_a, chunk)
    y = y + p["d_skip"][None, None, :, None] * xi.reshape(b, s, nh, hp).astype(jnp.float32)
    y = y.reshape(b, s, din).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"]["w"])
    return x + y @ p["out_proj"]


def mamba2_decode(p, x, state, cfg: ModelConfig):
    """One-step Mamba2.  state = {"h": [B,H,P,N], "conv": [B,K-1,C]}."""
    b, _, d = x.shape
    din = cfg.mamba_expand * d
    nh = din // cfg.mamba_headdim
    hp = cfg.mamba_headdim
    n = cfg.ssm_state
    h = L.rms_norm(x, p["norm"]["w"])
    proj = h @ p["in_proj"]
    z, xi, bmat, cmat, dt = jnp.split(proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"], state["conv"])
    xi, bmat, cmat = jnp.split(conv_out, [din, din + n], axis=-1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dtv * a)  # [B,H]
    xh = (xi[:, 0].astype(jnp.float32) * dtv.repeat(hp, axis=-1).reshape(b, din)).reshape(b, nh, hp)
    hs = state["h"] * dec[..., None, None] + jnp.einsum("bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), hs)
    y = y + p["d_skip"][None, :, None] * xi[:, 0].reshape(b, nh, hp).astype(jnp.float32)
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["out_norm"]["w"])
    return x + y @ p["out_proj"], {"h": hs, "conv": conv_state}


def init_mamba_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    din = cfg.mamba_expand * d
    nh = din // cfg.mamba_headdim
    return {
        "h": jnp.zeros((batch, nh, cfg.mamba_headdim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, din + 2 * cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV6 (Finch) block
# ---------------------------------------------------------------------------


def init_rwkv6(key, cfg: ModelConfig):
    dt = cfg.jdtype
    d = cfg.d_model
    nh = d // cfg.mamba_headdim  # head_dim reuse: rwkv head size
    ks = jax.random.split(key, 10)
    lora = 64
    return {
        "ln1": L.init_norm(d, dt),
        "mu": 0.5 * jnp.ones((5, d), dt),  # token-shift mixes for r,k,v,g,w
        "wr": L.dense_init(ks[0], (d, d), dt),
        "wk": L.dense_init(ks[1], (d, d), dt),
        "wv": L.dense_init(ks[2], (d, d), dt),
        "wg": L.dense_init(ks[3], (d, d), dt),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),  # base log-log decay
        "w_lora_a": L.dense_init(ks[4], (d, lora), dt),
        "w_lora_b": L.dense_init(ks[5], (lora, d), dt, scale=0.01),
        "bonus": jnp.zeros((nh, cfg.mamba_headdim), jnp.float32),
        "gn": L.init_norm(d, dt),
        "wo": L.dense_init(ks[6], (d, d), dt),
        "ln2": L.init_norm(d, dt),
        "cm_mu": 0.5 * jnp.ones((2, d), dt),  # channel-mix token shift (k, r)
        "cm_k": L.dense_init(ks[7], (d, cfg.d_ff), dt),
        "cm_v": L.dense_init(ks[8], (cfg.d_ff, d), dt),
        "cm_r": L.dense_init(ks[9], (d, d), dt),
    }


def _token_shift(x, prev=None):
    """x [B,S,D] -> previous-token tensor (zero or given left context)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def wkv6_chunked(r, k, v, w_log, bonus, nh: int, chunk: int = 64):
    """Chunked WKV6: per-head linear attention with data-dependent per-channel
    decay.  r,k,v [B,S,D]; w_log [B,S,D] (log decay); bonus [H, hd].

    Recurrence (matches ``rwkv6_decode``):
        y_t = r_t . ( S_{t-1} + exp(u) ⊙ k_t v_t^T ),
        S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    so the s<t coefficient is exp(cum_{t-1} - cum_s) per channel.  We factor it
    as A_t = r_t exp(cum_{t-1}) (<= e since cum <= 0) and
    B_s = k_s exp(-cum_s) (<= exp(chunk * |w|_max)); w_log is clamped to
    >= -1 and chunk <= 64 keeps |cum| <= 64 < log(fp32_max) ~ 88, so the
    factored MXU form cannot overflow.  Returns [B,S,D] (fp32).
    """
    b, s, d = r.shape
    hd = d // nh
    w_log = jnp.clip(w_log, -1.0, -1e-6)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    shp = (b, nc, chunk, nh, hd)
    rc, kc, vc, wc = (t.astype(jnp.float32).reshape(shp) for t in (r, k, v, w_log))
    cum = jnp.cumsum(wc, axis=2)  # [b,nc,c,h,hd], decreasing, <= 0

    # intra-chunk:  att[t,s] = sum_d r_t exp(cum_{t-1}) . k_s exp(-cum_s)
    a_t = rc * jnp.exp(cum - wc)  # exp(cum_{t-1}) = exp(cum_t - w_t)
    b_s = kc * jnp.exp(-cum)
    att = jnp.einsum("bkthd,bkshd->bkhts", a_t, b_s)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly past
    att = jnp.where(mask[None, None, None], att, 0.0)
    y_intra = jnp.einsum("bkhts,bkshd->bkthd", att, vc)

    # diagonal bonus term: (r_t . exp(u) k_t) v_t
    diag = jnp.einsum("bkthd,bkthd->bkth", rc, kc * jnp.exp(bonus)[None, None, None])
    y_diag = diag[..., None] * vc

    # inter-chunk state carry: S [B,H,hd_k,hd_v]
    tail = jnp.exp(cum[:, :, -1:] - cum)  # decay from s to chunk end, <= 1
    kx = jnp.einsum("bkshd,bkshe->bkhde", kc * tail, vc)
    chunk_dec = jnp.exp(cum[:, :, -1])  # [b,nc,h,hd]

    def carry(hstate, inp):
        kx_k, dec_k = inp
        h_in = hstate
        hstate = hstate * dec_k[..., None] + kx_k
        return hstate, h_in

    h0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    _, h_prev = lax.scan(carry, h0, (kx.transpose(1, 0, 2, 3, 4), chunk_dec.transpose(1, 0, 2, 3)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [b,nc,h,hd,hd] state entering chunk
    y_inter = jnp.einsum("bkthd,bkhde->bkthe", a_t, h_prev)
    y = (y_intra + y_diag + y_inter).reshape(b, s, d)
    return y


def rwkv6_block(p, x, cfg: ModelConfig, chunk: int = 128):
    b, s, d = x.shape
    nh = d // cfg.mamba_headdim
    h = L.rms_norm(x, p["ln1"]["w"])
    prev = _token_shift(h)
    mix = lambda i: h + (prev - h) * p["mu"][i]
    r = mix(0) @ p["wr"]
    k = mix(1) @ p["wk"]
    v = mix(2) @ p["wv"]
    g = jax.nn.silu(mix(3) @ p["wg"])
    w_log = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + (jnp.tanh(mix(4) @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    )  # [B,S,D], <= 0
    y = wkv6_chunked(r, k, v, w_log, p["bonus"], nh, chunk=min(chunk, s))
    y = L.rms_norm(y.astype(x.dtype), p["gn"]["w"]) * g
    x = x + y @ p["wo"]
    # channel mix
    h2 = L.rms_norm(x, p["ln2"]["w"])
    prev2 = _token_shift(h2)
    km = h2 + (prev2 - h2) * p["cm_mu"][0]
    rm = h2 + (prev2 - h2) * p["cm_mu"][1]
    vv = jnp.square(jax.nn.relu(km @ p["cm_k"])) @ p["cm_v"]
    return x + jax.nn.sigmoid(rm @ p["cm_r"]) * vv


def rwkv6_decode(p, x, state, cfg: ModelConfig):
    """One-step RWKV6.  state = {"wkv": [B,H,hd,hd], "shift1": [B,D],
    "shift2": [B,D]}."""
    b, _, d = x.shape
    nh = d // cfg.mamba_headdim
    hd = cfg.mamba_headdim
    h = L.rms_norm(x, p["ln1"]["w"])[:, 0]  # [B,D]
    prev = state["shift1"]
    mix = lambda i: h + (prev - h) * p["mu"][i]
    r = (mix(0) @ p["wr"]).reshape(b, nh, hd)
    k = (mix(1) @ p["wk"]).reshape(b, nh, hd)
    v = (mix(2) @ p["wv"]).reshape(b, nh, hd)
    g = jax.nn.silu(mix(3) @ p["wg"])
    w_log = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + (jnp.tanh(mix(4) @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    ).reshape(b, nh, hd)
    w_log = jnp.clip(w_log, -1.0, -1e-6)  # match wkv6_chunked
    u = p["bonus"].reshape(nh, hd)
    kv = jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", r.astype(jnp.float32), state["wkv"] + jnp.exp(u)[None, ..., None] * kv)
    wkv_new = state["wkv"] * jnp.exp(w_log)[..., None] + kv
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = L.rms_norm(y, p["gn"]["w"]) * g[:, None]
    x = x + y @ p["wo"]
    h2 = L.rms_norm(x, p["ln2"]["w"])[:, 0]
    prev2 = state["shift2"]
    km = h2 + (prev2 - h2) * p["cm_mu"][0]
    rm = h2 + (prev2 - h2) * p["cm_mu"][1]
    vv = jnp.square(jax.nn.relu(km @ p["cm_k"])) @ p["cm_v"]
    x = x + (jax.nn.sigmoid(rm @ p["cm_r"]) * vv)[:, None]
    return x, {"wkv": wkv_new, "shift1": h, "shift2": h2}


def init_rwkv_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    nh = d // cfg.mamba_headdim
    return {
        "wkv": jnp.zeros((batch, nh, cfg.mamba_headdim, cfg.mamba_headdim), jnp.float32),
        "shift1": jnp.zeros((batch, d), cfg.jdtype),
        "shift2": jnp.zeros((batch, d), cfg.jdtype),
    }


# ---------------------------------------------------------------------------
# full RWKV6 model
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.num_layers + 2)
    blocks = [init_rwkv6(keys[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    dt = cfg.jdtype
    return {
        "embed": L.dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "blocks": stacked,
        "ln_f": L.init_norm(cfg.d_model, dt),
        "head": L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dt),
    }


def forward(params, tokens, cfg: ModelConfig, *, last_only: bool = False):
    x = params["embed"][tokens]

    def body(x, lp):
        return rwkv6_block(lp, x, cfg), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(scan_body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]
    return L.rms_norm(x, params["ln_f"]["w"]) @ params["head"]


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    logits = forward(params, tokens[:, :-1], cfg)
    return L.softmax_xent(logits, tokens[:, 1:])


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None):
    state = init_rwkv_state(cfg, batch)
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape), state
    )
    return {"state": stacked, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = params["embed"][tokens]

    def body(x, inputs):
        lp, st = inputs
        x, st_new = rwkv6_decode(lp, x, st, cfg)
        return x, st_new

    x, new_state = lax.scan(body, x, (params["blocks"], cache["state"]))
    logits = L.rms_norm(x, params["ln_f"]["w"]) @ params["head"]
    return logits, {"state": new_state, "pos": cache["pos"] + 1}
