"""Zamba2-style hybrid: a stack of Mamba2 blocks with ONE shared
attention+MLP block invoked periodically (every ``cfg.attn_every`` Mamba
blocks).  The shared block's weights are reused at every invocation —
Zamba2's parameter-sharing trick (we omit the per-invocation LoRA deltas;
noted in DESIGN.md).

Structure: scan over G = num_layers // attn_every groups, each group =
attn_every Mamba2 blocks followed by one shared-attention call; remainder
layers (num_layers % attn_every) run as plain Mamba2 blocks after the scan.

long_500k note: the shared attention uses a sliding window at decode time
(ring-buffer cache of ``cfg.sliding_window``), keeping the hybrid
sub-quadratic end to end.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense, layers as L, ssm
from repro.models.config import ModelConfig


def _attn_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, family="dense", pattern=0, sliding_window=None, attn_every=0,
        qk_norm=False,
    )


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    g = cfg.num_layers // cfg.attn_every
    rem = cfg.num_layers - g * cfg.attn_every
    return g, rem


def init_params(key, cfg: ModelConfig):
    g, rem = _groups(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    mamba = [ssm.init_mamba2(keys[i], cfg) for i in range(g * cfg.attn_every)]
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs).reshape((g, cfg.attn_every) + xs[0].shape), *mamba
    )
    rem_blocks = [ssm.init_mamba2(keys[g * cfg.attn_every + i], cfg) for i in range(rem)]
    dt = cfg.jdtype
    params = {
        "embed": L.dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "mamba_groups": stacked,
        "mamba_rem": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rem_blocks) if rem else None,
        "shared_attn": dense.init_block(keys[-3], _attn_cfg(cfg)),
        "ln_f": L.init_norm(cfg.d_model, dt),
        "head": L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dt),
    }
    return params


def forward(params, tokens, cfg: ModelConfig, *, last_only: bool = False):
    x = params["embed"][tokens]
    s = tokens.shape[1]
    positions = jnp.arange(s)
    acfg = _attn_cfg(cfg)

    def body(x, lp):
        for i in range(cfg.attn_every):
            sub = jax.tree_util.tree_map(lambda a: a[i], lp)
            x = ssm.mamba2_forward(sub, x, cfg, chunk=min(128, s))
        x = dense.block_apply(acfg, params["shared_attn"], x, positions, is_global=True)
        return x, None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(scan_body, x, params["mamba_groups"])
    if params.get("mamba_rem") is not None:
        def rem_body(x, lp):
            return ssm.mamba2_forward(lp, x, cfg, chunk=min(128, s)), None
        x, _ = lax.scan(rem_body, x, params["mamba_rem"])
    if last_only:
        x = x[:, -1:]
    return L.rms_norm(x, params["ln_f"]["w"]) @ params["head"]


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    logits = forward(params, tokens[:, :-1], cfg)
    return L.softmax_xent(logits, tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    g, rem = _groups(cfg)
    dt = dtype or cfg.jdtype
    mstate = ssm.init_mamba_state(cfg, batch)
    cache_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "mamba": jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None, None], (g, cfg.attn_every) + l.shape), mstate
        ),
        "mamba_rem": jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (rem,) + l.shape), mstate
        ) if rem else None,
        "attn_k": jnp.zeros((g, batch, cache_len, cfg.num_kv_heads, cfg.hd), dt),
        "attn_v": jnp.zeros((g, batch, cache_len, cfg.num_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = params["embed"][tokens]
    pos = cache["pos"]
    acfg = _attn_cfg(cfg)
    cache_len = cache["attn_k"].shape[2]
    slot = pos % cache_len  # ring buffer (windowed when cache_len < max_len)

    def body(x, inputs):
        lp, mstates, kc, vc = inputs
        new_states = []
        for i in range(cfg.attn_every):
            sub = jax.tree_util.tree_map(lambda a: a[i], lp)
            st = jax.tree_util.tree_map(lambda a: a[i], mstates)
            x, st_new = ssm.mamba2_decode(sub, x, st, cfg)
            new_states.append(st_new)
        # shared attention with ring-buffer KV cache
        sp = params["shared_attn"]
        h = L.apply_norm(sp["ln1"], x, acfg.norm)
        q, k, v = L.qkv_project(sp["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
        positions = pos[None]
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        o = L.decode_attention(q, kc, vc, jnp.minimum(pos + 1, cache_len))
        x = x + L.attn_output(sp["attn"], o)
        h2 = L.apply_norm(sp["ln2"], x, acfg.norm)
        x = x + L.mlp(sp["mlp"], h2, acfg.act)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_states)
        return x, (stacked, kc, vc)

    x, (mstates, kc, vc) = lax.scan(
        body, x, (params["mamba_groups"], cache["mamba"], cache["attn_k"], cache["attn_v"])
    )
    new_cache = dict(cache, mamba=mstates, attn_k=kc, attn_v=vc, pos=pos + 1)
    if params.get("mamba_rem") is not None:
        def rem_body(x, inputs):
            lp, st = inputs
            x, st_new = ssm.mamba2_decode(lp, x, st, cfg)
            return x, st_new
        x, rem_states = lax.scan(rem_body, x, (params["mamba_rem"], cache["mamba_rem"]))
        new_cache["mamba_rem"] = rem_states
    logits = L.rms_norm(x, params["ln_f"]["w"]) @ params["head"]
    return logits, new_cache
