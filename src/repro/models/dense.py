"""Dense decoder-only transformer family.

Covers: starcoder2-3b (GELU MLP, layernorm, attn bias), qwen3-4b (qk-norm),
mistral-nemo-12b (128k rope), gemma3-12b (5:1 local:global sliding-window
pattern, dual rope theta), and the text backbone reused by qwen2-vl (M-RoPE).

Layers are stacked and scanned in *pattern groups*: parameters are shaped
[G, P, ...] where P = cfg.pattern (1 when uniform); the scan body unrolls the
P positions statically, so local (sliding-window) and global (full-causal)
layers each get their own specialized attention HLO — no runtime branching.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig


def _pattern(cfg: ModelConfig) -> tuple[int, int]:
    p = cfg.pattern or 1
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    return cfg.num_layers // p, p


def _is_global(cfg: ModelConfig, pos_in_group: int) -> bool:
    if cfg.pattern and cfg.sliding_window:
        return pos_in_group == cfg.pattern - 1  # gemma3: 5 local then 1 global
    return cfg.sliding_window is None


def _layer_theta(cfg: ModelConfig, is_global: bool) -> float:
    if cfg.rope_theta_local is not None and not is_global:
        return cfg.rope_theta_local
    return cfg.rope_theta


def init_block(key, cfg: ModelConfig):
    dt = cfg.jdtype
    k1, k2 = jax.random.split(key)
    with_bias = cfg.norm == "layernorm"
    return {
        "ln1": L.init_norm(cfg.d_model, dt, with_bias=with_bias),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, dt,
            qk_norm=cfg.qk_norm, bias=cfg.attn_bias,
        ),
        "ln2": L.init_norm(cfg.d_model, dt, with_bias=with_bias),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dt, act=cfg.act, bias=cfg.attn_bias),
    }


def init_params(key, cfg: ModelConfig):
    g, p = _pattern(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    blocks = [init_block(keys[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs).reshape((g, p) + xs[0].shape), *blocks)
    dt = cfg.jdtype
    params = {
        "embed": L.dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "blocks": stacked,
        "ln_f": L.init_norm(cfg.d_model, dt, with_bias=cfg.norm == "layernorm"),
        "head": L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dt),
    }
    return params


def _attention(cfg, p, x, positions, *, is_global, mrope_positions=None):
    q, k, v = L.qkv_project(p, x, cfg.num_heads, cfg.num_kv_heads, cfg.hd, qk_norm=cfg.qk_norm)
    theta = _layer_theta(cfg, is_global)
    if cfg.mrope and mrope_positions is not None:
        q = L.apply_mrope(q, mrope_positions, theta, cfg.mrope_sections)
        k = L.apply_mrope(k, mrope_positions, theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, positions, theta)
        k = L.apply_rope(k, positions, theta)
    if is_global or cfg.sliding_window is None:
        o = L.chunked_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    else:
        o = L.sliding_window_attention(q, k, v, window=cfg.sliding_window, q_chunk=cfg.q_chunk)
    return L.attn_output(p, o)


def block_apply(cfg, p, x, positions, *, is_global, mrope_positions=None):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    x = x + _attention(cfg, p["attn"], h, positions, is_global=is_global,
                       mrope_positions=mrope_positions)
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    return x + L.mlp(p["mlp"], h, cfg.act)


def forward(params, tokens, cfg: ModelConfig, *, input_embeds=None, mrope_positions=None,
            last_only: bool = False):
    """tokens [B, S] -> logits [B, S, V] (or [B, 1, V] when ``last_only`` —
    the prefill step's output).  ``input_embeds`` overrides token embedding
    lookup (VLM prefix injection)."""
    x = params["embed"][tokens] if input_embeds is None else input_embeds
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.norm == "rmsnorm" else x
    s = x.shape[1]
    positions = jnp.arange(s)
    g, pat = _pattern(cfg)

    def body(x, lp):
        for p in range(pat):
            sub = jax.tree_util.tree_map(lambda a: a[p], lp)
            x = block_apply(cfg, sub, x, positions,
                            is_global=_is_global(cfg, p),
                            mrope_positions=mrope_positions)
        return x, None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(scan_body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    return x @ params["head"]


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inputs, cfg)
    mask = batch.get("mask")
    return L.softmax_xent(logits, labels, mask[:, 1:] if mask is not None else None)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    g, p = _pattern(cfg)
    dt = dtype or cfg.jdtype
    shape = (g, p, batch, max_len, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig, *, mrope_positions=None):
    """One-token decode: tokens [B, 1] -> logits [B, 1, V], updated cache."""
    x = params["embed"][tokens]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.norm == "rmsnorm" else x
    pos = cache["pos"]
    positions = pos[None] + jnp.zeros((1,), jnp.int32)
    g, pat = _pattern(cfg)

    def body(x, inputs):
        lp, kc, vc = inputs  # kc/vc [P, B, Smax, Hkv, hd]
        new_k, new_v = [], []
        for p in range(pat):
            sub = jax.tree_util.tree_map(lambda a: a[p], lp)
            is_global = _is_global(cfg, p)
            h = L.apply_norm(sub["ln1"], x, cfg.norm)
            q, k, v = L.qkv_project(sub["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                                    cfg.hd, qk_norm=cfg.qk_norm)
            theta = _layer_theta(cfg, is_global)
            if cfg.mrope and mrope_positions is not None:
                q = L.apply_mrope(q, mrope_positions, theta, cfg.mrope_sections)
                k = L.apply_mrope(k, mrope_positions, theta, cfg.mrope_sections)
            else:
                q = L.apply_rope(q, positions, theta)
                k = L.apply_rope(k, positions, theta)
            kcp = lax.dynamic_update_slice_in_dim(kc[p], k.astype(kc.dtype), pos, axis=1)
            vcp = lax.dynamic_update_slice_in_dim(vc[p], v.astype(vc.dtype), pos, axis=1)
            window = None if is_global else cfg.sliding_window
            o = L.decode_attention(q, kcp, vcp, pos + 1, window=window)
            x = x + L.attn_output(sub["attn"], o)
            h2 = L.apply_norm(sub["ln2"], x, cfg.norm)
            x = x + L.mlp(sub["mlp"], h2, cfg.act)
            new_k.append(kcp)
            new_v.append(vcp)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    x, (nk, nv) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = x @ params["head"]
    return logits, {"k": nk, "v": nv, "pos": pos + 1}
