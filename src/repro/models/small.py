"""Small models for the paper's own MNIST experiments (Sec. V):
the one-vs-all linear classifier with squared hinge loss (V-A, convex) and a
small CNN (V-B, nonconvex).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# linear classifier, squared hinge (strictly convex with L2 reg)
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int = 784, n_classes: int = 10):
    return {
        "w": 0.01 * jax.random.normal(key, (d_in, n_classes), jnp.float32),
        "b": jnp.zeros((n_classes,), jnp.float32),
    }


def linear_loss(params, batch, *, l2: float = 1e-4):
    """One-vs-all squared hinge: batch = (x [N, d], y [N] int labels)."""
    x, y = batch
    scores = x @ params["w"] + params["b"]  # [N, C]
    targets = 2.0 * jax.nn.one_hot(y, scores.shape[1]) - 1.0  # +-1
    margins = jnp.maximum(0.0, 1.0 - targets * scores)
    loss = jnp.mean(jnp.sum(margins**2, axis=1))
    reg = l2 * (jnp.sum(params["w"] ** 2) + jnp.sum(params["b"] ** 2))
    return loss + reg


def linear_accuracy(params, x, y):
    pred = jnp.argmax(x @ params["w"] + params["b"], axis=1)
    return jnp.mean((pred == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# small CNN (2 conv + 2 fc), nonconvex
# ---------------------------------------------------------------------------


def init_cnn(key, n_classes: int = 10, c1: int = 8, c2: int = 16, fc: int = 64):
    ks = jax.random.split(key, 4)
    he = lambda k, shape, fan: (jnp.sqrt(2.0 / fan) * jax.random.normal(k, shape, jnp.float32))
    return {
        "conv1": he(ks[0], (3, 3, 1, c1), 9),
        "conv2": he(ks[1], (3, 3, c1, c2), 9 * c1),
        "fc1": he(ks[2], (7 * 7 * c2, fc), 7 * 7 * c2),
        "b1": jnp.zeros((fc,), jnp.float32),
        "fc2": he(ks[3], (fc, n_classes), fc),
        "b2": jnp.zeros((n_classes,), jnp.float32),
    }


def _conv(x, w):
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool(x):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_logits(params, x):
    """x [N, 28, 28, 1] -> [N, C]."""
    h = jax.nn.relu(_conv(x, params["conv1"]))
    h = _maxpool(h)
    h = jax.nn.relu(_conv(h, params["conv2"]))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"] + params["b1"])
    return h @ params["fc2"] + params["b2"]


def cnn_loss(params, batch):
    x, y = batch
    logits = cnn_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def cnn_accuracy(params, x, y):
    pred = jnp.argmax(cnn_logits(params, x), axis=1)
    return jnp.mean((pred == y).astype(jnp.float32))
