"""Uniform model API: every architecture family exposes

    init_params(key, cfg)               -> params pytree
    train_loss(params, batch, cfg)      -> scalar loss
    init_cache(cfg, batch, max_len)     -> decode cache/state pytree
    decode_step(params, cache, tok, cfg)-> (logits, new cache)

`build(cfg)` returns a ModelApi namespace dispatching on cfg.family; the
BRIDGE trainer, launcher, dry-run and smoke tests all go through this.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax

from repro.models import dense, encdec, hybrid, moe, ssm, vlm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    train_loss: Callable
    init_cache: Callable
    decode_step: Callable
    extra: dict

    def grad_fn(self):
        """(params, batch) -> (loss, grads) — the local f_j gradient for
        BRIDGE's step 6."""
        cfg = self.cfg
        loss = self.train_loss

        def fn(params, batch):
            return jax.value_and_grad(lambda p: loss(p, batch, cfg))(params)

        return fn


_FAMILIES = {
    "dense": dense,
    "moe": moe,
    "rwkv": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


def build(cfg: ModelConfig) -> ModelApi:
    mod = _FAMILIES[cfg.family]
    extra = {}
    if cfg.family == "encdec":
        extra["prefill_cache"] = encdec.prefill_cache
        extra["encode"] = encdec.encode
    if cfg.family == "vlm":
        extra["make_mrope_positions"] = vlm.make_mrope_positions
    if cfg.family == "moe":
        extra["moe_ffn"] = moe.moe_ffn
    return ModelApi(
        cfg=cfg,
        init_params=mod.init_params,
        train_loss=mod.train_loss,
        init_cache=mod.init_cache,
        decode_step=mod.decode_step,
        extra=extra,
    )


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    import math

    shapes = jax.eval_shape(lambda k: build(cfg).init_params(k, cfg), jax.random.PRNGKey(0))
    return sum(
        math.prod(l.shape) if l.shape else 1
        for l in jax.tree_util.tree_leaves(shapes)
    )
