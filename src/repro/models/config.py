"""Model configuration dataclass shared by the whole zoo."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // num_heads
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_local: float | None = None  # gemma3 dual-theta
    # sliding-window pattern: window size for "local" layers; pattern gives
    # the local:global grouping (e.g. gemma3 pattern=6 -> 5 local + 1 global)
    sliding_window: int | None = None
    pattern: int = 0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # routed/shared expert hidden dim
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # --- MLA ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MTP (deepseek-v3) ---
    mtp: bool = False
    mtp_weight: float = 0.3
    # --- SSM / hybrid ---
    ssm_state: int = 0
    mamba_headdim: int = 64
    mamba_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0  # zamba2: shared attention every k mamba blocks
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    max_target_len: int = 448
    # --- vlm ---
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    # numerics
    dtype: str = "float32"
    # attention chunking
    kv_chunk: int = 1024
    q_chunk: int = 512
    remat: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        base = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.head_dim else None,
            kv_chunk=64,
            q_chunk=32,
        )
        if self.num_experts:
            base.update(num_experts=4, top_k=2, moe_d_ff=64,
                        num_shared_experts=min(self.num_shared_experts, 1),
                        first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            base.update(kv_lora_rank=32, q_lora_rank=32, qk_nope_head_dim=16,
                        qk_rope_head_dim=16, v_head_dim=16, head_dim=None)
        if self.ssm_state:
            base.update(ssm_state=16, mamba_headdim=16)
        if self.encoder_layers:
            base.update(encoder_layers=2)
        if self.mrope:
            # rescale sections to the reduced head_dim (sum == hd // 2)
            half = 16  # head_dim 32 below
            base.update(mrope_sections=(half // 4, 3 * half // 8, 3 * half // 8))
        if self.sliding_window:
            base.update(sliding_window=64)
        if self.pattern:
            base.update(pattern=2, num_layers=4)
        if self.attn_every:
            base.update(attn_every=2, num_layers=4)
        base.update(overrides)
        return dataclasses.replace(self, **base)

    def param_count(self) -> int:
        """Analytic total parameter count (for roofline MODEL_FLOPS)."""
        d, l, v = self.d_model, self.num_layers, self.vocab_size
        hd = self.hd
        emb = v * d
        if self.family == "rwkv":
            per = 4 * d * d + 2 * d * self.d_ff + d * (self.d_model // self.mamba_headdim) * 0
            # rough: time-mix (r,k,v,g,o ~ 5 d^2) + channel-mix (2 d dff)
            per = 5 * d * d + 2 * d * self.d_ff
            return emb * 2 + l * per
        attn = d * (self.num_heads * hd) * 2 + d * (self.num_kv_heads * hd) * 2
        if self.use_mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.num_heads * self.v_head_dim * d
            )
        ff_mult = 3 if self.act == "swiglu" else 2
        dense_ff = ff_mult * d * self.d_ff
        if self.num_experts:
            moe_ff = ff_mult * d * self.moe_d_ff * (self.num_experts + self.num_shared_experts)
            n_moe = l - self.first_dense_layers
            total_ff = self.first_dense_layers * dense_ff + n_moe * (moe_ff + d * self.num_experts)
        else:
            total_ff = l * dense_ff
        total = emb * 2 + l * attn + total_ff
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_ff) + l * attn  # cross-attn
        if self.family == "hybrid":
            din = self.mamba_expand * d
            nh = din // self.mamba_headdim
            mamba = d * (2 * din + 2 * nh * self.ssm_state // (self.ssm_state or 1) * self.ssm_state + nh) + din * d
            mamba = d * 2 * din + din * (2 * self.ssm_state) + din * d + din * self.conv_kernel
            total = emb * 2 + l * mamba + (attn + dense_ff)  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed)."""
        if not self.num_experts:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        ff_mult = 3 if self.act == "swiglu" else 2
        full = self.param_count()
        moe_ff_all = ff_mult * d * self.moe_d_ff * self.num_experts
        moe_ff_act = ff_mult * d * self.moe_d_ff * self.top_k
        n_moe = l - self.first_dense_layers
        return int(full - n_moe * (moe_ff_all - moe_ff_act))
