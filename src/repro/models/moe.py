"""DeepSeek-style MoE transformer: Multi-head Latent Attention (MLA) +
shared/routed experts with top-k token-choice routing and capacity dropping.

Covers deepseek-v2-236b (160 routed top-6, 2 shared, kv_lora 512) and
deepseek-v3-671b (256 routed top-8, 1 shared, + MTP head).

TPU adaptation notes:
* Routing uses the sort-based dispatch (argsort by expert id + capacity
  padding) so expert matmuls are dense [E, C, d] x [E, d, ff] einsums that
  map straight onto the MXU with the expert axis sharded over "model"
  (expert parallelism).  GSPMD materializes the token shuffle as an
  all-to-all — exactly the collective the roofline tracks.
* Decode uses the *absorbed* MLA form: queries are projected into the
  kv_lora latent space so the cache stays compressed [B, S, r + rope] and
  no per-step [B, S, H, dh] key/value materialization happens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# MLA attention
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    h, dn, dr, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p = {
        "wq_a": L.dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), dt),
        "q_norm": L.init_norm(cfg.q_lora_rank, dt),
        "wq_b": L.dense_init(ks[1], (cfg.q_lora_rank, h * (dn + dr)), dt),
        "wkv_a": L.dense_init(ks[2], (cfg.d_model, cfg.kv_lora_rank + dr), dt),
        "kv_norm": L.init_norm(cfg.kv_lora_rank, dt),
        "wk_b": L.dense_init(ks[3], (cfg.kv_lora_rank, h * dn), dt),
        "wv_b": L.dense_init(ks[4], (cfg.kv_lora_rank, h * dv), dt),
        "wo": L.dense_init(ks[5], (h * dv, cfg.d_model), dt),
    }
    return p


def mla_qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = L.rms_norm(x @ p["wq_a"], p["q_norm"]["w"]) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    c_kv = L.rms_norm(c_kv, p["kv_norm"]["w"])
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,dr]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, x, cfg: ModelConfig, positions):
    """Prefill/training MLA: expand the latent back to per-head K/V."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = mla_qkv(p, x, cfg, positions)
    k_nope = (c_kv @ p["wk_b"]).reshape(b, s, h, dn)
    v = (c_kv @ p["wv_b"]).reshape(b, s, h, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    o = L.chunked_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    return o.reshape(b, s, h * dv) @ p["wo"]


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed-form single-token MLA decode against the compressed cache."""
    b = x.shape[0]
    h, dn, dr, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    positions = pos[None]
    q_nope, q_rope, c_kv_new, k_rope_new = mla_qkv(p, x, cfg, positions)
    ckv = lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv_new.astype(cache["ckv"].dtype), pos, axis=1)
    krope = lax.dynamic_update_slice_in_dim(cache["krope"], k_rope_new[:, :, 0].astype(cache["krope"].dtype), pos, axis=1)
    # absorb W_uk into q:  q_c [B,1,H,r]
    wk = p["wk_b"].reshape(r, h, dn)
    q_c = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), wk.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))
    s_c = jnp.einsum("bqhr,bkr->bhqk", q_c, ckv.astype(jnp.float32))
    s_r = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), krope.astype(jnp.float32))
    s = (s_c + s_r) * scale
    valid = jnp.arange(ckv.shape[1])[None, :] < (pos + 1)
    s = jnp.where(valid[:, None, None, :] if valid.ndim == 2 else valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhqk,bkr->bqhr", pr, ckv.astype(jnp.float32))  # [B,1,H,r]
    wv = p["wv_b"].reshape(r, h, dv)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx_c, wv.astype(jnp.float32)).astype(x.dtype)
    out = o.reshape(b, 1, h * dv) @ p["wo"]
    return out, {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# routed experts
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ModelConfig):
    dt = cfg.jdtype
    ks = jax.random.split(key, 5)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": L.dense_init(ks[0], (d, e), jnp.float32),
        "wg": L.dense_init(ks[1], (e, d, f), dt),
        "wu": L.dense_init(ks[2], (e, d, f), dt),
        "wd": L.dense_init(ks[3], (e, f, d), dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, f * cfg.num_shared_experts, dt, act="swiglu")
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = int(num_tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(c, cfg.top_k)


def route_topk(router_logits, cfg: ModelConfig):
    """Token-choice top-k with normalized gates (DeepSeek style)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, cfg.top_k)  # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return probs, gate_vals, gate_idx


def moe_ffn(p, x, cfg: ModelConfig):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(t, cfg)
    xf = x.reshape(t, d)
    probs, gate_vals, gate_idx = route_topk(xf @ p["router"], cfg)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = gate_idx.reshape(-1)  # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    lin = jnp.arange(t * k)
    is_new = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = lax.cummax(jnp.where(is_new, lin, 0))
    rank_sorted = lin - seg_start
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)  # overflow -> scratch slot
    # slot -> (token, k-choice) inverse map
    tok_of_choice = jnp.arange(t * k) // k
    slot_tok = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(tok_of_choice.astype(jnp.int32))
    slot_tok = slot_tok[: e * cap]
    slot_valid = slot_tok < t
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    # dispatch in the model dtype: the [E, C, d] buffer is the layer's largest
    # transient — keeping it bf16 halves MoE HBM traffic (EXPERIMENTS §Perf)
    expert_in = xf_pad[slot_tok].reshape(e, cap, d).astype(cfg.jdtype)  # [E, C, d]

    # ---- expert computation (MXU batched over the sharded expert axis) --
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", expert_in, p["wu"]
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(e * cap, d)

    # ---- combine ---------------------------------------------------------
    gate_flat = gate_vals.reshape(-1)  # [T*K]
    slot_gate = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(jnp.where(keep, gate_flat, 0.0))
    slot_gate = slot_gate[: e * cap]
    contrib = expert_out.astype(jnp.float32) * (slot_gate * slot_valid)[:, None]
    out = jnp.zeros((t + 1, d), jnp.float32).at[slot_tok].add(contrib)[:t]
    out = out.astype(x.dtype).reshape(b, s, d)

    # ---- auxiliary load-balance loss (Switch/DeepSeek style) ------------
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(jax.nn.one_hot(gate_idx, e).sum(axis=1), axis=0)  # token frac
    aux = e * jnp.sum(me * ce)

    if "shared" in p:
        out = out + L.mlp(p["shared"], x, "swiglu")
    return out, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, *, dense_ffn: bool):
    dt = cfg.jdtype
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_norm(cfg.d_model, dt),
        "attn": init_mla(k1, cfg),
        "ln2": L.init_norm(cfg.d_model, dt),
    }
    if dense_ffn:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dt, act="swiglu")
    else:
        p["moe"] = init_moe_ffn(k2, cfg)
    return p


def init_params(key, cfg: ModelConfig):
    nd = cfg.first_dense_layers
    keys = jax.random.split(key, cfg.num_layers + 4)
    dense_blocks = [init_block(keys[i], cfg, dense_ffn=True) for i in range(nd)]
    moe_blocks = [init_block(keys[i], cfg, dense_ffn=False) for i in range(nd, cfg.num_layers)]
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    dt = cfg.jdtype
    params = {
        "embed": L.dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "dense_blocks": stack(dense_blocks) if dense_blocks else None,
        "moe_blocks": stack(moe_blocks),
        "ln_f": L.init_norm(cfg.d_model, dt),
        "head": L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dt),
    }
    if cfg.mtp:
        k1, k2 = jax.random.split(keys[-3])
        params["mtp"] = {
            "proj": L.dense_init(k1, (2 * cfg.d_model, cfg.d_model), dt),
            "block": init_block(k2, cfg, dense_ffn=True),
            "ln": L.init_norm(cfg.d_model, dt),
        }
    return params


def _block_fwd(cfg, p, x, positions, *, dense_ffn: bool):
    h = L.rms_norm(x, p["ln1"]["w"])
    x = x + mla_attention(p["attn"], h, cfg, positions)
    h = L.rms_norm(x, p["ln2"]["w"])
    if dense_ffn:
        return x + L.mlp(p["mlp"], h, "swiglu"), 0.0
    out, aux = moe_ffn(p["moe"], h, cfg)
    return x + out, aux


def forward(params, tokens, cfg: ModelConfig, *, return_hidden=False, last_only: bool = False):
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, cfg.jdtype)
    s = tokens.shape[1]
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)
    if params.get("dense_blocks") is not None:
        def dbody(carry, lp):
            x, aux = carry
            x, a = _block_fwd(cfg, lp, x, positions, dense_ffn=True)
            return (x, aux + a), None
        dbody = jax.checkpoint(dbody) if cfg.remat else dbody
        (x, aux_total), _ = lax.scan(dbody, (x, aux_total), params["dense_blocks"])

    def mbody(carry, lp):
        x, aux = carry
        x, a = _block_fwd(cfg, lp, x, positions, dense_ffn=False)
        return (x, aux + a), None

    mbody = jax.checkpoint(mbody) if cfg.remat else mbody
    (x, aux_total), _ = lax.scan(mbody, (x, aux_total), params["moe_blocks"])
    if last_only:
        x = x[:, -1:]
    h_final = L.rms_norm(x, params["ln_f"]["w"])
    logits = h_final @ params["head"]
    if return_hidden:
        return logits, aux_total, x
    return logits, aux_total


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    if cfg.mtp:
        logits, aux, hidden = forward(params, inputs, cfg, return_hidden=True)
        loss = L.softmax_xent(logits, labels)
        # MTP: predict token t+2 from hidden_t combined with emb(token_{t+1})
        emb_next = params["embed"][inputs[:, 1:]] * jnp.asarray(cfg.d_model ** 0.5, cfg.jdtype)
        h_in = jnp.concatenate(
            [L.rms_norm(hidden[:, :-1], params["mtp"]["ln"]["w"]), emb_next], axis=-1
        ) @ params["mtp"]["proj"]
        positions = jnp.arange(h_in.shape[1])
        h_mtp, _ = _block_fwd(cfg, params["mtp"]["block"], h_in, positions, dense_ffn=True)
        logits2 = L.rms_norm(h_mtp, params["ln_f"]["w"]) @ params["head"]
        mtp_loss = L.softmax_xent(logits2[:, :-1], labels[:, 2:] if labels.shape[1] > 2 else labels[:, -1:])
        loss = loss + cfg.mtp_weight * mtp_loss
    else:
        logits, aux = forward(params, inputs, cfg)
        loss = L.softmax_xent(logits, labels)
    return loss + cfg.router_aux_weight * aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.jdtype
    nd = cfg.first_dense_layers
    nm = cfg.num_layers - nd
    mk = lambda n: {
        "ckv": jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((n, batch, max_len, cfg.qk_rope_head_dim), dt),
    }
    return {"dense": mk(nd) if nd else None, "moe": mk(nm), "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, cfg.jdtype)
    pos = cache["pos"]

    def make_body(dense_ffn):
        def body(x, inputs):
            lp, ckv, krope = inputs
            h = L.rms_norm(x, lp["ln1"]["w"])
            att, newc = mla_decode(lp["attn"], h, {"ckv": ckv, "krope": krope}, pos, cfg)
            x = x + att
            h = L.rms_norm(x, lp["ln2"]["w"])
            if dense_ffn:
                x = x + L.mlp(lp["mlp"], h, "swiglu")
            else:
                out, _ = moe_ffn(lp["moe"], h, cfg)
                x = x + out
            return x, (newc["ckv"], newc["krope"])
        return body

    new_cache = {"pos": pos + 1, "dense": None}
    if params.get("dense_blocks") is not None:
        x, (ck, kr) = lax.scan(
            make_body(True), x,
            (params["dense_blocks"], cache["dense"]["ckv"], cache["dense"]["krope"]),
        )
        new_cache["dense"] = {"ckv": ck, "krope": kr}
    x, (ck, kr) = lax.scan(
        make_body(False), x,
        (params["moe_blocks"], cache["moe"]["ckv"], cache["moe"]["krope"]),
    )
    new_cache["moe"] = {"ckv": ck, "krope": kr}
    logits = L.rms_norm(x, params["ln_f"]["w"]) @ params["head"]
    return logits, new_cache
