from repro.models.api import ModelApi, build, param_count
from repro.models.config import ModelConfig

__all__ = ["ModelApi", "ModelConfig", "build", "param_count"]
