"""Whisper-style encoder-decoder (audio backbone only).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs`` supplies precomputed frame embeddings [B, S_src, d]
(a single linear ``frontend_proj`` stands in for the conv stack's output
projection).  We implement the transformer encoder (bidirectional), the
causal decoder with cross-attention, learned positional embeddings, GELU
MLPs, and layernorm — the Whisper recipe.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig

MAX_SOURCE_LEN = 32768  # supports the prefill_32k input shape


def init_enc_block(key, cfg: ModelConfig):
    dt = cfg.jdtype
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg.d_model, dt, with_bias=True),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, dt, bias=True),
        "ln2": L.init_norm(cfg.d_model, dt, with_bias=True),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dt, act="gelu", bias=True),
    }


def init_dec_block(key, cfg: ModelConfig):
    dt = cfg.jdtype
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg.d_model, dt, with_bias=True),
        "self_attn": L.init_attention(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, dt, bias=True),
        "ln_x": L.init_norm(cfg.d_model, dt, with_bias=True),
        "cross_attn": L.init_attention(k2, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, dt, bias=True),
        "ln2": L.init_norm(cfg.d_model, dt, with_bias=True),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dt, act="gelu", bias=True),
    }


def init_params(key, cfg: ModelConfig):
    ne = cfg.encoder_layers
    nd = cfg.num_layers
    keys = jax.random.split(key, ne + nd + 4)
    enc = [init_enc_block(keys[i], cfg) for i in range(ne)]
    dec = [init_dec_block(keys[ne + i], cfg) for i in range(nd)]
    stack = lambda blocks: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    dt = cfg.jdtype
    return {
        "frontend_proj": L.dense_init(keys[-1], (cfg.d_model, cfg.d_model), dt),
        "enc_pos": 0.02 * jax.random.normal(keys[-2], (MAX_SOURCE_LEN, cfg.d_model), jnp.float32).astype(dt),
        "enc_blocks": stack(enc),
        "enc_ln": L.init_norm(cfg.d_model, dt, with_bias=True),
        "embed": L.dense_init(keys[-3], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "dec_pos": 0.02 * jax.random.normal(keys[-4], (cfg.max_target_len, cfg.d_model), jnp.float32).astype(dt),
        "dec_blocks": stack(dec),
        "dec_ln": L.init_norm(cfg.d_model, dt, with_bias=True),
    }


def encode(params, audio_embeds, cfg: ModelConfig):
    s = audio_embeds.shape[1]
    x = audio_embeds @ params["frontend_proj"] + params["enc_pos"][:s]

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, "layernorm")
        q, k, v = L.qkv_project(lp["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
        o = L.chunked_attention(q, k, v, causal=False, kv_chunk=cfg.kv_chunk)
        x = x + L.attn_output(lp["attn"], o)
        h = L.apply_norm(lp["ln2"], x, "layernorm")
        return x + L.mlp(lp["mlp"], h, "gelu"), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(scan_body, x, params["enc_blocks"])
    return L.apply_norm(params["enc_ln"], x, "layernorm")


def _dec_block(lp, x, memory, cfg: ModelConfig, *, self_kv=None, pos=None):
    """Decoder block; ``self_kv``/``pos`` switch between full-sequence
    (training) and single-token (decode with cache) self-attention."""
    h = L.apply_norm(lp["ln1"], x, "layernorm")
    q, k, v = L.qkv_project(lp["self_attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
    if self_kv is None:
        o = L.chunked_attention(q, k, v, causal=True, kv_chunk=min(cfg.kv_chunk, x.shape[1]))
        new_kv = None
    else:
        kc, vc = self_kv
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = L.decode_attention(q, kc, vc, pos + 1)
        new_kv = (kc, vc)
    x = x + L.attn_output(lp["self_attn"], o)
    # cross attention to encoder memory
    h = L.apply_norm(lp["ln_x"], x, "layernorm")
    qx, kx, vx = L.qkv_project(lp["cross_attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
    kxm, vxm = memory  # precomputed [B, S_src, H, hd]
    ox = L.chunked_attention(qx, kxm, vxm, causal=False, kv_chunk=cfg.kv_chunk)
    x = x + L.attn_output(lp["cross_attn"], ox)
    h = L.apply_norm(lp["ln2"], x, "layernorm")
    return x + L.mlp(lp["mlp"], h, "gelu"), new_kv


def _cross_kv(lp, enc_out, cfg):
    b, s, _ = enc_out.shape
    k = (enc_out @ lp["cross_attn"]["wk"] + lp["cross_attn"]["bk"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
    v = (enc_out @ lp["cross_attn"]["wv"] + lp["cross_attn"]["bv"]).reshape(b, s, cfg.num_kv_heads, cfg.hd)
    return k, v


def decode_train(params, enc_out, tokens, cfg: ModelConfig):
    s = tokens.shape[1]
    x = params["embed"][tokens] + params["dec_pos"][:s]

    def body(x, lp):
        memory = _cross_kv(lp, enc_out, cfg)
        x, _ = _dec_block(lp, x, memory, cfg)
        return x, None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(scan_body, x, params["dec_blocks"])
    x = L.apply_norm(params["dec_ln"], x, "layernorm")
    return x @ params["embed"].T  # tied output head (Whisper)


def forward(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["audio_embeds"], cfg)
    return decode_train(params, enc_out, batch["tokens"][:, :-1], cfg)


def train_loss(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.softmax_xent(logits, batch["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# serving: encoder runs once, decoder steps with self-attn + cross caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, src_len: int, dtype=None):
    dt = dtype or cfg.jdtype
    nd = cfg.num_layers
    t = cfg.max_target_len
    return {
        "self_k": jnp.zeros((nd, batch, t, cfg.num_kv_heads, cfg.hd), dt),
        "self_v": jnp.zeros((nd, batch, t, cfg.num_kv_heads, cfg.hd), dt),
        "cross_k": jnp.zeros((nd, batch, src_len, cfg.num_kv_heads, cfg.hd), dt),
        "cross_v": jnp.zeros((nd, batch, src_len, cfg.num_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cache(params, cache, audio_embeds, cfg: ModelConfig):
    """Run the encoder and populate the cross-attention KV cache."""
    enc_out = encode(params, audio_embeds, cfg)

    def body(_, lp):
        k, v = _cross_kv(lp, enc_out, cfg)
        return None, (k, v)

    _, (ck, cv) = lax.scan(body, None, params["dec_blocks"])
    return dict(cache, cross_k=ck.astype(cache["cross_k"].dtype), cross_v=cv.astype(cache["cross_v"].dtype))


def decode_step(params, cache, tokens, cfg: ModelConfig):
    pos = cache["pos"]
    x = params["embed"][tokens] + params["dec_pos"][pos][None, None]

    def body(x, inputs):
        lp, sk, sv, ck, cv = inputs
        x, new_kv = _dec_block(lp, x, (ck, cv), cfg, self_kv=(sk, sv), pos=pos)
        return x, new_kv

    x, (nk, nv) = lax.scan(
        body, x,
        (params["dec_blocks"], cache["self_k"], cache["self_v"], cache["cross_k"], cache["cross_v"]),
    )
    x = L.apply_norm(params["dec_ln"], x, "layernorm")
    logits = x @ params["embed"].T
    return logits, dict(cache, self_k=nk, self_v=nv, pos=pos + 1)
