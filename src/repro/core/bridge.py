"""The BRIDGE trainer — Algorithm 1 of the paper.

Two execution paths share the same screening code:

* **Simulation path** (this module): all M node replicas live on one host as a
  stacked ``[M, ...]`` pytree; per-iteration we (1) apply the Byzantine attack
  to the *broadcast* matrix, (2) screen at every honest node, (3) take the
  local gradient step  w_j(t+1) = y_j(t) - rho(t) * grad f_j(w_j(t)).
  This is the path used by the paper-replication benchmarks (MNIST-scale).

* **Sharded path** (`repro.core.gossip` + `repro.launch`): the same protocol
  over a TPU mesh where the node axis is sharded over ("pod","data") and each
  replica is tensor-parallel over "model".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core.graph import Topology


class BridgeState(NamedTuple):
    params: Any  # pytree with leading node axis [M, ...]
    t: jax.Array  # iteration counter
    key: jax.Array
    net: Any = None  # network-runtime state (mailboxes etc.); None when synchronous


@dataclasses.dataclass(frozen=True)
class BridgeConfig:
    topology: Topology
    rule: str = "trimmed_mean"  # trimmed_mean | median | krum | bulyan | mean
    num_byzantine: int = 0  # the bound b given to the screening rule
    attack: str = "none"
    byzantine_seed: int = 0
    # step size rho(t) = 1 / (lam * (t0 + t))  (Sec. IV); or constant if lr>0
    lam: float = 1.0
    t0: float = 50.0
    lr: float = 0.0  # if > 0, use constant step size instead
    screen_chunk: int | None = 1 << 20  # coordinate streaming chunk

    def step_size(self, t: jax.Array) -> jax.Array:
        if self.lr > 0:
            return jnp.asarray(self.lr, jnp.float32)
        return 1.0 / (self.lam * (self.t0 + t))


def stack_flatten(params: Any) -> tuple[jax.Array, Callable[[jax.Array], Any]]:
    """[M, ...] pytree -> ([M, D] matrix, unflatten)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    m = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    flat = jnp.concatenate([l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(w: jax.Array) -> Any:
        outs, off = [], 0
        for shape, size, ref in zip(shapes, sizes, leaves):
            outs.append(w[:, off : off + size].reshape((m,) + shape).astype(ref.dtype))
            off += size
        return jax.tree_util.tree_unflatten(treedef, outs)

    return flat, unflatten


class BridgeTrainer:
    """Drives Algorithm 1.  ``grad_fn(node_params, batch) -> (loss, grads)``
    computes the *local* empirical-risk gradient of one node.

    ``runtime`` plugs in a message-exchange model (see `repro.net.runtime`):
    ``None`` is the classic synchronous broadcast simulation; an
    `UnreliableRuntime` yields asynchronous BRIDGE over a lossy, delayed,
    time-varying network, screening whatever messages have arrived (within
    the runtime's staleness bound) and falling back to the node's own iterate
    whenever too few usable messages are present for the rule's Table-II
    minimum.  With an ideal channel and a static schedule the runtime path
    reproduces the synchronous path bit-for-bit."""

    def __init__(self, config: BridgeConfig, grad_fn: Callable, runtime=None):
        config.topology.validate_for_rule(config.rule)
        self.config = config
        self.grad_fn = grad_fn
        self.runtime = runtime
        self.adjacency = jnp.asarray(config.topology.adjacency)
        m = config.topology.num_nodes
        nbyz = min(config.num_byzantine, m)
        if config.attack == "none" or nbyz == 0:
            self.byz_mask = jnp.zeros((m,), dtype=bool)
        else:
            self.byz_mask = byz_lib.pick_byzantine_mask(m, nbyz, config.byzantine_seed)
        if runtime is None:
            self._attack = byz_lib.get_attack(config.attack)
            self._step_core = self._build_step_core()
        else:
            self._message_attack = byz_lib.get_message_attack(config.attack)
            self._step_core = self._build_runtime_step_core()
        self._step = jax.jit(self._step_core)

    @property
    def honest_mask(self) -> jax.Array:
        return ~self.byz_mask

    def init(self, params: Any, seed: int = 0) -> BridgeState:
        m = self.config.topology.num_nodes
        lead = jax.tree_util.tree_leaves(params)[0].shape[0]
        if lead != m:
            raise ValueError(f"params leading axis {lead} != num_nodes {m}")
        net = None
        if self.runtime is not None:
            w, _ = stack_flatten(params)
            net = self.runtime.init(m, w.shape[1])
        return BridgeState(params=params, t=jnp.zeros((), jnp.int32),
                           key=jax.random.PRNGKey(seed), net=net)

    def _grad_update_and_metrics(self, state, batch, y, unflatten):
        """(Step 6) local gradient update at w_j(t) + shared diagnostics."""
        cfg = self.config
        losses, grads = jax.vmap(self.grad_fn)(state.params, batch)
        g, _ = stack_flatten(grads)
        rho = cfg.step_size(state.t)
        w_new = y - rho * g
        new_params = unflatten(w_new)
        # consensus diagnostic over honest nodes
        hm = self.honest_mask
        cnt = jnp.sum(hm)
        mu = jnp.sum(jnp.where(hm[:, None], w_new, 0.0), axis=0) / cnt
        dev = jnp.where(hm[:, None], w_new - mu[None, :], 0.0)
        cons = jnp.sqrt(jnp.max(jnp.sum(dev * dev, axis=1)))
        metrics = {
            "loss": jnp.sum(jnp.where(hm, losses, 0.0)) / cnt,
            "consensus_dist": cons,
            "rho": rho,
        }
        return new_params, metrics

    def _build_step_core(self):
        cfg = self.config

        def step(state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
            w, unflatten = stack_flatten(state.params)
            key, sub = jax.random.split(state.key)
            # (Step 3-4) broadcast + Byzantine substitution of sent messages
            w_bcast = self._attack(w, self.byz_mask, sub, state.t)
            # (Step 5) screening at every node
            y = screening.screen_all(
                w_bcast, self.adjacency, rule=cfg.rule, b=cfg.num_byzantine,
                chunk=cfg.screen_chunk,
            )
            new_params, metrics = self._grad_update_and_metrics(state, batch, y, unflatten)
            return BridgeState(new_params, state.t + 1, key), metrics

        return step

    # Salt decorrelating the channel PRNG stream from the attack stream (both
    # derive from the same per-step subkey).
    _NET_SALT = 0x6E657430

    def _build_runtime_step_core(self):
        cfg = self.config
        runtime = self.runtime
        need = screening.min_neighbors(cfg.rule, cfg.num_byzantine)

        def step(state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
            w, unflatten = stack_flatten(state.params)
            key, sub = jax.random.split(state.key)
            adj_t = runtime.adjacency_at(state.t)
            # (Step 3-4) per-link transmissions with Byzantine substitution.
            msgs = self._message_attack(w, self.byz_mask, adj_t, sub, state.t)
            # Byzantine nodes screen with the same self-view they broadcast
            # (matching the synchronous path); message-only attacks have no
            # single broadcast value, so nodes screen with their true iterate.
            battack = self._message_attack.broadcast
            w_self = battack(w, self.byz_mask, sub, state.t) if battack else w
            net_key = jax.random.fold_in(sub, self._NET_SALT)
            net, views, mask, net_stats = runtime.exchange(
                state.net, msgs, w_self, adj_t, net_key, state.t
            )
            # (Step 5) asynchronous screening over whatever usable (arrived,
            # fresh) messages each node holds; nodes starved below the rule's
            # minimum usable count keep their own iterate this tick.
            y_rule = screening.screen_views(
                views, mask, w_self, rule=cfg.rule, b=cfg.num_byzantine,
                chunk=cfg.screen_chunk,
            )
            enough = jnp.sum(mask, axis=1) >= need
            y = jnp.where(enough[:, None], y_rule, w_self)
            new_params, metrics = self._grad_update_and_metrics(state, batch, y, unflatten)
            metrics.update(net_stats)
            metrics["screened_frac"] = jnp.mean(enough.astype(jnp.float32))
            return BridgeState(new_params, state.t + 1, key, net), metrics

        return step

    def step(self, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        return self._step(state, batch)

    def run(self, state: BridgeState, batch_fn: Callable[[int], Any], num_steps: int,
            eval_fn: Callable | None = None, eval_every: int = 0) -> tuple[BridgeState, list[dict]]:
        history = []
        for i in range(num_steps):
            state, metrics = self.step(state, batch_fn(i))
            if eval_fn is not None and eval_every and (i + 1) % eval_every == 0:
                metrics = dict(metrics)
                metrics.update(eval_fn(state))
                metrics["step"] = i + 1
                history.append(jax.device_get(metrics))
        return state, history


def replicate(params: Any, num_nodes: int, *, perturb: float = 0.0, key=None) -> Any:
    """Stack one model into [M, ...] node replicas; optional init perturbation
    (the paper initializes nodes inside a common ball, not identically —
    unlike ICwTM which *requires* identical initialization)."""

    def rep(leaf):
        return jnp.broadcast_to(leaf[None], (num_nodes,) + leaf.shape)

    stacked = jax.tree_util.tree_map(rep, params)
    if perturb > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [
            l + perturb * jax.random.normal(k, l.shape, l.dtype)
            for l, k in zip(leaves, keys)
        ]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked
