"""The BRIDGE trainer — Algorithm 1 of the paper.

Two execution paths share the same screening code:

* **Simulation path** (this module): all M node replicas live on one host as a
  stacked ``[M, ...]`` pytree; per-iteration we (1) apply the Byzantine attack
  to the *broadcast* matrix, (2) screen at every honest node, (3) take the
  local gradient step  w_j(t+1) = y_j(t) - rho(t) * grad f_j(w_j(t)).
  This is the path used by the paper-replication benchmarks (MNIST-scale).

* **Sharded path** (`repro.core.gossip` + `repro.launch`): the same protocol
  over a TPU mesh where the node axis is sharded over ("pod","data") and each
  replica is tensor-parallel over "model".
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.adversary import protocols as adv_lib
from repro.comm import codec as codec_lib
from repro.comm import exchange as comm_lib
from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core import neighbors as neighbors_lib
from repro.core.graph import Topology
from repro.core.neighbors import NeighborTable


class BridgeState(NamedTuple):
    params: Any  # pytree with leading node axis [M, ...]
    t: jax.Array  # iteration counter
    key: jax.Array
    net: Any = None  # network-runtime state (mailboxes etc.); None when synchronous
    # error-feedback residual of the wire codec (repro.comm): [M, d] per
    # sender on the broadcast path, [M, M, d] per link on the runtime path;
    # None when every codec in the bank is lossless (the default identity
    # path carries no extra state)
    comm: Any = None
    # adversary tracking state (repro.adversary.AdvState): the omniscient
    # adversary's carried observations of the honest trajectory; None when no
    # adversary in the bank is stateful (static attacks carry nothing)
    adv: Any = None
    # observability aggregates (repro.obs.trace.TraceState): in-scan screening
    # forensics, histograms, and the divergence sentinel; None (the default)
    # keeps the untraced program shape bit-for-bit
    obs: Any = None
    # trust carry (repro.trust.reputation.TrustState): per-edge suspicion,
    # reputation weights, and latched evictions; None (the default) keeps the
    # trust-free program shape bit-for-bit
    trust: Any = None
    # live-metric ring (repro.obs.metrics.MetricState): the [C, S] per-tick
    # scalar streams the chunked runners flush to metrics.jsonl between
    # dispatches; None (the default) keeps the metric-free program shape
    # bit-for-bit
    mets: Any = None


class CellParams(NamedTuple):
    """One experiment cell's runtime-switchable parameters.

    `BridgeTrainer` binds a single constant cell from its config; the batched
    grid engine (`repro.sim`) stacks one row per experiment and ``vmap``s the
    shared step over the leading axis.  Rule/attack selection is *data* — an
    int32 index into a static bank resolved by ``lax.switch`` — so E
    experiments with different rules, attacks, Byzantine counts, and step-size
    schedules share one compiled program.
    """

    rule_idx: jax.Array  # int32 index into the step's static rule bank
    attack_idx: jax.Array  # int32 index into the step's static attack bank
    b: jax.Array  # int32 Byzantine bound fed to the screening rule
    byz_mask: jax.Array  # [M] bool — which nodes actually attack
    lam: jax.Array  # f32 step-size decay rate
    t0: jax.Array  # f32 step-size offset
    lr: jax.Array  # f32 constant step size; 0 -> decaying 1/(lam*(t0+t))
    # int32 index into a scenario-banked runtime's bank (grid net path);
    # None on the single-runtime trainer path (no scenario axis).
    scenario_idx: Any = None
    # int32 index into the step's static wire-codec bank (repro.comm);
    # None selects entry 0 (single-codec trainers).
    codec_idx: Any = None
    # int32 index into the step's static adversary bank (repro.adversary);
    # None selects entry 0 (single-adversary trainers / no adversary axis).
    adv_idx: Any = None
    # [THETA_DIM] f32 per-cell adversary hyperparameters (attack scale / z /
    # ascent steps — see repro.adversary.adaptive); None -> the selected
    # adversary's registered defaults.  Data, not structure: the red-team
    # search mutates these between generations without retracing.
    adv_theta: Any = None
    # observability spec (repro.obs.trace.TraceSpec): *structural* auxiliary
    # data — a zero-leaf pytree node, so it is part of the jit cache key, not
    # an operand.  None (the default) keeps the exact untraced program shape;
    # a spec compiles forensic aggregation into the step (bit-inert for the
    # trajectory — property-tested).
    trace: Any = None
    # trust spec (repro.trust.reputation.TrustSpec): structural like `trace`
    # — None keeps the exact trust-free program; a spec compiles reputation
    # updates, eviction masking, and (net path) the echo protocol into the
    # step.  Unlike `trace`, trust ON deliberately changes the trajectory.
    trust: Any = None
    # live-metric spec (repro.obs.metrics.MetricSpec): structural like
    # `trace` — None keeps the exact metric-free program; a spec compiles the
    # per-tick scalar ring into the step (bit-inert for the trajectory —
    # the ring only reads values the step already computes).
    metrics: Any = None


def cell_step_size(cell: CellParams, t: jax.Array) -> jax.Array:
    """rho(t) = lr if lr > 0 else 1 / (lam * (t0 + t))  (Sec. IV)."""
    decayed = 1.0 / (cell.lam * (cell.t0 + t))
    return jnp.where(cell.lr > 0, cell.lr, decayed)


@dataclasses.dataclass(frozen=True)
class BridgeConfig:
    """Everything one BRIDGE trainer needs: graph, screening rule, threat
    model, wire format, step-size schedule, and the optional observability /
    trust specs.  Frozen — a config is a value, and `BridgeTrainer` derives
    all jit structure from it once at construction.

    Minimal usage::

        from repro.core.bridge import BridgeConfig, BridgeTrainer, replicate
        from repro.core.graph import erdos_renyi

        topo = erdos_renyi(10, 0.8, 2, seed=1)
        cfg = BridgeConfig(topology=topo, rule="trimmed_mean",
                           num_byzantine=2, attack="sign_flip")
        trainer = BridgeTrainer(cfg, grad_fn)          # grad_fn(params, batch)
        state = trainer.init(replicate(params0, 10))
        state, metrics = trainer.step(state, batch)

    See docs/ARCHITECTURE.md for the full one-tick dataflow the trainer
    compiles (attack -> adversary -> codec -> exchange -> screen -> apply ->
    obs/trust).
    """

    topology: Topology
    rule: str = "trimmed_mean"  # trimmed_mean | median | krum | bulyan | mean
    num_byzantine: int = 0  # the bound b given to the screening rule
    attack: str = "none"
    # adaptive adversary (repro.adversary): none | ipm | alie_online |
    # dissensus | inner_max | any static attack name (stateless tier).
    # Composes after `attack` (both substitute Byzantine rows, so use one).
    adversary: str = "none"
    codec: str = "identity"  # wire codec (repro.comm): identity | int8 | int4 | topk<P>...
    byzantine_seed: int = 0
    # step size rho(t) = 1 / (lam * (t0 + t))  (Sec. IV); or constant if lr>0
    lam: float = 1.0
    t0: float = 50.0
    lr: float = 0.0  # if > 0, use constant step size instead
    screen_chunk: int | None = 1 << 20  # coordinate streaming chunk
    # neighbor-indexed [M, K] state layout (repro.core.neighbors): screening
    # consumes gathered [M, K, d] views instead of masking the full [M, d]
    # broadcast per node — bit-identical to the dense path (property-tested)
    # and the only layout that scales past the dense O(M^2) wall
    sparse: bool = False
    # observability (repro.obs.trace.TraceSpec); None = untraced (default)
    trace: Any = None
    # trust layer (repro.trust.reputation.TrustSpec); None = off (default,
    # bit-inert) — a spec turns on reputation-weighted screening + eviction
    # (pair it with a rule from screening.WEIGHTED_RULES for soft weighting;
    # any rule gets hard eviction through the mask)
    trust: Any = None
    # live metrics (repro.obs.metrics.MetricSpec); None = off (default,
    # bit-inert) — a spec compiles the per-tick scalar ring into the step
    # and `run_chunks` flushes it to metrics.jsonl between dispatches
    metrics: Any = None

    def step_size(self, t: jax.Array) -> jax.Array:
        if self.lr > 0:
            return jnp.asarray(self.lr, jnp.float32)
        return 1.0 / (self.lam * (self.t0 + t))


def stack_batches(batch_fn: Callable[[int], Any], num_ticks: int) -> Any:
    """Materialize ``num_ticks`` batches on a new leading axis — the ``xs``
    the scan-over-ticks paths consume.  The single definition shared by
    `AsyncBridgeTrainer.run_ticks` and the grid engine, so both scan
    identical inputs (part of their bit-identity contract)."""
    batches = [batch_fn(i) for i in range(num_ticks)]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *batches
    )


def stack_flatten(params: Any) -> tuple[jax.Array, Callable[[jax.Array], Any]]:
    """[M, ...] pytree -> ([M, D] f32 matrix, unflatten).

    Screening always runs in f32; ``unflatten`` restores each leaf's own
    storage dtype, so mixed bf16/f32 pytrees round-trip without a silent
    upcast (regression-pinned by ``tests/test_bridge.py``).  The per-leaf
    dtypes are captured as *static* values — not by closing over the input
    leaves — so the closure never pins the original arrays alive across a
    step.  Note the f32 flat copy itself is the cost this function cannot
    avoid; `repro.stream` exists so LLM-scale runs never call it.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    m = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    dtypes = [jnp.dtype(l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(w: jax.Array) -> Any:
        outs, off = [], 0
        for shape, size, dtype in zip(shapes, sizes, dtypes, strict=True):
            outs.append(w[:, off : off + size].reshape((m,) + shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(treedef, outs)

    return flat, unflatten


# ---------------------------------------------------------------------------
# Cell-parameterized step builders
# ---------------------------------------------------------------------------
#
# One BRIDGE iteration, parameterized by a `CellParams` row plus static banks
# of rules/attacks.  `BridgeTrainer` binds a constant single-entry-bank cell
# (bit-identical to dedicated dispatch — the switches are elided); the grid
# engine vmaps the same function over stacked cells.  This is the single
# definition of Algorithm 1's iteration — the batched path reuses it rather
# than forking it.

# Salt decorrelating the channel PRNG stream from the attack stream (both
# derive from the same per-step subkey).
NET_SALT = 0x6E657430
# Salts for the wire-codec streams (stochastic rounding / codeword attacks),
# decorrelated from both the attack and the channel streams.
COMM_SALT = 0x636D6D30
WIRE_SALT = 0x77697230
# Salt for the adaptive-adversary stream (repro.adversary).
ADV_SALT = 0x61647630
# Salt for the trust layer's echo-digest stream (repro.trust.echo): the
# tick's public random projection derives from this fold, decorrelated from
# every other consumer of the step subkey.
TRUST_SALT = 0x74727530


def _cell_codec_idx(cell: CellParams):
    """codec bank index; None (single-codec trainers) selects entry 0."""
    if cell.codec_idx is None:
        return jnp.zeros((), jnp.int32)
    return cell.codec_idx


def _cell_adv_idx(cell: CellParams):
    """adversary bank index; None (single-adversary trainers) selects 0."""
    if cell.adv_idx is None:
        return jnp.zeros((), jnp.int32)
    return cell.adv_idx


def _wire_roundtrip(codec_bank, wire_bank, cell, sub, x, residual, byz, t, d, eids=None):
    """Encode -> codeword attack -> decode, with error feedback.

    Returns ``(x_hat, residual')`` — what receivers see and the advanced
    per-sender (or per-link) EF carry.  When nothing in the banks can alter a
    payload (all-lossless codecs, no wire attacks) the wire is skipped
    entirely: the default identity path stays structurally identical to the
    uncompressed trainer, which is the bit-identity contract the tests pin.
    ``eids`` (the per-link paths) re-keys every PRNG consumer — stochastic
    codec rounding, randk index draws, randomized wire attacks — per *edge
    id* instead of per tensor, so the dense ``[M, M, d]`` and sparse
    ``[M, K, d]`` layouts produce bitwise-identical codewords on matching
    edges (the dense<->sparse bit-identity contract for lossy codecs).
    """
    if comm_lib.bank_is_lossless(codec_bank) and all(a.name == "none" for a in wire_bank):
        return x, residual
    cidx = _cell_codec_idx(cell)
    comm_key = jax.random.fold_in(sub, COMM_SALT)
    wire_key = jax.random.fold_in(sub, WIRE_SALT)
    if eids is None:
        msg, target = comm_lib.encode_bank(codec_bank, cidx, comm_key, x, residual)
        msg = byz_lib.apply_wire_attack_bank(wire_bank, cell.attack_idx, msg, byz, wire_key, t, d)
        return comm_lib.decode_bank(codec_bank, cidx, msg, target, residual, comm_key)

    lead = x.shape[:-1]  # [M, M] or [M, K]

    def per_edge(eid, x_e, byz_e, st_e):
        ck = jax.random.fold_in(comm_key, eid)
        wk = jax.random.fold_in(wire_key, eid)
        msg, target = comm_lib.encode_bank(codec_bank, cidx, ck, x_e, st_e)
        msg = byz_lib.apply_wire_attack_bank(wire_bank, cell.attack_idx, msg, byz_e, wk, t, d)
        return comm_lib.decode_bank(codec_bank, cidx, msg, target, st_e, ck)

    flat = lambda a: a.reshape((-1,) + a.shape[len(lead):])
    st_flat = None if residual is None else jax.tree_util.tree_map(flat, residual)
    x_hat, st = jax.vmap(per_edge)(flat(eids), flat(x), flat(byz), st_flat)
    unflat = lambda a: a.reshape(lead + a.shape[1:])
    return unflat(x_hat), (None if residual is None else jax.tree_util.tree_map(unflat, st))


def _comm_metrics(codec_bank, cell, d: int, live_edges, residual) -> dict:
    """Exact bits-on-wire accounting + EF diagnostics (uniform keys across
    codec banks so grid groups concatenate)."""
    bits = comm_lib.wire_bits_bank(codec_bank, _cell_codec_idx(cell), d)
    bits_f = jnp.asarray(bits, jnp.float32)
    res = (jnp.zeros((), jnp.float32) if residual is None
           else jnp.sqrt(jnp.sum(residual.resid * residual.resid)))
    return {
        "wire_bits_per_edge": bits_f,
        "wire_bytes_total": bits_f / 8.0 * live_edges,
        "ef_residual_norm": res,
    }


def _grad_update_and_metrics(grad_fn, cell: CellParams, state: BridgeState, batch, y, unflatten):
    """(Step 6) local gradient update at w_j(t) + shared diagnostics.

    ``rho * g`` passes through `screening.fence` before the subtract: whether
    XLA contracts ``y - rho * g`` into an FNMA is *program-shape dependent*,
    and the grid's banked program and the trainer's single-bank program would
    otherwise drift ~1 ULP/step apart — breaking the bit-for-bit
    grid<->trainer contract the tests pin."""
    losses, grads = jax.vmap(grad_fn)(state.params, batch)
    g, _ = stack_flatten(grads)
    rho = cell_step_size(cell, state.t)
    w_new = y - screening.fence(rho * g)
    new_params = unflatten(w_new)
    # consensus diagnostic over honest nodes
    hm = ~cell.byz_mask
    cnt = jnp.sum(hm)
    mu = jnp.sum(jnp.where(hm[:, None], w_new, 0.0), axis=0) / cnt
    dev = jnp.where(hm[:, None], w_new - mu[None, :], 0.0)
    cons = jnp.sqrt(jnp.max(jnp.sum(dev * dev, axis=1)))
    metrics = {
        "loss": jnp.sum(jnp.where(hm, losses, 0.0)) / cnt,
        "consensus_dist": cons,
        "rho": rho,
    }
    if cell.metrics is not None:
        # honest-mean per-node gradient norm — the live-metric ring's
        # grad_norm column; gated on the (static) spec so the metric-free
        # program shape is untouched.  The fence severs CSE with the loss
        # reduction (grad_fn often shares g*g subexpressions with its loss),
        # which would otherwise re-fuse and ULP-shift the loss stream —
        # breaking metrics-on bit-inertness
        gf = screening.fence(g)
        gn = jnp.sqrt(jnp.sum(gf * gf, axis=1))
        metrics["grad_norm"] = jnp.sum(jnp.where(hm, gn, 0.0)) / cnt
    return new_params, metrics


def _fold_metric_ring(mspec, state: BridgeState, metrics: dict, *,
                      staleness=None, live=None):
    """Fold the tick's already-computed scalars into the live-metric ring
    (`repro.obs.metrics`).  Reads only — bit-inert for the trajectory; the
    whole call is gated on the (static) spec so ``metrics=None`` keeps the
    exact pre-metrics program."""
    if mspec is None:
        return state.mets
    from repro.obs import metrics as obs_metrics

    with jax.named_scope("bridge.metrics"):
        vals = {k: metrics[k]
                for k in ("loss", "consensus_dist", "grad_norm", "rho",
                          "wire_bits_per_edge", "wire_bytes_total")
                if k in metrics}
        if "obs_trim_frac" in metrics:
            vals["trim_frac"] = metrics["obs_trim_frac"]
        if "trust_evicted_frac" in metrics:
            vals["evicted_frac"] = metrics["trust_evicted_frac"]
        if staleness is not None and live is not None:
            vals.update(obs_metrics.stale_quantiles(staleness, live))
        return obs_metrics.update(mspec, state.mets, t=state.t, vals=vals)


def build_cell_step(grad_fn, adjacency, rules: tuple[str, ...], attacks, *,
                    codecs: tuple[str, ...] = ("identity",), wire_attacks=None,
                    adversaries: tuple[str, ...] | None = None,
                    screen_chunk=None, neighbors: NeighborTable | None = None):
    """The synchronous-broadcast iteration: ``step(cell, state, batch)``.

    ``rules`` is a static bank of screening-rule names, ``attacks`` a static
    bank of `byzantine.Attack`s, ``codecs`` a static bank of wire-codec names
    (`repro.comm`), ``wire_attacks`` the codeword-domain bank parallel to
    ``attacks`` (defaults to all no-ops), and ``adversaries`` a static bank
    of `repro.adversary` names (None / all-`none` skips the adversary stage
    structurally — the default path stays bit-identical); ``cell`` selects
    into all of them.

    ``neighbors`` switches screening to the neighbor-indexed sparse layout
    (`repro.core.neighbors`): each node screens its gathered ``[K, d]`` view
    instead of masking the full ``[M, d]`` broadcast — bit-identical outputs
    (property-tested), ``O(M K d)`` instead of ``O(M^2 d)`` work.
    """
    codec_bank = codec_lib.codec_bank(codecs)
    if wire_attacks is None:
        wire_attacks = (byz_lib.WIRE_ATTACKS["none"],) * len(attacks)
    adv_bank = None if adversaries is None else adv_lib.adversary_bank(adversaries)
    adv_engaged = adv_lib.bank_engaged(adv_bank)
    n_edges = jnp.sum(jnp.asarray(adjacency)).astype(jnp.float32)

    def screen(w_hat, self_vals, cell):
        if neighbors is not None:
            return screening.screen_views_banked(
                neighbors.gather_rows(w_hat), neighbors.valid_dev, self_vals,
                rules, cell.rule_idx, cell.b, chunk=screen_chunk)
        return screening.screen_all_banked(
            w_hat, adjacency, rules, cell.rule_idx, cell.b, chunk=screen_chunk,
            self_vals=self_vals)

    def screen_decide(w_hat, self_vals, cell, stride, weights=None, evicted=None):
        # decision-instrumented twin: same y op graph (bitwise), plus the
        # [M, W] per-edge trim fractions the obs/trust aggregates fold in.
        # `weights`/`evicted` (repro.trust) thread reputation into the rules
        # and latched evictions into the mask; both None keeps the exact
        # trust-free call.
        if neighbors is not None:
            mask = neighbors.valid_dev if evicted is None else neighbors.valid_dev & ~evicted
            return screening.screen_views_decide_banked(
                neighbors.gather_rows(w_hat), mask, self_vals,
                rules, cell.rule_idx, cell.b, decide_stride=stride, weights=weights)
        adj = adjacency if evicted is None else jnp.asarray(adjacency, bool) & ~evicted
        return screening.screen_all_decide_banked(
            w_hat, adj, rules, cell.rule_idx, cell.b, self_vals=self_vals,
            decide_stride=stride, weights=weights)

    def step(cell: CellParams, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        spec = cell.trace  # static: TraceSpec or None (zero-leaf aux data)
        tspec = cell.trust  # static: TrustSpec or None (zero-leaf aux data)
        w, unflatten = stack_flatten(state.params)
        d = w.shape[1]
        key, sub = jax.random.split(state.key)
        # (Step 3-4) broadcast + Byzantine substitution of sent messages
        with jax.named_scope("bridge.attack"):
            w_bcast = byz_lib.apply_attack_bank(
                attacks, cell.attack_idx, w, cell.byz_mask, sub, state.t)
        new_adv = state.adv
        if adv_engaged:
            # the adaptive adversary observes the honest trajectory and
            # re-crafts the Byzantine rows; its screening oracle is this
            # cell's own banked screen (differentiable — inner maximization
            # ascends through it)
            with jax.named_scope("bridge.adversary"):
                ctx = adv_lib.AdvCtx(screen=lambda wb: screen(wb, wb, cell))
                theta = adv_lib.cell_theta(adv_bank, _cell_adv_idx(cell), cell.adv_theta)
                w_bcast, new_adv = adv_lib.apply_adversary_bank(
                    adv_bank, _cell_adv_idx(cell), ctx, state.adv, theta,
                    w_bcast, cell.byz_mask, jax.random.fold_in(sub, ADV_SALT), state.t,
                )
        # wire codec: what receivers actually decode (identity: w_bcast itself)
        with jax.named_scope("bridge.codec"):
            w_hat, new_comm = _wire_roundtrip(
                codec_bank, wire_attacks, cell, sub, w_bcast, state.comm,
                cell.byz_mask, state.t, d,
            )
        # (Step 5) screening at every node: neighbors are seen through the
        # wire; the node's own iterate never travels and stays uncompressed
        trim = None
        with jax.named_scope("bridge.screen"):
            if tspec is not None:
                # trust on: always the decide path (the trim fractions are
                # the evidence), reputation weights into the weighted rules,
                # evicted edges cleared from the mask
                from repro.trust import reputation as trust_lib

                screening.check_decide_streams(rules, d, screen_chunk)
                stride = (spec.decide_stride if spec is not None and spec.forensics
                          else tspec.decide_stride)
                y, trim = screen_decide(
                    w_hat, w_bcast, cell, stride,
                    weights=trust_lib.edge_weights(tspec, state.trust),
                    evicted=state.trust.evicted)
            elif spec is not None and spec.forensics:
                screening.check_decide_streams(rules, d, screen_chunk)
                y, trim = screen_decide(w_hat, w_bcast, cell, spec.decide_stride)
            else:
                y = screen(w_hat, w_bcast, cell)
        with jax.named_scope("bridge.apply"):
            new_params, metrics = _grad_update_and_metrics(
                grad_fn, cell, state, batch, y, unflatten)
        metrics.update(_comm_metrics(codec_bank, cell, d, n_edges, new_comm))
        new_obs = state.obs
        if spec is not None:
            from repro.obs import trace as obs_trace

            with jax.named_scope("bridge.obs"):
                live = byz_edge = None
                if trim is not None:
                    if neighbors is not None:
                        live = neighbors.valid_dev
                        byz_edge = neighbors.gather_senders(cell.byz_mask, fill=False)
                    else:
                        live = jnp.asarray(adjacency, bool)
                        byz_edge = jnp.broadcast_to(cell.byz_mask[None, :], live.shape)
                    live_f = live.astype(jnp.float32)
                    metrics["obs_trim_frac"] = (
                        jnp.sum(trim * live_f) / jnp.maximum(jnp.sum(live_f), 1.0))
                new_obs = obs_trace.update(
                    spec, state.obs, t=state.t, loss=metrics["loss"],
                    consensus=metrics["consensus_dist"], trim_frac=trim,
                    live=live, byz_edge=byz_edge, staleness=None,
                    wire_bits=comm_lib.wire_bits_bank(codec_bank, _cell_codec_idx(cell), d),
                    live_edges=n_edges, d=d)
        new_trust = state.trust
        if tspec is not None:
            from repro.trust import reputation as trust_lib

            with jax.named_scope("bridge.trust"):
                # no echo on the broadcast path: one payload per sender, so
                # equivocation is structurally impossible — trim evidence only
                if neighbors is not None:
                    live_t = neighbors.valid_dev & ~state.trust.evicted
                else:
                    live_t = jnp.asarray(adjacency, bool) & ~state.trust.evicted
                new_trust = trust_lib.update(
                    tspec, state.trust, t=state.t,
                    trim_frac=jnp.where(live_t, trim, 0.0), live=live_t)
                metrics["trust_evicted_frac"] = jnp.mean(
                    new_trust.evicted.astype(jnp.float32))
        new_mets = _fold_metric_ring(cell.metrics, state, metrics)
        return BridgeState(new_params, state.t + 1, key, state.net, new_comm,
                           new_adv, new_obs, new_trust, new_mets), metrics

    return step


def build_cell_runtime_step(grad_fn, runtime, rules: tuple[str, ...], message_attacks, *,
                            codecs: tuple[str, ...] = ("identity",), wire_attacks=None,
                            adversaries: tuple[str, ...] | None = None,
                            screen_chunk=None):
    """The network-runtime iteration: ``step(cell, state, batch)``.

    ``message_attacks`` is a static bank of `byzantine.MessageAttack`s and
    ``codecs`` / ``wire_attacks`` the wire-format banks (see
    `build_cell_step`).  Messages are encoded per *link* — a Byzantine sender
    tells different lies on different links, so its codewords (and the
    error-feedback residuals behind them) diverge per link too.  A runtime
    exposing ``cell_aware = True`` (the grid engine's scenario-banked
    runtime) additionally receives the cell so it can switch channel/schedule
    per experiment; the standard runtimes keep their two-argument contract.

    ``adversaries`` crafts per-link lies adaptively (`repro.adversary`): on a
    single-channel runtime the adversary additionally sees the coordinate
    subset a bandwidth-capped channel will deliver this tick and the
    channel's expected latency — the staleness-exploiting message variants.
    """
    cell_aware = bool(getattr(runtime, "cell_aware", False))
    # neighbor-indexed layout (repro.core.neighbors): the runtime exposes its
    # static table and every per-link tensor in this step is [M, K, ...]
    nbr = getattr(runtime, "neighbors", None)
    codec_bank = codec_lib.codec_bank(codecs)
    if wire_attacks is None:
        wire_attacks = (byz_lib.WIRE_ATTACKS["none"],) * len(message_attacks)
    adv_bank = None if adversaries is None else adv_lib.adversary_bank(adversaries)
    adv_engaged = adv_lib.bank_engaged(adv_bank)
    # omniscient channel knowledge is only well defined when the runtime has
    # ONE channel (the scenario-banked grid runtime switches per cell; its
    # adversaries fall back to attacking every coordinate, latency 0)
    channel = getattr(runtime, "channel", None)
    adv_latency = 0.0
    if channel is not None:
        adv_latency = 0.5 * (channel.latency_min + channel.latency_max)

    def screen_oracle(wb, adj_t, cell):
        """The adversary's differentiable per-tick screening closure."""
        if nbr is not None:
            return screening.screen_views_banked(
                nbr.gather_rows(wb), adj_t, wb, rules, cell.rule_idx, cell.b,
                chunk=screen_chunk)
        return screening.screen_all_banked(
            wb, adj_t, rules, cell.rule_idx, cell.b, chunk=screen_chunk,
            self_vals=wb)

    def step(cell: CellParams, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        spec = cell.trace  # static: TraceSpec or None (zero-leaf aux data)
        tspec = cell.trust  # static: TrustSpec or None (zero-leaf aux data)
        w, unflatten = stack_flatten(state.params)
        d = w.shape[1]
        m = w.shape[0]
        key, sub = jax.random.split(state.key)
        # dense: the tick's [M, M] adjacency; sparse: the [M, K] live-slot mask
        adj_t = runtime.adjacency_at(state.t, cell) if cell_aware else runtime.adjacency_at(state.t)
        # (Step 3-4) per-link transmissions with Byzantine substitution.
        with jax.named_scope("bridge.attack"):
            if nbr is not None:
                msgs = byz_lib.apply_sparse_message_attack_bank(
                    message_attacks, cell.attack_idx, w, cell.byz_mask, nbr, adj_t, sub, state.t
                )
            else:
                msgs = byz_lib.apply_message_attack_bank(
                    message_attacks, cell.attack_idx, w, cell.byz_mask, adj_t, sub, state.t
                )
            # Byzantine nodes screen with the same self-view they broadcast
            # (matching the synchronous path); message-only attacks have no
            # single broadcast value, so nodes screen with their true iterate.
            w_self = byz_lib.apply_self_view_bank(
                message_attacks, cell.attack_idx, w, cell.byz_mask, sub, state.t
            )
        new_adv = state.adv
        if adv_engaged:
            with jax.named_scope("bridge.adversary"):
                net_key_peek = jax.random.fold_in(sub, NET_SALT)
                deliver = None
                peek = getattr(runtime, "delivered_coord_mask", None)
                if peek is not None and not cell_aware:
                    deliver = peek(net_key_peek, d)
                ctx = adv_lib.AdvCtx(
                    screen=lambda wb: screen_oracle(wb, adj_t, cell),
                    deliver_mask=deliver,
                    latency=adv_latency,
                )
                theta = adv_lib.cell_theta(adv_bank, _cell_adv_idx(cell), cell.adv_theta)
                if nbr is not None:
                    adv_msgs, adv_self, new_adv = adv_lib.apply_sparse_message_adversary_bank(
                        adv_bank, _cell_adv_idx(cell), ctx, state.adv, theta,
                        w, cell.byz_mask, nbr, adj_t, jax.random.fold_in(sub, ADV_SALT), state.t,
                    )
                    adv_sender_byz = nbr.gather_senders(cell.byz_mask, fill=False)
                else:
                    adv_msgs, adv_self, new_adv = adv_lib.apply_message_adversary_bank(
                        adv_bank, _cell_adv_idx(cell), ctx, state.adv, theta,
                        w, cell.byz_mask, adj_t, jax.random.fold_in(sub, ADV_SALT), state.t,
                    )
                    adv_sender_byz = jnp.broadcast_to(cell.byz_mask[None, :], adj_t.shape)
                # the adversary re-crafts Byzantine senders only; honest links
                # keep whatever the static message-attack stage produced, bitwise
                msgs = jnp.where(adv_sender_byz[:, :, None], adv_msgs, msgs)
                w_self = jnp.where(cell.byz_mask[:, None], adv_self, w_self)
        # wire codec per link ([receiver, sender/slot] leading axes); the
        # sender axis marks whose codewords the wire attacks may corrupt, and
        # per-edge ids key their PRNG streams identically on both layouts
        if nbr is not None:
            byz_link = nbr.gather_senders(cell.byz_mask, fill=False)
            eids = nbr.edge_ids
        else:
            byz_link = jnp.broadcast_to(cell.byz_mask[None, :], adj_t.shape)
            eids = jnp.asarray(neighbors_lib.edge_id_grid(m))
        with jax.named_scope("bridge.codec"):
            msgs_hat, comm_full = _wire_roundtrip(
                codec_bank, wire_attacks, cell, sub, msgs, state.comm,
                byz_link, state.t, d, eids=eids,
            )
            if state.comm is not None and comm_full is not state.comm:
                # a sender advances a link's public copy / residual only for
                # messages actually put on the wire this tick (live edges);
                # channel drops are downstream and invisible to it
                comm_full = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(adj_t[:, :, None], new, old),
                    comm_full, state.comm)
        wire_bits = comm_lib.wire_bits_bank(codec_bank, _cell_codec_idx(cell), d)
        net_key = jax.random.fold_in(sub, NET_SALT)
        with jax.named_scope("bridge.exchange"):
            if cell_aware:
                net, views, mask, net_stats = runtime.exchange(
                    state.net, msgs_hat, w_self, adj_t, net_key, state.t, cell,
                    wire_bits=wire_bits,
                )
            else:
                net, views, mask, net_stats = runtime.exchange(
                    state.net, msgs_hat, w_self, adj_t, net_key, state.t,
                    wire_bits=wire_bits,
                )
        # (Step 5) asynchronous screening over whatever usable (arrived,
        # fresh) messages each node holds; nodes starved below the rule's
        # minimum usable count keep their own iterate this tick.
        trim = None
        mask_eff = mask
        with jax.named_scope("bridge.screen"):
            if tspec is not None:
                # trust on: decide path (trim fractions are the evidence),
                # reputation weights into the weighted rules, evicted edges
                # cleared from the usable mask as if the link had died
                from repro.trust import reputation as trust_lib

                screening.check_decide_streams(rules, d, screen_chunk)
                stride = (spec.decide_stride if spec is not None and spec.forensics
                          else tspec.decide_stride)
                mask_eff = mask & ~state.trust.evicted
                y_rule, trim = screening.screen_views_decide_banked(
                    views, mask_eff, w_self, rules, cell.rule_idx, cell.b,
                    decide_stride=stride,
                    weights=trust_lib.edge_weights(tspec, state.trust),
                )
            elif spec is not None and spec.forensics:
                screening.check_decide_streams(rules, d, screen_chunk)
                y_rule, trim = screening.screen_views_decide_banked(
                    views, mask, w_self, rules, cell.rule_idx, cell.b,
                    decide_stride=spec.decide_stride,
                )
            else:
                y_rule = screening.screen_views_banked(
                    views, mask, w_self, rules, cell.rule_idx, cell.b, chunk=screen_chunk,
                )
            need = screening.min_neighbors_banked(rules, cell.rule_idx, cell.b)
            enough = jnp.sum(mask_eff, axis=1) >= need
            y = jnp.where(enough[:, None], y_rule, w_self)
        with jax.named_scope("bridge.apply"):
            new_params, metrics = _grad_update_and_metrics(
                grad_fn, cell, state, batch, y, unflatten)
        metrics.update(net_stats)
        metrics["screened_frac"] = jnp.mean(enough.astype(jnp.float32))
        metrics.update(_comm_metrics(
            codec_bank, cell, d, jnp.sum(adj_t).astype(jnp.float32), comm_full))
        new_obs = state.obs
        if spec is not None:
            from repro.obs import trace as obs_trace

            with jax.named_scope("bridge.obs"):
                live = byz_edge = None
                if trim is not None:
                    # nodes starved below the Table-II minimum fell back to
                    # their own iterate — their rows never screened this tick
                    # (mask_eff == mask when trust is off)
                    live = mask_eff & enough[:, None]
                    trim = jnp.where(live, trim, 0.0)
                    byz_edge = byz_link & live
                    live_f = live.astype(jnp.float32)
                    metrics["obs_trim_frac"] = (
                        jnp.sum(trim * live_f) / jnp.maximum(jnp.sum(live_f), 1.0))
                new_obs = obs_trace.update(
                    spec, state.obs, t=state.t, loss=metrics["loss"],
                    consensus=metrics["consensus_dist"], trim_frac=trim,
                    live=live, byz_edge=byz_edge,
                    staleness=obs_trace.staleness_of(net, state.t),
                    wire_bits=wire_bits,
                    live_edges=jnp.sum(adj_t).astype(jnp.float32), d=d)
        new_trust = state.trust
        if tspec is not None:
            from repro.trust import echo as echo_lib
            from repro.trust import reputation as trust_lib
            from repro.net import mailbox as mb

            echo_ev = None
            if tspec.echo:
                # (commit-then-gossip) digest what each node holds, exchange
                # digest rows one hop, and cross-check within matching send
                # generations — quorum-confirmed mismatches are equivocation
                with jax.named_scope("bridge.echo"):
                    trust_key = jax.random.fold_in(sub, TRUST_SALT)
                    gens = getattr(net, "send_tick", None)
                    if gens is None:
                        # net-less runtime (ideal synchronous exchange): every
                        # usable view was sent this tick
                        gens = jnp.where(mask, state.t, mb.NEVER)
                    if nbr is not None:
                        vals_d = echo_lib.scatter_dense(nbr, views, 0.0)
                        gens_d = echo_lib.scatter_dense(nbr, gens, mb.NEVER)
                        valid_d = echo_lib.scatter_dense(nbr, mask_eff, False)
                        gossip_d = echo_lib.scatter_dense(nbr, adj_t, False)
                    else:
                        vals_d, gens_d, valid_d = views, gens, mask_eff
                        gossip_d = jnp.asarray(adj_t, bool)
                    dig_d = echo_lib.digest_all(tspec, vals_d, trust_key)
                    if adv_engaged and adv_lib.bank_accuses(adv_bank):
                        # slanderers forge the digest rows they *report*
                        # (their own receptions stay honest — value screening
                        # sees nothing; only the gossip lies)
                        theta_acc = adv_lib.cell_theta(
                            adv_bank, _cell_adv_idx(cell), cell.adv_theta)
                        dig_d = adv_lib.apply_accuse_bank(
                            adv_bank, _cell_adv_idx(cell), theta_acc, dig_d,
                            cell.byz_mask, trust_key, state.t)
                    ev_d, _mism = echo_lib.equivocation_evidence(
                        dig_d, gens_d, valid_d, gossip_d, cell.b,
                        tol=tspec.echo_tol)
                    if nbr is not None:
                        echo_ev = nbr.gather_edges(ev_d, 0.0)
                    else:
                        echo_ev = ev_d
            with jax.named_scope("bridge.trust"):
                # rows starved below the rule minimum never screened: their
                # trim fractions are fallback artifacts, not evidence
                screened = mask_eff & enough[:, None]
                new_trust = trust_lib.update(
                    tspec, state.trust, t=state.t,
                    trim_frac=jnp.where(screened, trim, 0.0),
                    live=mask_eff, echo_evidence=echo_ev)
                metrics["trust_evicted_frac"] = jnp.mean(
                    new_trust.evicted.astype(jnp.float32))
        stale_m = None
        if cell.metrics is not None:
            from repro.obs import trace as obs_trace

            stale_m = obs_trace.staleness_of(net, state.t)
        new_mets = _fold_metric_ring(cell.metrics, state, metrics,
                                     staleness=stale_m, live=mask)
        return BridgeState(new_params, state.t + 1, key, net, comm_full,
                           new_adv, new_obs, new_trust, new_mets), metrics

    return step


def build_stream_cell_step(grad_fn, spec, adjacency, rules, attacks, **kwargs):
    """The chunk-streaming twin of `build_cell_step` /
    `build_cell_runtime_step`: the same attack -> codec -> (exchange ->)
    screen -> apply tick, executed per coordinate block of a parameter-pytree
    partition ``spec`` (`repro.stream.blocks.BlockSpec`) so the flat ``[M, d]``
    matrix of `stack_flatten` never materializes.  Thin delegator — the
    implementation lives in `repro.stream.engine` (imported lazily; the
    streaming subsystem imports this module for `BridgeState`/`CellParams`).
    """
    from repro.stream.engine import build_stream_cell_step as _impl

    return _impl(grad_fn, spec, adjacency, rules, attacks, **kwargs)


class BridgeTrainer:
    """Drives Algorithm 1.  ``grad_fn(node_params, batch) -> (loss, grads)``
    computes the *local* empirical-risk gradient of one node.

    ``runtime`` plugs in a message-exchange model (see `repro.net.runtime`):
    ``None`` is the classic synchronous broadcast simulation; an
    `UnreliableRuntime` yields asynchronous BRIDGE over a lossy, delayed,
    time-varying network, screening whatever messages have arrived (within
    the runtime's staleness bound) and falling back to the node's own iterate
    whenever too few usable messages are present for the rule's Table-II
    minimum.  With an ideal channel and a static schedule the runtime path
    reproduces the synchronous path bit-for-bit."""

    def __init__(self, config: BridgeConfig, grad_fn: Callable, runtime=None):
        config.topology.validate_for_rule(config.rule)
        self.config = config
        self.grad_fn = grad_fn
        self.runtime = runtime
        self.adjacency = jnp.asarray(config.topology.adjacency)
        m = config.topology.num_nodes
        nbyz = min(config.num_byzantine, m)
        if (config.attack == "none" and config.adversary == "none") or nbyz == 0:
            self.byz_mask = jnp.zeros((m,), dtype=bool)
        else:
            self.byz_mask = byz_lib.pick_byzantine_mask(m, nbyz, config.byzantine_seed)
        self.codec = codec_lib.get_codec(config.codec)
        wire_bank = byz_lib.wire_attack_bank((config.attack,))
        # the adversary bank is engaged only when named, so the default path
        # keeps its exact pre-adversary program shape
        self._adv_bank = (None if config.adversary == "none"
                          else adv_lib.adversary_bank((config.adversary,)))
        # the sync path's neighbor table (sparse layout); runtimes carry
        # their own (built from the schedule union)
        self.neighbors = None
        if config.sparse and runtime is None:
            self.neighbors = NeighborTable.from_adjacency(config.topology.adjacency)
        if config.sparse and runtime is not None and getattr(runtime, "neighbors", None) is None:
            raise ValueError(
                "BridgeConfig(sparse=True) with an explicit dense runtime: pass a "
                "neighbor-indexed runtime (SparseUnreliableRuntime) or drop the flag "
                "— a dense runtime would silently keep the O(M^2) state layout")
        if runtime is None:
            self._attack = byz_lib.get_attack(config.attack)
            step = build_cell_step(
                grad_fn, self.adjacency, (config.rule,), (self._attack,),
                codecs=(config.codec,), wire_attacks=wire_bank,
                adversaries=None if self._adv_bank is None else (config.adversary,),
                screen_chunk=config.screen_chunk, neighbors=self.neighbors,
            )
        else:
            self._message_attack = byz_lib.get_message_attack(config.attack)
            step = build_cell_runtime_step(
                grad_fn, runtime, (config.rule,), (self._message_attack,),
                codecs=(config.codec,), wire_attacks=wire_bank,
                adversaries=None if self._adv_bank is None else (config.adversary,),
                screen_chunk=config.screen_chunk,
            )
        # The cell rides along as a jit *operand*, not a closure constant, so
        # the compiled program is shape-identical to the batched grid engine's
        # (constant-folding a baked-in cell perturbs fusion at ULP level,
        # breaking the bit-for-bit grid<->trainer equivalence contract).
        self._cell = self.cell_params()
        self._raw_step = step
        self._jit_step = jax.jit(step)

    def cell_params(self) -> CellParams:
        """The constant single-cell parameters equivalent to this config
        (bank indices are 0 — the trainer's banks have one entry each)."""
        cfg = self.config
        adv_idx = adv_theta = None
        if self._adv_bank is not None:
            # theta rides as a jit operand (like the cell itself) for
            # program-shape parity with the grid engine
            adv_idx = jnp.zeros((), jnp.int32)
            adv_theta = jnp.asarray(self._adv_bank[0].default_theta, jnp.float32)
        return CellParams(
            rule_idx=jnp.zeros((), jnp.int32),
            attack_idx=jnp.zeros((), jnp.int32),
            b=jnp.asarray(cfg.num_byzantine, jnp.int32),
            byz_mask=self.byz_mask,
            lam=jnp.asarray(cfg.lam, jnp.float32),
            t0=jnp.asarray(cfg.t0, jnp.float32),
            lr=jnp.asarray(cfg.lr, jnp.float32),
            codec_idx=jnp.zeros((), jnp.int32),
            adv_idx=adv_idx,
            adv_theta=adv_theta,
            trace=cfg.trace,
            trust=cfg.trust,
            metrics=cfg.metrics,
        )

    @property
    def honest_mask(self) -> jax.Array:
        return ~self.byz_mask

    def init(self, params: Any, seed: int = 0) -> BridgeState:
        m = self.config.topology.num_nodes
        lead = jax.tree_util.tree_leaves(params)[0].shape[0]
        if lead != m:
            raise ValueError(f"params leading axis {lead} != num_nodes {m}")
        net = comm = adv = None
        w, _ = stack_flatten(params)
        dim = w.shape[1]
        if self.runtime is not None:
            net = self.runtime.init(m, dim, max_wire_bits=self.codec.wire_bits(dim))
            # per-link codec carry: [M, M, d] dense, [M, K, d] neighbor-indexed
            rt_nbr = getattr(self.runtime, "neighbors", None)
            link = m if rt_nbr is None else rt_nbr.k
            comm = comm_lib.init_residual((m, link, dim), (self.codec,))
        else:
            comm = comm_lib.init_residual((m, dim), (self.codec,))
        if adv_lib.bank_stateful(self._adv_bank):
            adv = adv_lib.init_state(dim)
        obs = trust = None
        nbr = (self.neighbors if self.runtime is None
               else getattr(self.runtime, "neighbors", None))
        width = m if nbr is None else nbr.k
        if self.config.trace is not None:
            from repro.obs import trace as obs_trace

            obs = obs_trace.init_state(self.config.trace, m, width)
        if self.config.trust is not None:
            from repro.trust import reputation as trust_lib

            trust = trust_lib.init_state(self.config.trust, m, width)
        mets = None
        if self.config.metrics is not None:
            from repro.obs import metrics as obs_metrics

            mets = obs_metrics.init_state(self.config.metrics)
        return BridgeState(params=params, t=jnp.zeros((), jnp.int32),
                           key=jax.random.PRNGKey(seed), net=net, comm=comm,
                           adv=adv, obs=obs, trust=trust, mets=mets)

    def step(self, state: BridgeState, batch: Any) -> tuple[BridgeState, dict]:
        return self._jit_step(self._cell, state, batch)

    def run(self, state: BridgeState, batch_fn: Callable[[int], Any], num_steps: int,
            eval_fn: Callable | None = None, eval_every: int = 0) -> tuple[BridgeState, list[dict]]:
        history = []
        for i in range(num_steps):
            state, metrics = self.step(state, batch_fn(i))
            if eval_fn is not None and eval_every and (i + 1) % eval_every == 0:
                metrics = dict(metrics)
                metrics.update(eval_fn(state))
                metrics["step"] = i + 1
                history.append(jax.device_get(metrics))
        return state, history

    # -- chunked host loop (the live-telemetry / grid-throughput hook) ------

    def _chunk_scan(self):
        """The jitted scan-over-one-chunk with a DONATED state carry.  jax
        caches compilations per chunk length, so a run costs one trace for
        the full-width chunks plus one for a ragged tail."""
        fn = getattr(self, "_chunk_scan_fn", None)
        if fn is None:
            raw = self._raw_step

            def scan_chunk(cell, st, xs):
                # Python side effect: executes only while tracing — the
                # retrace guard (`repro.analysis.retrace`) reads this counter
                # to prove a run cost one trace per distinct chunk length
                self.chunk_trace_count = getattr(self, "chunk_trace_count", 0) + 1
                return jax.lax.scan(lambda s, b: raw(cell, s, b), st, xs)

            fn = self._chunk_scan_fn = jax.jit(scan_chunk, donate_argnums=(1,))
        return fn

    def run_chunks(self, state: BridgeState, batch_fn: Callable[[int], Any],
                   num_steps: int, *, chunk: int | None = None, writer=None,
                   events=None, tag: str = "train",
                   start: int = 0) -> tuple[BridgeState, dict]:
        """Run ``num_steps`` ticks as a host loop over jitted scan *chunks*
        with donated carries — dispatch never waits for host I/O.

        After each chunk the live-metric ring is handed to ``writer``
        (`repro.obs.metrics.MetricWriter` — which copies it device-side
        before the next dispatch invalidates the donated buffer) and a
        ``train.chunk`` record lands in ``events``.  ``chunk`` defaults to
        the metric spec's ring capacity (no tick overwritten before it is
        flushed), or 64 without one.  Returns ``(final_state, metrics)``
        with ``[T]`` metric streams, bitwise identical to step-at-a-time /
        single-scan execution (pinned by ``tests/test_metrics.py``).
        """
        mspec = getattr(self.config, "metrics", None)
        if chunk is None:
            chunk = mspec.capacity if mspec is not None else 64
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if mspec is not None and chunk > mspec.capacity:
            raise ValueError(
                f"chunk {chunk} exceeds MetricSpec.capacity {mspec.capacity}: "
                f"the ring would overwrite unflushed ticks")
        scan_chunk = self._chunk_scan()
        tree = jax.tree_util.tree_map
        chunks_ms = []
        done = start
        while done < start + num_steps:
            hi = min(done + chunk, start + num_steps)
            xs = stack_batches(lambda i: batch_fn(done + i), hi - done)
            t_chunk = time.perf_counter()
            with warnings.catch_warnings():
                # backends without buffer donation (older CPU jaxlibs) warn
                # per compile; the donation is an optimization, not a
                # correctness requirement
                warnings.filterwarnings(
                    "ignore", message=".*[Dd]onat.*", category=UserWarning)
                state, ms = scan_chunk(self._cell, state, xs)
            # host work below overlaps the dispatched device computation:
            # the writer copies the ring and device_gets on its own thread
            if writer is not None:
                writer.flush(state.mets, tag=tag)
            if events is not None:
                # dispatch wall, deliberately not block_until_ready — the
                # overlap IS the feature (grid.chunk events block instead)
                # `train_tag`, not `tag`: EventLog.emit's first argument IS
                # the record's "tag" field and fields must not collide
                events.emit("train.chunk", train_tag=tag, lo=done, hi=hi,
                            dispatch_s=time.perf_counter() - t_chunk)
            chunks_ms.append(ms)
            done = hi
        metrics = tree(lambda *xs: jnp.concatenate(xs, axis=0), *chunks_ms)
        return state, metrics


def replicate(params: Any, num_nodes: int, *, perturb: float = 0.0, key=None) -> Any:
    """Stack one model into [M, ...] node replicas; optional init perturbation
    (the paper initializes nodes inside a common ball, not identically —
    unlike ICwTM which *requires* identical initialization)."""

    def rep(leaf):
        return jnp.broadcast_to(leaf[None], (num_nodes,) + leaf.shape)

    stacked = jax.tree_util.tree_map(rep, params)
    if perturb > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [
            l + perturb * jax.random.normal(k, l.shape, l.dtype)
            for l, k in zip(leaves, keys, strict=True)
        ]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "bridge.prng.single_use", "prng",
        "no PRNG key in a compiled step feeds two distinct random draws "
        "without an intervening split/fold_in — per-edge wire-roundtrip and "
        "per-step subkey independence, statically (flat, sparse, net, and "
        "metrics-on canonical programs)",
        params=(("programs", ("flat", "sparse", "net", "metrics")),),
    ),
    Contract(
        "bridge.salts.distinct", "lint",
        "the stream salts (attack / channel / codec / wire / adversary / "
        "trust) are pairwise distinct, so streams folded from one step "
        "subkey never correlate",
        params=(("check", "salts_distinct"),
                ("salts", ("NET_SALT", "COMM_SALT", "WIRE_SALT", "ADV_SALT",
                           "TRUST_SALT"))),
    ),
    Contract(
        "bridge.sparse.no_dense_mmd", "memory",
        "the sparse (neighbor-indexed) step never materializes a tensor as "
        "large as the dense [M, M, d] float layout it replaces",
        params=(("programs", ("sparse",)), ("budget", "dense_mmd")),
    ),
    Contract(
        "bridge.run_chunks.single_trace", "retrace",
        "a uniform-chunk run_chunks costs exactly one trace, and an "
        "identically-shaped re-run costs zero (compilations are cached per "
        "chunk length)",
        params=(("max_traces", 1),),
    ),
    Contract(
        "bridge.chunk_carry.donated", "memory",
        "the chunk scan's donated state carry survives into the compiled "
        "module's input_output_alias table (donation honored, not silently "
        "copied)",
        params=(("check", "donation"),),
    ),
)
