"""Screening rules of the BRIDGE framework (Sec. III, Table II).

All rules share the signature::

    screen(values, mask, self_value, b) -> y

where ``values`` is ``[n, d]`` — the messages received from (up to) ``n``
potential in-neighbors, ``mask`` is ``[n]`` bool marking which rows are real
neighbors (graphs have varying degree; rows with ``mask==False`` are ignored),
``self_value`` is ``[d]`` — the node's own iterate, and ``b`` is the maximum
number of Byzantine nodes to tolerate.

These are the pure-jnp reference implementations; `repro.kernels` provides the
Pallas TPU realizations of the coordinate-wise hot loops, and `gossip.py`
applies these rules on parameter shards under shard_map.

Numerics note: trimmed-mean / median are rank-based, so they are invariant to
any monotone per-coordinate transform of the Byzantine entries — the basis of
the paper's resilience argument (Eq. 14: every surviving Byzantine value is a
convex combination of honest values).

Masked entries use a ``+inf`` sentinel, NOT a large finite constant: a finite
sentinel silently corrupts the rank windows whenever legitimate (or attacked)
values exceed it — e.g. fp32 payloads in the 1e30..3e38 range, or bf16
overflow products — because data then sorts *past* the sentinel rows.  With
``+inf`` every finite value ranks strictly before the sentinels.  Non-finite
*payloads* still rank correctly (-inf trims from the bottom, +inf from the
top); NaN payloads would poison the sort order and are explicitly guarded to
``+inf`` so they are trimmed with the other top-magnitude outliers.
"""
from __future__ import annotations

import functools
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp

_MASKED = jnp.inf  # sentinel for masked entries; see module docstring


def sum_rows(x: jax.Array) -> jax.Array:
    """Strictly sequential sum over the leading (neighbor) axis.

    ``jnp.sum`` lowers to a shape-dependent reduction tree, so summing the
    same non-zero rows padded to *different* row counts can differ in ULPs —
    which would break the dense [M]-row vs sparse [K]-row screening
    bit-identity contract (`repro.core.neighbors`).  A left-to-right chain is
    layout-invariant: ``x + 0.0`` is exact, so present-but-zeroed padded rows
    drop out bitwise.  ONLY safe when the summand contains no multiply: XLA
    may FMA-contract ``a * b + total`` in one program shape but not the
    other, which is exactly the ULP drift the chain exists to prevent — sums
    over products must use `sum_rows_mat`.  Falls back to ``jnp.sum`` above
    the same row bound as `sort_rows` (a huge-M dense run is the slow
    oracle, not a bit-identity reference).
    """
    n = x.shape[0]
    if n > 64:
        return jnp.sum(x, axis=0)
    total = x[0]
    for i in range(1, n):
        total = total + x[i]
    return total


def sum_rows_mat(x: jax.Array) -> jax.Array:
    """`sum_rows` for summands that contain a product (geomedian's weighted
    rows, clipped-mean's scaled deltas): a ``lax.scan`` *materializes* its
    ``xs`` operand, so the producer multiply is rounded to storage precision
    before the loop and the body is a pure, contraction-proof add.
    (``optimization_barrier`` would be cheaper but has no batching rule on
    jax 0.4.x.)"""
    n = x.shape[0]
    if n > 64:
        return jnp.sum(x, axis=0)
    total, _ = jax.lax.scan(lambda tot, row: (tot + row, None), jnp.zeros_like(x[0]), x)
    return total


def fence(x: jax.Array) -> jax.Array:
    """Round ``x`` to storage precision behind a ``lax.scan`` (whose ``xs``
    XLA must materialize).  Rules whose *last* operation is a multiply
    (`coordinate_median`'s ``0.5 * (lo + hi)``) would otherwise leave the
    caller free to FMA-contract that multiply into its own subtract in one
    program shape but not another — the same cross-program ULP drift
    `sum_rows_mat` guards inside the rules.  The scan is length TWO, not
    one: XLA's while-loop simplifier unrolls trip-count-<=1 loops, which
    would re-fuse the producer and void the fence."""
    out, _ = jax.lax.scan(lambda c, row: (row, None), jnp.zeros_like(x),
                          jnp.stack([x, x]))
    return out


def effective_trim(b, count: jax.Array) -> jax.Array:
    """The trim width a ``count``-strong usable neighborhood can support:
    ``min(b, (count - 1) // 2)``.

    `Topology.validate_for_rule` certifies Table II's ``|N_j| >= 2b + 1`` on
    the *static* graph only; a churn/partition schedule (`repro.net.dynamic`)
    can drop a tick's live in-degree below that, where an unclamped trim
    window would sweep ``+inf`` sentinel rows into the kept ranks and the
    divisor ``count - 2b + 1`` through zero.  At or above the bound the clamp
    is the identity (``b_eff == b``) — bit-identical to the unclamped rule —
    and below it the rule degrades to the widest trim the tick supports (the
    network runtime additionally freezes such nodes entirely; this clamp
    covers the paths with no freeze, e.g. the adversary's per-tick screening
    oracle).  Regression-tested in ``tests/test_sparse.py``.
    """
    cnt = jnp.asarray(count, jnp.int32)
    return jnp.clip(jnp.asarray(b, jnp.int32), 0, jnp.maximum((cnt - 1) // 2, 0))


def _sanitize(values: jax.Array) -> jax.Array:
    """NaN payloads -> +inf so rank-based rules treat them as maximal outliers
    (the explicit finite-payload guard for the inf-sentinel masking)."""
    return jnp.where(jnp.isnan(values), _MASKED, values)


@functools.lru_cache(maxsize=None)
def _batcher_pairs(n: int) -> tuple[tuple[int, int], ...]:
    """Batcher odd-even mergesort compare-exchange schedule for n elements
    (works for arbitrary n, ~n/2 log^2 n pairs)."""
    pairs = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(min(k, n - j - k)):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return tuple(pairs)


def sort_rows(values: jax.Array) -> jax.Array:
    """Ascending sort of ``values [n, d]`` along the (small) neighbor axis.

    XLA's CPU sort lowers to a scalar per-column loop — ~1us per 12-element
    column, which makes screening the step's hot spot.  For the neighbor
    counts BRIDGE actually sees (n <= a few dozen) a Batcher odd-even merge
    network of element-wise ``minimum``/``maximum`` over whole [d] rows
    vectorizes instead, an order of magnitude faster, and produces the exact
    sorted array (values are unique-by-rank, so the output is identical to
    ``jnp.sort``).  Large n falls back to ``jnp.sort``.  NaNs must already be
    sanitized (min/max would propagate them through the network).
    """
    n = values.shape[0]
    if n > 64:
        return jnp.sort(values, axis=0)
    rows = list(values)
    for a, b in _batcher_pairs(n):
        lo = jnp.minimum(rows[a], rows[b])
        hi = jnp.maximum(rows[a], rows[b])
        rows[a], rows[b] = lo, hi
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# Coordinate-wise rules (BRIDGE-T, BRIDGE-M)
# ---------------------------------------------------------------------------


def trimmed_mean(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int) -> jax.Array:
    """BRIDGE-T screening — Eq. (7)-(10).

    Per coordinate k: drop the b largest and b smallest neighbor values, then
    average the survivors together with the node's own value, with divisor
    ``|N_j| - 2b + 1``.
    """
    n = values.shape[0]
    count = jnp.sum(mask)  # |N_j|, traced scalar
    b_eff = effective_trim(b, count)  # == b whenever count >= 2b + 1
    masked = jnp.where(mask[:, None], _sanitize(values), _MASKED)
    order = sort_rows(masked)  # ascending; masked at the end
    idx = jnp.arange(n)[:, None]
    keep = (idx >= b_eff) & (idx < count - b_eff)  # ranks [b_eff, |N_j| - b_eff)
    total = sum_rows(jnp.where(keep, order, 0.0)) + self_value
    y = total / (count - 2 * b_eff + 1).astype(values.dtype)
    # XLA CPU re-computes the fused sort network per consumer; a scalar
    # full-reduce consumer forces `order` to materialize once (~3x on
    # [128, 16, 64]).  min (not sum: huge payloads overflow a sum to
    # inf - inf = NaN) of NaN-sanitized input is never NaN, so the select is
    # the identity bitwise, but the compare can't be constant-folded — that
    # is what keeps the reduce alive.
    anchor = jnp.min(order)
    return jnp.where(anchor == anchor, y, jnp.zeros_like(y))


def coordinate_median(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int = 0) -> jax.Array:
    """BRIDGE-M screening — Eq. (11): coordinate-wise median over N_j ∪ {j}.

    Even cardinalities average the two middle order statistics.
    """
    del b  # median needs no explicit knowledge of b (Sec. III)
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    full_mask = jnp.concatenate([mask, jnp.ones((1,), dtype=bool)], axis=0)
    n1 = stacked.shape[0]
    count = jnp.sum(full_mask)
    order = sort_rows(jnp.where(full_mask[:, None], _sanitize(stacked), _MASKED))
    lo = (count - 1) // 2
    hi = count // 2
    idx = jnp.arange(n1)[:, None]
    pick_lo = jnp.sum(jnp.where(idx == lo, order, 0.0), axis=0)
    pick_hi = jnp.sum(jnp.where(idx == hi, order, 0.0), axis=0)
    return fence(0.5 * (pick_lo + pick_hi))


# ---------------------------------------------------------------------------
# Vector rules (BRIDGE-K, BRIDGE-B)
# ---------------------------------------------------------------------------


def pairwise_sq_dists(values: jax.Array, mask: jax.Array, self_value: jax.Array):
    """[n+1, n+1] squared distances among neighbors + self (self last row/col).

    Returns (dists, full_mask); masked rows/cols hold +BIG off-diagonal.
    """
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    full_mask = jnp.concatenate([mask, jnp.ones((1,), dtype=bool)], axis=0)
    sq = jnp.sum(stacked * stacked, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (stacked @ stacked.T)
    d2 = jnp.maximum(d2, 0.0)
    valid = full_mask[:, None] & full_mask[None, :]
    d2 = jnp.where(valid, d2, _MASKED)
    return d2, full_mask


def _krum_scores(d2: jax.Array, full_mask: jax.Array, count: jax.Array, b: int) -> jax.Array:
    """Krum score per candidate row of the distance matrix ``d2``.

    score(i) = sum of the (|N_j| - b - 2) smallest distances from i to other
    valid vectors (Eq. 12).  Invalid candidates get +inf scores.
    """
    n1 = d2.shape[0]
    eye = jnp.eye(n1, dtype=bool)
    d2 = jnp.where(eye, _MASKED, d2)  # exclude self-distance
    order = jnp.sort(d2, axis=1)  # ascending per candidate
    k = count - b - 2  # number of nearest peers to sum (traced)
    idx = jnp.arange(n1)[None, :]
    take = idx < jnp.maximum(k, 1)
    # transpose so the (sorted-rank) reduction runs through the
    # layout-invariant sequential chain — see `sum_rows`
    scores = sum_rows(jnp.where(take, order, 0.0).T)
    return jnp.where(full_mask, scores, jnp.inf)


def krum(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int) -> jax.Array:
    """BRIDGE-K screening — Eq. (12): output the whole vector of the neighbor
    minimizing the Krum score.  Candidates are the neighbors only (i ∈ N_j),
    while distances range over N_j ∪ {j}."""
    d2, full_mask = pairwise_sq_dists(values, mask, self_value)
    count = jnp.sum(mask)  # |N_j|
    scores = _krum_scores(d2, full_mask, count, b)
    cand_scores = jnp.where(mask, scores[:-1], jnp.inf)  # exclude self as candidate
    i_star = jnp.argmin(cand_scores)
    return values[i_star]


def _bulyan_select(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int) -> jax.Array:
    """Bulyan's recursive-Krum selection mask: the |N_j| - 2b neighbors the
    trimmed-mean stage then aggregates.  Factored out so the decision-
    instrumented twin reuses the exact selection op graph."""
    n = values.shape[0]
    d2, full_mask = pairwise_sq_dists(values, mask, self_value)
    count0 = jnp.sum(mask)
    n_select = count0 - 2 * b  # traced

    def body(step, carry):
        cand_mask, sel_mask = carry
        cnt = jnp.sum(cand_mask)
        fm = jnp.concatenate([cand_mask, jnp.ones((1,), dtype=bool)])
        valid = fm[:, None] & fm[None, :]
        d2s = jnp.where(valid, d2, _MASKED)
        scores = _krum_scores(d2s, fm, cnt, b)
        cand_scores = jnp.where(cand_mask, scores[:-1], jnp.inf)
        i_star = jnp.argmin(cand_scores)
        active = step < n_select
        pick = jnp.zeros((n,), dtype=bool).at[i_star].set(active)
        return cand_mask & ~pick, sel_mask | pick

    _, selected = jax.lax.fori_loop(0, n, body, (mask, jnp.zeros((n,), dtype=bool)))
    return selected


def bulyan(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int) -> jax.Array:
    """BRIDGE-B screening: recursive-Krum selection of |N_j| - 2b neighbors,
    then coordinate-wise trimmed mean (with self) over the selected set."""
    selected = _bulyan_select(values, mask, self_value, b)
    return trimmed_mean(values, selected, self_value, b)


def geometric_median(values: jax.Array, mask: jax.Array, self_value: jax.Array,
                     b: int = 0, *, iters: int = 8, eps: float = 1e-6) -> jax.Array:
    """Geometric median over N_j ∪ {j} via Weiszfeld iterations — an extra
    BRIDGE variant from the robust-statistics menu the paper points at
    (Sec. III: "additional variants ... from the literature on robust
    statistics").  Breakdown point 1/2; no explicit b needed."""
    del b
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    fm = jnp.concatenate([mask, jnp.ones((1,), bool)], axis=0).astype(values.dtype)
    y = sum_rows_mat(stacked * fm[:, None]) / jnp.sum(fm)

    def body(y, _):
        d = jnp.sqrt(jnp.sum((stacked - y[None]) ** 2, axis=1) + eps)
        w = fm / d
        y = sum_rows_mat(stacked * w[:, None]) / sum_rows(w[:, None])[0]
        return y, None

    y, _ = jax.lax.scan(body, y, None, length=iters)
    return y


def clipped_mean(values: jax.Array, mask: jax.Array, self_value: jax.Array,
                 b: int = 0, *, tau: float = 1.0) -> jax.Array:
    """Centered clipping (Karimireddy et al. style): average of neighbor
    deltas clipped to an l2 ball of radius tau around the node's own iterate.
    Bounds each neighbor's influence by tau/|N_j| per step."""
    del b
    delta = values - self_value[None, :]
    nrm = jnp.sqrt(jnp.sum(delta * delta, axis=1, keepdims=True) + 1e-12)
    scale = jnp.minimum(1.0, tau / nrm)
    clipped = delta * scale
    cnt = jnp.sum(mask)
    return self_value + sum_rows_mat(jnp.where(mask[:, None], clipped, 0.0)) / jnp.maximum(cnt, 1)


def mean(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int = 0) -> jax.Array:
    """No screening — plain DGD neighbor averaging (uniform weights over
    N_j ∪ {j}).  The b=0 baseline the paper's Figures 1-2 compare against."""
    del b
    count = jnp.sum(mask)
    total = sum_rows(jnp.where(mask[:, None], values, 0.0)) + self_value
    return total / (count + 1).astype(values.dtype)


# ---------------------------------------------------------------------------
# Reputation-aware rules (repro.trust)
# ---------------------------------------------------------------------------
#
# The trust layer carries per-edge reputation weights (``clip(1 - suspicion,
# 0, 1)``, 0 = evicted) and feeds them to these rules through the ``weights``
# keyword of the decide-banked dispatch.  With ``weights=None`` they act with
# uniform weights, so they remain valid standalone registry entries; rules
# outside `WEIGHTED_RULES` simply ignore the weights operand (eviction still
# reaches them through the screening mask).  Because detection-and-eviction
# removes attackers instead of out-voting them, the rep variants advertise a
# weaker MIN_NEIGHBORS requirement (b + 1 instead of 2b + 1) — the degree
# headroom the detect-and-expel breakdown study spends (benchmarks/
# trust_bench.py).


def _rep_trim_window(values, mask, b):
    """Shared kept-window core: boundary order statistics of the masked sort
    (the same dynamic row gathers the decision twins use)."""
    count = jnp.sum(mask)
    b_eff = effective_trim(b, count)
    masked = jnp.where(mask[:, None], _sanitize(values), _MASKED)
    order = sort_rows(masked)
    lo = jax.lax.dynamic_index_in_dim(order, b_eff, 0, keepdims=False)
    hi = jax.lax.dynamic_index_in_dim(
        order, jnp.maximum(count - b_eff - 1, b_eff), 0, keepdims=False)
    kept = mask[:, None] & (masked >= lo[None, :]) & (masked <= hi[None, :])
    return masked, order, kept


def rep_trimmed_mean(values, mask, self_value, b, *, weights=None):
    """Reputation-weighted BRIDGE-T: trim the b largest / b smallest per
    coordinate as usual, then average the survivors with per-edge reputation
    weights (self always weight 1): ``y = (sum_i w_i kept_i v_i + self) /
    (sum_i w_i kept_i + 1)``.  Uniform weights recover a tie-inclusive
    trimmed mean; weight-0 (evicted) edges drop out exactly."""
    n = values.shape[0]
    masked, order, kept = _rep_trim_window(values, mask, b)
    w = jnp.ones((n,), values.dtype) if weights is None else jnp.asarray(
        weights, values.dtype)
    wk = jnp.where(kept, w[:, None], 0.0)
    total = sum_rows_mat(wk * jnp.where(kept, masked, 0.0)) + self_value
    y = total / (sum_rows_mat(wk) + 1.0)
    anchor = jnp.min(order)  # sort-materialization anchor, see trimmed_mean
    return jnp.where(anchor == anchor, y, jnp.zeros_like(y))


def rep_median(values, mask, self_value, b=0, *, weights=None):
    """Reputation-weighted coordinate median: per coordinate, the smallest
    value whose cumulative reputation weight reaches half the total (self
    carries weight 1, masked rows weight 0).  Uniform weights recover the
    lower-median pick of BRIDGE-M."""
    del b
    n1 = values.shape[0] + 1
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    fm = jnp.concatenate([mask, jnp.ones((1,), bool)], axis=0)
    w = (jnp.ones(values.shape[:1], values.dtype) if weights is None
         else jnp.asarray(weights, values.dtype))
    wfull = jnp.concatenate([jnp.where(mask, w, 0.0), jnp.ones((1,), values.dtype)])
    sv = jnp.where(fm[:, None], _sanitize(stacked), _MASKED)
    order_idx = jnp.argsort(sv, axis=0)
    sorted_vals = jnp.take_along_axis(sv, order_idx, axis=0)
    sorted_w = jnp.take_along_axis(
        jnp.broadcast_to(wfull[:, None], (n1,) + sv.shape[1:]), order_idx, axis=0)
    cum = jnp.cumsum(sorted_w, axis=0)
    first = jnp.argmax(cum >= 0.5 * cum[-1][None, :], axis=0)
    return jnp.take_along_axis(sorted_vals, first[None, :], axis=0)[0]


def rep_trimmed_mean_with_decisions(values, mask, self_value, b, *, weights=None,
                                    decide_stride=1):
    n = values.shape[0]
    masked, order, kept = _rep_trim_window(values, mask, b)
    w = jnp.ones((n,), values.dtype) if weights is None else jnp.asarray(
        weights, values.dtype)
    wk = jnp.where(kept, w[:, None], 0.0)
    total = sum_rows_mat(wk * jnp.where(kept, masked, 0.0)) + self_value
    y = total / (sum_rows_mat(wk) + 1.0)
    s = decide_stride
    trim = jnp.mean((mask[:, None] & ~kept[:, ::s]).astype(jnp.float32), axis=1)
    anchor = jnp.min(order)
    y = jnp.where(anchor == anchor, y, jnp.zeros_like(y))
    trim = jnp.where(anchor == anchor, trim, jnp.zeros_like(trim))
    return y, trim


def rep_median_with_decisions(values, mask, self_value, b=0, *, weights=None,
                              decide_stride=1):
    y = rep_median(values, mask, self_value, b, weights=weights)
    # trim membership mirrors coordinate_median_with_decisions: a value
    # "survives" when it sits inside the (unweighted) middle-rank window of
    # the stacked values — what feeds suspicion is who keeps landing in the
    # tails, which is a rank property independent of the weights
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    full_mask = jnp.concatenate([mask, jnp.ones((1,), dtype=bool)], axis=0)
    n1 = stacked.shape[0]
    count = jnp.sum(full_mask)
    masked = jnp.where(full_mask[:, None], _sanitize(stacked), _MASKED)
    order = sort_rows(masked)
    lo = (count - 1) // 2
    hi = count // 2
    idx = jnp.arange(n1)[:, None]
    pick_lo = jnp.sum(jnp.where(idx == lo, order, 0.0), axis=0)
    pick_hi = jnp.sum(jnp.where(idx == hi, order, 0.0), axis=0)
    s = decide_stride
    kept = (masked[:, ::s] >= pick_lo[None, ::s]) & (masked[:, ::s] <= pick_hi[None, ::s])
    trim = jnp.mean((full_mask[:, None] & ~kept).astype(jnp.float32), axis=1)
    return y, trim[:-1]


# Rules that consume per-edge reputation weights (the rest ignore the
# operand; eviction still reaches them through the screening mask).
WEIGHTED_RULES: frozenset = frozenset({"rep_trimmed_mean", "rep_median"})


# The screening-rule registry.  Names here are what `--rules`, ExperimentGrid
# and the banked lax.switch dispatch resolve; adding a rule means adding an
# entry in each of: RULES, MIN_NEIGHBORS (its Table-II degree requirement —
# `rep_*` rules advertise b + 1, backed by trust-layer eviction rather than
# out-voting), RULES_WITH_DECISIONS if it can report per-edge trim decisions
# (repro.obs forensics), and WEIGHTED_RULES if it consumes reputation
# weights.  Every rule takes masked `[n, d]` neighbor values (absent rows
# carry the +inf sentinel) and must stay total-ordered under inf/NaN decode
# garbage — see docs/ARCHITECTURE.md ("bridge.screen") for where this runs.
RULES: dict[str, Callable] = {
    "trimmed_mean": trimmed_mean,
    "median": coordinate_median,
    "krum": krum,
    "bulyan": bulyan,
    "geomedian": geometric_median,
    "clipped_mean": clipped_mean,
    "mean": mean,
    "rep_trimmed_mean": rep_trimmed_mean,
    "rep_median": rep_median,
}


# ---------------------------------------------------------------------------
# Decision-instrumented twins (screening forensics — repro.obs)
# ---------------------------------------------------------------------------
#
# Each `<rule>_with_decisions` returns ``(y, trim_frac)`` where ``y`` is built
# from the *identical op graph* as the plain rule (bitwise-equal outputs —
# property-tested in tests/test_obs.py, the trace-inertness contract) and
# ``trim_frac[i]`` is the fraction of coordinates on which neighbor i's value
# was excluded from the aggregate (0/1 for the vector rules).  Decisions are
# derived from order statistics the rule already computes — kept-boundary
# thresholds instead of O(n^2 d) per-coordinate rank matrices — so the obs
# path stays inside the <10% overhead budget at M=512.


def trimmed_mean_with_decisions(values, mask, self_value, b, *, decide_stride=1):
    n = values.shape[0]
    count = jnp.sum(mask)
    b_eff = effective_trim(b, count)
    masked = jnp.where(mask[:, None], _sanitize(values), _MASKED)
    order = sort_rows(masked)
    idx = jnp.arange(n)[:, None]
    keep = (idx >= b_eff) & (idx < count - b_eff)
    total = sum_rows(jnp.where(keep, order, 0.0)) + self_value
    y = total / (count - 2 * b_eff + 1).astype(values.dtype)
    # kept iff the value lies within the kept-rank boundary order statistics
    # (ties at the boundary count as kept — conservative for the counters).
    # The picks are dynamic row gathers, not masked reductions: on the
    # anchor-materialized `order` they read 2 rows instead of sweeping all of
    # [n, d] twice — the difference between +6% and +96% step overhead at
    # d=7850.  decide_stride > 1 estimates the per-edge fractions on every
    # stride-th coordinate: sort and boundary picks stay exact, only the
    # O(n*d) membership pass shrinks — the counters' ranking signal
    # accumulates over ticks either way
    s = decide_stride
    lo = jax.lax.dynamic_index_in_dim(order, b_eff, 0, keepdims=False)
    hi = jax.lax.dynamic_index_in_dim(
        order, jnp.maximum(count - b_eff - 1, b_eff), 0, keepdims=False)
    kept = (masked[:, ::s] >= lo[None, ::s]) & (masked[:, ::s] <= hi[None, ::s])
    trim = jnp.mean((mask[:, None] & ~kept).astype(jnp.float32), axis=1)
    # XLA CPU re-computes the fused sort network once per consumer; a scalar
    # full-reduce consumer forces `order` to materialize exactly once, making
    # every other read of it free (measured 5-6x on [128, 16, 64]).  min (not
    # sum, which huge payloads overflow to inf - inf = NaN) of NaN-sanitized
    # input is never NaN, so the select is the identity bitwise — but the
    # compare can't be constant-folded, which keeps the reduce alive.
    anchor = jnp.min(order)
    trim = jnp.where(anchor == anchor, trim, jnp.zeros_like(trim))
    return y, trim


def coordinate_median_with_decisions(values, mask, self_value, b=0, *, decide_stride=1):
    del b
    stacked = jnp.concatenate([values, self_value[None, :]], axis=0)
    full_mask = jnp.concatenate([mask, jnp.ones((1,), dtype=bool)], axis=0)
    n1 = stacked.shape[0]
    count = jnp.sum(full_mask)
    masked = jnp.where(full_mask[:, None], _sanitize(stacked), _MASKED)
    order = sort_rows(masked)
    lo = (count - 1) // 2
    hi = count // 2
    idx = jnp.arange(n1)[:, None]
    pick_lo = jnp.sum(jnp.where(idx == lo, order, 0.0), axis=0)
    pick_hi = jnp.sum(jnp.where(idx == hi, order, 0.0), axis=0)
    y = fence(0.5 * (pick_lo + pick_hi))
    # a value "survives" the median when it sits inside [lo, hi] — i.e. it is
    # one of the middle order statistics the output averages (decide_stride
    # samples the membership pass; see trimmed_mean_with_decisions)
    s = decide_stride
    kept = (masked[:, ::s] >= pick_lo[None, ::s]) & (masked[:, ::s] <= pick_hi[None, ::s])
    trim = jnp.mean((full_mask[:, None] & ~kept).astype(jnp.float32), axis=1)
    return y, trim[:-1]  # drop the self row: decisions are about neighbors


def krum_with_decisions(values, mask, self_value, b, *, decide_stride=1):
    del decide_stride  # whole-vector decision
    n = values.shape[0]
    d2, full_mask = pairwise_sq_dists(values, mask, self_value)
    count = jnp.sum(mask)
    scores = _krum_scores(d2, full_mask, count, b)
    cand_scores = jnp.where(mask, scores[:-1], jnp.inf)
    i_star = jnp.argmin(cand_scores)
    trim = (mask & (jnp.arange(n) != i_star)).astype(jnp.float32)
    return values[i_star], trim


def bulyan_with_decisions(values, mask, self_value, b, *, decide_stride=1):
    selected = _bulyan_select(values, mask, self_value, b)
    y, trim_inner = trimmed_mean_with_decisions(values, selected, self_value, b,
                                                decide_stride=decide_stride)
    # deselected-by-Krum neighbors are fully trimmed; the rest carry the
    # inner trimmed-mean's per-coordinate fractions
    return y, jnp.where(mask & ~selected, 1.0, trim_inner)


def geometric_median_with_decisions(values, mask, self_value, b=0, *,
                                    iters: int = 8, eps: float = 1e-6,
                                    decide_stride=1):
    del decide_stride  # whole-vector decision
    y = geometric_median(values, mask, self_value, b, iters=iters, eps=eps)
    # soft suspicion: distance to the median, normalized by the masked median
    # distance (Weiszfeld downweights rows by 1/distance, so this is the
    # influence deficit); 0 for rows at/inside the typical radius
    n = values.shape[0]
    diff = values - y[None, :]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=1) + eps)
    cnt = jnp.sum(mask)
    order = jnp.sort(jnp.where(mask, dist, jnp.inf))
    idx = jnp.arange(n)
    lo = jnp.maximum((cnt - 1) // 2, 0)
    hi = jnp.maximum(cnt // 2, 0)
    med = 0.5 * (jnp.sum(jnp.where(idx == lo, order, 0.0))
                 + jnp.sum(jnp.where(idx == hi, order, 0.0)))
    trim = jnp.where(mask, jnp.clip(1.0 - med / jnp.maximum(dist, 1e-12), 0.0, 1.0), 0.0)
    return y, trim.astype(jnp.float32)


def clipped_mean_with_decisions(values, mask, self_value, b=0, *, tau: float = 1.0,
                                decide_stride=1):
    del decide_stride  # whole-vector decision
    y = clipped_mean(values, mask, self_value, b, tau=tau)
    delta = values - self_value[None, :]
    nrm = jnp.sqrt(jnp.sum(delta * delta, axis=1) + 1e-12)
    # clipped = influence capped at tau/|N_j| — the rule's trim analogue
    trim = (mask & (nrm > tau)).astype(jnp.float32)
    return y, trim


def mean_with_decisions(values, mask, self_value, b=0, *, decide_stride=1):
    del decide_stride
    return mean(values, mask, self_value, b), jnp.zeros(values.shape[:1], jnp.float32)


RULES_WITH_DECISIONS: dict[str, Callable] = {
    "trimmed_mean": trimmed_mean_with_decisions,
    "median": coordinate_median_with_decisions,
    "krum": krum_with_decisions,
    "bulyan": bulyan_with_decisions,
    "geomedian": geometric_median_with_decisions,
    "clipped_mean": clipped_mean_with_decisions,
    "mean": mean_with_decisions,
    "rep_trimmed_mean": rep_trimmed_mean_with_decisions,
    "rep_median": rep_median_with_decisions,
}


def get_rule(name: str) -> Callable:
    try:
        return RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown screening rule {name!r}; options: {sorted(RULES)}") from None


# Minimum in-neighborhood size each rule needs to tolerate b Byzantine nodes
# (Table II).  Shared by `graph.Topology.validate_for_rule` and the network
# runtime, which falls back to the node's own iterate whenever fewer usable
# (arrived, fresh) messages are available at a tick.
MIN_NEIGHBORS: dict[str, Callable[[int], int]] = {
    "trimmed_mean": lambda b: 2 * b + 1,
    "median": lambda b: 1,
    "krum": lambda b: b + 3,
    "bulyan": lambda b: max(4 * b, 3 * b + 2) + 1,
    "geomedian": lambda b: 2 * b + 1,
    "clipped_mean": lambda b: 1,
    "mean": lambda b: 0,
    # detect-and-expel variants: eviction removes attackers instead of
    # out-voting them, so the static degree requirement relaxes to b + 1
    # honest-majority headroom (the trust breakdown study's premise)
    "rep_trimmed_mean": lambda b: b + 1,
    "rep_median": lambda b: 1,
}


def min_neighbors(rule: str, b: int) -> int:
    try:
        return MIN_NEIGHBORS[rule](b)
    except KeyError:
        raise ValueError(
            f"unknown screening rule {rule!r}; options: {sorted(MIN_NEIGHBORS)}") from None


# Traceable twins of MIN_NEIGHBORS: ``b`` may be a traced int32 scalar (the
# batched grid engine carries the Byzantine bound as per-experiment data), so
# Python ``max`` is replaced by ``jnp.maximum`` and constants are anchored to
# ``b`` to keep every branch shape/dtype-uniform under ``lax.switch``.
_MIN_NEIGHBORS_TRACEABLE: dict[str, Callable] = {
    "trimmed_mean": lambda b: 2 * b + 1,
    "median": lambda b: 0 * b + 1,
    "krum": lambda b: b + 3,
    "bulyan": lambda b: jnp.maximum(4 * b, 3 * b + 2) + 1,
    "geomedian": lambda b: 2 * b + 1,
    "clipped_mean": lambda b: 0 * b + 1,
    "mean": lambda b: 0 * b,
    "rep_trimmed_mean": lambda b: b + 1,
    "rep_median": lambda b: 0 * b + 1,
}


def min_neighbors_banked(rules: Sequence[str], rule_idx, b) -> jax.Array:
    """Table-II minimum usable in-neighborhood for the rule selected by the
    traced index ``rule_idx`` into the static bank ``rules``; ``b`` may be a
    traced int32 scalar."""
    fns = [_MIN_NEIGHBORS_TRACEABLE[r] for r in rules]
    bi = jnp.asarray(b, jnp.int32)
    if len(fns) == 1:
        return jnp.asarray(fns[0](bi), jnp.int32)
    branches = [lambda bb, fn=fn: jnp.asarray(fn(bb), jnp.int32) for fn in fns]
    return jax.lax.switch(rule_idx, branches, bi)


# ---------------------------------------------------------------------------
# Network-wide application (simulation path, single host)
# ---------------------------------------------------------------------------


def _streams(rule: str, d: int, chunk: int | None) -> bool:
    """True when coordinate streaming engages: then the node axis must be
    iterated sequentially (lax.map) to keep peak memory at [n, chunk] per
    node instead of vmap's [M, n, chunk]."""
    return rule not in ("krum", "bulyan") and chunk is not None and d > chunk


# Rules whose output on a coordinate block equals the same block sliced out of
# the full-d output — the block-streaming contract of `repro.stream`.  This is
# strictly stronger than what `_streams` gates: geomedian's Weiszfeld weights
# and clipped_mean's clipping radii are functions of *full-vector* norms, so
# chunked evaluation changes their result (only tolerable inside `_apply_rule`
# because the default ``screen_chunk`` exceeds every experiment's d); the
# rules here are purely per-coordinate, so block results are bitwise equal.
STREAMABLE_RULES: frozenset = frozenset(
    {"trimmed_mean", "median", "mean", "rep_trimmed_mean", "rep_median"})

# The complement, spelled out rather than computed: `repro.analysis.lint`
# asserts {STREAMABLE_RULES, STREAM_REJECTED_RULES} is an exact partition of
# RULES, so adding a rule forces an explicit streamability decision — a rule
# left out of both sets is a lint failure, not a silent default.
STREAM_REJECTED_RULES: frozenset = frozenset(
    {"krum", "bulyan", "geomedian", "clipped_mean"})


def check_streamable(rules: Sequence[str]) -> None:
    """Raise for rules whose blockwise result differs from the full-d result
    (`repro.stream` refuses them instead of silently changing the rule)."""
    bad = [r for r in rules if r not in STREAMABLE_RULES]
    if bad:
        raise ValueError(
            f"rules {bad} are not coordinate-decomposable and cannot stream "
            f"over parameter blocks (repro.stream); streamable rules: "
            f"{sorted(STREAMABLE_RULES)}")


def _apply_rule(fn, rule, values, mask_j, self_j, b, chunk):
    """One node's screening over its received value matrix ``values [n, d]``,
    optionally streaming coordinate-wise rules over chunks of the coordinate
    dimension (bounding peak memory at ``[n, chunk]`` intermediates per node).
    Shared by `screen_all` (one broadcast matrix for everyone) and
    `screen_views` (per-node mailbox views) so the two paths are numerically
    identical."""
    d = values.shape[1]
    if rule in ("krum", "bulyan") or chunk is None or d <= chunk:
        return fn(values, mask_j, self_j, b)
    # coordinate-wise rules can stream over coordinate chunks
    pad = (-d) % chunk
    wp = jnp.pad(values, ((0, 0), (0, pad)))
    sp = jnp.pad(self_j, (0, pad))
    nchunks = wp.shape[1] // chunk
    wc = wp.reshape(values.shape[0], nchunks, chunk).transpose(1, 0, 2)
    sc = sp.reshape(nchunks, chunk)
    out = jax.lax.map(lambda vs: fn(vs[0], mask_j, vs[1], b), (wc, sc))
    return out.reshape(-1)[:d]


@functools.partial(jax.jit, static_argnames=("rule", "b", "chunk"))
def screen_all(
    w: jax.Array,
    adjacency: jax.Array,
    *,
    rule: str,
    b: int,
    chunk: int | None = None,
) -> jax.Array:
    """Apply a screening rule at every node: ``w`` is ``[M, d]`` stacked node
    iterates (Byzantine rows already substituted by the attack model —
    Definition 1 concerns what nodes *broadcast*), ``adjacency[j, i]`` marks i
    as an in-neighbor of j.  Returns the ``[M, d]`` screened outputs y_j.

    Nodes are screened via ``vmap`` (one fused program over the node axis —
    a sequential ``lax.map`` pays ~ms of while-loop overhead per node on
    CPU).  When ``chunk`` engages (coordinate-wise rule, d > chunk), nodes
    fall back to a sequential ``lax.map`` so peak intermediates stay at
    ``[n, chunk]`` per node — the memory contract huge-d training relies on.
    """
    fn = get_rule(rule)

    def per_node(mask_j, self_j):
        return _apply_rule(fn, rule, w, mask_j, self_j, b, chunk)

    if _streams(rule, w.shape[1], chunk):
        return jax.lax.map(lambda args: per_node(*args), (adjacency, w))
    return jax.vmap(per_node)(adjacency, w)


@functools.partial(jax.jit, static_argnames=("rule", "b", "chunk"))
def screen_views(
    views: jax.Array,
    mask: jax.Array,
    self_vals: jax.Array,
    *,
    rule: str,
    b: int,
    chunk: int | None = None,
) -> jax.Array:
    """Apply a screening rule at every node over *per-node* value views.

    Unlike `screen_all`, where every node screens rows of one shared broadcast
    matrix, here node j screens its own ``views[j] [M, d]`` — e.g. mailbox
    contents delivered by an unreliable network (`repro.net`), where different
    nodes hold different (possibly stale) versions of a sender's iterate and a
    Byzantine sender may have told different receivers different things.
    ``mask[j, i]`` marks the (j, i) entry as usable (arrived and fresh);
    ``self_vals[j]`` is node j's own iterate.  Returns ``[M, d]`` outputs y_j.
    """
    fn = get_rule(rule)

    def per_node(view_j, mask_j, self_j):
        return _apply_rule(fn, rule, view_j, mask_j, self_j, b, chunk)

    if _streams(rule, views.shape[-1], chunk):
        return jax.lax.map(lambda args: per_node(*args), (views, mask, self_vals))
    return jax.vmap(per_node)(views, mask, self_vals)


# ---------------------------------------------------------------------------
# Banked (branchless) dispatch — the batched-grid hot path
# ---------------------------------------------------------------------------
#
# The grid engine runs E experiments with *different* rules inside one jitted
# program, so rule selection cannot be a Python-level ``get_rule``: it is a
# ``lax.switch`` over a static bank of rule names, indexed by a traced int32.
# Under ``vmap`` the switch lowers to "compute every bank entry, select one"
# — branchless, one compilation, no per-cell retracing.  Banks should
# therefore contain only the distinct rules a grid actually uses.  With a
# single-entry bank these degenerate to exactly `screen_all` / `screen_views`
# (the switch is elided), which is how `BridgeTrainer` calls them — keeping
# the per-experiment and batched paths bit-identical.


def _rule_branch(rule: str, chunk):
    fn = get_rule(rule)

    def run(values_per_node, mask_per_node, self_vals, b):
        def per_node(values_j, mask_j, self_j):
            return _apply_rule(fn, rule, values_j, mask_j, self_j, b, chunk)

        if _streams(rule, values_per_node.shape[-1], chunk):
            return jax.lax.map(lambda args: per_node(*args),
                               (values_per_node, mask_per_node, self_vals))
        return jax.vmap(per_node)(values_per_node, mask_per_node, self_vals)

    return run


def _rule_branch_broadcast(rule: str, chunk):
    # like _rule_branch, but every node screens rows of ONE shared matrix —
    # closed over, never materialized per node, so the streaming path keeps
    # its O(M*d + n*chunk) peak instead of an [M, M, d] broadcast
    fn = get_rule(rule)

    def run(w, adjacency, b, self_vals):
        def per_node(mask_j, self_j):
            return _apply_rule(fn, rule, w, mask_j, self_j, b, chunk)

        if _streams(rule, w.shape[1], chunk):
            return jax.lax.map(lambda args: per_node(*args), (adjacency, self_vals))
        return jax.vmap(per_node)(adjacency, self_vals)

    return run


def screen_all_banked(
    w: jax.Array,
    adjacency: jax.Array,
    rules: Sequence[str],
    rule_idx,
    b,
    *,
    chunk: int | None = None,
    self_vals: jax.Array | None = None,
) -> jax.Array:
    """`screen_all` with the rule chosen by a traced ``rule_idx`` into the
    static ``rules`` bank and a (possibly traced) Byzantine bound ``b``.

    ``self_vals`` separates the matrix nodes *screen* (``w`` — what arrived,
    e.g. decoded wire codewords) from the value each node combines as its own
    (``self_vals[j]`` — its local iterate, which never travels the wire and
    is never compressed).  Defaults to ``w`` itself, the classic broadcast
    semantics where both coincide."""
    if self_vals is None:
        self_vals = w
    branches = [_rule_branch_broadcast(r, chunk) for r in rules]
    if len(branches) == 1:
        return branches[0](w, adjacency, b, self_vals)
    return jax.lax.switch(rule_idx, branches, w, adjacency, b, self_vals)


def screen_views_banked(
    views: jax.Array,
    mask: jax.Array,
    self_vals: jax.Array,
    rules: Sequence[str],
    rule_idx,
    b,
    *,
    chunk: int | None = None,
) -> jax.Array:
    """`screen_views` with banked rule dispatch (see `screen_all_banked`)."""
    branches = [_rule_branch(r, chunk) for r in rules]
    if len(branches) == 1:
        return branches[0](views, mask, self_vals, b)
    return jax.lax.switch(rule_idx, branches, views, mask, self_vals, b)


# ---------------------------------------------------------------------------
# Banked dispatch with decisions (screening forensics — repro.obs)
# ---------------------------------------------------------------------------
#
# Same shape as the plain banked dispatch, but every branch runs the rule's
# decision-instrumented twin and returns ``(y [M, d], trim_frac [M, n])``.
# The decide path never streams coordinates (the trim matrix spans all of d by
# construction); callers must guard with `check_decide_streams` so engaging
# forensics where streaming would have engaged is a loud error, not a silent
# memory blowup.


def check_decide_streams(rules: Sequence[str], d: int, chunk: int | None) -> None:
    """Raise when screening forensics would collide with coordinate
    streaming (`_streams`): the decision path evaluates rules unchunked."""
    bad = [r for r in rules if _streams(r, d, chunk)]
    if bad:
        raise ValueError(
            f"screening forensics cannot stream coordinates: rules {bad} at d={d} "
            f"engage screen_chunk={chunk}; raise screen_chunk above d or set "
            f"TraceSpec(forensics=False)")


def _rule_branch_decide(rule: str, decide_stride: int, weighted: bool = False):
    fn = RULES_WITH_DECISIONS[rule]
    if weighted:
        # reputation-weighted dispatch (repro.trust): every branch of the
        # switch takes the [M, n] weight rows so signatures stay uniform;
        # rules outside WEIGHTED_RULES ignore the operand (eviction reaches
        # them through the mask)
        if rule in WEIGHTED_RULES:
            def run(values_per_node, mask_per_node, self_vals, b, weights):
                return jax.vmap(
                    lambda v, m, s, wt: fn(v, m, s, b, weights=wt,
                                           decide_stride=decide_stride))(
                    values_per_node, mask_per_node, self_vals, weights)
        else:
            def run(values_per_node, mask_per_node, self_vals, b, weights):
                del weights
                return jax.vmap(lambda v, m, s: fn(v, m, s, b,
                                                   decide_stride=decide_stride))(
                    values_per_node, mask_per_node, self_vals)
        return run

    def run(values_per_node, mask_per_node, self_vals, b):
        return jax.vmap(lambda v, m, s: fn(v, m, s, b, decide_stride=decide_stride))(
            values_per_node, mask_per_node, self_vals)

    return run


def _rule_branch_broadcast_decide(rule: str, decide_stride: int, weighted: bool = False):
    fn = RULES_WITH_DECISIONS[rule]
    if weighted:
        if rule in WEIGHTED_RULES:
            def run(w, adjacency, b, self_vals, weights):
                return jax.vmap(
                    lambda m, s, wt: fn(w, m, s, b, weights=wt,
                                        decide_stride=decide_stride))(
                    adjacency, self_vals, weights)
        else:
            def run(w, adjacency, b, self_vals, weights):
                del weights
                return jax.vmap(lambda m, s: fn(w, m, s, b,
                                                decide_stride=decide_stride))(
                    adjacency, self_vals)
        return run

    def run(w, adjacency, b, self_vals):
        return jax.vmap(lambda m, s: fn(w, m, s, b, decide_stride=decide_stride))(
            adjacency, self_vals)

    return run


def screen_all_decide_banked(
    w: jax.Array,
    adjacency: jax.Array,
    rules: Sequence[str],
    rule_idx,
    b,
    *,
    self_vals: jax.Array | None = None,
    decide_stride: int = 1,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """`screen_all_banked` returning ``(y, trim_frac)`` — ``y`` bitwise-equal
    to the plain path, ``trim_frac[j, i]`` the fraction of coordinates on
    which receiver j excluded sender i this tick (estimated on every
    ``decide_stride``-th coordinate when > 1).  ``weights`` (``[M, n]``
    reputation rows, `repro.trust`) routes to rules in `WEIGHTED_RULES`;
    ``None`` keeps the exact unweighted program shape."""
    if self_vals is None:
        self_vals = w
    if weights is not None:
        branches = [_rule_branch_broadcast_decide(r, decide_stride, weighted=True)
                    for r in rules]
        if len(branches) == 1:
            return branches[0](w, adjacency, b, self_vals, weights)
        return jax.lax.switch(rule_idx, branches, w, adjacency, b, self_vals, weights)
    branches = [_rule_branch_broadcast_decide(r, decide_stride) for r in rules]
    if len(branches) == 1:
        return branches[0](w, adjacency, b, self_vals)
    return jax.lax.switch(rule_idx, branches, w, adjacency, b, self_vals)


def screen_views_decide_banked(
    views: jax.Array,
    mask: jax.Array,
    self_vals: jax.Array,
    rules: Sequence[str],
    rule_idx,
    b,
    *,
    decide_stride: int = 1,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """`screen_views_banked` returning ``(y, trim_frac)`` (see
    `screen_all_decide_banked`); ``weights`` as there."""
    if weights is not None:
        branches = [_rule_branch_decide(r, decide_stride, weighted=True)
                    for r in rules]
        if len(branches) == 1:
            return branches[0](views, mask, self_vals, b, weights)
        return jax.lax.switch(rule_idx, branches, views, mask, self_vals, b, weights)
    branches = [_rule_branch_decide(r, decide_stride) for r in rules]
    if len(branches) == 1:
        return branches[0](views, mask, self_vals, b)
    return jax.lax.switch(rule_idx, branches, views, mask, self_vals, b)


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "screening.fence.survives", "fence",
        "every `fence` site survives the optimized HLO as a trip-count-2 "
        "while loop (XLA unrolls trip-count-<=1 loops, which would re-fuse "
        "the producer and void the storage-precision rounding)",
        params=(("min_fences", 1),),
    ),
    Contract(
        "screening.metrics.gradnorm_unfused", "fence",
        "the metrics-on program keeps exactly one more fence than its "
        "metrics-off twin: the grad-norm reduction stays un-CSE'd from the "
        "loss reduction (metrics-on bit-inertness)",
        params=(("delta", 1),),
    ),
    Contract(
        "screening.stream.partition", "lint",
        "every rule in RULES sits in exactly one of STREAMABLE_RULES / "
        "STREAM_REJECTED_RULES",
        params=(("check", "stream_partition"),),
    ),
    Contract(
        "screening.registries.complete", "lint",
        "MIN_NEIGHBORS, its traceable twin, and RULES_WITH_DECISIONS cover "
        "exactly RULES's keys; WEIGHTED_RULES is a subset",
        params=(("check", "registry_completeness"),),
    ),
)
