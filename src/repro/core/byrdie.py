"""ByRDiE baseline (Yang & Bajwa, 2019 [58]) — the coordinate-descent
predecessor the paper compares against in Fig. 3.

One ByRDiE *iteration* sweeps all d coordinates: for each coordinate k the
nodes exchange scalar values [w_i]_k, screen them with the scalar trimmed
mean, and take a coordinate gradient step — requiring d network-wide scalar
broadcasts and d local full-gradient evaluations per sweep, versus BRIDGE's
single vector broadcast and single gradient per iteration.  This is exactly
the communication/computation overhead the paper's Fig. 3 quantifies.

Faithful simulation of d sequential scalar rounds is O(d) gradient
evaluations per sweep; we process coordinates in ``block`` -sized groups
(gradient recomputed per group) — ``block=1`` is exact ByRDiE; larger blocks
are a controlled approximation whose communication accounting stays exact
(we count scalars exchanged either way).  Benchmarks note the block size.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import byzantine as byz_lib
from repro.core import screening
from repro.core.bridge import stack_flatten
from repro.core.graph import Topology


class ByrdieState(NamedTuple):
    params: Any
    t: jax.Array  # sweep counter
    key: jax.Array
    scalars_sent: jax.Array  # cumulative per-node scalar broadcasts


@dataclasses.dataclass(frozen=True)
class ByrdieConfig:
    topology: Topology
    num_byzantine: int = 0
    attack: str = "none"
    byzantine_seed: int = 0
    lam: float = 1.0
    t0: float = 50.0
    block: int = 256  # coordinates per gradient recomputation

    def step_size(self, t):
        return 1.0 / (self.lam * (self.t0 + t))


class ByrdieTrainer:
    def __init__(self, config: ByrdieConfig, grad_fn: Callable):
        config.topology.validate_for_rule("trimmed_mean")
        self.config = config
        self.grad_fn = grad_fn
        self.adjacency = jnp.asarray(config.topology.adjacency)
        m = config.topology.num_nodes
        if config.attack == "none" or config.num_byzantine == 0:
            self.byz_mask = jnp.zeros((m,), dtype=bool)
        else:
            self.byz_mask = byz_lib.pick_byzantine_mask(
                m, config.num_byzantine, config.byzantine_seed
            )
        self._attack = byz_lib.get_attack(config.attack)
        self._sweep = jax.jit(self._build_sweep())

    def init(self, params: Any, seed: int = 0) -> ByrdieState:
        return ByrdieState(
            params=params,
            t=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(seed),
            scalars_sent=jnp.zeros((), jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32),
        )

    def _build_sweep(self):
        cfg = self.config
        b = cfg.num_byzantine

        def sweep(state: ByrdieState, batch: Any):
            w0, unflatten = stack_flatten(state.params)
            m, d = w0.shape
            nblocks = -(-d // cfg.block)
            pad = nblocks * cfg.block - d
            rho = cfg.step_size(state.t)
            key, sub = jax.random.split(state.key)

            def block_body(i, carry):
                w, = carry
                # recompute full local gradients at the CURRENT iterate
                params = unflatten(w)
                _, grads = jax.vmap(self.grad_fn)(params, batch)
                g, _ = stack_flatten(grads)
                # coordinate window [i*block, (i+1)*block)
                start = i * cfg.block
                wk = jax.lax.dynamic_slice(w, (0, start), (m, cfg.block))
                gk = jax.lax.dynamic_slice(g, (0, start), (m, cfg.block))
                wk_b = self._attack(wk, self.byz_mask, jax.random.fold_in(sub, i), state.t)
                yk = screening.screen_all(wk_b, self.adjacency, rule="trimmed_mean", b=b)
                wk_new = yk - rho * gk
                w = jax.lax.dynamic_update_slice(w, wk_new, (0, start))
                return (w,)

            wpad = jnp.pad(w0, ((0, 0), (0, pad)))
            (wfin,) = jax.lax.fori_loop(0, nblocks, block_body, (wpad,))
            w_new = wfin[:, :d]
            # communication accounting: each node broadcasts every coordinate
            # once per sweep (scalar messages), identical to exact ByRDiE.
            sent = state.scalars_sent + d
            losses, _ = jax.vmap(self.grad_fn)(unflatten(w_new), batch)
            hm = ~self.byz_mask
            loss = jnp.sum(jnp.where(hm, losses, 0.0)) / jnp.sum(hm)
            return (
                ByrdieState(unflatten(w_new), state.t + 1, key, sent),
                {"loss": loss, "scalars_sent": sent},
            )

        return sweep

    def sweep(self, state, batch):
        return self._sweep(state, batch)
