"""Sharded gossip + screening for the TPU mesh execution path.

The node axis of every parameter leaf ``[M, ...]`` is sharded over the mesh's
node axes (``("data",)`` single-pod, ``("pod","data")`` multi-pod); the
remaining dims are tensor-parallel over ``"model"``.  Screening therefore
operates per chip on that chip's coordinate shard — coordinate-wise rules
(BRIDGE-T/M, the analyzed variants) are embarrassingly parallel across
coordinates, so *no cross-"model" communication is needed at all*; only the
node axis communicates.

Two collective schedules (the subject of §Perf iteration 1):

* ``all_gather`` — paper-faithful broadcast: every chip all-gathers all M
  node values of its shard (M*P bytes on the wire per step) and screens its
  own node's row.
* ``all_to_all`` — beyond-paper coordinate-partitioned schedule: each chip's
  shard is split into M coordinate chunks; a first all_to_all transposes
  (node, chunk) ownership, every chip screens its chunk **for all M
  receivers**, a second all_to_all transposes back (2*P bytes on the wire).
  Valid because BRIDGE-T/M are coordinate-separable (Sec. III: "the
  calculation of y_j(t) has to be carried out in a coordinate-wise manner").

Vector rules (BRIDGE-K/B) need global inter-replica distances; those are
computed with pure-GSPMD reductions (per-leaf partial Gram matrices that XLA
turns into reduce-scatter/all-reduce over "model") followed by a node-axis
gather of the selected replicas.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import screening

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

_COORD_RULES = ("trimmed_mean", "median", "mean")


def _flatten_local(x):
    return x.reshape(x.shape[0], -1)


def _inject_attack(vals, byz_mask, attack, key, t, node_index):
    """Substitute Byzantine rows of the gathered value matrix [M, s]."""
    if attack == "none" or byz_mask is None:
        return vals
    if attack == "random":
        k = jax.random.fold_in(jax.random.fold_in(key, t), node_index)
        noise = 10.0 * jax.random.normal(k, vals.shape, vals.dtype)
        return jnp.where(byz_mask[:, None], noise, vals)
    if attack == "sign_flip":
        return jnp.where(byz_mask[:, None], -4.0 * vals, vals)
    raise ValueError(f"attack {attack!r} not supported on the sharded path")


def _quantize_int8(x):
    """Per-tensor-chunk symmetric int8 quantization.  Monotone per coordinate
    (single shared positive scale), so rank-based screening (trimmed mean /
    median survivor SETS) is exactly preserved; only the averaged magnitudes
    carry quantization error.  Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def coordwise_gossip_leaf(
    leaf: jax.Array,
    spec: P,
    *,
    mesh: jax.sharding.Mesh,
    node_axes,
    rule: str,
    b: int,
    adjacency: jax.Array,
    schedule: str = "all_gather",
    byz_mask: jax.Array | None = None,
    attack: str = "none",
    key: jax.Array | None = None,
    t: jax.Array | int = 0,
    quantize: bool = False,
) -> jax.Array:
    """Screen one [M, ...] parameter leaf with a coordinate-wise rule."""
    assert rule in _COORD_RULES, rule
    m = leaf.shape[0]
    fn = screening.get_rule(rule)
    if key is None:
        key = jax.random.PRNGKey(0)
    t = jnp.asarray(t, jnp.int32)
    if byz_mask is None:
        byz_mask = jnp.zeros((m,), dtype=bool)

    def ag_body(x, adj, bm, k, tt):
        s = _flatten_local(x)  # [m_loc, s]
        if quantize:
            q, scale = _quantize_int8(s)
            gq = lax.all_gather(q, node_axes, axis=0, tiled=True)  # int8 wire
            gs = lax.all_gather(scale[None], node_axes, axis=0, tiled=True)
            g = gq.astype(jnp.float32) * gs[:, None]
        else:
            g = lax.all_gather(s, node_axes, axis=0, tiled=True)  # [M, s]
        j = lax.axis_index(node_axes)
        g = _inject_attack(g, bm, attack, k, tt, j)
        y = fn(g, adj[j], g[j], b)  # own-row screening; self row is masked
        # (adjacency has no self loops so g[j] enters only via self_value)
        return y.astype(x.dtype).reshape(x.shape[1:])[None]

    def a2a_body(x, adj, bm, k, tt):
        s = _flatten_local(x)[0]  # [s] (m_loc == 1)
        size = s.shape[0]
        pad = (-size) % m
        sp = jnp.pad(s, (0, pad)).reshape(m, -1)  # [M, chunk]: my coords, split
        if quantize:
            q, scale = _quantize_int8(sp)
            vq = lax.all_to_all(q, node_axes, split_axis=0, concat_axis=0, tiled=True)
            vs = lax.all_gather(scale[None], node_axes, axis=0, tiled=True)  # [M]
            vals = vq.astype(jnp.float32) * vs[:, None]
        else:
            vals = lax.all_to_all(sp, node_axes, split_axis=0, concat_axis=0, tiled=True)
        # vals[i] = node i's chunk r (r = my node row)
        r = lax.axis_index(node_axes)
        vals = _inject_attack(vals, bm, attack, k, tt, r)
        # Screen chunk r for ALL receivers j.  Sequential over receivers:
        # a vmap here materializes [M, M, chunk] masked copies for the sort
        # (M x the a2a buffer — measured 3.5TB/chip on deepseek-v3), while
        # lax.map keeps the peak at [M, chunk] for identical total compute.
        y_all = lax.map(
            lambda args: fn(vals, args[0], args[1], b).astype(x.dtype),
            (adj, vals),
        )  # [M, chunk]
        back = lax.all_to_all(y_all, node_axes, split_axis=0, concat_axis=0, tiled=True)
        # back[c] = my screened chunk c
        out = back.reshape(-1)[:size]
        return out.reshape(x.shape[1:])[None]

    body = ag_body if schedule == "all_gather" else a2a_body
    shmapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, P(), P(), P(), P()),
        out_specs=spec,
    )
    return shmapped(leaf, adjacency, byz_mask, key, t)


def _node_gram(leaf: jax.Array) -> jax.Array:
    """[M, M] Gram matrix of a [M, ...] leaf — GSPMD reduces over "model"."""
    rest = tuple(range(1, leaf.ndim))
    x = leaf.astype(jnp.float32)
    return jnp.tensordot(x, x, axes=(rest, rest))


def vector_rule_select(
    params: Any,
    *,
    rule: str,
    b: int,
    adjacency: jax.Array,
) -> jax.Array:
    """Compute the per-node selection of BRIDGE-K (index [M]) or BRIDGE-B
    (selection mask [M, M]) from global inter-replica distances."""
    leaves = jax.tree_util.tree_leaves(params)
    gram = functools.reduce(lambda a, c: a + c, [_node_gram(l) for l in leaves])
    sq = jnp.diagonal(gram)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)  # [M, M]
    m = d2.shape[0]
    big = jnp.asarray(1e30, d2.dtype)

    def krum_index(mask_j, j):
        # candidate rows = neighbors; peer distances range over N_j ∪ {j}
        cnt = jnp.sum(mask_j)
        peers = mask_j | (jnp.arange(m) == j)
        dmat = jnp.where(peers[None, :], d2, big)
        dmat = jnp.where(jnp.eye(m, dtype=bool), big, dmat)
        order = jnp.sort(dmat, axis=1)
        kk = jnp.maximum(cnt - b - 2, 1)
        take = jnp.arange(m)[None, :] < kk
        scores = jnp.sum(jnp.where(take, order, 0.0), axis=1)
        scores = jnp.where(mask_j, scores, jnp.inf)
        return jnp.argmin(scores)

    if rule == "krum":
        return jax.vmap(krum_index)(adjacency, jnp.arange(m))

    if rule == "bulyan":
        def select_for(mask_j, j):
            n_sel = jnp.sum(mask_j) - 2 * b
            self_row = jnp.arange(m) == j

            def bodyfn(step, carry):
                cand, sel = carry
                cnt = jnp.sum(cand)
                peers = cand | self_row  # distances range over candidates + self
                dmat = jnp.where(peers[None, :], d2, big)
                dmat = jnp.where(jnp.eye(m, dtype=bool), big, dmat)
                order = jnp.sort(dmat, axis=1)
                kk = jnp.maximum(cnt - b - 2, 1)
                take = jnp.arange(m)[None, :] < kk
                scores = jnp.sum(jnp.where(take, order, 0.0), axis=1)
                scores = jnp.where(cand, scores, jnp.inf)
                i_star = jnp.argmin(scores)
                active = step < n_sel
                pick = jnp.zeros((m,), dtype=bool).at[i_star].set(active)
                return cand & ~pick, sel | pick

            _, sel = lax.fori_loop(0, m, bodyfn, (mask_j, jnp.zeros((m,), bool)))
            return sel

        return jax.vmap(select_for)(adjacency, jnp.arange(m))

    raise ValueError(rule)


def gossip_screen_params(
    params: Any,
    specs: Any,
    *,
    mesh: jax.sharding.Mesh,
    node_axes,
    rule: str,
    b: int,
    adjacency: jax.Array,
    schedule: str = "all_gather",
    byz_mask: jax.Array | None = None,
    attack: str = "none",
    key: jax.Array | None = None,
    t: jax.Array | int = 0,
    quantize: bool = False,
) -> Any:
    """Screen a full [M, ...] parameter pytree.  ``specs`` is a matching pytree
    of PartitionSpecs (node axis first)."""
    if rule in _COORD_RULES:
        return jax.tree_util.tree_map(
            lambda leaf, spec: coordwise_gossip_leaf(
                leaf, spec, mesh=mesh, node_axes=node_axes, rule=rule, b=b,
                adjacency=adjacency, schedule=schedule, byz_mask=byz_mask,
                attack=attack, key=key, t=t, quantize=quantize,
            ),
            params,
            specs,
        )
    if rule == "krum":
        idx = vector_rule_select(params, rule="krum", b=b, adjacency=adjacency)
        return jax.tree_util.tree_map(lambda leaf: jnp.take(leaf, idx, axis=0), params)
    if rule == "bulyan":
        sel = vector_rule_select(params, rule="bulyan", b=b, adjacency=adjacency)

        def leaf_tm(leaf, spec):
            # trimmed mean over the *selected* set (selection mask replaces
            # adjacency); coordinate-wise, so reuse the coordwise machinery.
            return coordwise_gossip_leaf(
                leaf, spec, mesh=mesh, node_axes=node_axes, rule="trimmed_mean",
                b=b, adjacency=sel, schedule=schedule, byz_mask=byz_mask,
                attack=attack, key=key, t=t,
            )

        return jax.tree_util.tree_map(leaf_tm, params, specs)
    raise ValueError(f"unknown rule {rule!r}")
