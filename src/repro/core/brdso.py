"""BRDSO baseline — Peng, Li & Ling, "Byzantine-robust decentralized
stochastic optimization over static and time-varying networks" [60].

The paper's Fig. 6-7 compares BRIDGE-T to BRDSO in non-i.i.d. settings.
BRDSO robustifies decentralized SGD with a total-variation penalty: node j
minimizes  f_j(w_j) + lam0 * sum_{i in N_j} ||w_j - w_i||_1 , whose
subgradient step is

    w_j(t+1) = w_j(t) - rho(t) * ( grad f_j(w_j(t))
                + lam0 * sum_{i in N_j} sign(w_j(t) - w_i(t)) ).

The sign() saturation is what bounds each Byzantine neighbor's influence.
This is the static-network instantiation; we use it as the comparison
baseline exactly where the paper does.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import byzantine as byz_lib
from repro.core.bridge import stack_flatten
from repro.core.graph import Topology


class BrdsoState(NamedTuple):
    params: Any
    t: jax.Array
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class BrdsoConfig:
    topology: Topology
    num_byzantine: int = 0
    attack: str = "none"
    byzantine_seed: int = 0
    lam: float = 1.0
    t0: float = 50.0
    lam0: float = 0.05  # TV-penalty weight
    lr: float = 0.0

    def step_size(self, t):
        if self.lr > 0:
            return jnp.asarray(self.lr, jnp.float32)
        return 1.0 / (self.lam * (self.t0 + t))


class BrdsoTrainer:
    def __init__(self, config: BrdsoConfig, grad_fn: Callable):
        self.config = config
        self.grad_fn = grad_fn
        self.adjacency = jnp.asarray(config.topology.adjacency)
        m = config.topology.num_nodes
        if config.attack == "none" or config.num_byzantine == 0:
            self.byz_mask = jnp.zeros((m,), dtype=bool)
        else:
            self.byz_mask = byz_lib.pick_byzantine_mask(
                m, config.num_byzantine, config.byzantine_seed
            )
        self._attack = byz_lib.get_attack(config.attack)
        self._step = jax.jit(self._build_step())

    def init(self, params: Any, seed: int = 0) -> BrdsoState:
        return BrdsoState(params, jnp.zeros((), jnp.int32), jax.random.PRNGKey(seed))

    def _build_step(self):
        cfg = self.config

        def step(state: BrdsoState, batch: Any):
            w, unflatten = stack_flatten(state.params)
            key, sub = jax.random.split(state.key)
            w_bcast = self._attack(w, self.byz_mask, sub, state.t)
            adj = self.adjacency.astype(w.dtype)  # [M, M]

            # TV subgradient: sum_i in N_j sign(w_j - w_i)
            def tv_row(mask_row, w_j):
                diff = jnp.sign(w_j[None, :] - w_bcast)  # [M, d]
                return jnp.sum(jnp.where(mask_row[:, None] > 0, diff, 0.0), axis=0)

            tv = jax.lax.map(lambda args: tv_row(*args), (adj, w))
            losses, grads = jax.vmap(self.grad_fn)(state.params, batch)
            g, _ = stack_flatten(grads)
            rho = cfg.step_size(state.t)
            w_new = w - rho * (g + cfg.lam0 * tv)
            hm = ~self.byz_mask
            cnt = jnp.sum(hm)
            loss = jnp.sum(jnp.where(hm, losses, 0.0)) / cnt
            mu = jnp.sum(jnp.where(hm[:, None], w_new, 0.0), axis=0) / cnt
            dev = jnp.where(hm[:, None], w_new - mu[None, :], 0.0)
            cons = jnp.sqrt(jnp.max(jnp.sum(dev * dev, axis=1)))
            return (
                BrdsoState(unflatten(w_new), state.t + 1, key),
                {"loss": loss, "consensus_dist": cons},
            )

        return step

    def step(self, state, batch):
        return self._step(state, batch)
