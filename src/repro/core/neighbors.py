"""Static padded neighbor-index tables — the sparse [M, K] layout key.

Every dense runtime structure in this repo is quadratic in the node count:
mailbox rings are ``[M, M, L, d]``, per-link error-feedback residuals are
``[M, M, d]``, and screening sorts all ``M`` candidate rows per node.  On the
sparse graphs BRIDGE actually certifies (Assumption 4 holds on ER / small-
world / geometric graphs with ``K = max in-degree << M``) almost all of that
state is structurally dead: node j can only ever hear from its in-neighbors.

A `NeighborTable` is the static gather key that collapses the dead axis:
``idx[j, k]`` is the node id of j's k-th in-neighbor (rows padded to the
shared width ``K`` with the sentinel index ``num_nodes``), ``valid[j, k]``
marks the real slots.  Per-link state then lives as ``[M, K, ...]`` — the
mailbox ring becomes ``[M, K, L, d]``, residuals ``[M, K, d]``, channel
events ``[M, K]`` — and screening consumes the ``[M, K, d]`` gathered views
directly (the ``+inf``-sentinel masking in `repro.core.screening` already
treats padded rows as absent neighbors).

The table is built once on the host (from a static `Topology` or from the
union of a ``[T, M, M]`` schedule, so churned-away edges keep their slot) and
is a jit constant: gathers against it lower to static-index `take`s.

Padded slots are *inert by construction*: they are never marked live, never
pushed to, never counted — property-tested in ``tests/test_sparse.py``
(widening ``k`` beyond the max in-degree changes no output bit).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def edge_id_grid(num_nodes: int) -> np.ndarray:
    """``[M, M]`` unique per-edge ids: ``receiver * (M + 1) + sender``.

    THE edge-id scheme — the per-link PRNG streams (stochastic codec
    rounding, randomized wire attacks) fold these ids into their keys, and
    dense<->sparse bit-identity holds precisely because both layouts derive
    matching ids for matching edges (`NeighborTable.edge_ids` gathers from
    this same formula; the ``M + 1`` stride keeps sentinel-padded slots —
    sender index ``M`` — collision-free)."""
    r = np.arange(num_nodes, dtype=np.int64)
    return (r[:, None] * (num_nodes + 1) + r[None, :]).astype(np.int32)


class NeighborTable:
    """Static ``[M, K]`` in-neighbor index table (see module docstring).

    ``idx`` keeps the sentinel ``num_nodes`` in padded slots (host-side
    clarity; an accidental un-masked gather fails loudly in numpy).  Device
    gathers go through ``safe_idx`` — the sentinel clipped to ``num_nodes-1``
    — plus the ``valid`` mask, so padded rows carry a real-but-ignored row
    instead of relying on out-of-range gather semantics.
    """

    def __init__(self, idx: np.ndarray, valid: np.ndarray, num_nodes: int):
        idx = np.asarray(idx, np.int32)
        valid = np.asarray(valid, bool)
        if idx.shape != valid.shape or idx.ndim != 2 or idx.shape[0] != num_nodes:
            raise ValueError(f"table shapes {idx.shape} / {valid.shape} must be [M={num_nodes}, K]")
        self.idx = idx
        self.valid = valid
        self.num_nodes = int(num_nodes)
        self.k = int(idx.shape[1])
        # device-side constants
        self.safe_idx = jnp.asarray(np.minimum(idx, num_nodes - 1))
        self.valid_dev = jnp.asarray(valid)
        # per-slot edge ids — the gather of `edge_id_grid` through the table
        # (sentinel slots get unique ids that never collide with a real edge)
        self.edge_ids = jnp.asarray(
            np.arange(num_nodes, dtype=np.int64)[:, None] * (num_nodes + 1)
            + idx.astype(np.int64), jnp.int32)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_adjacency(cls, adjacency, k: int | None = None) -> "NeighborTable":
        """Table of a static ``[M, M]`` adjacency (``adjacency[j, i]`` marks i
        an in-neighbor of j).  ``k`` pads beyond the max in-degree (shared
        widths let tables of different graphs stack); it must cover it."""
        adj = np.asarray(getattr(adjacency, "adjacency", adjacency), bool)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency must be [M, M], got {adj.shape}")
        m = adj.shape[0]
        deg = adj.sum(axis=1)
        kmax = int(deg.max()) if m else 0
        if k is None:
            k = kmax
        if k < kmax:
            raise ValueError(f"k={k} cannot hold max in-degree {kmax}")
        idx = np.full((m, k), m, np.int32)
        valid = np.zeros((m, k), bool)
        for j in range(m):
            ns = np.nonzero(adj[j])[0]
            idx[j, : len(ns)] = ns
            valid[j, : len(ns)] = True
        return cls(idx, valid, m)

    @classmethod
    def from_schedule(cls, schedule, k: int | None = None) -> "NeighborTable":
        """Table of the *union* graph of a ``[T, M, M]`` schedule: an edge
        that is live at any tick owns a slot for the whole run (churned-away
        edges keep their mailbox history; the per-tick live mask is what
        gates sends)."""
        sched = np.asarray(schedule, bool)
        if sched.ndim != 3 or sched.shape[1] != sched.shape[2]:
            raise ValueError(f"schedule must be [T, M, M], got {sched.shape}")
        return cls.from_adjacency(sched.any(axis=0), k=k)

    # -- gathers ------------------------------------------------------------

    def gather_rows(self, x: jax.Array) -> jax.Array:
        """``x [M, ...] -> [M, K, ...]``: slot (j, k) holds the row of j's
        k-th in-neighbor (padded slots hold a real-but-masked row)."""
        return jnp.take(x, self.safe_idx, axis=0)

    def gather_edges(self, mat: jax.Array, fill=None) -> jax.Array:
        """``mat [M, M] -> [M, K]``: slot (j, k) holds ``mat[j, idx[j, k]]``.
        ``fill`` replaces padded slots (bool ``fill=False`` masks them out);
        None leaves the gathered-but-meaningless value in place."""
        out = jnp.take_along_axis(mat, self.safe_idx, axis=1)
        if fill is None:
            return out
        return jnp.where(self.valid_dev, out, fill)

    def gather_senders(self, vec: jax.Array, fill=None) -> jax.Array:
        """``vec [M] -> [M, K]``: per-slot sender attribute (e.g. the
        Byzantine mask); ``fill`` as in `gather_edges`."""
        out = jnp.take(vec, self.safe_idx, axis=0)
        if fill is None:
            return out
        return jnp.where(self.valid_dev, out, fill)

    def live_schedule(self, schedule) -> np.ndarray:
        """Pre-gather a ``[T, M, M]`` schedule to the ``[T, M, K]`` per-slot
        live mask (host-side, once) — the sparse runtime never touches an
        ``[M, M]`` adjacency at trace time."""
        sched = np.asarray(schedule, bool)
        safe = np.minimum(self.idx, self.num_nodes - 1)
        live = np.take_along_axis(sched, safe[None].repeat(sched.shape[0], 0), axis=2)
        return live & self.valid[None]
