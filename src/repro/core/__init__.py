"""Core BRIDGE library — the paper's contribution as composable JAX modules."""
from repro.core.bridge import BridgeConfig, BridgeState, BridgeTrainer, replicate, stack_flatten
from repro.core.brdso import BrdsoConfig, BrdsoTrainer
from repro.core.byrdie import ByrdieConfig, ByrdieTrainer
from repro.core.byzantine import (
    ATTACKS,
    MESSAGE_ATTACKS,
    attack_names,
    get_attack,
    get_message_attack,
    pick_byzantine_mask,
)
from repro.core.graph import (
    Topology,
    check_assumption4,
    complete_graph,
    erdos_renyi,
    make_topology,
    metropolis_weights,
    random_geometric,
    ring_of_cliques,
    small_world,
    toroidal_grid,
)
from repro.core.gossip import coordwise_gossip_leaf, gossip_screen_params, vector_rule_select
from repro.core.neighbors import NeighborTable
from repro.core.screening import RULES, get_rule, min_neighbors, screen_all, screen_views

__all__ = [
    "BridgeConfig", "BridgeState", "BridgeTrainer", "replicate", "stack_flatten",
    "BrdsoConfig", "BrdsoTrainer", "ByrdieConfig", "ByrdieTrainer",
    "ATTACKS", "MESSAGE_ATTACKS", "attack_names", "get_attack",
    "get_message_attack", "pick_byzantine_mask",
    "Topology", "check_assumption4", "complete_graph", "erdos_renyi",
    "make_topology", "metropolis_weights", "random_geometric",
    "ring_of_cliques", "small_world", "toroidal_grid", "NeighborTable",
    "coordwise_gossip_leaf", "gossip_screen_params", "vector_rule_select",
    "RULES", "get_rule", "min_neighbors", "screen_all", "screen_views",
]
