"""Byzantine attack models (Definition 1).

A Byzantine node may broadcast *anything*; we model attacks as functions that
substitute the broadcast rows of the stacked iterate matrix ``w [M, d]`` for
the nodes marked in ``byz_mask``.  The node's internal state keeps evolving
normally — only what it *sends* is corrupted, matching the paper's experiments
("broadcast random vectors to all their neighbors during each iteration").
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: Callable  # (w [M,d], byz_mask [M], key, t) -> w_broadcast [M,d]

    def __call__(self, w, byz_mask, key, t):
        return self.fn(w, byz_mask, key, t)


def _none(w, byz_mask, key, t):
    return w


def _random_gaussian(scale: float = 10.0):
    """The paper's experimental attack: broadcast random vectors."""

    def fn(w, byz_mask, key, t):
        noise = scale * jax.random.normal(jax.random.fold_in(key, t), w.shape, w.dtype)
        return jnp.where(byz_mask[:, None], noise, w)

    return fn


def _sign_flip(scale: float = 4.0):
    """Broadcast the negated (scaled) true iterate — pulls consensus backward."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], -scale * w, w)

    return fn


def _same_value(value: float = 100.0):
    """All Byzantine nodes collude on one large constant vector."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], jnp.full_like(w, value), w)

    return fn


def _alie(z: float = 1.5):
    """'A Little Is Enough'-style attack: collude on mean + z*std of the honest
    iterates per coordinate — crafted to hide inside the trimming band."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
        crafted = mu + z * jnp.sqrt(var + 1e-12)
        return jnp.where(byz_mask[:, None], crafted[None, :], w)

    return fn


def _shift(delta: float = 5.0):
    """Coordinated constant shift of the honest mean."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        return jnp.where(byz_mask[:, None], (mu + delta)[None, :], w)

    return fn


ATTACKS: dict[str, Attack] = {
    "none": Attack("none", _none),
    "random": Attack("random", _random_gaussian()),
    "sign_flip": Attack("sign_flip", _sign_flip()),
    "same_value": Attack("same_value", _same_value()),
    "alie": Attack("alie", _alie()),
    "shift": Attack("shift", _shift()),
}


def get_attack(name: str) -> Attack:
    try:
        return ATTACKS[name]
    except KeyError:
        raise ValueError(f"unknown attack {name!r}; options: {sorted(ATTACKS)}")


def pick_byzantine_mask(num_nodes: int, num_byzantine: int, seed: int = 0) -> jnp.ndarray:
    """Deterministically pick which nodes are Byzantine (simulation side)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    idx = rng.choice(num_nodes, size=num_byzantine, replace=False)
    mask = np.zeros((num_nodes,), dtype=bool)
    mask[idx] = True
    return jnp.asarray(mask)
