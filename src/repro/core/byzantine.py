"""Byzantine attack models (Definition 1).

A Byzantine node may broadcast *anything*; we model attacks as functions that
substitute the broadcast rows of the stacked iterate matrix ``w [M, d]`` for
the nodes marked in ``byz_mask``.  The node's internal state keeps evolving
normally — only what it *sends* is corrupted, matching the paper's experiments
("broadcast random vectors to all their neighbors during each iteration").

Two attack granularities:

* **Broadcast attacks** (`Attack`, the seed model): the adversary substitutes
  one row per Byzantine node — every receiver sees the same corrupted value.
  This is all Definition 1 permits over a broadcast medium.
* **Message attacks** (`MessageAttack`, used by the `repro.net` runtime): the
  adversary crafts the full ``[receiver, sender, d]`` message tensor, so a
  Byzantine node can tell *different* lies to different neighbors — e.g. the
  `selective_victim` attack, which stays truthful to well-connected receivers
  while feeding crafted values only to low-degree ones, hiding from any
  detector that cross-checks reports between neighbors.  Every broadcast
  attack lifts to a message attack (same value tiled to all receivers).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: Callable  # (w [M,d], byz_mask [M], key, t) -> w_broadcast [M,d]

    def __call__(self, w, byz_mask, key, t):
        return self.fn(w, byz_mask, key, t)


def _none(w, byz_mask, key, t):
    return w


def _random_gaussian(scale: float = 10.0):
    """The paper's experimental attack: broadcast random vectors."""

    def fn(w, byz_mask, key, t):
        noise = scale * jax.random.normal(jax.random.fold_in(key, t), w.shape, w.dtype)
        return jnp.where(byz_mask[:, None], noise, w)

    return fn


def _sign_flip(scale: float = 4.0):
    """Broadcast the negated (scaled) true iterate — pulls consensus backward."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], -scale * w, w)

    return fn


def _same_value(value: float = 100.0):
    """All Byzantine nodes collude on one large constant vector."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], jnp.full_like(w, value), w)

    return fn


def _alie(z: float = 1.5):
    """'A Little Is Enough'-style attack: collude on mean + z*std of the honest
    iterates per coordinate — crafted to hide inside the trimming band."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
        crafted = mu + z * jnp.sqrt(var + 1e-12)
        return jnp.where(byz_mask[:, None], crafted[None, :], w)

    return fn


def _shift(delta: float = 5.0):
    """Coordinated constant shift of the honest mean."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        return jnp.where(byz_mask[:, None], (mu + delta)[None, :], w)

    return fn


ATTACKS: dict[str, Attack] = {
    "none": Attack("none", _none),
    "random": Attack("random", _random_gaussian()),
    "sign_flip": Attack("sign_flip", _sign_flip()),
    "same_value": Attack("same_value", _same_value()),
    "alie": Attack("alie", _alie()),
    "shift": Attack("shift", _shift()),
}


# ---------------------------------------------------------------------------
# Message-level attacks (per-link lies, require the repro.net runtime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MessageAttack:
    """An attack on the per-link message tensor.

    ``fn(w [M,d], byz_mask [M], adjacency [M,M], key, t) -> msgs [M,M,d]``
    where ``msgs[j, i]`` is what node i sends node j this tick (rows for
    non-edges are ignored by the runtime).  ``broadcast`` is the equivalent
    broadcast-granularity `Attack` when one exists (lifted attacks keep it so
    the runtime path can reproduce the broadcast path bit-for-bit — including
    the attacked self-view Byzantine nodes screen with).
    """

    name: str
    fn: Callable
    broadcast: Attack | None = None

    def __call__(self, w, byz_mask, adjacency, key, t):
        return self.fn(w, byz_mask, adjacency, key, t)


def lift_broadcast_attack(attack: Attack) -> MessageAttack:
    """Tile a broadcast attack to message granularity: every receiver gets the
    same (possibly corrupted) row."""

    def fn(w, byz_mask, adjacency, key, t):
        w_bcast = attack(w, byz_mask, key, t)
        m = w.shape[0]
        return jnp.broadcast_to(w_bcast[None, :, :], (m,) + w.shape)

    return MessageAttack(attack.name, fn, broadcast=attack)


def _selective_victim(z: float = 1.5):
    """Per-neighbor selective-victim attack (only expressible on messages).

    Byzantine nodes send their *true* iterate to high-in-degree receivers —
    who could out-vote the lie anyway and whose honest neighbors might notice
    inconsistent reports — and an ALIE-style crafted value (honest mean +
    z * per-coordinate std, tuned to hide inside the trimming band) only to
    receivers whose in-degree is at most the network median.  Topology-aware:
    the victim set is recomputed from the tick's adjacency, so edge churn
    shifts the blast radius."""

    def fn(w, byz_mask, adjacency, key, t):
        m = w.shape[0]
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
        crafted = mu + z * jnp.sqrt(var + 1e-12)
        in_deg = jnp.sum(adjacency, axis=1)
        victim = in_deg <= jnp.median(in_deg)  # [M] receivers
        lie_edge = victim[:, None] & byz_mask[None, :]  # [receiver, sender]
        msgs = jnp.broadcast_to(w[None, :, :], (m,) + w.shape)
        return jnp.where(lie_edge[:, :, None], crafted[None, None, :], msgs)

    return fn


MESSAGE_ATTACKS: dict[str, MessageAttack] = {
    name: lift_broadcast_attack(a) for name, a in ATTACKS.items()
}
MESSAGE_ATTACKS["selective_victim"] = MessageAttack(
    "selective_victim", _selective_victim()
)


def attack_names() -> list[str]:
    """All registered attack names (broadcast + message-only)."""
    return sorted(set(ATTACKS) | set(MESSAGE_ATTACKS))


# ---------------------------------------------------------------------------
# Banked (branchless) dispatch — attack selection as data
# ---------------------------------------------------------------------------
#
# The batched grid engine runs experiments with *different* attacks inside one
# jitted program, so attack selection is a ``lax.switch`` over a static bank
# of registered attacks, indexed by a traced int32 carried in the experiment's
# `CellParams`.  Under ``vmap`` the switch lowers to compute-all-and-select;
# banks should contain only the distinct attacks a grid actually uses.  A
# single-entry bank elides the switch entirely, which is how `BridgeTrainer`
# drives these helpers — the per-experiment and batched paths stay
# bit-identical.


def attack_bank(names: Sequence[str]) -> tuple[Attack, ...]:
    """Resolve broadcast-attack names to a static bank (order preserved)."""
    return tuple(get_attack(n) for n in names)


def message_attack_bank(names: Sequence[str]) -> tuple[MessageAttack, ...]:
    """Resolve attack names to a static message-granularity bank."""
    return tuple(get_message_attack(n) for n in names)


def apply_attack_bank(bank: tuple[Attack, ...], attack_idx, w, byz_mask, key, t):
    """Broadcast-substitution by the bank entry selected by ``attack_idx``."""
    if len(bank) == 1:
        return bank[0](w, byz_mask, key, t)
    return jax.lax.switch(attack_idx, [a.fn for a in bank], w, byz_mask, key, t)


def apply_message_attack_bank(bank: tuple[MessageAttack, ...], attack_idx, w, byz_mask, adjacency, key, t):
    """Per-link message crafting by the selected bank entry."""
    if len(bank) == 1:
        return bank[0](w, byz_mask, adjacency, key, t)
    return jax.lax.switch(attack_idx, [a.fn for a in bank], w, byz_mask, adjacency, key, t)


def apply_self_view_bank(bank: tuple[MessageAttack, ...], attack_idx, w, byz_mask, key, t):
    """The self-view Byzantine nodes screen with, per selected attack: the
    lifted broadcast value when one exists (so the runtime path reproduces the
    broadcast path bit-for-bit), else the true iterate (message-only attacks
    have no single broadcast value)."""

    def branch(a: MessageAttack):
        if a.broadcast is not None:
            return a.broadcast.fn
        return lambda w, byz_mask, key, t: w

    fns = [branch(a) for a in bank]
    if len(fns) == 1:
        return fns[0](w, byz_mask, key, t)
    return jax.lax.switch(attack_idx, fns, w, byz_mask, key, t)


def get_attack(name: str) -> Attack:
    try:
        return ATTACKS[name]
    except KeyError:
        if name in MESSAGE_ATTACKS:
            raise ValueError(
                f"attack {name!r} crafts per-link messages and needs the network "
                f"runtime (repro.net / BridgeTrainer(runtime=...)); broadcast-path "
                f"options: {sorted(ATTACKS)}"
            )
        raise ValueError(f"unknown attack {name!r}; options: {attack_names()}")


def get_message_attack(name: str) -> MessageAttack:
    try:
        return MESSAGE_ATTACKS[name]
    except KeyError:
        raise ValueError(f"unknown attack {name!r}; options: {attack_names()}")


def pick_byzantine_mask(num_nodes: int, num_byzantine: int, seed: int = 0) -> jnp.ndarray:
    """Deterministically pick which nodes are Byzantine (simulation side)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    idx = rng.choice(num_nodes, size=num_byzantine, replace=False)
    mask = np.zeros((num_nodes,), dtype=bool)
    mask[idx] = True
    return jnp.asarray(mask)
