"""Byzantine attack models (Definition 1).

A Byzantine node may broadcast *anything*; we model attacks as functions that
substitute the broadcast rows of the stacked iterate matrix ``w [M, d]`` for
the nodes marked in ``byz_mask``.  The node's internal state keeps evolving
normally — only what it *sends* is corrupted, matching the paper's experiments
("broadcast random vectors to all their neighbors during each iteration").

Two attack granularities:

* **Broadcast attacks** (`Attack`, the seed model): the adversary substitutes
  one row per Byzantine node — every receiver sees the same corrupted value.
  This is all Definition 1 permits over a broadcast medium.
* **Message attacks** (`MessageAttack`, used by the `repro.net` runtime): the
  adversary crafts the full ``[receiver, sender, d]`` message tensor, so a
  Byzantine node can tell *different* lies to different neighbors — e.g. the
  `selective_victim` attack, which stays truthful to well-connected receivers
  while feeding crafted values only to low-degree ones, hiding from any
  detector that cross-checks reports between neighbors.  Every broadcast
  attack lifts to a message attack (same value tiled to all receivers).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: Callable  # (w [M,d], byz_mask [M], key, t) -> w_broadcast [M,d]

    def __call__(self, w, byz_mask, key, t):
        return self.fn(w, byz_mask, key, t)


def _none(w, byz_mask, key, t):
    return w


def _random_gaussian(scale: float = 10.0):
    """The paper's experimental attack: broadcast random vectors."""

    def fn(w, byz_mask, key, t):
        noise = scale * jax.random.normal(jax.random.fold_in(key, t), w.shape, w.dtype)
        return jnp.where(byz_mask[:, None], noise, w)

    return fn


def _sign_flip(scale: float = 4.0):
    """Broadcast the negated (scaled) true iterate — pulls consensus backward."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], -scale * w, w)

    return fn


def _same_value(value: float = 100.0):
    """All Byzantine nodes collude on one large constant vector."""

    def fn(w, byz_mask, key, t):
        return jnp.where(byz_mask[:, None], jnp.full_like(w, value), w)

    return fn


def _alie(z: float = 1.5):
    """'A Little Is Enough'-style attack: collude on mean + z*std of the honest
    iterates per coordinate — crafted to hide inside the trimming band."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
        crafted = mu + z * jnp.sqrt(var + 1e-12)
        return jnp.where(byz_mask[:, None], crafted[None, :], w)

    return fn


def _shift(delta: float = 5.0):
    """Coordinated constant shift of the honest mean."""

    def fn(w, byz_mask, key, t):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        return jnp.where(byz_mask[:, None], (mu + delta)[None, :], w)

    return fn


# The broadcast-attack registry (Definition 1's granularity: one lie per
# sender per tick).  Attacks here are auto-lifted into MESSAGE_ATTACKS (the
# per-link tier) and re-registered as stateless adversaries in
# `repro.adversary` — `repro.adversary.registry_tiers()` is the single
# source of truth for the full namespace (broadcast / message / wire /
# adversary / equivocator / slanderer); register a new name in exactly one
# tier, and the bank builders pick it up by name.
ATTACKS: dict[str, Attack] = {
    "none": Attack("none", _none),
    "random": Attack("random", _random_gaussian()),
    "sign_flip": Attack("sign_flip", _sign_flip()),
    "same_value": Attack("same_value", _same_value()),
    "alie": Attack("alie", _alie()),
    "shift": Attack("shift", _shift()),
}


# ---------------------------------------------------------------------------
# Message-level attacks (per-link lies, require the repro.net runtime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MessageAttack:
    """An attack on the per-link message tensor.

    ``fn(w [M,d], byz_mask [M], adjacency [M,M], key, t) -> msgs [M,M,d]``
    where ``msgs[j, i]`` is what node i sends node j this tick (rows for
    non-edges are ignored by the runtime).  ``broadcast`` is the equivalent
    broadcast-granularity `Attack` when one exists (lifted attacks keep it so
    the runtime path can reproduce the broadcast path bit-for-bit — including
    the attacked self-view Byzantine nodes screen with).

    ``sparse_fn(w, byz_mask, nbr, live [M,K], key, t) -> msgs [M,K,d]`` is
    the neighbor-indexed variant (`repro.core.neighbors.NeighborTable`): slot
    (j, k) holds what sender ``nbr.idx[j, k]`` tells receiver j.  It must be
    the exact gather of the dense tensor — ``msgs_sparse[j, k] ==
    msgs_dense[j, nbr.idx[j, k]]`` bitwise — which is what keeps the sparse
    runtime a bit-identical twin of the dense oracle.  Attacks whose
    per-link values derive from per-sender/per-receiver quantities (all
    current registrations) get this for free via gathers; `lift_sparse`
    derives it for lifted broadcast attacks.
    """

    name: str
    fn: Callable
    broadcast: Attack | None = None
    sparse_fn: Callable | None = None

    def __call__(self, w, byz_mask, adjacency, key, t):
        return self.fn(w, byz_mask, adjacency, key, t)


def lift_broadcast_attack(attack: Attack) -> MessageAttack:
    """Tile a broadcast attack to message granularity: every receiver gets the
    same (possibly corrupted) row."""

    def fn(w, byz_mask, adjacency, key, t):
        w_bcast = attack(w, byz_mask, key, t)
        m = w.shape[0]
        return jnp.broadcast_to(w_bcast[None, :, :], (m,) + w.shape)

    def sparse_fn(w, byz_mask, nbr, live, key, t):
        del live  # lifted attacks corrupt the sender row regardless of edges
        return nbr.gather_rows(attack(w, byz_mask, key, t))

    return MessageAttack(attack.name, fn, broadcast=attack, sparse_fn=sparse_fn)


def _selective_victim(z: float = 1.5):
    """Per-neighbor selective-victim attack (only expressible on messages).

    Byzantine nodes send their *true* iterate to high-in-degree receivers —
    who could out-vote the lie anyway and whose honest neighbors might notice
    inconsistent reports — and an ALIE-style crafted value (honest mean +
    z * per-coordinate std, tuned to hide inside the trimming band) only to
    receivers whose in-degree is at most the network median.  Topology-aware:
    the victim set is recomputed from the tick's adjacency, so edge churn
    shifts the blast radius."""

    def crafted_and_victims(w, byz_mask, in_deg):
        honest = ~byz_mask
        cnt = jnp.sum(honest)
        mu = jnp.sum(jnp.where(honest[:, None], w, 0.0), axis=0) / cnt
        var = jnp.sum(jnp.where(honest[:, None], (w - mu) ** 2, 0.0), axis=0) / cnt
        crafted = mu + z * jnp.sqrt(var + 1e-12)
        victim = in_deg <= jnp.median(in_deg)  # [M] receivers
        return crafted, victim

    def fn(w, byz_mask, adjacency, key, t):
        m = w.shape[0]
        crafted, victim = crafted_and_victims(w, byz_mask, jnp.sum(adjacency, axis=1))
        lie_edge = victim[:, None] & byz_mask[None, :]  # [receiver, sender]
        msgs = jnp.broadcast_to(w[None, :, :], (m,) + w.shape)
        return jnp.where(lie_edge[:, :, None], crafted[None, None, :], msgs)

    def sparse_fn(w, byz_mask, nbr, live, key, t):
        # in-degrees from the [M, K] live mask are the dense row sums exactly
        # (padded slots are never live), so the victim set — and with it every
        # per-slot lie — is the bitwise gather of the dense tensor
        crafted, victim = crafted_and_victims(w, byz_mask, jnp.sum(live, axis=1))
        lie_edge = victim[:, None] & nbr.gather_senders(byz_mask, fill=False)
        return jnp.where(lie_edge[:, :, None], crafted[None, None, :], nbr.gather_rows(w))

    return fn, sparse_fn


MESSAGE_ATTACKS: dict[str, MessageAttack] = {
    name: lift_broadcast_attack(a) for name, a in ATTACKS.items()
}
_sv_fn, _sv_sparse = _selective_victim()
MESSAGE_ATTACKS["selective_victim"] = MessageAttack(
    "selective_victim", _sv_fn, sparse_fn=_sv_sparse
)


# ---------------------------------------------------------------------------
# Wire attacks (compressed-domain: the adversary crafts the CODEWORD)
# ---------------------------------------------------------------------------
#
# With a `repro.comm` codec on the wire, Definition 1's "may broadcast
# anything" includes the encoded representation itself: a Byzantine node can
# emit byte patterns no honest encoder produces, abuse the dequantization
# metadata, or lie about which coordinates a sparse payload carries.
# Receivers run the decoder on whatever arrives — screening is evaluated
# against what decoders actually *emit* (which for garbage float bits
# includes inf/NaN payloads; the inf-sentinel + NaN guard in
# `repro.core.screening` is what keeps rank-based rules total-ordered).
#
# A `WireAttack` transforms the `repro.comm.codec.WireMsg` after honest
# encoding and before decoding, substituting the fields of Byzantine senders
# only.  ``byz`` is a bool mask broadcastable against the message's leading
# axes ([M] on the broadcast path, [M, M] receiver x sender on the per-link
# path).  Attacks are no-ops on fields the selected codec ignores (e.g.
# scale abuse under the identity codec) — the registry composes freely with
# every codec, and the interesting cells are where attack and codec bite.


@dataclasses.dataclass(frozen=True)
class WireAttack:
    """An attack on the encoded codeword.

    ``fn(msg: WireMsg, byz, key, t, d) -> WireMsg`` where ``d`` is the
    decoded dimension (index lies must stay in-range to be maximally
    damaging — out-of-range scatter indices are dropped by the decoder).

    On the per-link runtime paths the step applies this bank once per *edge*
    under ``vmap``, with ``key`` already folded with the edge id
    (`bridge._wire_roundtrip`) — so randomized attacks draw bitwise-identical
    garbage on matching edges of the dense ``[M, M, ...]`` and sparse
    ``[M, K, ...]`` layouts without knowing which layout they are in.  The
    broadcast path applies it once over the whole ``[M, ...]`` tensor (shared
    codewords, shared draws).
    """

    name: str
    fn: Callable

    def __call__(self, msg, byz, key, t, d):
        return self.fn(msg, byz, key, t, d)


def _wire_none(msg, byz, key, t, d):
    return msg


def _sub(field, byz, crafted):
    """Substitute Byzantine senders' rows of one message field (``byz`` has
    the message's leading axes; fields append 1-2 trailing axes)."""
    b = byz.reshape(byz.shape + (1,) * (field.ndim - byz.ndim))
    return jnp.where(b, crafted, field)


def _garbage_codeword():
    """Uniformly random payload bytes + random sparse indices: the decoder
    sees byte soup.  Under the identity codec the bitcast emits arbitrary
    float32 patterns — including inf/NaN — stress-testing the screening
    guards; under quantized codecs it is bounded-range noise."""

    def fn(msg, byz, key, t, d):
        kp, ki = jax.random.split(jax.random.fold_in(key, t))
        payload = jax.random.randint(
            kp, msg.payload.shape, -128, 128, jnp.int32).astype(jnp.int8)
        idx = jax.random.randint(ki, msg.idx.shape, 0, max(d, 1), jnp.int32)
        return msg._replace(payload=_sub(msg.payload, byz, payload),
                            idx=_sub(msg.idx, byz, idx))

    return fn


def _scale_abuse(factor: float = 1e4):
    """Quant-range abuse: the payload bytes look like a perfectly ordinary
    codeword, but the dequantization scale is inflated so receivers decode
    values ``factor``x larger than honest magnitudes.  Invisible to any
    detector that inspects payload statistics; a no-op on codecs that carry
    no scale (identity, float32 sparse)."""

    def fn(msg, byz, key, t, d):
        return msg._replace(scale=_sub(msg.scale, byz, msg.scale * factor))

    return fn


def _index_lie():
    """Top-k index lies: Byzantine senders keep their honest-looking values
    but claim they belong to the first k coordinates, concentrating all
    adversarial energy on a small fixed subset (and starving the rest).
    Only bites sparse codecs — dense decoders ignore the index field."""

    def fn(msg, byz, key, t, d):
        k = msg.idx.shape[-1]
        lie = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), msg.idx.shape)
        return msg._replace(idx=_sub(msg.idx, byz, lie))

    return fn


WIRE_ATTACKS: dict[str, WireAttack] = {
    "none": WireAttack("none", _wire_none),
    "garbage_codeword": WireAttack("garbage_codeword", _garbage_codeword()),
    "scale_abuse": WireAttack("scale_abuse", _scale_abuse()),
    "index_lie": WireAttack("index_lie", _index_lie()),
}


def attack_names() -> list[str]:
    """All attack names registered in THIS module's three tiers (broadcast +
    message-only + wire).  The full four-tier namespace — including the
    adaptive-adversary tier — is owned by
    `repro.adversary.protocols.registry_tiers` (the single source of truth;
    a validation test asserts every name lives in exactly one tier), whose
    `attack_names` supersedes this one for user-facing listings."""
    return sorted(set(ATTACKS) | set(MESSAGE_ATTACKS)
                  | (set(WIRE_ATTACKS) - {"none"}))


# ---------------------------------------------------------------------------
# Banked (branchless) dispatch — attack selection as data
# ---------------------------------------------------------------------------
#
# The batched grid engine runs experiments with *different* attacks inside one
# jitted program, so attack selection is a ``lax.switch`` over a static bank
# of registered attacks, indexed by a traced int32 carried in the experiment's
# `CellParams`.  Under ``vmap`` the switch lowers to compute-all-and-select;
# banks should contain only the distinct attacks a grid actually uses.  A
# single-entry bank elides the switch entirely, which is how `BridgeTrainer`
# drives these helpers — the per-experiment and batched paths stay
# bit-identical.


def attack_bank(names: Sequence[str]) -> tuple[Attack, ...]:
    """Resolve broadcast-attack names to a static bank (order preserved)."""
    return tuple(get_attack(n) for n in names)


def message_attack_bank(names: Sequence[str]) -> tuple[MessageAttack, ...]:
    """Resolve attack names to a static message-granularity bank."""
    return tuple(get_message_attack(n) for n in names)


def wire_attack_bank(names: Sequence[str]) -> tuple[WireAttack, ...]:
    """The codeword-domain component of each attack name: the registered
    `WireAttack` for wire-attack names, the no-op for iterate-domain attacks.
    Indexed by the SAME ``attack_idx`` as the iterate-domain banks, so one
    grid axis covers both domains."""
    return tuple(WIRE_ATTACKS.get(n, WIRE_ATTACKS["none"]) for n in names)


def apply_wire_attack_bank(bank: tuple[WireAttack, ...], attack_idx, msg, byz, key, t, d: int):
    """Codeword substitution by the bank entry selected by ``attack_idx``."""
    if len(bank) == 1:
        return bank[0](msg, byz, key, t, d)
    branches = [(lambda a: lambda m, bz, k, tt: a(m, bz, k, tt, d))(a) for a in bank]
    return jax.lax.switch(attack_idx, branches, msg, byz, key, t)


def apply_attack_bank(bank: tuple[Attack, ...], attack_idx, w, byz_mask, key, t):
    """Broadcast-substitution by the bank entry selected by ``attack_idx``."""
    if len(bank) == 1:
        return bank[0](w, byz_mask, key, t)
    return jax.lax.switch(attack_idx, [a.fn for a in bank], w, byz_mask, key, t)


def apply_message_attack_bank(bank: tuple[MessageAttack, ...], attack_idx, w, byz_mask, adjacency, key, t):
    """Per-link message crafting by the selected bank entry."""
    if len(bank) == 1:
        return bank[0](w, byz_mask, adjacency, key, t)
    return jax.lax.switch(attack_idx, [a.fn for a in bank], w, byz_mask, adjacency, key, t)


def apply_sparse_message_attack_bank(bank: tuple[MessageAttack, ...], attack_idx, w,
                                     byz_mask, nbr, live, key, t):
    """Neighbor-indexed message crafting: the ``[M, K, d]`` twin of
    `apply_message_attack_bank` (``nbr`` a `NeighborTable`, ``live [M, K]``
    the tick's per-slot live mask).  Every bank entry must carry a
    ``sparse_fn`` (all registered attacks do)."""
    for a in bank:
        if a.sparse_fn is None:
            raise ValueError(
                f"message attack {a.name!r} has no sparse_fn — required on the "
                f"neighbor-indexed runtime path")
    if len(bank) == 1:
        return bank[0].sparse_fn(w, byz_mask, nbr, live, key, t)
    branches = [
        (lambda fn: lambda ww, bm, lv, k, tt: fn(ww, bm, nbr, lv, k, tt))(a.sparse_fn)
        for a in bank
    ]
    return jax.lax.switch(attack_idx, branches, w, byz_mask, live, key, t)


def apply_self_view_bank(bank: tuple[MessageAttack, ...], attack_idx, w, byz_mask, key, t):
    """The self-view Byzantine nodes screen with, per selected attack: the
    lifted broadcast value when one exists (so the runtime path reproduces the
    broadcast path bit-for-bit), else the true iterate (message-only attacks
    have no single broadcast value)."""

    def branch(a: MessageAttack):
        if a.broadcast is not None:
            return a.broadcast.fn
        return lambda w, byz_mask, key, t: w

    fns = [branch(a) for a in bank]
    if len(fns) == 1:
        return fns[0](w, byz_mask, key, t)
    return jax.lax.switch(attack_idx, fns, w, byz_mask, key, t)


def get_attack(name: str) -> Attack:
    # wire attacks corrupt the codeword only; their iterate-domain component
    # is the no-op (the step applies the wire bank after encoding)
    if name in WIRE_ATTACKS:
        return ATTACKS["none"]
    try:
        return ATTACKS[name]
    except KeyError:
        if name in MESSAGE_ATTACKS:
            raise ValueError(
                f"attack {name!r} crafts per-link messages and needs the network "
                f"runtime (repro.net / BridgeTrainer(runtime=...)); broadcast-path "
                f"options: {sorted(ATTACKS)}"
            ) from None
        raise ValueError(f"unknown attack {name!r}; options: {attack_names()}") from None


def get_message_attack(name: str) -> MessageAttack:
    if name in WIRE_ATTACKS:
        return MESSAGE_ATTACKS["none"]
    try:
        return MESSAGE_ATTACKS[name]
    except KeyError:
        raise ValueError(f"unknown attack {name!r}; options: {attack_names()}") from None


def pick_byzantine_mask(num_nodes: int, num_byzantine: int, seed: int = 0) -> jnp.ndarray:
    """Deterministically pick which nodes are Byzantine (simulation side)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    idx = rng.choice(num_nodes, size=num_byzantine, replace=False)
    mask = np.zeros((num_nodes,), dtype=bool)
    mask[idx] = True
    return jnp.asarray(mask)
