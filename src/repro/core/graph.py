"""Communication-graph utilities for decentralized learning.

The paper (Sec. II-B) models the network as a directed, static, connected
graph G(J, E).  Byzantine resilience requires the redundancy condition of
Assumption 4: every reduced graph G_red(b) — obtained by removing the
Byzantine nodes and additionally b incoming edges from every honest node —
must contain a source component of cardinality >= b+1.

Exact certification is combinatorial (the paper leaves it open); we provide
(i) the paper's empirical recipe — Erdos-Renyi graphs whose minimum degree
exceeds 2b — and (ii) a randomized checker that samples reduced graphs and
verifies the source-component condition on each sample via SCC condensation.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

try:  # networkx is available in this environment; keep a guard for minimal installs
    import networkx as nx

    _HAS_NX = True
except Exception:  # pragma: no cover
    _HAS_NX = False


@dataclasses.dataclass(frozen=True)
class Topology:
    """A static communication graph over ``num_nodes`` nodes.

    ``adjacency[j, i] == True`` iff node ``i`` is an in-neighbor of node ``j``
    (node j receives messages from node i).  Self-loops are always False —
    the node's own value is handled separately by the screening rules.
    """

    adjacency: np.ndarray  # [M, M] bool
    num_byzantine: int  # the bound b the protocol is configured for

    def __post_init__(self):
        adj = np.asarray(self.adjacency, dtype=bool)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency must be square, got {adj.shape}")
        if adj.diagonal().any():
            raise ValueError("adjacency must not contain self-loops")
        object.__setattr__(self, "adjacency", adj)

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def in_degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def min_in_degree(self) -> int:
        return int(self.in_degrees.min())

    def neighbors(self, j: int) -> np.ndarray:
        return np.nonzero(self.adjacency[j])[0]

    def validate_for_rule(self, rule: str) -> None:
        """Check the per-rule minimum neighborhood sizes of Table II."""
        from repro.core.screening import min_neighbors

        need = min_neighbors(rule, self.num_byzantine)
        if self.min_in_degree < need:
            raise ValueError(
                f"rule {rule!r} with b={self.num_byzantine} needs min in-degree "
                f">= {need}, graph has {self.min_in_degree}"
            )


# Above this node count `erdos_renyi` defaults to the degree-only recipe:
# the SCC-condensation sampler builds `num_samples` networkx digraphs of
# ~M^2 edges per candidate graph — minutes per retry at M ~ 512, for a check
# the paper itself replaces with the min-degree condition at scale (Sec. V).
DEGREE_ONLY_NODES = 128


def erdos_renyi(
    num_nodes: int,
    p: float,
    num_byzantine: int,
    *,
    seed: int = 0,
    max_tries: int = 200,
    check_samples: int = 50,
    assumption4: str = "auto",
) -> Topology:
    """Generate an undirected-as-bidirectional ER graph satisfying the paper's
    empirical Assumption-4 recipe (min degree > 2b) and a sampled reduced-graph
    check.  Matches Sec. V: "connect each pair of nodes with probability 0.5"
    and "the degree of the least connected node is larger than 2b".

    ``check_samples`` is forwarded to `check_assumption4` (it was silently
    hardcoded to half the documented default).  ``assumption4`` selects the
    certification mode: ``"sampled"`` always runs the reduced-graph sampler,
    ``"degree"`` accepts on the min-degree condition alone (the paper's
    large-graph recipe), ``"auto"`` (default) switches to degree-only above
    `DEGREE_ONLY_NODES` nodes, where the sampler's quadratic graph cost makes
    generation prohibitive.
    """
    if assumption4 not in ("auto", "sampled", "degree"):
        raise ValueError(f"assumption4 must be auto|sampled|degree, got {assumption4!r}")
    sample = assumption4 == "sampled" or (
        assumption4 == "auto" and num_nodes <= DEGREE_ONLY_NODES)
    rng = np.random.default_rng(seed)
    b = num_byzantine
    for _ in range(max_tries):
        upper = rng.random((num_nodes, num_nodes)) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        np.fill_diagonal(adj, False)
        topo = Topology(adjacency=adj, num_byzantine=b)
        if topo.min_in_degree <= 2 * b:
            continue
        if not sample:
            return topo
        if check_assumption4(topo, num_samples=check_samples, seed=int(rng.integers(2**31))):
            return topo
    raise RuntimeError(
        f"could not generate ER({num_nodes}, {p}) graph satisfying Assumption 4 "
        f"with b={b} in {max_tries} tries"
    )


def ring_of_cliques(num_cliques: int, clique_size: int, num_byzantine: int) -> Topology:
    """A structured topology useful for stress-testing consensus: cliques
    connected in a ring.  Generally does NOT satisfy Assumption 4 for b>0 —
    used in tests as a negative example."""
    m = num_cliques * clique_size
    adj = np.zeros((m, m), dtype=bool)
    for c in range(num_cliques):
        lo = c * clique_size
        for a in range(lo, lo + clique_size):
            for bb in range(lo, lo + clique_size):
                if a != bb:
                    adj[a, bb] = True
        nxt = ((c + 1) % num_cliques) * clique_size
        adj[lo, nxt] = True
        adj[nxt, lo] = True
    return Topology(adjacency=adj, num_byzantine=num_byzantine)


def complete_graph(num_nodes: int, num_byzantine: int) -> Topology:
    adj = ~np.eye(num_nodes, dtype=bool)
    return Topology(adjacency=adj, num_byzantine=num_byzantine)


# ---------------------------------------------------------------------------
# Large-graph topologies (K = max in-degree << M)
# ---------------------------------------------------------------------------
#
# The paper's Sec.-V experiments live on tiny dense ER graphs, but its
# scalability claim — and the sparse [M, K] runtime layout
# (repro.core.neighbors) — is about graphs whose degree stays bounded while M
# grows.  These builders produce the three standard such families at M >= 512
# with K <= a few dozen, each constructed so every node's in-degree clears the
# Table-II minimum for the configured b (degree-only Assumption-4 recipe; the
# sampled reduced-graph check remains available via `check_assumption4`).


def small_world(
    num_nodes: int,
    nearest: int,
    num_byzantine: int,
    *,
    rewire_prob: float = 0.2,
    seed: int = 0,
    max_degree: int | None = None,
) -> Topology:
    """Watts-Strogatz small world: a ring lattice where every node links its
    ``nearest`` neighbors on each side, with each edge's far endpoint rewired
    to a uniform node with probability ``rewire_prob``.  Rewiring moves only
    the *outgoing-side* endpoint and keeps edges bidirectional, so every
    node keeps degree >= ``nearest``; ``max_degree`` (default
    ``2 * nearest + 4``) rejects rewires onto already-popular nodes, keeping
    ``K = max in-degree`` hard-bounded — the contract the sparse ``[M, K]``
    layout sizes its state by."""
    m, k = num_nodes, nearest
    if not 1 <= k < m // 2:
        raise ValueError(f"need 1 <= nearest < num_nodes/2, got {k} vs {m}")
    need = 2 * num_byzantine + 1
    if 2 * k < need:
        raise ValueError(
            f"small_world(nearest={k}) has min degree {2 * k} < 2b+1 = {need}")
    cap = max_degree if max_degree is not None else 2 * k + 4
    if cap < 2 * k:
        raise ValueError(f"max_degree={cap} below the lattice degree {2 * k}")
    rng = np.random.default_rng(seed)
    adj = np.zeros((m, m), dtype=bool)
    for j in range(m):
        for off in range(1, k + 1):
            adj[j, (j + off) % m] = True
    adj = adj | adj.T
    deg = adj.sum(axis=1)
    for j in range(m):
        for off in range(1, k + 1):
            if rng.random() < rewire_prob:
                tgt = (j + off) % m
                cand = int(rng.integers(m))
                # a rewire must keep the old endpoint ABOVE the Table-II
                # floor (losing an edge may not starve it below 2b+1) and
                # the new endpoint below the K cap
                if (cand != j and not adj[j, cand] and adj[j, tgt]
                        and deg[tgt] > need and deg[cand] < cap and deg[j] <= cap):
                    adj[j, tgt] = adj[tgt, j] = False
                    adj[j, cand] = adj[cand, j] = True
                    deg[tgt] -= 1
                    deg[cand] += 1
    np.fill_diagonal(adj, False)
    topo = Topology(adjacency=adj, num_byzantine=num_byzantine)
    assert topo.min_in_degree >= need, "rewire floor violated (builder bug)"
    return topo


def random_geometric(
    num_nodes: int,
    num_byzantine: int,
    *,
    radius: float | None = None,
    seed: int = 0,
    max_tries: int = 50,
) -> Topology:
    """Random geometric graph: nodes uniform in the unit square, edges within
    ``radius`` (the standard wireless / sensor-network model — the setting
    ByRDiE and BRIDGE motivate).  ``radius=None`` starts at the connectivity
    threshold ``sqrt(2 log M / M)`` and grows it until every node clears the
    Table-II minimum degree ``2b + 1``."""
    m, b = num_nodes, num_byzantine
    rng = np.random.default_rng(seed)
    pts = rng.random((m, 2))
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    r = radius if radius is not None else float(np.sqrt(2.0 * np.log(max(m, 2)) / m))
    need = 2 * b + 1
    for _ in range(max_tries):
        adj = d2 <= r * r
        np.fill_diagonal(adj, False)
        topo = Topology(adjacency=adj, num_byzantine=b)
        if topo.min_in_degree >= need:
            return topo
        if radius is not None:
            break
        r *= 1.15
    raise RuntimeError(
        f"random_geometric({m}, r={r:.3f}) min degree "
        f"{int(adj.sum(1).min())} < {need} for b={b}")


def toroidal_grid(
    rows: int,
    cols: int,
    num_byzantine: int,
    *,
    diagonal: bool = False,
) -> Topology:
    """``rows x cols`` torus: every node links its 4 lattice neighbors
    (8 with ``diagonal=True``) with wraparound — the fixed-K (4 or 8),
    maximum-diameter stress case for consensus at scale.  Supports b = 1
    (b = 3 with diagonals) under the 2b+1 degree recipe."""
    m = rows * cols
    if rows < 3 or cols < 3:
        raise ValueError(f"torus needs rows, cols >= 3, got {rows}x{cols}")
    deg = 8 if diagonal else 4
    need = 2 * num_byzantine + 1
    if deg < need:
        raise ValueError(f"toroidal grid degree {deg} < 2b+1 = {need}")
    adj = np.zeros((m, m), dtype=bool)
    offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    if diagonal:
        offs += [(-1, -1), (-1, 1), (1, -1), (1, 1)]
    for r in range(rows):
        for c in range(cols):
            j = r * cols + c
            for dr, dc in offs:
                adj[j, ((r + dr) % rows) * cols + ((c + dc) % cols)] = True
    np.fill_diagonal(adj, False)
    return Topology(adjacency=adj, num_byzantine=num_byzantine)


def _torus_of(m: int, b: int, arg) -> Topology:
    rows = int(arg) if arg is not None else int(np.sqrt(m))
    if rows < 1 or m % rows:
        raise ValueError(f"torus of {m} nodes needs a row count dividing it, got {rows}")
    return toroidal_grid(rows, m // rows, b)


# Registry of named topology builders — ``spec`` strings like
# ``"small_world:8"`` let benchmarks / CLIs pick large-graph families
# without new flag plumbing per family (see `make_topology`).
TOPOLOGIES = {
    "erdos_renyi": lambda m, b, seed, arg: erdos_renyi(
        m, arg if arg is not None else 0.5, b, seed=seed),
    "small_world": lambda m, b, seed, arg: small_world(
        m, int(arg) if arg is not None else max(2 * b + 1, 4), b, seed=seed),
    "geometric": lambda m, b, seed, arg: random_geometric(
        m, b, radius=arg, seed=seed),
    "torus": lambda m, b, seed, arg: _torus_of(m, b, arg),
    "complete": lambda m, b, seed, arg: complete_graph(m, b),
}


def make_topology(spec: str, num_nodes: int, num_byzantine: int, *, seed: int = 0) -> Topology:
    """Build a named topology: ``spec`` is ``name`` or ``name:<arg>`` where
    the argument is family-specific (ER edge probability, small-world
    ``nearest``, geometric radius, torus row count)."""
    name, _, arg = spec.partition(":")
    if name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}; options: {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](num_nodes, num_byzantine, seed, float(arg) if arg else None)


def _has_source_component(adj: np.ndarray, min_size: int) -> bool:
    """True iff the digraph has an SCC of size >= min_size from which every
    node is reachable (Definition 2)."""
    if not _HAS_NX:  # pragma: no cover - networkx present in target env
        raise RuntimeError("networkx required for Assumption 4 checking")
    g = nx.from_numpy_array(adj.T.astype(int), create_using=nx.DiGraph)
    # adj[j, i] means i -> j can send; build digraph with edge i->j.
    cond = nx.condensation(g)
    n_total = g.number_of_nodes()
    for scc_id in cond.nodes:
        members = cond.nodes[scc_id]["members"]
        if len(members) < min_size:
            continue
        reachable = nx.descendants(cond, scc_id) | {scc_id}
        covered = sum(len(cond.nodes[s]["members"]) for s in reachable)
        if covered == n_total:
            return True
    return False


def check_assumption4(
    topo: Topology,
    *,
    num_samples: int = 50,
    seed: int = 0,
    byzantine_sets: Sequence[Sequence[int]] | None = None,
) -> bool:
    """Randomized check of Assumption 4.

    Samples Byzantine subsets of size b (or uses the provided ones) and, for
    each, samples adversarial removals of b incoming edges per honest node,
    then verifies the reduced graph retains a source component of size b+1.
    A False return is definitive for the sampled instance; True means "no
    counterexample found" (the exact problem is combinatorial).
    """
    rng = np.random.default_rng(seed)
    m, b = topo.num_nodes, topo.num_byzantine
    if b == 0:
        return _has_source_component(topo.adjacency, 1)
    sets = byzantine_sets
    if sets is None:
        sets = [rng.choice(m, size=b, replace=False) for _ in range(num_samples)]
    for byz in sets:
        byz = np.asarray(byz)
        keep = np.setdiff1d(np.arange(m), byz)
        sub = topo.adjacency[np.ix_(keep, keep)].copy()
        # adversarially remove b incoming edges per honest node (random sample)
        red = sub.copy()
        for row in range(red.shape[0]):
            ins = np.nonzero(red[row])[0]
            if len(ins) > 0:
                drop = rng.choice(ins, size=min(b, len(ins)), replace=False)
                red[row, drop] = False
        if not _has_source_component(red, b + 1):
            return False
    return True


def metropolis_weights(topo: Topology) -> np.ndarray:
    """Doubly-stochastic Metropolis-Hastings mixing matrix for faultless DGD."""
    adj = topo.adjacency
    deg = adj.sum(axis=1)
    m = topo.num_nodes
    w = np.zeros((m, m), dtype=np.float64)
    for j in range(m):
        for i in np.nonzero(adj[j])[0]:
            w[j, i] = 1.0 / (1 + max(deg[j], deg[i]))
        w[j, j] = 1.0 - w[j].sum()
    return w
