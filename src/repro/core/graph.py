"""Communication-graph utilities for decentralized learning.

The paper (Sec. II-B) models the network as a directed, static, connected
graph G(J, E).  Byzantine resilience requires the redundancy condition of
Assumption 4: every reduced graph G_red(b) — obtained by removing the
Byzantine nodes and additionally b incoming edges from every honest node —
must contain a source component of cardinality >= b+1.

Exact certification is combinatorial (the paper leaves it open); we provide
(i) the paper's empirical recipe — Erdos-Renyi graphs whose minimum degree
exceeds 2b — and (ii) a randomized checker that samples reduced graphs and
verifies the source-component condition on each sample via SCC condensation.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

try:  # networkx is available in this environment; keep a guard for minimal installs
    import networkx as nx

    _HAS_NX = True
except Exception:  # pragma: no cover
    _HAS_NX = False


@dataclasses.dataclass(frozen=True)
class Topology:
    """A static communication graph over ``num_nodes`` nodes.

    ``adjacency[j, i] == True`` iff node ``i`` is an in-neighbor of node ``j``
    (node j receives messages from node i).  Self-loops are always False —
    the node's own value is handled separately by the screening rules.
    """

    adjacency: np.ndarray  # [M, M] bool
    num_byzantine: int  # the bound b the protocol is configured for

    def __post_init__(self):
        adj = np.asarray(self.adjacency, dtype=bool)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency must be square, got {adj.shape}")
        if adj.diagonal().any():
            raise ValueError("adjacency must not contain self-loops")
        object.__setattr__(self, "adjacency", adj)

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def in_degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def min_in_degree(self) -> int:
        return int(self.in_degrees.min())

    def neighbors(self, j: int) -> np.ndarray:
        return np.nonzero(self.adjacency[j])[0]

    def validate_for_rule(self, rule: str) -> None:
        """Check the per-rule minimum neighborhood sizes of Table II."""
        from repro.core.screening import min_neighbors

        need = min_neighbors(rule, self.num_byzantine)
        if self.min_in_degree < need:
            raise ValueError(
                f"rule {rule!r} with b={self.num_byzantine} needs min in-degree "
                f">= {need}, graph has {self.min_in_degree}"
            )


def erdos_renyi(
    num_nodes: int,
    p: float,
    num_byzantine: int,
    *,
    seed: int = 0,
    max_tries: int = 200,
) -> Topology:
    """Generate an undirected-as-bidirectional ER graph satisfying the paper's
    empirical Assumption-4 recipe (min degree > 2b) and a sampled reduced-graph
    check.  Matches Sec. V: "connect each pair of nodes with probability 0.5"
    and "the degree of the least connected node is larger than 2b"."""
    rng = np.random.default_rng(seed)
    b = num_byzantine
    for _ in range(max_tries):
        upper = rng.random((num_nodes, num_nodes)) < p
        adj = np.triu(upper, 1)
        adj = adj | adj.T
        np.fill_diagonal(adj, False)
        topo = Topology(adjacency=adj, num_byzantine=b)
        if topo.min_in_degree <= 2 * b:
            continue
        if check_assumption4(topo, num_samples=25, seed=int(rng.integers(2**31))):
            return topo
    raise RuntimeError(
        f"could not generate ER({num_nodes}, {p}) graph satisfying Assumption 4 "
        f"with b={b} in {max_tries} tries"
    )


def ring_of_cliques(num_cliques: int, clique_size: int, num_byzantine: int) -> Topology:
    """A structured topology useful for stress-testing consensus: cliques
    connected in a ring.  Generally does NOT satisfy Assumption 4 for b>0 —
    used in tests as a negative example."""
    m = num_cliques * clique_size
    adj = np.zeros((m, m), dtype=bool)
    for c in range(num_cliques):
        lo = c * clique_size
        for a in range(lo, lo + clique_size):
            for bb in range(lo, lo + clique_size):
                if a != bb:
                    adj[a, bb] = True
        nxt = ((c + 1) % num_cliques) * clique_size
        adj[lo, nxt] = True
        adj[nxt, lo] = True
    return Topology(adjacency=adj, num_byzantine=num_byzantine)


def complete_graph(num_nodes: int, num_byzantine: int) -> Topology:
    adj = ~np.eye(num_nodes, dtype=bool)
    return Topology(adjacency=adj, num_byzantine=num_byzantine)


def _has_source_component(adj: np.ndarray, min_size: int) -> bool:
    """True iff the digraph has an SCC of size >= min_size from which every
    node is reachable (Definition 2)."""
    if not _HAS_NX:  # pragma: no cover - networkx present in target env
        raise RuntimeError("networkx required for Assumption 4 checking")
    g = nx.from_numpy_array(adj.T.astype(int), create_using=nx.DiGraph)
    # adj[j, i] means i -> j can send; build digraph with edge i->j.
    cond = nx.condensation(g)
    n_total = g.number_of_nodes()
    for scc_id in cond.nodes:
        members = cond.nodes[scc_id]["members"]
        if len(members) < min_size:
            continue
        reachable = nx.descendants(cond, scc_id) | {scc_id}
        covered = sum(len(cond.nodes[s]["members"]) for s in reachable)
        if covered == n_total:
            return True
    return False


def check_assumption4(
    topo: Topology,
    *,
    num_samples: int = 50,
    seed: int = 0,
    byzantine_sets: Sequence[Sequence[int]] | None = None,
) -> bool:
    """Randomized check of Assumption 4.

    Samples Byzantine subsets of size b (or uses the provided ones) and, for
    each, samples adversarial removals of b incoming edges per honest node,
    then verifies the reduced graph retains a source component of size b+1.
    A False return is definitive for the sampled instance; True means "no
    counterexample found" (the exact problem is combinatorial).
    """
    rng = np.random.default_rng(seed)
    m, b = topo.num_nodes, topo.num_byzantine
    if b == 0:
        return _has_source_component(topo.adjacency, 1)
    sets = byzantine_sets
    if sets is None:
        sets = [rng.choice(m, size=b, replace=False) for _ in range(num_samples)]
    for byz in sets:
        byz = np.asarray(byz)
        keep = np.setdiff1d(np.arange(m), byz)
        sub = topo.adjacency[np.ix_(keep, keep)].copy()
        # adversarially remove b incoming edges per honest node (random sample)
        red = sub.copy()
        for row in range(red.shape[0]):
            ins = np.nonzero(red[row])[0]
            if len(ins) > 0:
                drop = rng.choice(ins, size=min(b, len(ins)), replace=False)
                red[row, drop] = False
        if not _has_source_component(red, b + 1):
            return False
    return True


def metropolis_weights(topo: Topology) -> np.ndarray:
    """Doubly-stochastic Metropolis-Hastings mixing matrix for faultless DGD."""
    adj = topo.adjacency
    deg = adj.sum(axis=1)
    m = topo.num_nodes
    w = np.zeros((m, m), dtype=np.float64)
    for j in range(m):
        for i in np.nonzero(adj[j])[0]:
            w[j, i] = 1.0 / (1 + max(deg[j], deg[i]))
        w[j, j] = 1.0 - w[j].sum()
    return w
