from repro.optim.sgd import (
    AdamWState,
    adamw_init,
    adamw_update,
    bridge_schedule,
    constant_schedule,
    cosine_schedule,
    momentum_init,
    momentum_update,
)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update",
    "bridge_schedule", "constant_schedule", "cosine_schedule",
    "momentum_init", "momentum_update",
]
