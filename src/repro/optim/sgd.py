"""Optimizers and step-size schedules.

The paper's analyzed setting is plain (sub)gradient descent with the
decreasing schedule rho(t) = 1/(lam (t0 + t)) — ``bridge_schedule``.  The
BRIDGE update itself is y - rho*g (no optimizer state); momentum and AdamW
are provided as beyond-paper options for the LLM examples (applied to the
*post-screening* iterate, preserving the screen-then-step structure).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def bridge_schedule(lam: float = 1.0, t0: float = 50.0):
    def rho(t):
        return 1.0 / (lam * (t0 + t))

    return rho


def constant_schedule(lr: float):
    def rho(t):
        return jnp.asarray(lr, jnp.float32)

    return rho


def cosine_schedule(peak: float, total_steps: int, warmup: int = 0):
    def rho(t):
        t = jnp.asarray(t, jnp.float32)
        warm = peak * t / jnp.maximum(warmup, 1)
        frac = jnp.clip((t - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(t < warmup, warm, cos)

    return rho


# ---------------------------------------------------------------------------
# momentum
# ---------------------------------------------------------------------------


def momentum_init(params: Any) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def momentum_update(grads, state, *, beta: float = 0.9):
    new_state = jax.tree_util.tree_map(
        lambda m, g: beta * m + g.astype(jnp.float32), state, grads
    )
    return new_state, new_state


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params: Any) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, jnp.float32)
    return AdamWState(
        mu=jax.tree_util.tree_map(z, params),
        nu=jax.tree_util.tree_map(z, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0):
    count = state.count + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamWState(mu, nu, count)
