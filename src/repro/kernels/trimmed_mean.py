"""Pallas TPU kernel for coordinate-wise trimmed-mean screening (BRIDGE-T).

TPU adaptation of the paper's screening hot loop (Eqs. 7-10).  A GPU
implementation would sort each coordinate's n neighbor values; on TPU a full
sort wastes the VPU — instead we exploit b << n and *iteratively extract* the
b maxima and b minima with masked max/min reductions over the (8-sublane
aligned) neighbor axis, which is a pure element-wise/reduce pattern the VPU
pipelines well.  The coordinate dimension is tiled into 128-lane-aligned VMEM
blocks; each grid step screens one block of coordinates for one node.

Shapes: values ``[n, d]`` (n = padded neighborhood), mask ``[n]`` marks real
neighbors, self_value ``[d]``; out ``[d]``.  A leading *experiment* axis is
also accepted — ``values [E, n, d]``, ``mask [E, n]``, ``self_value [E, d]``
-> ``out [E, d]`` — mapping E onto the first Pallas grid dimension so batched
rule x attack x seed sweeps (`repro.sim`) screen every experiment in one
kernel launch.  b is static and shared across the batch.

Masked lanes use ±inf sentinels (matching `repro.core.screening`): a finite
sentinel mis-ranks legitimately huge payloads (>1e30 fp32 values, bf16
overflow products).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")


def _first_true(flags: jax.Array) -> jax.Array:
    """Per-coordinate mask of the first True row (axis 0), without cumsum.

    Pallas-TPU friendly: uses a running 'seen' accumulator over the static
    neighbor axis (unrolled python loop) instead of lax.cumsum.
    """
    n = flags.shape[0]
    rows = []
    seen = jnp.zeros_like(flags[0])
    for i in range(n):
        take = flags[i] & ~seen
        rows.append(take)
        seen = seen | flags[i]
    return jnp.stack(rows, axis=0)


def _trimmed_mean_block(values, valid, self_value, b: int):
    """Screen one [n, blk] block; `valid` is the [n, blk] neighbor mask.

    The trim width is clamped to ``min(b, (count - 1) // 2)`` exactly like
    `repro.core.screening.effective_trim`: identical at or above Table II's
    ``2b + 1`` minimum, and degrades instead of dividing through zero on a
    starved neighborhood (dynamic schedules)."""
    count = jnp.sum(valid[:, :1].astype(jnp.float32))  # |N_j| (mask is per-row)
    b_eff = jnp.minimum(jnp.float32(b), jnp.floor(jnp.maximum(count - 1.0, 0.0) / 2.0))
    m = valid
    v = values
    for i in range(b):  # drop up to b maxima (gated by the clamp)
        cur = jnp.max(jnp.where(m, v, -_INF), axis=0, keepdims=True)
        hit = _first_true((v == cur) & m)
        m = m & ~(hit & (i < b_eff))
    for i in range(b):  # drop up to b minima
        cur = jnp.min(jnp.where(m, v, _INF), axis=0, keepdims=True)
        hit = _first_true((v == cur) & m)
        m = m & ~(hit & (i < b_eff))
    total = jnp.sum(jnp.where(m, v, 0.0), axis=0) + self_value
    return total / (count - 2 * b_eff + 1)


def _kernel(values_ref, mask_ref, self_ref, out_ref, *, b: int):
    values = values_ref[0].astype(jnp.float32)  # [n, blk]
    # NaN payloads -> +inf so they are trimmed as maximal outliers instead of
    # poisoning the max/min extraction (matches repro.core.screening)
    values = jnp.where(jnp.isnan(values), _INF, values)
    mask = mask_ref[0]  # [n, 1] float (0/1)
    self_value = self_ref[0]  # [1, blk]
    valid = (mask > 0.5) & jnp.ones_like(values, dtype=bool)
    out_ref[0] = _trimmed_mean_block(
        values, valid, self_value[0].astype(jnp.float32), b
    ).astype(out_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("b", "block_d", "interpret"))
def trimmed_mean_pallas(
    values: jax.Array,
    mask: jax.Array,
    self_value: jax.Array,
    b: int,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Trimmed-mean screening of ``values [n, d]`` (or ``[E, n, d]``) against
    ``self_value [d]`` (or ``[E, d]``)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = values.ndim == 2
    if squeeze:
        values, mask, self_value = values[None], mask[None], self_value[None]
    e, n, d = values.shape
    pad_d = (-d) % block_d
    vp = jnp.pad(values, ((0, 0), (0, 0), (0, pad_d)))
    sp = jnp.pad(self_value, ((0, 0), (0, pad_d)))[:, None, :]  # [E, 1, dpad]
    mp = mask.astype(jnp.float32)[:, :, None]  # [E, n, 1]
    dp = d + pad_d
    grid = (e, dp // block_d)
    out = pl.pallas_call(
        functools.partial(_kernel, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
            pl.BlockSpec((1, n, 1), lambda ei, i: (ei, 0, 0)),
            pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        out_shape=jax.ShapeDtypeStruct((e, 1, dp), values.dtype),
        interpret=interpret,
    )(vp, mp, sp)
    out = out[:, 0, :d]
    return out[0] if squeeze else out
