"""Pallas TPU kernel for Krum pairwise-distance scoring (BRIDGE-K/B).

The O(n^2 d) hot loop of the vector screening rules is the pairwise
squared-distance (Gram) accumulation.  We tile the coordinate dimension into
VMEM blocks and accumulate  G += X_blk @ X_blk^T  on the MXU across grid
steps (output revisiting), then form  d2 = diag + diag^T - 2G  in the final
grid step.  The [n, n] score matrix is tiny (n <= ~64) — the kernel is
entirely bound by streaming X through VMEM once, which is optimal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, gram_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # [n, blk]

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    gram_ref[...] += jnp.dot(x, x.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_sq_dists_pallas(
    stacked: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """[n, n] squared euclidean distances between rows of ``stacked [n, d]``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = stacked.shape
    pad_d = (-d) % block_d
    xp = jnp.pad(stacked, ((0, 0), (0, pad_d)))
    dp = d + pad_d
    gram = pl.pallas_call(
        _kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(xp)
    sq = jnp.diagonal(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
