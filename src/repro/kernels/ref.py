"""Pure-jnp oracles for the Pallas screening kernels (allclose targets).

Like the kernels, the coordinate-wise oracles accept an optional leading
experiment axis (``[E, n, d]`` values with ``[E, n]`` masks) via vmap.
Masked entries use the ``+inf`` sentinel (see `repro.core.screening`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INF = jnp.inf


def _maybe_batch(fn, values, *args):
    if values.ndim == 3:
        return jax.vmap(fn)(values, *args)
    return fn(values, *args)


def trimmed_mean_ref(values: jax.Array, mask: jax.Array, self_value: jax.Array, b: int) -> jax.Array:
    """Sort-based masked trimmed mean — Eqs. (7)-(10)."""

    def one(values, mask, self_value):
        n = values.shape[0]
        v = values.astype(jnp.float32)
        v = jnp.where(jnp.isnan(v), _INF, v)  # NaN guard, matches core screening
        count = jnp.sum(mask)
        order = jnp.sort(jnp.where(mask[:, None], v, _INF), axis=0)
        idx = jnp.arange(n)[:, None]
        keep = (idx >= b) & (idx < count - b)
        total = jnp.sum(jnp.where(keep, order, 0.0), axis=0) + self_value.astype(jnp.float32)
        return (total / (count - 2 * b + 1)).astype(values.dtype)

    return _maybe_batch(one, values, mask, self_value)


def median_ref(values: jax.Array, mask: jax.Array) -> jax.Array:
    """Sort-based masked coordinate-wise median (rows already include self)."""

    def one(values, mask):
        n = values.shape[0]
        v = values.astype(jnp.float32)
        v = jnp.where(jnp.isnan(v), _INF, v)  # NaN guard, matches core screening
        count = jnp.sum(mask)
        order = jnp.sort(jnp.where(mask[:, None], v, _INF), axis=0)
        lo, hi = (count - 1) // 2, count // 2
        idx = jnp.arange(n)[:, None]
        pick = lambda r: jnp.sum(jnp.where(idx == r, order, 0.0), axis=0)
        return (0.5 * (pick(lo) + pick(hi))).astype(values.dtype)

    return _maybe_batch(one, values, mask)


def pairwise_sq_dists_ref(stacked: jax.Array) -> jax.Array:
    x = stacked.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)


# ---------------------------------------------------------------------------
# Compressed-exchange oracles: decode-then-screen, the fused kernels' anchor
# ---------------------------------------------------------------------------


def dequant_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Affine decode of int8 codewords: ``q [..., n, d]`` codes with
    ``scale [..., n, S, 2]`` per-block (scale, zero) pairs (one per
    `repro.comm.codec.SCALE_BLOCK` coordinates — the codec's wire layout)
    -> guarded float32 values.  NaNs (inf scale x zero code, producible by
    scale-abuse wire attacks) are guarded to +inf, matching
    `repro.core.screening`."""
    from repro.comm.codec import apply_scales

    v = apply_scales(q, scale)
    return jnp.where(jnp.isnan(v), _INF, v)


def dequant_trimmed_mean_ref(q, scale, mask, self_value, b: int) -> jax.Array:
    """Unfused pipeline: materialize the float32 neighbor tensor, then screen."""
    return trimmed_mean_ref(dequant_ref(q, scale), mask, self_value, b)


def dequant_median_ref(q, scale, mask, self_value) -> jax.Array:
    """Unfused pipeline for BRIDGE-M: decode, append the (uncompressed) self
    row, coordinate-median over N_j ∪ {j}."""

    def one(q, scale, mask, self_value):
        v = dequant_ref(q, scale)
        rows = jnp.concatenate([v, self_value.astype(jnp.float32)[None]], axis=0)
        fm = jnp.concatenate([mask, jnp.ones((1,), bool)], axis=0)
        return median_ref(rows, fm)

    if q.ndim == 3:
        return jax.vmap(one)(q, scale, mask, self_value)
    return one(q, scale, mask, self_value)
