"""Pallas TPU kernel for coordinate-wise median screening (BRIDGE-M).

Rank-by-counting instead of sorting: for each row i we count, per coordinate,
how many valid entries precede it in the (value, index) lexicographic order.
The two middle order statistics are then selected by rank equality and
averaged (even/odd cardinalities handled uniformly).  O(n^2 * blk) VPU
compares with an unrolled outer loop — n (neighbors+self) is <= a few dozen,
so this beats a bitonic sort's log^2 passes at these sizes and needs no
cross-lane shuffles.

Input rows INCLUDE the node's own value (mask row set accordingly) — the
median in Eq. (11) ranges over N_j ∪ {j}.

A leading *experiment* axis is accepted — ``values [E, n, d]``, ``mask
[E, n]`` -> ``out [E, d]`` — mapped onto the first Pallas grid dimension so
batched sweeps (`repro.sim`) screen every experiment in one launch.

Masked lanes use a ``+inf`` sentinel (matching `repro.core.screening`): a
finite sentinel mis-ranks legitimately huge payloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")


def _median_block(values, valid):
    """Median over axis 0 of one [n, blk] block under the [n, blk] mask."""
    n = values.shape[0]
    count = jnp.sum(valid[:, :1].astype(jnp.int32))  # cardinality (per-row mask)
    lo = (count - 1) // 2
    hi = count // 2
    v = jnp.where(valid, values, _INF)
    acc_lo = jnp.zeros_like(values[0])
    acc_hi = jnp.zeros_like(values[0])
    for i in range(n):
        vi = v[i]
        # rank of row i among valid entries (lexicographic tie-break by row)
        less = jnp.zeros_like(vi, dtype=jnp.int32)
        for j in range(n):
            if j == i:
                continue
            vj = v[j]
            prec = (vj < vi) | ((vj == vi) & (j < i))
            less = less + (prec & valid[j]).astype(jnp.int32)
        ok = valid[i]
        acc_lo = acc_lo + jnp.where(ok & (less == lo), vi, 0.0)
        acc_hi = acc_hi + jnp.where(ok & (less == hi), vi, 0.0)
    return 0.5 * (acc_lo + acc_hi)


def _kernel(values_ref, mask_ref, out_ref):
    values = values_ref[0].astype(jnp.float32)
    # NaN payloads -> +inf so rank-counting stays total-ordered (matches
    # repro.core.screening's guard)
    values = jnp.where(jnp.isnan(values), _INF, values)
    mask = mask_ref[0]
    valid = (mask > 0.5) & jnp.ones_like(values, dtype=bool)
    out_ref[0] = _median_block(values, valid).astype(out_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def median_pallas(
    values: jax.Array,
    mask: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Masked coordinate-wise median of ``values [n, d]`` (or ``[E, n, d]``)
    over the neighbor axis."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = values.ndim == 2
    if squeeze:
        values, mask = values[None], mask[None]
    e, n, d = values.shape
    pad_d = (-d) % block_d
    vp = jnp.pad(values, ((0, 0), (0, 0), (0, pad_d)))
    mp = mask.astype(jnp.float32)[:, :, None]  # [E, n, 1]
    dp = d + pad_d
    out = pl.pallas_call(
        _kernel,
        grid=(e, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
            pl.BlockSpec((1, n, 1), lambda ei, i: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        out_shape=jax.ShapeDtypeStruct((e, 1, dp), values.dtype),
        interpret=interpret,
    )(vp, mp)
    out = out[:, 0, :d]
    return out[0] if squeeze else out
