"""Pallas TPU kernel for coordinate-wise median screening (BRIDGE-M).

Rank-by-counting instead of sorting: for each row i we count, per coordinate,
how many valid entries precede it in the (value, index) lexicographic order.
The two middle order statistics are then selected by rank equality and
averaged (even/odd cardinalities handled uniformly).  O(n^2 * blk) VPU
compares with an unrolled outer loop — n (neighbors+self) is <= a few dozen,
so this beats a bitonic sort's log^2 passes at these sizes and needs no
cross-lane shuffles.

Input rows INCLUDE the node's own value (mask row set accordingly) — the
median in Eq. (11) ranges over N_j ∪ {j}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1e30


def _median_block(values, valid):
    """Median over axis 0 of one [n, blk] block under the [n, blk] mask."""
    n = values.shape[0]
    count = jnp.sum(valid[:, :1].astype(jnp.int32))  # cardinality (per-row mask)
    lo = (count - 1) // 2
    hi = count // 2
    v = jnp.where(valid, values, _BIG)
    acc_lo = jnp.zeros_like(values[0])
    acc_hi = jnp.zeros_like(values[0])
    for i in range(n):
        vi = v[i]
        # rank of row i among valid entries (lexicographic tie-break by row)
        less = jnp.zeros_like(vi, dtype=jnp.int32)
        for j in range(n):
            if j == i:
                continue
            vj = v[j]
            prec = (vj < vi) | ((vj == vi) & (j < i))
            less = less + (prec & valid[j]).astype(jnp.int32)
        ok = valid[i]
        acc_lo = acc_lo + jnp.where(ok & (less == lo), vi, 0.0)
        acc_hi = acc_hi + jnp.where(ok & (less == hi), vi, 0.0)
    return 0.5 * (acc_lo + acc_hi)


def _kernel(values_ref, mask_ref, out_ref):
    values = values_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    valid = (mask > 0.5) & jnp.ones_like(values, dtype=bool)
    out_ref[...] = _median_block(values, valid).astype(out_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def median_pallas(
    values: jax.Array,
    mask: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Masked coordinate-wise median of ``values [n, d]`` over axis 0."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = values.shape
    pad_d = (-d) % block_d
    vp = jnp.pad(values, ((0, 0), (0, pad_d)))
    mp = mask.astype(jnp.float32)[:, None]
    dp = d + pad_d
    out = pl.pallas_call(
        _kernel,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), values.dtype),
        interpret=interpret,
    )(vp, mp)
    return out[0, :d]
