"""Fused Pallas gather -> (dequantize ->) screen kernels — the sparse hot path.

On the neighbor-indexed layout (`repro.core.neighbors`) screening node j
means: gather its K in-neighbor rows from the ``[M, d]`` broadcast matrix (or
its ``[M, P]`` int8 codeword bank), decode them, and reduce coordinate-wise.
The staged jnp pipeline materializes the gathered ``[M, K, d]`` float tensor
in HBM just to immediately reduce it; these kernels instead gather the K rows
*inside the VMEM block* with dynamic row slices, dequantize in-register, and
screen in the same pass — one kernel launch per coordinate block, and neither
``[M, M, d]`` nor ``[M, K, d]`` ever exists.

Layout per grid step ``(j, i)``: the whole value bank's rows for coordinate
block ``i`` sit in VMEM (``[M, block_d]`` — f32 at block_d=512 and M=512 is
1 MB, comfortably inside VMEM), node j's ``[K]`` neighbor indices arrive as a
scalar row, and K unrolled ``pl.ds`` row loads build the ``[K, block_d]``
neighborhood.  K is static and small (the whole point of the sparse layout),
so the unrolled gather is a handful of sublane moves.

The correctness anchors are the staged paths: ``gather -> screening rule``
(pure jnp, `repro.core.screening`) for the f32 kernels and ``gather ->
`repro.kernels.dequant_screen` `` for the codeword kernels; the tests assert
exact agreement and ``benchmarks/scale_bench.py`` times fused vs staged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm.codec import SCALE_BLOCK
from repro.kernels.dequant_screen import _dequant_rows
from repro.kernels.median import _median_block
from repro.kernels.trimmed_mean import _trimmed_mean_block

_INF = float("inf")


def _gather_rows(w_ref, idx_ref, k: int):
    """K unrolled dynamic row loads: [K, blk] neighborhood of this node."""
    rows = [w_ref[pl.ds(idx_ref[0, kk], 1), :] for kk in range(k)]
    return jnp.concatenate(rows, axis=0)


def _gtm_kernel(idx_ref, valid_ref, w_ref, self_ref, out_ref, *, b: int, k: int):
    v = _gather_rows(w_ref, idx_ref, k)  # [K, blk]
    v = jnp.where(jnp.isnan(v), _INF, v)
    valid = (valid_ref[0][:, None] > 0.5) & jnp.ones_like(v, dtype=bool)
    out_ref[0] = _trimmed_mean_block(v, valid, self_ref[0], b)


def _gmed_kernel(idx_ref, valid_ref, w_ref, self_ref, out_ref, *, k: int):
    v = _gather_rows(w_ref, idx_ref, k)
    self_row = self_ref[0][None, :]
    rows = jnp.concatenate([jnp.where(jnp.isnan(v), _INF, v),
                            jnp.where(jnp.isnan(self_row), _INF, self_row)], axis=0)
    valid = jnp.concatenate(
        [(valid_ref[0][:, None] > 0.5) & jnp.ones_like(v, dtype=bool),
         jnp.ones_like(self_row, dtype=bool)], axis=0)
    out_ref[0] = _median_block(rows, valid)


def _gdq_tm_kernel(idx_ref, valid_ref, q_ref, scale_ref, self_ref, out_ref, *,
                   b: int, k: int):
    q = _gather_rows(q_ref, idx_ref, k)  # [K, blk] int8
    sc = jnp.concatenate(
        [scale_ref[pl.ds(idx_ref[0, kk], 1), :, :] for kk in range(k)], axis=0)
    v = _dequant_rows(q, sc)  # guarded f32 [K, blk]
    valid = (valid_ref[0][:, None] > 0.5) & jnp.ones_like(v, dtype=bool)
    out_ref[0] = _trimmed_mean_block(v, valid, self_ref[0], b)


def _gdq_med_kernel(idx_ref, valid_ref, q_ref, scale_ref, self_ref, out_ref, *, k: int):
    q = _gather_rows(q_ref, idx_ref, k)
    sc = jnp.concatenate(
        [scale_ref[pl.ds(idx_ref[0, kk], 1), :, :] for kk in range(k)], axis=0)
    v = _dequant_rows(q, sc)
    self_row = self_ref[0][None, :]
    rows = jnp.concatenate([v, jnp.where(jnp.isnan(self_row), _INF, self_row)], axis=0)
    valid = jnp.concatenate(
        [(valid_ref[0][:, None] > 0.5) & jnp.ones_like(v, dtype=bool),
         jnp.ones_like(self_row, dtype=bool)], axis=0)
    out_ref[0] = _median_block(rows, valid)


def _prep(idx, valid, m: int, d: int, block_d: int, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if idx.ndim != 2 or idx.shape != valid.shape or idx.shape[0] != m:
        raise ValueError(f"idx/valid must be [M={m}, K], got {idx.shape} / {valid.shape}")
    k = idx.shape[1]
    # padded slots (sentinel index M) are clamped to a real row and killed by
    # the valid mask — same contract as NeighborTable.safe_idx
    idx = jnp.minimum(idx.astype(jnp.int32), m - 1)
    pad_d = (-d) % block_d
    return interpret, k, idx, valid.astype(jnp.float32), pad_d


@functools.partial(jax.jit, static_argnames=("b", "rule", "block_d", "interpret"))
def gather_screen_pallas(
    w: jax.Array,
    idx: jax.Array,
    valid: jax.Array,
    self_vals: jax.Array,
    b: int,
    *,
    rule: str = "trimmed_mean",
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather->screen over float values: ``w [M, d]`` stacked broadcast
    rows, ``idx/valid [M, K]`` the neighbor table, ``self_vals [M, d]`` the
    (never-gathered) own iterates -> ``[M, d]`` screened outputs.  ``rule``
    is ``trimmed_mean`` (BRIDGE-T) or ``median`` (BRIDGE-M)."""
    m, d = w.shape
    interpret, k, idx, validf, pad_d = _prep(idx, valid, m, d, block_d, interpret)
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, pad_d)))
    sp = jnp.pad(self_vals.astype(jnp.float32), ((0, 0), (0, pad_d)))
    dp = d + pad_d
    if rule == "trimmed_mean":
        kernel = functools.partial(_gtm_kernel, b=b, k=k)
    elif rule == "median":
        kernel = functools.partial(_gmed_kernel, k=k)
    else:
        raise ValueError(f"rule must be trimmed_mean|median, got {rule!r}")
    out = pl.pallas_call(
        kernel,
        grid=(m, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, k), lambda j, i: (j, 0)),
            pl.BlockSpec((1, k), lambda j, i: (j, 0)),
            pl.BlockSpec((m, block_d), lambda j, i: (0, i)),
            pl.BlockSpec((1, block_d), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m, dp), jnp.float32),
        interpret=interpret,
    )(idx, validf, wp, sp)
    return out[:, :d]


@functools.partial(jax.jit, static_argnames=("b", "rule", "block_d", "interpret"))
def gather_dequant_screen_pallas(
    q: jax.Array,
    scale: jax.Array,
    idx: jax.Array,
    valid: jax.Array,
    self_vals: jax.Array,
    b: int,
    *,
    rule: str = "trimmed_mean",
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused gather->dequantize->screen over int8 codewords: ``q [M, d]``
    int8 codes + ``scale [M, S, 2]`` per-`SCALE_BLOCK` affine pairs (the
    `repro.comm` wire layout), gathered per node through ``idx/valid [M, K]``
    and screened against the uncompressed ``self_vals [M, d]`` -> ``[M, d]``.
    Neither the decoded float bank nor the gathered neighborhood tensor ever
    reaches HBM."""
    if block_d % SCALE_BLOCK:
        raise ValueError(f"block_d must be a multiple of {SCALE_BLOCK}, got {block_d}")
    m, d = q.shape
    interpret, k, idx, validf, pad_d = _prep(idx, valid, m, d, block_d, interpret)
    qp = jnp.pad(q, ((0, 0), (0, pad_d)))
    s_need = (d + pad_d) // SCALE_BLOCK
    scp = jnp.pad(scale, ((0, 0), (0, s_need - scale.shape[1]), (0, 0)))
    sp = jnp.pad(self_vals.astype(jnp.float32), ((0, 0), (0, pad_d)))
    dp = d + pad_d
    sb = block_d // SCALE_BLOCK
    if rule == "trimmed_mean":
        kernel = functools.partial(_gdq_tm_kernel, b=b, k=k)
    elif rule == "median":
        kernel = functools.partial(_gdq_med_kernel, k=k)
    else:
        raise ValueError(f"rule must be trimmed_mean|median, got {rule!r}")
    out = pl.pallas_call(
        kernel,
        grid=(m, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, k), lambda j, i: (j, 0)),
            pl.BlockSpec((1, k), lambda j, i: (j, 0)),
            pl.BlockSpec((m, block_d), lambda j, i: (0, i)),
            pl.BlockSpec((m, sb, 2), lambda j, i: (0, i, 0)),
            pl.BlockSpec((1, block_d), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j, i: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m, dp), jnp.float32),
        interpret=interpret,
    )(idx, validf, qp, scp, sp)
    return out[:, :d]
