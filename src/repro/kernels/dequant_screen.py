"""Fused Pallas dequantize -> screen kernels (compressed-exchange hot path).

With an int8 wire codec (`repro.comm`), each node holds its neighbors'
*codewords*: an ``int8 [n, d]`` payload buffer plus a per-sender ``[n, 2]``
(scale, zero) dequantization pair.  The naive pipeline materializes
``float32 [n, d]`` (4x the codeword bytes) in HBM just to immediately reduce
it coordinate-wise; these kernels instead dequantize *inside the VMEM block*
and run the screening reduction in the same pass — one kernel launch, no
float32 neighbor tensor, 4x less HBM traffic on the dominant operand.  The
decode-then-screen pipeline (`repro.kernels.ops.dequant` followed by the
screening kernels, or the pure-jnp `ref` path) is the correctness anchor:
``benchmarks/comm_bench.py`` times fused vs staged and the tests assert
exact agreement.

Dequantization is the codec's affine map ``q * scale + zero`` — including
whatever a wire attack left in the scale field, so screening is exercised
against what decoders actually emit (scale abuse can produce ``inf``, and
``inf * 0`` NaNs are guarded to ``+inf`` exactly like `repro.core.screening`).

Shapes mirror the other kernels: ``q [n, d]`` int8 / ``scale [n, S, 2]``
(one affine pair per `repro.comm.codec.SCALE_BLOCK` coordinates — the codec's
wire layout) / ``mask [n]`` / ``self_value [d]`` -> ``[d]``, with an optional
leading experiment axis (``[E, n, d]`` etc.) mapped onto the first Pallas
grid dimension.  ``b`` is static; ``block_d`` must be a multiple of
`SCALE_BLOCK` so each grid step's scale slice aligns with its coordinates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm.codec import SCALE_BLOCK
from repro.kernels.median import _median_block
from repro.kernels.trimmed_mean import _trimmed_mean_block

_INF = float("inf")


def _dequant_rows(q, scale):
    """[n, blk] int8 codes + [n, sb, 2] per-block affine pairs -> guarded
    f32 rows (sb = blk / SCALE_BLOCK)."""
    n, blk = q.shape
    sb = scale.shape[1]
    qb = q.astype(jnp.float32).reshape(n, sb, blk // sb)
    v = (qb * scale[:, :, 0:1] + scale[:, :, 1:2]).reshape(n, blk)
    # abused scales decode to inf; inf * 0 codes to NaN — guard to +inf so
    # rank-based screening trims them as maximal outliers (core.screening)
    return jnp.where(jnp.isnan(v), _INF, v)


def _dequant_kernel(q_ref, scale_ref, out_ref):
    out_ref[0] = _dequant_rows(q_ref[0], scale_ref[0]).astype(out_ref.dtype)


def _fused_tm_kernel(q_ref, scale_ref, mask_ref, self_ref, out_ref, *, b: int):
    v = _dequant_rows(q_ref[0], scale_ref[0])  # [n, blk]
    valid = (mask_ref[0] > 0.5) & jnp.ones_like(v, dtype=bool)
    self_value = self_ref[0][0].astype(jnp.float32)  # [blk]
    out_ref[0] = _trimmed_mean_block(v, valid, self_value, b).astype(out_ref.dtype)[None]


def _fused_med_kernel(q_ref, scale_ref, mask_ref, self_ref, out_ref):
    v = _dequant_rows(q_ref[0], scale_ref[0])  # [n, blk]
    self_row = self_ref[0].astype(jnp.float32)  # [1, blk]
    # Eq. (11) medians over N_j ∪ {j}: the node's own (never-compressed)
    # iterate joins the dequantized neighbor rows inside the block
    rows = jnp.concatenate([v, jnp.where(jnp.isnan(self_row), _INF, self_row)], axis=0)
    valid = jnp.concatenate(
        [(mask_ref[0] > 0.5) & jnp.ones_like(v, dtype=bool),
         jnp.ones_like(self_row, dtype=bool)], axis=0)
    out_ref[0] = _median_block(rows, valid).astype(out_ref.dtype)[None]


def _prep(q, scale, mask, self_value, block_d, interpret):
    """Shared batching/padding: returns (e, n, d, padded operands, grid)."""
    if block_d % SCALE_BLOCK:
        raise ValueError(f"block_d must be a multiple of {SCALE_BLOCK}, got {block_d}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = q.ndim == 2
    if squeeze:
        q, scale, mask = q[None], scale[None], mask[None]
        if self_value is not None:
            self_value = self_value[None]
    e, n, d = q.shape
    pad_d = (-d) % block_d
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_d)))
    # scale blocks padded to cover the padded coordinate range (zero scale
    # decodes the zero-padded tail to exact zeros)
    s_need = (d + pad_d) // SCALE_BLOCK
    scp = jnp.pad(scale, ((0, 0), (0, 0), (0, s_need - scale.shape[2]), (0, 0)))
    sp = None
    if self_value is not None:
        sp = jnp.pad(self_value, ((0, 0), (0, pad_d)))[:, None, :]  # [E, 1, dpad]
    mp = None if mask is None else mask.astype(jnp.float32)[:, :, None]  # [E, n, 1]
    return squeeze, interpret, e, n, d, d + pad_d, qp, scp, mp, sp


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def dequant_pallas(
    q: jax.Array,
    scale: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Standalone decode: ``q [n, d]`` (or ``[E, n, d]``) int8 codes +
    ``scale [n, 2]`` affine pairs -> guarded ``float32`` values.  This is the
    first stage of the *unfused* decode-then-screen pipeline the fused
    kernels are benchmarked against (it materializes the float32 tensor the
    fused path never writes)."""
    squeeze, interpret, e, n, d, dp, qp, sc, _, _ = _prep(
        q, scale, jnp.ones(q.shape[:-1], bool), None, block_d, interpret)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(e, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
            pl.BlockSpec((1, n, block_d // SCALE_BLOCK, 2), lambda ei, i: (ei, 0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
        out_shape=jax.ShapeDtypeStruct((e, n, dp), jnp.float32),
        interpret=interpret,
    )(qp, sc)
    out = out[:, :, :d]
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("b", "block_d", "interpret"))
def dequant_trimmed_mean_pallas(
    q: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    self_value: jax.Array,
    b: int,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused int8-codeword trimmed-mean screening (BRIDGE-T): dequantize each
    VMEM block and screen it in one pass — ``float32 [n, d]`` never exists."""
    squeeze, interpret, e, n, d, dp, qp, sc, mp, sp = _prep(
        q, scale, mask, self_value, block_d, interpret)
    out = pl.pallas_call(
        functools.partial(_fused_tm_kernel, b=b),
        grid=(e, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
            pl.BlockSpec((1, n, block_d // SCALE_BLOCK, 2), lambda ei, i: (ei, 0, i, 0)),
            pl.BlockSpec((1, n, 1), lambda ei, i: (ei, 0, 0)),
            pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        out_shape=jax.ShapeDtypeStruct((e, 1, dp), jnp.float32),
        interpret=interpret,
    )(qp, sc, mp, sp)
    out = out[:, 0, :d]
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def dequant_median_pallas(
    q: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    self_value: jax.Array,
    *,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused int8-codeword coordinate-median screening (BRIDGE-M) over
    N_j ∪ {j}; the self row joins uncompressed inside the kernel."""
    squeeze, interpret, e, n, d, dp, qp, sc, mp, sp = _prep(
        q, scale, mask, self_value, block_d, interpret)
    out = pl.pallas_call(
        _fused_med_kernel,
        grid=(e, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, n, block_d), lambda ei, i: (ei, 0, i)),
            pl.BlockSpec((1, n, block_d // SCALE_BLOCK, 2), lambda ei, i: (ei, 0, i, 0)),
            pl.BlockSpec((1, n, 1), lambda ei, i: (ei, 0, 0)),
            pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d), lambda ei, i: (ei, 0, i)),
        out_shape=jax.ShapeDtypeStruct((e, 1, dp), jnp.float32),
        interpret=interpret,
    )(qp, sc, mp, sp)
    out = out[:, 0, :d]
    return out[0] if squeeze else out
