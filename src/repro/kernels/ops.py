"""Public jit'd wrappers for the screening kernels.

``use_pallas`` selects the Pallas TPU path (interpret-mode on CPU) vs the
pure-jnp reference; both produce identical results — the dispatcher lets the
trainer flip implementations per platform/config.

Every entry point runs under a ``jax.named_scope`` (``kernels.<name>``) so
``jax.profiler`` captures (``--profile`` on the launch CLIs) attribute
device time to the kernel, not to an anonymous fusion.  Named scopes are
op-metadata only — they never change the computed values.
"""
from __future__ import annotations


import jax

from repro.kernels import ref
from repro.kernels.dequant_screen import (
    dequant_median_pallas,
    dequant_pallas,
    dequant_trimmed_mean_pallas,
)
from repro.kernels.krum import pairwise_sq_dists_pallas
from repro.kernels.median import median_pallas
from repro.kernels.trimmed_mean import trimmed_mean_pallas


def trimmed_mean(values, mask, self_value, b: int, *, use_pallas: bool = True, **kw):
    with jax.named_scope("kernels.trimmed_mean"):
        if use_pallas:
            return trimmed_mean_pallas(values, mask, self_value, b, **kw)
        return ref.trimmed_mean_ref(values, mask, self_value, b)


def median(values, mask, *, use_pallas: bool = True, **kw):
    with jax.named_scope("kernels.median"):
        if use_pallas:
            return median_pallas(values, mask, **kw)
        return ref.median_ref(values, mask)


def pairwise_sq_dists(stacked, *, use_pallas: bool = True, **kw):
    with jax.named_scope("kernels.pairwise_sq_dists"):
        if use_pallas:
            return pairwise_sq_dists_pallas(stacked, **kw)
        return ref.pairwise_sq_dists_ref(stacked)


def dequant(q, scale, *, use_pallas: bool = True, **kw):
    """Decode int8 codewords to float32 (stage 1 of the unfused pipeline)."""
    with jax.named_scope("kernels.dequant"):
        if use_pallas:
            return dequant_pallas(q, scale, **kw)
        return ref.dequant_ref(q, scale)


def dequant_trimmed_mean(q, scale, mask, self_value, b: int, *, use_pallas: bool = True, **kw):
    """Fused dequantize->trimmed-mean over int8 neighbor codewords."""
    with jax.named_scope("kernels.dequant_trimmed_mean"):
        if use_pallas:
            return dequant_trimmed_mean_pallas(q, scale, mask, self_value, b, **kw)
        return ref.dequant_trimmed_mean_ref(q, scale, mask, self_value, b)


def dequant_median(q, scale, mask, self_value, *, use_pallas: bool = True, **kw):
    """Fused dequantize->median over int8 neighbor codewords (self joins
    uncompressed)."""
    with jax.named_scope("kernels.dequant_median"):
        if use_pallas:
            return dequant_median_pallas(q, scale, mask, self_value, **kw)
        return ref.dequant_median_ref(q, scale, mask, self_value)


# ---------------------------------------------------------------------------
# static-analysis contracts (checked by `python -m repro.analysis`)
# ---------------------------------------------------------------------------

from repro.analysis.contracts import Contract  # noqa: E402  (dependency-light)

CONTRACTS: tuple[Contract, ...] = (
    Contract(
        "kernels.dispatch.ref_twin", "lint",
        "every public kernel dispatcher routes to BOTH a `_pallas` "
        "implementation and a `ref.` twin — the parity surface that lets "
        "interpret-mode CPU CI stand in for the TPU path",
        params=(("check", "kernel_ref_twins"), ("module", "repro.kernels.ops")),
    ),
)
