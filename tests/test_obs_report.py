"""`repro.obs.report` edge cases: partial or empty RUN_DIRs must render a
report (or exit with a one-line message), never stack-trace — the CLI runs
last in CI jobs, against whatever artifacts the run actually left behind."""
import json

import pytest

from repro.obs import report


def _write_summary(path, cells):
    with open(path, "w") as f:
        json.dump({"meta": {"kind": "test"}, "cells": cells}, f)


def _write_events(path, records):
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")


def test_empty_run_dir_exits_with_message(tmp_path):
    with pytest.raises(SystemExit, match="no obs_summary.json"):
        report.main([str(tmp_path)])


def test_summary_only_run_dir_renders(tmp_path, capsys):
    _write_summary(tmp_path / "obs_summary.json", [
        {"tag": "a", "rule": "median", "first_bad_tick": None,
         "survival": {"byz_trim_freq": 0.8, "honest_trim_freq": 0.1},
         "auc_byzantine_edges": 0.95,
         "top_edges": [{"trim_freq": 0.8, "receiver": 1, "sender": 2,
                        "seen": 10, "byzantine": True}]},
    ])
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    assert "cells traced: 1" in out
    assert "all traced cells stayed finite" in out
    assert "top 10 suspect edges" in out


def test_events_only_run_dir_renders(tmp_path, capsys):
    _write_events(tmp_path / "events.jsonl", [
        {"tag": "grid.chunk", "wall_s": 0.5},
        {"tag": "run.end"},  # no compile split recorded — must not KeyError
        {"tag": "obs.divergence", "cell": "c0", "first_bad_tick": 3},
    ])
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    assert "event stream / wall-time breakdown" in out
    assert "divergence events" in out
    # summary sections are simply absent, not broken
    assert "cells traced" not in out


def test_empty_cell_list_renders(tmp_path, capsys):
    _write_summary(tmp_path / "obs_summary.json", [])
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    assert "cells traced: 0" in out


def test_minimal_cells_without_optional_keys(tmp_path, capsys):
    # summarize() output varies with the spec (no senders -> no survival
    # split, no reservoir, ...): the renderer must take bare records
    _write_summary(tmp_path / "obs_summary.json", [
        {"first_bad_tick": 4},
        {"tag": "b"},
    ])
    report.main([str(tmp_path)])
    out = capsys.readouterr().out
    assert "cells traced: 2" in out
    assert "first_bad_tick" in out  # the sentinel table still renders
    assert "cell0" in out  # untagged cells get positional names


def test_out_flag_writes_report_file(tmp_path, capsys):
    _write_summary(tmp_path / "obs_summary.json", [])
    out_path = tmp_path / "report.txt"
    report.main([str(tmp_path), "--out", str(out_path)])
    assert out_path.read_text() == capsys.readouterr().out


def test_explicit_paths_override_run_dir(tmp_path, capsys):
    other = tmp_path / "elsewhere.json"
    _write_summary(other, [{"tag": "x"}])
    report.main(["--summary", str(other)])
    assert "cells traced: 1" in capsys.readouterr().out
