"""Extra screening rules (geometric median, centered clipping) and the
int8-quantized gossip: robustness + rank-preservation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BridgeConfig, BridgeTrainer, complete_graph, erdos_renyi, replicate, screen_all
from repro.core.gossip import _quantize_int8


def test_geomedian_resists_outliers():
    m, b = 15, 2
    topo = complete_graph(m, b)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.1, (m, 6)), jnp.float32)
    w = w.at[3].set(1e3).at[7].set(-1e3)
    honest = np.setdiff1d(np.arange(m), [3, 7])
    y = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule="geomedian", b=b))[honest]
    # geometric median stays near the honest cluster despite huge outliers
    assert np.abs(y).max() < 1.0


def test_clipped_mean_bounds_influence():
    m = 10
    topo = complete_graph(m, 1)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.1, (m, 4)), jnp.float32)
    w_attacked = w.at[2].set(1e4)
    y0 = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule="clipped_mean", b=1))
    y1 = np.asarray(screen_all(w_attacked, jnp.asarray(topo.adjacency), rule="clipped_mean", b=1))
    # a single byzantine neighbor swaps its clipped delta (norm <= tau) for
    # another (norm <= tau): output moves by at most 2*tau/|N_j|
    honest = [i for i in range(m) if i != 2]
    assert np.linalg.norm(y1[honest] - y0[honest], axis=1).max() <= 2.0 / 9 + 1e-5


@pytest.mark.parametrize("rule", ["geomedian", "clipped_mean"])
def test_extra_rules_train_quadratic(rule):
    m, b, d = 12, 2, 5
    topo = erdos_renyi(m, 0.8, b, seed=1)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)

    def grad_fn(params, batch):
        w, c = params["w"], batch
        return 0.5 * jnp.sum((w - c) ** 2), {"w": w - c}

    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=b, attack="random", t0=10)
    tr = BridgeTrainer(cfg, grad_fn)
    params = replicate({"w": jnp.zeros(d)}, m, perturb=0.1, key=jax.random.PRNGKey(0))
    st = tr.init(params)
    for _ in range(300):
        st, metrics = tr.step(st, targets)
    hm = np.asarray(tr.honest_mask)
    w_fin = np.asarray(st.params["w"])[hm].mean(0)
    t = np.asarray(targets)[hm]
    assert np.linalg.norm(w_fin - t.mean(0)) < 1.5
    assert float(metrics["consensus_dist"]) < 1.0


def test_int8_quantization_rank_preserving():
    """The gossip quantizer is monotone per chunk: sort order (and hence
    trimmed-mean/median survivor SETS) is preserved up to ties."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    q, scale = _quantize_int8(x)
    xq = q.astype(jnp.float32) * scale
    # order preserved where quantized values are distinct
    o1 = np.argsort(np.asarray(x), axis=0, kind="stable")
    o2 = np.argsort(np.asarray(xq), axis=0, kind="stable")
    qv = np.asarray(q)
    disagree = (np.take_along_axis(qv, o1, 0) != np.take_along_axis(qv, o2, 0))
    assert not disagree.any()
    # reconstruction error bounded by scale/2
    assert float(jnp.max(jnp.abs(xq - x))) <= float(scale) * 0.5 + 1e-6
