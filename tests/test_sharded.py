"""Multi-device tests (sharded gossip + mini dry-run), run in subprocesses so
XLA_FLAGS can force placeholder devices without polluting the main test
process (which must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_sharded_gossip_matches_reference():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import complete_graph, screen_all, gossip_screen_params
        from repro.core.bridge import stack_flatten
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,2), ("data","model"))
        M = 4
        topo = complete_graph(M, 1)
        adj = jnp.asarray(topo.adjacency)
        rng = np.random.default_rng(0)
        params = {"a": jnp.asarray(rng.normal(size=(M, 6, 8)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(M, 10)), jnp.float32)}
        specs = {"a": P("data", None, "model"), "b": P("data", "model")}
        sharded = {k: jax.device_put(v, NamedSharding(mesh, specs[k])) for k,v in params.items()}
        w, unflatten = stack_flatten(params)
        for rule in ["trimmed_mean", "median", "krum"]:
            ref = unflatten(screen_all(w, adj, rule=rule, b=1))
            scheds = ["all_gather", "all_to_all"] if rule != "krum" else ["all_gather"]
            for sched in scheds:
                out = gossip_screen_params(sharded, specs, mesh=mesh, node_axes="data",
                                           rule=rule, b=1, adjacency=adj, schedule=sched)
                err = max(float(jnp.max(jnp.abs(x-y))) for x,y in
                          zip(jax.tree.leaves(out), jax.tree.leaves(ref), strict=True))
                assert err < 1e-5, (rule, sched, err)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_byzantine_attack_screened():
    """Random attack rows injected on the sharded path are screened out."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import complete_graph, gossip_screen_params
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,1), ("data","model"))
        M = 8
        topo = complete_graph(M, 2)
        adj = jnp.asarray(topo.adjacency)
        rng = np.random.default_rng(0)
        params = {"a": jnp.asarray(rng.random((M, 16)), jnp.float32)}
        specs = {"a": P("data", "model")}
        byz = jnp.zeros((M,), bool).at[2].set(True).at[5].set(True)
        out = gossip_screen_params(params, specs, mesh=mesh, node_axes="data",
                                   rule="trimmed_mean", b=2, adjacency=adj,
                                   schedule="all_gather", byz_mask=byz, attack="random",
                                   key=jax.random.PRNGKey(0), t=3)
        honest = np.asarray(~byz)
        y = np.asarray(out["a"])[honest]
        hv = np.asarray(params["a"])[honest]
        assert (y >= hv.min(0)-1e-4).all() and (y <= hv.max(0)+1e-4).all()
        print("OK")
    """)
    assert "OK" in out


def test_mini_multipod_dryrun_lowers():
    """2x2x2 'multi-pod' mesh analog: train step for a reduced arch lowers,
    compiles, and contains node-axis collectives."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.shapes import InputShape, train_specs
        from repro.core.graph import complete_graph
        from repro.core.bridge import replicate
        from repro.launch import sharding
        from repro.launch.steps import make_train_step
        from repro.models import api as model_api

        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2,2,2), ("pod","data","model"))
        nax = ("pod","data")
        cfg = get_config("qwen3-4b").reduced()
        api = model_api.build(cfg)
        m = 4
        shape = InputShape("mini", 64, 8, "train")
        key = jax.random.PRNGKey(0)
        pshapes = jax.eval_shape(lambda k: replicate(api.init_params(k, cfg), m), key)
        pspecs = sharding.param_specs(cfg, pshapes, node_axes=nax)
        batch = train_specs(cfg, shape, m)
        bspecs = sharding.train_batch_specs(batch, nax)
        adj = jnp.asarray(complete_graph(m, 1).adjacency)
        step = make_train_step(cfg, mesh, nax, pspecs, adj, rule="trimmed_mean",
                               num_byzantine=1)
        in_sh = (sharding.named(mesh, pspecs), sharding.named(mesh, bspecs), None)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                pshapes, batch, jax.ShapeDtypeStruct((), jnp.float32))
            compiled = lowered.compile()
        txt = compiled.as_text()
        assert "all-gather" in txt or "all-reduce" in txt
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print("OK")
    """, devices=8)
    assert "OK" in out


def test_serve_step_lowers_with_cache_sharding():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.shapes import InputShape, decode_token_specs
        from repro.launch import sharding
        from repro.launch.steps import make_serve_step
        from repro.models import api as model_api

        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,2), ("data","model"))
        nax = ("data",)
        cfg = get_config("mistral-nemo-12b").reduced()
        api = model_api.build(cfg)
        shape = InputShape("mini_decode", 256, 8, "decode")
        key = jax.random.PRNGKey(0)
        pshapes = jax.eval_shape(lambda k: api.init_params(k, cfg), key)
        pspecs = sharding.param_specs(cfg, pshapes, node_axes=None)
        cshapes = jax.eval_shape(lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = sharding.cache_specs(cfg, cshapes, node_axes=nax, mesh=mesh,
                                      batch=shape.global_batch, seq_len=shape.seq_len)
        batch = decode_token_specs(cfg, shape)
        bspecs = sharding.serve_batch_specs(batch, nax, shape.global_batch, mesh)
        step = make_serve_step(cfg)
        in_sh = (sharding.named(mesh, pspecs), sharding.named(mesh, cspecs),
                 sharding.named(mesh, bspecs))
        with mesh:
            compiled = jax.jit(step, in_shardings=in_sh).lower(pshapes, cshapes, batch).compile()
        assert compiled.memory_analysis().temp_size_in_bytes >= 0
        print("OK")
    """, devices=8)
    assert "OK" in out
