"""Sparse neighbor-indexed state (ISSUE 5).

Contracts pinned here:

* **dense<->sparse bit-identity** — the neighbor-indexed ``[M, K]`` layout
  (`repro.core.neighbors`) reproduces the dense oracle bit-for-bit: at the
  screening level for every registered rule, and end-to-end (params AND loss
  traces) for rule x attack x codec grids on both the synchronous and the
  unreliable-network paths — the full registered product in the ``slow``
  tier, a representative subset in the default tier;
* **padded-row inertness** — widening the table beyond the max in-degree
  changes no output bit, and padded mailbox slots never leave `NEVER`;
* **NEVER-sentinel behavior at large tick counts** — `staleness` saturates
  instead of overflowing ``tick - NEVER``, `usable_mask` never resurrects an
  empty slot;
* **starved-tick degree clamp** (satellite bugfix) — `effective_trim` keeps
  the trimmed mean finite when a churn/partition tick drops the usable
  in-degree below Table II's ``2b + 1`` (the static `validate_for_rule`
  cannot see dynamic schedules), and stays bit-identical at or above it;
* the fused Pallas gather->screen kernels agree exactly with the staged
  jnp path, and the sparse jitted step's HLO contains no ``[M, M, d]``-scale
  tensor (`repro.launch.hlo_analysis.largest_tensor_bytes`).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate, screening
from repro.core.graph import random_geometric, small_world, toroidal_grid
from repro.core.neighbors import NeighborTable
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.net import mailbox as mb
from repro.net.dynamic import edge_churn
from repro.sim import ExperimentGrid, GridEngine
from repro.sim.engine import stack_batches

M, D, T = 10, 6, 5


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def topo():
    # dense enough for bulyan at b=1 (min degree 6) while degrees still vary
    for seed in range(1, 50):
        t = erdos_renyi(M, 0.8, 1, seed=seed)
        if t.min_in_degree >= 6 and len(set(t.in_degrees.tolist())) > 1:
            return t
    raise RuntimeError("no suitable fixture topology")


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


def _leaf_equal(x, y) -> bool:
    x, y = np.asarray(x), np.asarray(y)
    if x.dtype.kind == "f":
        # NaN == NaN positionally: the mean x garbage_codeword oracle cell
        # legitimately diverges to NaN (no screening, inf payloads) on BOTH
        # layouts, and jnp's == would call identical NaN trajectories unequal
        return bool(np.array_equal(x, y, equal_nan=True))
    return bool(np.array_equal(x, y))


def tree_bitwise_equal(a, b):
    return bool(jax.tree_util.tree_all(
        jax.tree_util.tree_map(_leaf_equal, a, b)))


# ---------------------------------------------------------------------------
# NeighborTable
# ---------------------------------------------------------------------------


def test_table_construction_and_gathers(topo):
    nbr = NeighborTable.from_adjacency(topo.adjacency)
    assert nbr.k == topo.in_degrees.max()
    for j in range(M):
        real = nbr.idx[j][nbr.valid[j]]
        np.testing.assert_array_equal(np.sort(real), np.nonzero(topo.adjacency[j])[0])
        assert (nbr.idx[j][~nbr.valid[j]] == M).all()  # sentinel index
    w = jnp.arange(M * D, dtype=jnp.float32).reshape(M, D)
    g = nbr.gather_rows(w)
    for j in range(M):
        for k in range(nbr.k):
            if nbr.valid[j, k]:
                assert bool(jnp.all(g[j, k] == w[nbr.idx[j, k]]))
    # schedule-union table covers churned edges
    sched = edge_churn(topo, 8, 0.4, seed=0)
    nbr_s = NeighborTable.from_schedule(sched)
    union = np.asarray(sched).any(axis=0)
    live = nbr_s.live_schedule(sched)
    assert live.shape == (8, M, nbr_s.k)
    assert live.sum() == np.asarray(sched).sum()
    assert nbr_s.valid.sum() == union.sum()


def test_sparse_flag_rejects_dense_runtime(topo):
    from repro.net.runtime import UnreliableRuntime

    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", sparse=True)
    with pytest.raises(ValueError, match="dense runtime"):
        BridgeTrainer(cfg, quad_grad_fn, runtime=UnreliableRuntime(topo))


def test_edge_id_grid_matches_table(topo):
    from repro.core.neighbors import edge_id_grid

    nbr = NeighborTable.from_adjacency(topo.adjacency)
    grid_ids = edge_id_grid(M)
    for j in range(M):
        for k in range(nbr.k):
            if nbr.valid[j, k]:
                assert int(nbr.edge_ids[j, k]) == int(grid_ids[j, nbr.idx[j, k]])


def test_table_rejects_undersized_k(topo):
    kmax = int(topo.in_degrees.max())
    with pytest.raises(ValueError):
        NeighborTable.from_adjacency(topo.adjacency, k=kmax - 1)


# ---------------------------------------------------------------------------
# screening-level bit-identity + padded inertness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(screening.RULES))
def test_screen_dense_sparse_bitwise(topo, rule):
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32) * 40)
    b = 1
    nbr = NeighborTable.from_adjacency(topo.adjacency)
    wide = NeighborTable.from_adjacency(topo.adjacency, k=nbr.k + 3)
    adj = jnp.asarray(topo.adjacency)
    dense = screening.screen_all_banked(w, adj, (rule,), 0, b)
    sparse = screening.screen_views_banked(
        nbr.gather_rows(w), nbr.valid_dev, w, (rule,), 0, b)
    padded = screening.screen_views_banked(
        wide.gather_rows(w), wide.valid_dev, w, (rule,), 0, b)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse),
                                  err_msg=f"dense vs sparse diverged for {rule}")
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(padded),
                                  err_msg=f"padded rows not inert for {rule}")


# ---------------------------------------------------------------------------
# end-to-end bit-identity: rule x attack x codec grids, dense vs sparse
# ---------------------------------------------------------------------------

ALL_RULES = tuple(sorted(screening.RULES))
ALL_ATTACKS = ("none", "random", "sign_flip", "same_value", "alie", "shift",
               "selective_victim", "garbage_codeword", "scale_abuse", "index_lie")
ALL_CODECS = ("identity", "int8", "int4", "topk25", "randk25", "topk25_int8")


def _run_grid(topo, batches, *, rules, attacks, codecs, sparse, scenarios=("lossy_laggy", "churn")):
    grid = ExperimentGrid(topo, rules, attacks, (1,), (0,), scenarios=scenarios,
                          codecs=codecs, lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, num_ticks=T if scenarios else None,
                        sparse=sparse)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    return final.params, metrics["loss"]


def _assert_grid_pair(topo, batches, **kw):
    p_dense, l_dense = _run_grid(topo, batches, sparse=False, **kw)
    p_sparse, l_sparse = _run_grid(topo, batches, sparse=True, **kw)
    assert tree_bitwise_equal(p_dense, p_sparse), f"params diverged for {kw}"
    np.testing.assert_array_equal(np.asarray(l_dense), np.asarray(l_sparse),
                                  err_msg=f"loss traces diverged for {kw}")


def test_grid_dense_sparse_bit_identity_smoke(topo, batches):
    """Default-tier subset: representative rules/attacks/codecs on the net
    path (mailboxes, churn, channel noise) AND the sync path."""
    _assert_grid_pair(topo, batches, rules=("trimmed_mean", "median"),
                      attacks=("random", "selective_victim"), codecs=("identity",))
    _assert_grid_pair(topo, batches, rules=("trimmed_mean",),
                      attacks=("alie", "garbage_codeword"), codecs=("int8",))
    _assert_grid_pair(topo, batches, rules=("trimmed_mean", "krum"),
                      attacks=("random",), codecs=("identity",), scenarios=None)


@pytest.mark.slow
def test_grid_dense_sparse_bit_identity_all_rules_attacks(topo, batches):
    """Every registered rule x every attack tier (identity codec), one
    grouped grid per layout — the full-product acceptance half 1."""
    _assert_grid_pair(topo, batches, rules=ALL_RULES, attacks=ALL_ATTACKS,
                      codecs=("identity",))


@pytest.mark.slow
def test_grid_dense_sparse_bit_identity_all_codecs(topo, batches):
    """Every registered codec family x iterate/wire attacks (trimmed mean +
    median) — the full-product acceptance half 2."""
    _assert_grid_pair(topo, batches, rules=("trimmed_mean", "median"),
                      attacks=("alie", "garbage_codeword", "scale_abuse", "index_lie"),
                      codecs=ALL_CODECS)


@pytest.mark.slow
def test_sync_grid_dense_sparse_bit_identity_all(topo, batches):
    """The synchronous-broadcast path over every rule x broadcast attack."""
    _assert_grid_pair(topo, batches, rules=ALL_RULES,
                      attacks=("none", "random", "sign_flip", "alie", "shift"),
                      codecs=("identity", "int8"), scenarios=None)


def test_trainer_dense_sparse_bit_identity_lossy_channel(topo, targets):
    """AsyncBridgeTrainer twins: drop + latency + churn + int8 codec."""
    sched = edge_churn(topo, 2 * T, 0.2, seed=3)
    outs = []
    for sparse in (False, True):
        cfg = AsyncBridgeConfig(
            topology=topo, rule="trimmed_mean", num_byzantine=1, attack="alie",
            codec="int8", channel=ChannelConfig(drop_prob=0.15, latency_max=2),
            staleness_bound=3, schedule=sched, lam=1.0, t0=10.0, sparse=sparse)
        tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
        st, ms = tr.run_ticks(tr.init(init_fn(0), seed=0), lambda i: targets, 2 * T)
        outs.append((st.params, ms["loss"], ms["delivered_frac"], ms["usable_in"]))
    assert tree_bitwise_equal(outs[0][0], outs[1][0])
    for a, b in zip(outs[0][1:], outs[1][1:], strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adversary_sparse_runtime_close(topo, targets):
    """Adaptive adversaries run on the sparse runtime; the inner-max ascent
    differentiates through a gather instead of a mask-select, so this pins
    allclose (bitwise holds for the attack/codec tiers above)."""
    outs = []
    for sparse in (False, True):
        cfg = AsyncBridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=1,
                                adversary="dissensus", lam=1.0, t0=10.0, sparse=sparse)
        tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
        st, ms = tr.run_ticks(tr.init(init_fn(0), seed=0), lambda i: targets, T)
        outs.append(np.asarray(st.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-6)


def test_padded_width_inert_end_to_end(topo, targets):
    """A runtime whose table is padded wider than the max in-degree is
    bit-identical to the tight one (padded slots never change any output),
    and its padded mailbox slots stay at NEVER forever."""
    from repro.net.runtime import SparseUnreliableRuntime

    sched = edge_churn(topo, T, 0.2, seed=5)
    outs, states = [], []
    for extra_k in (0, 4):
        nbr = NeighborTable.from_schedule(sched,
                                          k=NeighborTable.from_schedule(sched).k + extra_k)
        runtime = SparseUnreliableRuntime(sched, ChannelConfig(drop_prob=0.1),
                                          staleness_bound=3, neighbors=nbr)
        cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=1,
                           attack="random", lam=1.0, t0=10.0)
        tr = BridgeTrainer(cfg, quad_grad_fn, runtime=runtime)
        st = tr.init(init_fn(0), seed=0)
        for i in range(T):
            st, _ = tr.step(st, targets)
        outs.append(st.params)
        states.append((nbr, st.net))
    assert tree_bitwise_equal(outs[0], outs[1])
    nbr, net = states[1]
    pad = ~jnp.asarray(nbr.valid)
    assert bool(jnp.all(jnp.where(pad, net.send_tick, mb.NEVER) == mb.NEVER))
    assert bool(jnp.all(jnp.where(pad[..., None], net.ring_valid, False) == False))  # noqa: E712


# ---------------------------------------------------------------------------
# NEVER sentinel at large tick counts
# ---------------------------------------------------------------------------


def test_staleness_saturates_and_usable_mask_no_overflow():
    state = mb.init_mailbox(2, 3, max_delay=1, width=2)
    # one real delivery at tick 0 on slot (0, 0)
    msgs = jnp.ones((2, 2, 3))
    send = jnp.zeros((2, 2), bool).at[0, 0].set(True)
    state = mb.push(state, msgs, send, jnp.zeros((2, 2), jnp.int32), jnp.int32(0))
    state, arrived = mb.deliver(state, jnp.int32(0))
    assert bool(arrived[0, 0])
    for t in (5, 2**30, 2**31 - 2):  # far past the int32 overflow of t - NEVER
        tt = jnp.int32(t)
        stale = mb.staleness(state, tt)
        usable = mb.usable_mask(state, tt, bound=10)
        # empty slots: saturated staleness, never usable
        assert int(stale[1, 1]) == np.iinfo(np.int32).max
        assert not bool(usable[1, 1])
        # the real entry: exact staleness, usable iff within bound
        assert int(stale[0, 0]) == t
        assert bool(usable[0, 0]) == (t <= 10)


# ---------------------------------------------------------------------------
# starved-tick trim clamp (satellite bugfix)
# ---------------------------------------------------------------------------


def test_effective_trim_clamp():
    b = jnp.int32(2)
    assert int(screening.effective_trim(b, 5)) == 2  # at the 2b+1 bound
    assert int(screening.effective_trim(b, 7)) == 2  # above: identity
    assert int(screening.effective_trim(b, 4)) == 1  # starved: clamp
    assert int(screening.effective_trim(b, 1)) == 0
    assert int(screening.effective_trim(b, 0)) == 0


def test_trimmed_mean_starved_tick_stays_finite():
    """In-degree 1 with b=1 used to divide by count - 2b + 1 == 0 and sweep
    +inf sentinels into the window; the clamp degrades to an untrimmed mean
    over what's live instead."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, D)), jnp.float32)
    starved = jnp.zeros((4, 4), bool).at[0, 1].set(True).at[1, 0].set(True)
    starved = starved.at[2, 3].set(True).at[3, 2].set(True)
    y = screening.screen_all_banked(w, starved, ("trimmed_mean",), 0, 1)
    assert bool(jnp.all(jnp.isfinite(y)))
    # count=1, b_eff=0: mean of the single neighbor and self
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray((w[1] + w[0]) / 2.0),
                               rtol=1e-6)
    # and at/above the Table-II bound the clamp is the identity (bitwise)
    full = jnp.asarray(~np.eye(4, dtype=bool))
    y_full = screening.screen_all_banked(w, full, ("trimmed_mean",), 0, 1)
    order = jnp.sort(jnp.where(full[0][:, None], w, jnp.inf), axis=0)
    ref0 = (order[1] + w[0]) / 2.0  # 3 neighbors, trim 1 high 1 low, + self
    np.testing.assert_allclose(np.asarray(y_full[0]), np.asarray(ref0), rtol=1e-6)


def test_churn_below_min_degree_regression(topo, targets):
    """A churn schedule that dips live in-degree below 2b+1: training stays
    finite, and on starved ticks a node's update freezes to its own iterate
    (pure local SGD) — the runtime guard + clamp acting together."""
    sched = np.asarray(edge_churn(topo, 4 * T, 0.85, seed=9))  # heavy churn
    in_deg = sched.sum(axis=2)
    assert in_deg.min() < 3, "fixture must actually dip below 2b+1"
    for sparse in (False, True):
        cfg = AsyncBridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=1,
                                attack="random", schedule=sched, staleness_bound=0,
                                lam=1.0, t0=10.0, sparse=sparse)
        tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
        st, ms = tr.run_ticks(tr.init(init_fn(0), seed=0), lambda i: targets, 4 * T)
        assert bool(jnp.all(jnp.isfinite(st.params["w"]))), "params blew up under churn"
        assert np.isfinite(np.asarray(ms["loss"])).all()
        assert float(np.asarray(ms["screened_frac"]).min()) < 1.0  # freeze engaged


# ---------------------------------------------------------------------------
# fused Pallas gather->screen kernels + HLO layout assertion
# ---------------------------------------------------------------------------


def test_gather_screen_kernels_match_staged(topo):
    from repro.comm.codec import SCALE_BLOCK
    from repro.kernels.gather_screen import (
        gather_dequant_screen_pallas,
        gather_screen_pallas,
    )

    rng = np.random.default_rng(2)
    d = 300
    w = jnp.asarray(rng.normal(size=(M, d)).astype(np.float32) * 30)
    nbr = NeighborTable.from_adjacency(topo.adjacency)
    idx, valid = jnp.asarray(nbr.idx), nbr.valid_dev
    for rule in ("trimmed_mean", "median"):
        ref = screening.screen_views_banked(nbr.gather_rows(w), valid, w, (rule,), 0, 1)
        out = gather_screen_pallas(w, idx, valid, w, 1, rule=rule, block_d=128)
        # kernel blocks extract extrema iteratively (VPU-friendly) while the
        # jnp rule sorts — same survivors, different summation order, so the
        # comparison is allclose (the test_kernels convention)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)
    # int8 codeword variant vs gather + the existing dequant_screen kernels
    from repro.kernels.dequant_screen import dequant_trimmed_mean_pallas

    q = jnp.asarray(rng.integers(-128, 128, size=(M, d)).astype(np.int8))
    s = -(-d // SCALE_BLOCK)
    scale = jnp.asarray(np.stack([rng.uniform(0.01, 0.1, size=(M, s)),
                                  rng.uniform(-1, 1, size=(M, s))], -1), jnp.float32)
    staged = dequant_trimmed_mean_pallas(
        jnp.take(q, nbr.safe_idx, axis=0), jnp.take(scale, nbr.safe_idx, axis=0),
        valid, w, 1, block_d=128)
    fused = gather_dequant_screen_pallas(q, scale, idx, valid, w, 1,
                                         rule="trimmed_mean", block_d=128)
    np.testing.assert_array_equal(np.asarray(staged), np.asarray(fused))


@pytest.mark.slow
def test_sparse_step_hlo_has_no_dense_tensor():
    """The jitted sparse runtime step never materializes an [M, M, d]-scale
    tensor (scale_bench asserts the same at M = 512)."""
    from repro.launch import hlo_analysis

    m, d = 64, 256
    topo64 = small_world(m, 5, 1, seed=0)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)

    def gfn(params, batch):
        w = params["w"]
        return 0.5 * jnp.sum((w - batch) ** 2), {"w": w - batch}

    cfg = AsyncBridgeConfig(topology=topo64, rule="trimmed_mean", num_byzantine=1,
                            attack="alie", channel=ChannelConfig(drop_prob=0.1),
                            lam=1.0, t0=10.0, sparse=True)
    tr = AsyncBridgeTrainer(cfg, gfn)
    st = tr.init(replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                           key=jax.random.PRNGKey(0)), seed=0)
    text = jax.jit(tr._raw_step).lower(tr._cell, st, targets).compile().as_text()
    largest = hlo_analysis.largest_tensor_bytes(text)
    assert largest < m * m * d * 4, f"dense-scale tensor in sparse HLO: {largest}"


# ---------------------------------------------------------------------------
# large-graph topology builders
# ---------------------------------------------------------------------------


def test_large_topology_builders():
    sw = small_world(64, 4, 1, seed=0)
    assert sw.min_in_degree >= 3 and sw.in_degrees.max() <= 16
    # rewiring must never starve a node below the Table-II floor: at
    # nearest=3, b=2 the lattice degree (6) is exactly sufficient and every
    # rewire decrement is at risk of crossing 2b+1=5 (regression: the floor
    # check used to look at the lattice only)
    for seed in range(4):
        assert small_world(256, 3, 2, seed=seed).min_in_degree >= 5
    assert not np.asarray(sw.adjacency).diagonal().any()
    assert (sw.adjacency == sw.adjacency.T).all()
    geo = random_geometric(64, 1, seed=0)
    assert geo.min_in_degree >= 3
    tor = toroidal_grid(8, 8, 1)
    assert (tor.in_degrees == 4).all()
    tor8 = toroidal_grid(8, 8, 1, diagonal=True)
    assert (tor8.in_degrees == 8).all()
    from repro.core.graph import make_topology

    assert make_topology("small_world:4", 64, 1).num_nodes == 64
    assert make_topology("torus:8", 64, 1).num_nodes == 64
    with pytest.raises(ValueError):
        make_topology("nope", 8, 0)


def test_erdos_renyi_check_plumbing(monkeypatch):
    """check_samples reaches check_assumption4 (it was hardcoded to 25), and
    large M takes the degree-only fast path (no sampler call at all)."""
    import repro.core.graph as graph_lib

    calls = {}
    real = graph_lib.check_assumption4

    def spy(topo, *, num_samples=50, seed=0, byzantine_sets=None):
        calls["num_samples"] = num_samples
        return real(topo, num_samples=num_samples, seed=seed,
                    byzantine_sets=byzantine_sets)

    monkeypatch.setattr(graph_lib, "check_assumption4", spy)
    graph_lib.erdos_renyi(10, 0.8, 1, seed=0, check_samples=7)
    assert calls["num_samples"] == 7
    calls.clear()
    # degree-only fast path: the sampler must not run above DEGREE_ONLY_NODES
    topo = graph_lib.erdos_renyi(graph_lib.DEGREE_ONLY_NODES + 16, 0.3, 1, seed=0)
    assert calls == {}
    assert topo.min_in_degree > 2
    # explicit override forces sampling even at large M
    graph_lib.erdos_renyi(10, 0.8, 1, seed=0, assumption4="sampled")
    assert calls["num_samples"] == 50
