"""Data pipeline: synthetic MNIST-like, partitioners, token pipeline."""
import numpy as np

from repro.data import (
    TokenPipeline,
    make_mnist_like,
    partition_extreme_noniid,
    partition_iid,
    partition_moderate_noniid,
)
from repro.data.partition import stack_node_batches


def test_mnist_like_shapes_and_separability():
    x, y, xt, yt = make_mnist_like(2000, 400, seed=0)
    assert x.shape == (2000, 784) and y.shape == (2000,)
    # classes must be separable: nearest-class-mean accuracy well above chance
    means = np.stack([x[y == c].mean(0) for c in range(10)])
    d = ((xt[:, None] - means[None]) ** 2).sum(-1)
    acc = (d.argmin(1) == yt).mean()
    assert acc > 0.6, acc


def test_partition_iid_covers_all():
    x, y, *_ = make_mnist_like(1000, 10, seed=1)
    shards = partition_iid(x, y, 10)
    assert len(shards) == 10
    assert sum(len(s[1]) for s in shards) == 1000
    # every shard should see most classes
    assert all(len(np.unique(s[1])) >= 5 for s in shards)


def test_partition_extreme_single_label():
    x, y, *_ = make_mnist_like(2000, 10, seed=2)
    shards = partition_extreme_noniid(x, y, 10)
    for xs, ys in shards:
        assert len(np.unique(ys)) == 1


def test_partition_moderate_two_labels():
    x, y, *_ = make_mnist_like(2000, 10, seed=3)
    shards = partition_moderate_noniid(x, y, 10)
    counts = [len(np.unique(ys)) for _, ys in shards]
    assert max(counts) <= 2 and np.mean(counts) > 1.5


def test_stack_node_batches_shapes():
    x, y, *_ = make_mnist_like(500, 10, seed=4)
    shards = partition_iid(x, y, 5)
    fn = stack_node_batches(shards, 8)
    bx, by = fn(0)
    assert bx.shape == (5, 8, 784) and by.shape == (5, 8)


def test_token_pipeline_deterministic_and_structured():
    pipe = TokenPipeline(vocab_size=512, seq_len=32, batch_per_node=4, num_nodes=3, seed=7)
    b1 = pipe.batch(0)
    b2 = pipe.batch(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (3, 4, 33)
    b3 = pipe.batch(1)
    assert (b1["tokens"] != b3["tokens"]).any()
    assert b1["tokens"].max() < 512
