"""repro.obs: trace inertness, forensics quality, and the divergence sentinel.

The obs contract (ISSUE 6 acceptance):
* (a) tracing is BIT-INERT — params and metric streams with
  ``TraceSpec`` on are bitwise equal to the untraced run, across
  rule x attack x codec, sync + net paths, dense + sparse layouts,
  aggregate + reservoir modes, and ``decide_stride`` subsampling;
* (b) tracing OFF is structurally absent — ``state.obs is None`` and no obs
  metric streams appear;
* (c) the per-edge trim-frequency counters rank true Byzantine in-edges
  above honest edges (Mann-Whitney AUC);
* (d) the NaN sentinel locates the first non-finite tick, end-to-end through
  `BreakdownEngine` (divergence is *located*, not inferred from NaN soup);
plus unit coverage of the decision twins, the aggregate folds, and the
forensics/streaming collision guard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adversary.breakdown import BreakdownConfig, BreakdownEngine
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate, screening
from repro.core.bridge import stack_batches
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.obs import EventLog, TraceSpec, read_events
from repro.obs import trace as obs_trace
from repro.sim import ExperimentGrid, GridEngine

M, D, T = 12, 5, 25


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


def _sync_run(topo, targets, *, rule="trimmed_mean", attack="alie",
              codec="identity", sparse=False, trace=None, ticks=T, b=2):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=b, attack=attack,
                       codec=codec, sparse=sparse, trace=trace, lam=1.0, t0=10.0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    streams = {"loss": [], "consensus_dist": []}
    for _ in range(ticks):
        st, m = tr.step(st, targets)
        for k in streams:
            streams[k].append(m[k])
    return tr, st, {k: np.asarray(jnp.stack(v)) for k, v in streams.items()}


def _net_run(topo, batches, *, sparse, trace=None):
    cfg = AsyncBridgeConfig(
        topology=topo, rule="trimmed_mean", num_byzantine=2, attack="alie",
        channel=ChannelConfig(drop_prob=0.1), staleness_bound=2,
        lam=1.0, t0=10.0, sparse=sparse, trace=trace)
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, metrics = tr.run_scan(st, batches)
    return tr, st, metrics


# ---------------------------------------------------------------------------
# (a) bit-inertness: traced trajectory == untraced trajectory, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule,attack,codec,sparse,b", [
    ("trimmed_mean", "alie", "identity", False, 2),
    ("trimmed_mean", "sign_flip", "int8", False, 2),
    ("median", "alie", "identity", True, 2),
    ("krum", "random", "identity", False, 2),
    # bulyan needs in-degree >= 4b+1 > this graph's 6; its twin is covered
    # bitwise by test_decision_twins_match_plain_rules
])
def test_sync_trace_bit_inert(topo, targets, rule, attack, codec, sparse, b):
    """Aggregates + reservoir compiled into the step change NOTHING about the
    trajectory — params and metric streams are bitwise equal."""
    spec = TraceSpec(reservoir=3, stride=8)
    _, st_off, ms_off = _sync_run(topo, targets, rule=rule, attack=attack,
                                  codec=codec, sparse=sparse, trace=None, b=b)
    tr, st_on, ms_on = _sync_run(topo, targets, rule=rule, attack=attack,
                                 codec=codec, sparse=sparse, trace=spec, b=b)
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    for k in ms_off:
        np.testing.assert_array_equal(ms_off[k], ms_on[k],
                                      err_msg=f"metric {k} diverged under tracing")
    # and the aggregates actually observed the run
    assert st_off.obs is None
    assert float(jnp.sum(st_on.obs.edge_seen)) > 0
    assert float(jnp.sum(st_on.obs.bits_hist)) > 0  # wire-bits binned
    summary = obs_trace.summarize(spec, st_on.obs, byz_mask=np.asarray(tr.byz_mask))
    assert set(summary["reservoir"]["ticks"]) == {8, 16, 24}


@pytest.mark.parametrize("stride", [2, 5])
def test_decide_stride_still_bit_inert(topo, targets, stride):
    """Coordinate-subsampled membership (`decide_stride` > 1) trades counter
    variance only — the aggregate y stays exact, so the trajectory stays
    bitwise equal and the counters still accumulate."""
    _, st_off, ms_off = _sync_run(topo, targets, sparse=True, trace=None)
    _, st_on, ms_on = _sync_run(topo, targets, sparse=True,
                                trace=TraceSpec(decide_stride=stride))
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    np.testing.assert_array_equal(ms_off["loss"], ms_on["loss"])
    assert float(jnp.sum(st_on.obs.edge_trim)) > 0


@pytest.mark.parametrize("sparse", [False, True])
def test_net_trace_bit_inert(topo, batches, sparse):
    """The network-runtime path (drops, staleness, mailboxes): traced run is
    bitwise the untraced one, and the staleness histogram fills."""
    _, st_off, ms_off = _net_run(topo, batches, sparse=sparse, trace=None)
    _, st_on, ms_on = _net_run(topo, batches, sparse=sparse, trace=TraceSpec())
    np.testing.assert_array_equal(np.asarray(st_off.params["w"]),
                                  np.asarray(st_on.params["w"]))
    np.testing.assert_array_equal(np.asarray(ms_off["loss"]),
                                  np.asarray(ms_on["loss"]))
    assert st_off.obs is None
    assert float(jnp.sum(st_on.obs.stale_hist)) > 0


def test_grid_trace_bit_inert_and_stacked(topo, batches):
    """The batched grid engine: an engine-wide spec stacks obs over [E]
    without perturbing any cell's trajectory."""
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("alie",), (2,),
                          (0, 1), lam=1.0, t0=10.0)
    spec = TraceSpec()
    eng_off = GridEngine(grid, quad_grad_fn)
    fin_off, ms_off = eng_off.run(eng_off.init(init_fn), batches)
    eng_on = GridEngine(grid, quad_grad_fn, trace=spec)
    fin_on, ms_on = eng_on.run(eng_on.init(init_fn), batches)
    np.testing.assert_array_equal(np.asarray(fin_off.params["w"]),
                                  np.asarray(fin_on.params["w"]))
    np.testing.assert_array_equal(np.asarray(ms_off["loss"]),
                                  np.asarray(ms_on["loss"]))
    assert fin_on.obs.edge_seen.shape == (eng_on.num_cells, M, M)
    senders = eng_on.sender_grid()
    for i in range(eng_on.num_cells):
        obs_i = jax.tree_util.tree_map(lambda leaf: leaf[i], fin_on.obs)
        s = obs_trace.summarize(spec, obs_i, byz_mask=eng_on.byz_masks[i],
                                senders=senders)
        assert s["auc_byzantine_edges"] is not None


# ---------------------------------------------------------------------------
# decision twins: same y op graph as the plain rules, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", sorted(screening.RULES_WITH_DECISIONS))
@pytest.mark.parametrize("stride", [1, 3])
def test_decision_twins_match_plain_rules(rule, stride):
    rng = np.random.default_rng(7)
    n, d, b = 9, 6, 2
    v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.8).at[: 2 * b + 1].set(True)
    sv = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    y_plain = screening.RULES[rule](v, mask, sv, b)
    y_twin, trim = screening.RULES_WITH_DECISIONS[rule](
        v, mask, sv, b, decide_stride=stride)
    np.testing.assert_array_equal(np.asarray(y_plain), np.asarray(y_twin),
                                  err_msg=f"{rule} twin y diverged from plain rule")
    assert trim.shape == (n,)
    t = np.asarray(trim)
    assert np.all((t >= 0) & (t <= 1))
    assert np.all(t[~np.asarray(mask)] == 0)  # dead edges never counted


# ---------------------------------------------------------------------------
# (b) off = structurally absent; forensics/streaming collision is loud
# ---------------------------------------------------------------------------


def test_trace_off_is_structurally_absent(topo, targets):
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    assert st.obs is None
    st, metrics = tr.step(st, targets)
    assert st.obs is None
    assert "obs_trim_frac" not in metrics


def test_check_decide_streams_raises(topo, targets):
    with pytest.raises(ValueError, match="forensics"):
        screening.check_decide_streams(["trimmed_mean"], d=100, chunk=10)
    # krum never streams coordinates -> no collision
    screening.check_decide_streams(["krum"], d=100, chunk=10)
    # end-to-end: forensics where streaming would engage fails at trace time
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0, screen_chunk=2,
                       trace=TraceSpec())
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    with pytest.raises(ValueError, match="forensics"):
        tr.step(st, targets)


def test_trace_spec_validation():
    with pytest.raises(ValueError, match="TraceSpec"):
        TraceSpec(decide_stride=0)
    with pytest.raises(ValueError, match="TraceSpec"):
        TraceSpec(reservoir=-1)
    with pytest.raises(ValueError, match="TraceSpec"):
        TraceSpec(stride=0)


# ---------------------------------------------------------------------------
# (c) forensics quality: counters rank Byzantine in-edges
# ---------------------------------------------------------------------------


def test_trim_counters_rank_byzantine_edges(topo, targets):
    spec = TraceSpec()
    tr, st, _ = _sync_run(topo, targets, trace=spec)
    senders = obs_trace.sender_grid(M, adjacency=topo.adjacency)
    summary = obs_trace.summarize(spec, st.obs, byz_mask=np.asarray(tr.byz_mask),
                                  senders=senders)
    assert summary["auc_byzantine_edges"] >= 0.7
    sv = summary["survival"]
    assert sv["byz_trim_freq"] > sv["honest_trim_freq"]
    # the suspicion ranking leads with a true Byzantine sender
    assert summary["top_edges"][0]["byzantine"] is True


def test_ranking_auc():
    assert obs_trace.ranking_auc([0.9, 0.8, 0.1, 0.2], [1, 1, 0, 0]) == 1.0
    assert obs_trace.ranking_auc([0.1, 0.2, 0.9, 0.8], [1, 1, 0, 0]) == 0.0
    assert obs_trace.ranking_auc([0.5, 0.5, 0.5, 0.5], [1, 1, 0, 0]) == 0.5
    assert obs_trace.ranking_auc([0.5, 0.5], [1, 1]) is None  # one-class


# ---------------------------------------------------------------------------
# aggregate folds: histograms, reservoir round-robin, EMA
# ---------------------------------------------------------------------------


def test_update_folds_histograms_and_reservoir():
    spec = TraceSpec(reservoir=2, stride=2, hist_bins=4, stale_max=8, ema=0.5)
    st = obs_trace.init_state(spec, 3, 3)
    live = jnp.ones((3, 3), bool)
    byz = jnp.zeros((3, 3), bool).at[:, 0].set(True)
    trim = jnp.where(byz, 0.9, 0.1)
    for t in range(6):
        st = obs_trace.update(
            spec, st, t=t, loss=float(t), consensus=0.0, trim_frac=trim,
            live=live, byz_edge=byz, staleness=jnp.full((3, 3), 5),
            wire_bits=8 * D, d=D, live_edges=9.0)
    # staleness 5 with bin width ceil(8/4)=2 -> bin 2, 9 live edges x 6 ticks
    np.testing.assert_array_equal(np.asarray(st.stale_hist), [0, 0, 54, 0])
    assert float(jnp.sum(st.bits_hist)) == 54.0  # 9 edges x 6 ticks
    # slots written at t=0,2,4 round-robin over 2 -> final ticks {4, 2}
    assert set(np.asarray(st.res_tick).tolist()) == {4, 2}
    # EMA: l_0 = 0, then l_t = 0.5 l_{t-1} + 0.5 t  ->  l_5 = 4.03125
    assert float(st.loss_trace) == pytest.approx(4.03125)
    assert float(st.byz_trim) == pytest.approx(0.9 * 3 * 6)
    assert float(st.hon_trim) == pytest.approx(0.1 * 6 * 6)
    assert int(st.first_bad) == -1


# ---------------------------------------------------------------------------
# (d) divergence sentinel: first bad tick, end-to-end
# ---------------------------------------------------------------------------


def test_sentinel_locates_first_bad_tick(topo, targets):
    bad_at = 7

    def batch_fn(i):
        return jnp.full_like(targets, jnp.inf) if i == bad_at else targets

    spec = TraceSpec(forensics=False, sentinel=True)
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="alie", lam=1.0, t0=10.0, trace=spec)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    for i in range(T):
        st, _ = tr.step(st, batch_fn(i))
    assert int(st.obs.first_bad) == bad_at  # first, not last, non-finite tick
    assert obs_trace.summarize(spec, st.obs)["first_bad_tick"] == bad_at


def test_breakdown_engine_locates_divergence(topo, batches, tmp_path):
    """Regression: `BreakdownEngine`'s default sentinel-only trace records
    WHEN each diverging probe went non-finite and emits ``obs.divergence``
    events, instead of reporting an opaque NaN final loss."""

    def unstable_grad_fn(params, batch):
        # effective step size ~1e3 >> 2: the quadratic iteration overflows
        # f32 within a few ticks, the divergence the sentinel must date
        w, c = params["w"], batch
        loss = 0.5e4 * jnp.sum((w - c) ** 2)
        return loss, {"w": 1e4 * (w - c)}

    events_path = tmp_path / "events.jsonl"
    cfg = BreakdownConfig(mode="ladder", seeds=(0,), b_max=2)
    with EventLog(str(events_path)) as ev:
        eng = BreakdownEngine(topo, ("trimmed_mean",), ("random",),
                              unstable_grad_fn, init_fn, batches,
                              lam=1.0, t0=10.0, config=cfg, events=ev)
        eng.run()
    for key, rec in eng.probes.items():
        assert not rec["finite"], key
        assert rec["first_bad_tick"] is not None and 0 <= rec["first_bad_tick"] < T
    names = [e["tag"] for e in read_events(str(events_path))]
    assert "obs.divergence" in names
