"""Loop-aware HLO analyzer: trip-count multiplication and collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jnp.zeros((64, 64))
    w = jnp.zeros((10, 64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = H.analyze(compiled.as_text())
    expect = 10 * 2 * 64**3
    assert 0.95 * expect < cost.flops < 1.2 * expect


def test_nested_scan_multiplied():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    x = jnp.zeros((32, 32))
    w = jnp.zeros((5, 32, 32))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = H.analyze(compiled.as_text())
    expect = 5 * 3 * 2 * 32**3
    assert 0.9 * expect < cost.flops < 1.3 * expect


def test_dot_flops_exact():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((128, 256)), jnp.zeros((256, 64))).compile()
    cost = H.analyze(compiled.as_text())
    expect = 2 * 128 * 256 * 64
    assert abs(cost.flops - expect) / expect < 0.05


def test_roofline_terms():
    c = H.Cost(flops=197e12, bytes=819e9, coll_wire=50e9)
    rl = H.roofline_from_cost(c)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 1.0) < 1e-9
    assert rl.step_time_s == pytest.approx(1.0)


def test_parse_handles_tuple_comments():
    text = """
HloModule test

ENTRY %main (p: f32[4]) -> (f32[4], s32[]) {
  %p = f32[4]{0} parameter(0)
  %c = s32[] constant(3)
  ROOT %t = (f32[4]{0}, /*index=1*/s32[]) tuple(%p, %c)
}
"""
    comps = H.parse_hlo(text)
    assert "main" in comps
    ops = [i.opcode for i in comps["main"]]
    assert "tuple" in ops
