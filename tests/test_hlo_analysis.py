"""Loop-aware HLO analyzer: trip-count multiplication and collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jnp.zeros((64, 64))
    w = jnp.zeros((10, 64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = H.analyze(compiled.as_text())
    expect = 10 * 2 * 64**3
    assert 0.95 * expect < cost.flops < 1.2 * expect


def test_nested_scan_multiplied():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    x = jnp.zeros((32, 32))
    w = jnp.zeros((5, 32, 32))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = H.analyze(compiled.as_text())
    expect = 5 * 3 * 2 * 32**3
    assert 0.9 * expect < cost.flops < 1.3 * expect


def test_dot_flops_exact():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((128, 256)), jnp.zeros((256, 64))).compile()
    cost = H.analyze(compiled.as_text())
    expect = 2 * 128 * 256 * 64
    assert abs(cost.flops - expect) / expect < 0.05


def test_roofline_terms():
    c = H.Cost(flops=197e12, bytes=819e9, coll_wire=50e9)
    rl = H.roofline_from_cost(c)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 1.0) < 1e-9
    assert abs(rl.collective_s - 1.0) < 1e-9
    assert rl.step_time_s == pytest.approx(1.0)


def test_parse_handles_tuple_comments():
    text = """
HloModule test

ENTRY %main (p: f32[4]) -> (f32[4], s32[]) {
  %p = f32[4]{0} parameter(0)
  %c = s32[] constant(3)
  ROOT %t = (f32[4]{0}, /*index=1*/s32[]) tuple(%p, %c)
}
"""
    comps = H.parse_hlo(text)
    assert "main" in comps
    ops = [i.opcode for i in comps["main"]]
    assert "tuple" in ops


# ---------------------------------------------------------------------------
# golden-text tests for the catalog helpers (while_loops / donated_params /
# largest_tensors) feeding the static-analysis passes
# ---------------------------------------------------------------------------

GOLDEN_WHILE = """
HloModule golden

%cond (arg: (s32[], f32[4])) -> pred[] {
  %arg = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (barg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %barg = (s32[], f32[4]{0}) parameter(0)
  %j = s32[] get-tuple-element(%barg), index=0
  %one = s32[] constant(1)
  %j1 = s32[] add(%j, %one)
  %v = f32[4]{0} get-tuple-element(%barg), index=1
  ROOT %out = (s32[], f32[4]{0}) tuple(%j1, %v)
}

ENTRY %main (p: f32[4]) -> (s32[], f32[4]) {
  %p = f32[4]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[4]{0}) tuple(%z, %p)
  ROOT %w = (s32[], f32[4]{0}) while(%init), condition=%cond, body=%body
}
"""


def test_while_loops_golden_trip_count():
    loops = H.while_loops(GOLDEN_WHILE)
    assert len(loops) == 1
    assert loops[0].trip_count == 7
    assert "s32[]" in loops[0].carry_type


def test_while_loops_real_scan_trip_counts():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci * 1.0001 + 1.0, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    text = jax.jit(f).lower(jnp.zeros((4,))).compile().as_text()
    trips = sorted(w.trip_count for w in H.while_loops(text))
    assert trips == [3, 5]


def test_largest_tensors_golden_dtype_table():
    text = """
HloModule sizes

ENTRY %main (a: f32[12,9,16]) -> bf16[100] {
  %a = f32[12,9,16]{2,1,0} parameter(0)
  %b = s8[12,12,16]{2,1,0} constant(0)
  %p = pred[64]{0} constant(0)
  ROOT %r = bf16[100]{0} constant(0)
}
"""
    top = H.largest_tensors(text, top=4)
    # f32[12,9,16]=6912 > s8[12,12,16]=2304 > bf16[100]=200 > pred[64]=64
    assert [(b, dt) for b, dt, _ in top] == [
        (6912, "f32"), (2304, "s8"), (200, "bf16"), (64, "pred")]
    assert top[0][2] == (12, 9, 16)
    assert H.largest_tensor_bytes(text) == 6912


def test_collective_wire_multipliers_golden():
    text = """
HloModule coll

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%a), to_apply=%add
}

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""
    cost = H.analyze(text)
    # ring all-reduce moves ~2x the operand bytes over the wire
    assert cost.coll_wire == pytest.approx(2.0 * 1024 * 4)

    gathered = text.replace(
        "ROOT %ar = f32[1024]{0} all-reduce(%a), to_apply=%add",
        "ROOT %ag = f32[4096]{0} all-gather(%a), dimensions={0}")
    cost = H.analyze(gathered)
    # all-gather is counted on RESULT bytes with a 1x multiplier
    assert cost.coll_wire == pytest.approx(4096 * 4)


GOLDEN_ALIAS_HEADER = (
    "HloModule chunk, input_output_alias={ {0}: (4, {}, may-alias), "
    "{1}: (2, {}, may-alias), {2, 1}: (3, {}, must-alias) }, "
    "entry_computation_layout={(f32[4])->f32[4]}\n\n"
    "ENTRY %main (p: f32[4]) -> f32[4] {\n"
    "  ROOT %p = f32[4]{0} parameter(0)\n"
    "}\n"
)


def test_donated_params_golden():
    pairs = H.donated_params(GOLDEN_ALIAS_HEADER)
    assert ((0,), 4) in pairs
    assert ((1,), 2) in pairs
    assert ((2, 1), 3) in pairs
    assert len(pairs) == 3


def test_donated_params_absent_when_no_donation():
    text = jax.jit(lambda x: x + 1.0).lower(jnp.zeros((4,))).compile().as_text()
    assert H.donated_params(text) == []


def test_donated_params_real_donation():
    from repro.analysis.hlo import donation_supported

    if not donation_supported():
        pytest.skip("backend drops donations; aliasing table never emitted")
    text = (jax.jit(lambda x: x * 2.0, donate_argnums=(0,))
            .lower(jnp.zeros((8,), jnp.float32)).compile().as_text())
    assert H.donated_params(text) != []
