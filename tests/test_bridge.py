"""BridgeTrainer behaviour: consensus, resilience, baselines.

These are the paper's central claims at test scale:
* Theorem 1 — honest nodes reach consensus;
* Theorem 2 — iterates approach the (statistical) optimum;
* Sec. V — DGD breaks under attack, BRIDGE variants survive.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BrdsoConfig,
    BrdsoTrainer,
    BridgeConfig,
    BridgeTrainer,
    ByrdieConfig,
    ByrdieTrainer,
    erdos_renyi,
    replicate,
)

M, B_BYZ, D = 12, 2, 5


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, B_BYZ, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def _run(topo, targets, rule, attack, steps=250, b=B_BYZ):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=b, attack=attack,
                       lam=1.0, t0=10)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    params = replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(0))
    st = tr.init(params)
    for _ in range(steps):
        st, m = tr.step(st, targets)
    return tr, st, m


@pytest.mark.parametrize("rule", ["trimmed_mean", "median", "krum"])
def test_consensus_under_attack(topo, targets, rule):
    """Theorem 1: honest nodes' iterates converge to each other."""
    tr, st, m = _run(topo, targets, rule, "random")
    assert float(m["consensus_dist"]) < 0.15


def test_convergence_near_honest_optimum(topo, targets):
    """Theorem 2 (qualitative): the consensus point lies in the convex hull
    neighborhood of honest nodes' optima."""
    tr, st, m = _run(topo, targets, "trimmed_mean", "random", steps=400)
    hm = np.asarray(tr.honest_mask)
    w_fin = np.asarray(st.params["w"])[hm].mean(0)
    t = np.asarray(targets)[hm]
    assert (w_fin > t.min(0) - 0.3).all() and (w_fin < t.max(0) + 0.3).all()
    # and reasonably close to the honest mean (the faultless optimum)
    assert np.linalg.norm(w_fin - t.mean(0)) < 0.8


def test_dgd_fails_bridge_survives(topo, targets):
    """Sec. V headline: classic DGD collapses under Byzantine attack while
    BRIDGE-T keeps training."""
    _, st_dgd, m_dgd = _run(topo, targets, "mean", "random")
    _, st_brt, m_brt = _run(topo, targets, "trimmed_mean", "random")
    assert float(m_brt["loss"]) < 0.5 * float(m_dgd["loss"])


def test_faultless_bridge_matches_dgd(topo, targets):
    """Fig. 1: with no faults, BRIDGE-T performs about as well as DGD."""
    _, _, m_dgd = _run(topo, targets, "mean", "none", b=0)
    _, _, m_brt = _run(topo, targets, "trimmed_mean", "none", b=1)
    assert float(m_brt["loss"]) < float(m_dgd["loss"]) * 1.5 + 0.2


def _honest_optimal_loss(tr, targets):
    """Best achievable consensus loss: 0.5 * mean_j ||c_j - c_bar||^2."""
    hm = np.asarray(~tr.byz_mask)
    t = np.asarray(targets)[hm]
    c = t.mean(0)
    return 0.5 * float(np.mean(np.sum((t - c) ** 2, axis=1)))


def test_byrdie_sweep_and_accounting(topo, targets):
    cfg = ByrdieConfig(topology=topo, num_byzantine=B_BYZ, attack="random", block=2, t0=10)
    tr = ByrdieTrainer(cfg, quad_grad_fn)
    params = replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(0))
    st = tr.init(params)
    for _ in range(40):
        st, m = tr.sweep(st, targets)
    assert float(m["scalars_sent"]) == 40 * D  # one scalar broadcast per coord per sweep
    assert float(m["loss"]) < _honest_optimal_loss(tr, targets) + 1.0


def test_brdso_step(topo, targets):
    cfg = BrdsoConfig(topology=topo, num_byzantine=B_BYZ, attack="random", lam0=0.1, t0=10)
    tr = BrdsoTrainer(cfg, quad_grad_fn)
    params = replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(0))
    st = tr.init(params)
    for _ in range(300):
        st, m = tr.step(st, targets)
    assert float(m["loss"]) < _honest_optimal_loss(tr, targets) + 1.0
    # BRDSO's TV penalty enforces consensus only up to O(rho*lam0) — much
    # looser than BRIDGE's screening-averaging (one of the paper's points).
    assert float(m["consensus_dist"]) < 3.0


@pytest.mark.parametrize("attack", ["sign_flip", "same_value", "alie", "shift"])
def test_attack_zoo_resilience(topo, targets, attack):
    tr, st, m = _run(topo, targets, "trimmed_mean", attack, steps=300)
    hm = np.asarray(tr.honest_mask)
    w_fin = np.asarray(st.params["w"])[hm].mean(0)
    t = np.asarray(targets)[hm]
    assert np.linalg.norm(w_fin - t.mean(0)) < 1.5


def test_step_size_schedule(topo):
    cfg = BridgeConfig(topology=topo, lam=2.0, t0=10)
    assert abs(float(cfg.step_size(0)) - 1 / 20) < 1e-6
    assert float(cfg.step_size(10)) < float(cfg.step_size(0))
