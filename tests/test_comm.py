"""repro.comm: compressed Byzantine-resilient exchange.

The subsystem's contract surface (ISSUE 3 acceptance):
* codec round-trip properties — identity is an exact bitcast (including
  ``-0.0``), stochastic quantizers are mean-preserving and step-bounded,
  sparsifiers keep exactly k coordinates;
* exact bits-on-wire accounting (int8+top-k >= 4x under paper-scale d);
* banked ``lax.switch`` dispatch == dedicated codec, bit-for-bit;
* error-feedback residuals stay bounded and compressed BRIDGE converges
  next to the uncompressed trainer;
* identity-codec runs are bit-identical to the uncompressed
  `BridgeTrainer` / `GridEngine`, and a codec x rule x attack grid still
  compiles ONCE;
* compressed-domain attacks (garbage codewords, quant-scale abuse, sparse
  index lies) are decoded and *screened*;
* `repro.net` charges serialization latency from ``wire_bits`` and samples
  bandwidth-cap survivors from the per-tick PRNG (regression: the old
  deterministic prefix mask starved high-index coordinates);
* fused Pallas dequant->screen kernels == decode-then-screen references;
* `benchmarks.check_regression` per-file re-baselining + missing-baseline
  warn-not-fail.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommState, codec_bank, decode_bank, encode_bank, get_codec, wire_bits_bank
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.core import byzantine as byz_lib
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig, UnreliableRuntime
from repro.sim import ExperimentGrid, GridEngine
from repro.sim.engine import stack_batches

M, D, T = 12, 5, 20


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


# ---------------------------------------------------------------------------
# Codec round-trip properties
# ---------------------------------------------------------------------------


def test_identity_codec_is_exact_bitcast():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(7, 130)), jnp.float32)
    x = x.at[0, 0].set(-0.0).at[1, 1].set(jnp.inf)  # bit-level corner cases
    c = get_codec("identity")
    out = c.decode(c.encode(jax.random.PRNGKey(0), x), 130)
    np.testing.assert_array_equal(
        np.asarray(x).view(np.uint32), np.asarray(out).view(np.uint32))
    assert c.lossless and c.wire_bits(130) == 32 * 130


@pytest.mark.parametrize("name,levels", [("int8", 127), ("int4", 7)])
def test_quantizer_step_bound_and_unbiasedness(name, levels):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 96)), jnp.float32)
    c = get_codec(name)
    step = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True)) / levels
    # per-draw error never exceeds one quantization step
    out = c.decode(c.encode(jax.random.PRNGKey(0), x), 96)
    assert np.all(np.abs(np.asarray(out - x)) <= step + 1e-6)
    # stochastic rounding is mean-preserving: the average over keys
    # approaches x much closer than any deterministic rounding bias could
    outs = jnp.stack([
        c.decode(c.encode(jax.random.PRNGKey(i), x), 96) for i in range(400)
    ])
    bias = np.abs(np.asarray(outs.mean(0) - x))
    assert np.max(bias / step) < 0.25


@pytest.mark.parametrize("name", ["topk25", "randk25", "topk25_int8"])
def test_sparse_codecs_keep_exactly_k(name):
    rng = np.random.default_rng(2)
    d = 120
    x = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    c = get_codec(name)
    k = c.kept(d)
    assert k == 30
    out = np.asarray(c.decode(c.encode(jax.random.PRNGKey(0), x), d))
    assert (np.count_nonzero(out, axis=-1) <= k).all()
    if name == "topk25":
        # exact top-|x| selection survives the float32 round trip
        for row_out, row_in in zip(out, np.asarray(x), strict=True):
            kept = np.nonzero(row_out)[0]
            top = np.argsort(-np.abs(row_in))[:k]
            assert set(kept) == set(top)
            np.testing.assert_array_equal(row_out[kept], row_in[kept])


def test_wire_bits_exact_accounting():
    import math

    d = 7850  # the MNIST-like linear model's flattened dimension
    nsc = -(-d // 128)  # one 32-bit dequant scale per SCALE_BLOCK=128 coords
    ident = get_codec("identity").wire_bits(d)
    assert ident == 32 * d
    assert get_codec("int8").wire_bits(d) == 8 * d + 32 * nsc
    assert get_codec("int4").wire_bits(d) == 4 * d + 32 * nsc
    # randk ships no indices (shared PRNG); topk ships its k-subset as an
    # enumerative (combinatorial number system) rank: ceil(log2 C(d, k))
    k = get_codec("randk25").kept(d)
    assert get_codec("randk25").wire_bits(d) == 32 * k
    rank_bits = (math.comb(d, k) - 1).bit_length()
    assert get_codec("topk25").wire_bits(d) == 32 * k + rank_bits
    assert get_codec("topk25_int8").wire_bits(d) == 8 * k + rank_bits + 32 * (-(-k // 128))
    # the acceptance codec: int8 values + top-half-k indices >= 4x smaller
    # while dense enough for loss parity (benchmarks/comm_bench.py)
    assert ident / get_codec("topk50_int8").wire_bits(d) >= 4.0
    assert ident / get_codec("topk25_int8").wire_bits(d) >= 4.0


def test_codec_registry_validation():
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("zstd")
    with pytest.raises(ValueError, match="codecs"):
        ExperimentGrid(erdos_renyi(M, 0.8, 1, seed=0), ("trimmed_mean",), ("random",),
                       codecs=("identity", "identity"))
    with pytest.raises(ValueError, match="unknown codec"):
        ExperimentGrid(erdos_renyi(M, 0.8, 1, seed=0), ("trimmed_mean",), ("random",),
                       codecs=("gzip",))


def test_banked_dispatch_matches_dedicated_codec():
    rng = np.random.default_rng(3)
    d = 130
    x = jnp.asarray(rng.normal(size=(6, d)), jnp.float32)
    st0 = CommState(est=jnp.zeros_like(x), resid=jnp.zeros_like(x))
    names = ("identity", "int8", "topk25_int8")
    bank = codec_bank(names)
    key = jax.random.PRNGKey(7)
    for i, name in enumerate(names):
        # zero estimate + zero residual: the transmitted delta is x itself,
        # so the banked round trip must equal the dedicated codec's
        msg, tgt = encode_bank(bank, jnp.int32(i), key, x, st0)
        x_hat, st1 = decode_bank(bank, jnp.int32(i), msg, tgt, st0)
        ded = get_codec(name)
        expect = ded.decode(ded.encode(key, x), d)
        np.testing.assert_array_equal(np.asarray(x_hat), np.asarray(expect))
        assert int(wire_bits_bank(bank, jnp.int32(i), d)) == ded.wire_bits(d)
        # the public copy moved to what receivers decoded
        np.testing.assert_array_equal(np.asarray(st1.est if name != "identity" else x_hat),
                                      np.asarray(x_hat))


# ---------------------------------------------------------------------------
# Error feedback: bounded residual, convergence next to uncompressed
# ---------------------------------------------------------------------------


def _run_trainer(topo, targets, codec, steps=150, attack="random", rule="trimmed_mean"):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=2, attack=attack,
                       codec=codec, lam=1.0, t0=10)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0))
    norms = []
    for _ in range(steps):
        st, m = tr.step(st, targets)
        norms.append(float(m["ef_residual_norm"]))
    return tr, st, m, norms


def test_error_feedback_residual_bounded_and_convergent(topo, targets):
    _, _, m_id, norms_id = _run_trainer(topo, targets, "identity")
    assert norms_id == [0.0] * len(norms_id)  # lossless: no feedback at all
    for codec in ("int8", "int4"):
        tr, st, m, norms = _run_trainer(topo, targets, codec)
        # the residual is the compressor's bounded steady-state error, not a
        # divergent accumulator: its tail never exceeds a few times its
        # early levels and stays finite
        assert np.isfinite(norms).all()
        assert max(norms[-30:]) <= 5.0 * max(max(norms[:30]), 1e-3)
        # compressed BRIDGE lands next to the uncompressed trainer
        assert float(m["loss"]) < float(m_id["loss"]) * 1.10 + 0.05
        assert float(m["consensus_dist"]) < 0.5


def test_topk_with_error_feedback_converges(topo, targets):
    _, _, m_id, _ = _run_trainer(topo, targets, "identity", steps=250)
    _, _, m, norms = _run_trainer(topo, targets, "topk25_int8", steps=250)
    assert np.isfinite(norms).all()
    assert float(m["loss"]) < float(m_id["loss"]) * 1.15 + 0.1
    assert float(m["consensus_dist"]) < 0.5


# ---------------------------------------------------------------------------
# Identity bit-equivalence + one-compile codec grids
# ---------------------------------------------------------------------------


def _sequential(topo, targets, cell):
    # the cell's mask_seed (seed-axis-varying since ISSUE 4) maps onto the
    # trainer's byzantine_seed — same draw, same attacking nodes
    cfg = BridgeConfig(topology=topo, rule=cell.rule, num_byzantine=cell.b,
                       attack=cell.attack, codec=cell.codec, lam=1.0, t0=10.0,
                       byzantine_seed=cell.mask_seed if cell.mask_seed is not None else 0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(cell.seed), seed=cell.seed)
    losses = []
    for _ in range(T):
        st, m = tr.step(st, targets)
        losses.append(m["loss"])
    return np.asarray(st.params["w"]), np.asarray(jnp.stack(losses))


@pytest.mark.slow
def test_codec_grid_compiles_once_and_matches_trainers(topo, targets, batches):
    """codec x rule x attack x seed as ONE compiled program, every cell
    bit-identical to its own (codec-configured) BridgeTrainer run."""
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("random", "scale_abuse"),
                          (2,), (0, 1), codecs=("identity", "int8", "topk25_int8"),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    assert engine.trace_count == 0
    final, metrics = engine.run(state, batches)
    assert engine.trace_count == 1  # 24 experiments, one compilation
    assert engine.num_cells == 24
    for i, cell in enumerate(engine.cells):
        w_seq, loss_seq = _sequential(topo, targets, cell)
        np.testing.assert_array_equal(w_seq, np.asarray(final.params["w"][i]),
                                      err_msg=f"params diverged for {cell}")
        np.testing.assert_array_equal(loss_seq, np.asarray(metrics["loss"][i]),
                                      err_msg=f"loss trace diverged for {cell}")
    # per-cell wire accounting is the codec's exact constant
    for i, cell in enumerate(engine.cells):
        assert float(metrics["wire_bits_per_edge"][i, -1]) == get_codec(cell.codec).wire_bits(D)


def test_banked_codec_grid_identity_cells_exact_lossy_allclose(topo, targets, batches):
    """group=False (fully banked switches): identity cells stay bit-exact;
    lossy codecs agree to ULP (XLA's FMA contraction of the dequant multiply
    is program-shape dependent — see repro.comm.exchange)."""
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random",), (2,), (0, 1),
                          codecs=("identity", "int8"), lam=1.0, t0=10.0)
    grouped = GridEngine(grid, quad_grad_fn)
    banked = GridEngine(grid, quad_grad_fn, group=False)
    f1, _ = grouped.run(grouped.init(init_fn), batches)
    f2, _ = banked.run(banked.init(init_fn), batches)
    for i, cell in enumerate(grouped.cells):
        a, b = np.asarray(f1.params["w"][i]), np.asarray(f2.params["w"][i])
        if cell.codec == "identity":
            np.testing.assert_array_equal(a, b, err_msg=f"{cell}")
        else:
            # the 1-ULP/step contraction drift compounds through the tracked
            # estimate; after T=20 ticks it sits ~1e-4, far below the int8
            # quantization step (~1e-2) that bounds the codec's real error
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3, err_msg=f"{cell}")


def test_identity_codec_async_still_bitwise_equals_sync(topo, targets):
    """The comm plumbing is transparent end-to-end: the ideal-channel async
    path (which now encodes/decodes per link) still reproduces the
    synchronous trainer bit-for-bit under the identity codec."""
    cfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                       attack="random", lam=1.0, t0=10)
    sync = BridgeTrainer(cfg, quad_grad_fn)
    acfg = AsyncBridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                             attack="random", lam=1.0, t0=10,
                             channel=ChannelConfig.ideal(), staleness_bound=0)
    atr = AsyncBridgeTrainer(acfg, quad_grad_fn)
    s1, s2 = sync.init(init_fn(0)), atr.init(init_fn(0))
    for _ in range(25):
        s1, _ = sync.step(s1, targets)
        s2, _ = atr.step(s2, targets)
    np.testing.assert_array_equal(np.asarray(s1.params["w"]), np.asarray(s2.params["w"]))


# ---------------------------------------------------------------------------
# Compressed-domain attacks: screening sees what decoders emit
# ---------------------------------------------------------------------------


def test_wire_attack_registry():
    assert set(byz_lib.WIRE_ATTACKS) >= {"none", "garbage_codeword", "scale_abuse", "index_lie"}
    # wire attacks resolve to the no-op in the iterate-domain registries
    assert byz_lib.get_attack("scale_abuse").name == "none"
    assert byz_lib.get_message_attack("garbage_codeword").name == "none"
    for n in ("garbage_codeword", "scale_abuse", "index_lie"):
        assert n in byz_lib.attack_names()
    bank = byz_lib.wire_attack_bank(("random", "scale_abuse"))
    assert [a.name for a in bank] == ["none", "scale_abuse"]


def test_scale_abuse_decodes_huge_but_is_screened(topo, targets):
    """Quant-range abuse inflates Byzantine codewords by 1e4 — screening
    still trims them: honest nodes converge near the honest mean."""
    tr, st, m, _ = _run_trainer(topo, targets, "int8", steps=250, attack="scale_abuse")
    hm = np.asarray(tr.honest_mask)
    t = np.asarray(targets)[hm]
    w_fin = np.asarray(st.params["w"])[hm].mean(0)
    assert np.isfinite(np.asarray(st.params["w"])).all()
    assert np.linalg.norm(w_fin - t.mean(0)) < 1.0
    assert float(m["consensus_dist"]) < 0.5


def test_garbage_codeword_survives_identity_decode(topo, targets):
    """Garbage payload bytes under the identity codec decode to arbitrary
    float bit patterns (inf/NaN included); the NaN guard + inf sentinels keep
    screening finite and convergent."""
    tr, st, m, _ = _run_trainer(topo, targets, "identity", steps=250,
                                attack="garbage_codeword")
    assert np.isfinite(np.asarray(st.params["w"])).all()
    assert float(m["consensus_dist"]) < 0.5


def test_randk_rederives_indices_index_lies_cannot_bite():
    """randk's wire format ships ZERO index bits — receivers re-derive the
    subset from the shared per-tick PRNG — so a forged idx field must change
    nothing when the decoder holds the key (the in-protocol path)."""
    rng = np.random.default_rng(6)
    d = 64
    x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    byz = jnp.asarray([False, True, False, False])
    key = jax.random.PRNGKey(3)
    c = get_codec("randk25")
    msg = c.encode(key, x)
    lied = byz_lib.WIRE_ATTACKS["index_lie"](msg, byz, key, jnp.int32(0), d)
    np.testing.assert_array_equal(np.asarray(c.decode(msg, d, key)),
                                  np.asarray(c.decode(lied, d, key)))
    # and the re-derived decode round-trips exactly like the carried-idx one
    np.testing.assert_array_equal(np.asarray(c.decode(msg, d, key)),
                                  np.asarray(c.decode(msg, d)))


def test_index_lie_only_bites_sparse_codecs():
    rng = np.random.default_rng(5)
    d = 64
    x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    byz = jnp.asarray([False, True, False, False])
    key = jax.random.PRNGKey(0)
    atk = byz_lib.WIRE_ATTACKS["index_lie"]
    for name, bites in (("int8", False), ("topk25", True)):
        c = get_codec(name)
        msg = c.encode(key, x)
        attacked = atk(msg, byz, key, jnp.int32(0), d)
        clean = np.asarray(c.decode(msg, d))
        lied = np.asarray(c.decode(attacked, d))
        np.testing.assert_array_equal(clean[~np.asarray(byz)], lied[~np.asarray(byz)])
        changed = not np.array_equal(clean[1], lied[1])
        assert changed == bites
        if bites:  # all adversarial energy lands on the first k coordinates
            assert (np.nonzero(lied[1])[0] < c.kept(d)).all()


# ---------------------------------------------------------------------------
# repro.net: serialization from wire_bits + PRNG bandwidth masking
# ---------------------------------------------------------------------------


def test_serialization_ticks_from_wire_bits():
    ch = ChannelConfig(bits_per_tick=1000)
    assert ch.serial_ticks(900) == 0  # fits in the send tick
    assert ch.serial_ticks(1001) == 1
    assert ch.serial_ticks(5000) == 4
    assert int(ch.serial_ticks(jnp.int32(5000))) == 4
    assert ChannelConfig().serial_ticks(10**6) == 0  # uncapped link
    assert ch.max_total_latency(5000) == 4
    d = 100
    ident, int8 = get_codec("identity").wire_bits(d), get_codec("int8").wire_bits(d)
    assert ch.serial_ticks(ident) > ch.serial_ticks(int8)  # compression buys ticks


def test_narrowband_delivery_codec_dependent(topo, targets):
    """On a serialization-limited link the float32 payload arrives ticks
    later than the int8 codeword — delivered_frac at tick 0 shows it."""
    d = 100
    ch = ChannelConfig(bits_per_tick=get_codec("int8").wire_bits(d) + 1)
    rt = UnreliableRuntime(topo, ch, staleness_bound=10)
    m = topo.num_nodes
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    msgs = jnp.broadcast_to(w[None], (m, m, d))
    adj = jnp.asarray(topo.adjacency)
    key = jax.random.PRNGKey(0)
    for codec, frac in (("int8", 1.0), ("identity", 0.0)):
        wb = get_codec(codec).wire_bits(d)
        net = rt.init(m, d, max_wire_bits=get_codec("identity").wire_bits(d))
        net, _, _, stats = rt.exchange(net, msgs, w, adj, key, jnp.int32(0), wire_bits=wb)
        assert float(stats["delivered_frac"]) == frac


def test_bandwidth_cap_subset_fixed_at_send_time(topo):
    """The transmitted coordinate subset is part of the in-flight message:
    re-reading a stale mailbox entry on later ticks must NOT re-draw the
    mask and leak coordinates that never crossed the wire."""
    d = 10
    ch = ChannelConfig(bandwidth_cap=3, latency_min=1, latency_max=1)
    rt = UnreliableRuntime(topo, ch, staleness_bound=10)
    m = topo.num_nodes
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    msgs = jnp.broadcast_to(w[None], (m, m, d))
    adj = jnp.asarray(topo.adjacency)
    none = jnp.zeros_like(adj)
    net = rt.init(m, d)
    net, v0, _, _ = rt.exchange(net, msgs, w, adj, jax.random.PRNGKey(0), jnp.int32(0))
    # tick 1 delivers; ticks 2..4 read the SAME stored entry with no new sends
    views = []
    for t in range(1, 5):
        net, v, mask, _ = rt.exchange(net, msgs, w, none, jax.random.PRNGKey(t), jnp.int32(t))
        views.append(np.asarray(v))
    j, i = map(int, np.argwhere(np.asarray(adj))[0])
    sent = ~np.isclose(views[0][j, i], np.asarray(w)[j])  # coords from the sender
    assert sent.sum() <= 3
    for v in views[1:]:
        np.testing.assert_array_equal(views[0][j, i], v[j, i],
                                      err_msg="stale entry changed across reads (mask leak)")


def test_bandwidth_cap_prefix_bias_regression():
    """The old mask transmitted the FIRST `cap` coordinates every tick — a
    deterministic prefix that permanently starved high-index coordinates.
    The per-tick PRNG subset covers every coordinate with roughly uniform
    frequency (and still transmits exactly `cap` of them)."""
    d, cap, ticks = 32, 8, 300
    ch = ChannelConfig(bandwidth_cap=cap)
    counts = np.zeros(d)
    for i in range(ticks):
        mask = np.asarray(ch.coord_mask(jax.random.PRNGKey(i), d))
        assert mask.sum() == cap
        counts += mask
    assert counts.min() > 0, "some coordinate never transmitted (prefix bias)"
    # uniform-ish coverage: every coordinate within 3x of the expected rate
    expected = ticks * cap / d
    assert counts.max() < 3 * expected and counts.min() > expected / 3
    # and the old deterministic-prefix behaviour is really gone: the tail
    # (coords >= cap) transmits about as often as the head
    assert counts[cap:].sum() > 0.5 * counts.sum()


# ---------------------------------------------------------------------------
# Fused Pallas dequant->screen kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,b", [((9, 130), 1), ((16, 700), 3), ((3, 9, 130), 2)])
def test_fused_dequant_trimmed_mean_matches_reference(shape, b):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(shape[-1] + b)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    msg = get_codec("int8").encode(jax.random.PRNGKey(0), x)
    lead, d = shape[:-1], shape[-1]
    mask = jnp.asarray(rng.random(lead) < 0.8)
    mask = mask.at[..., : 2 * b + 1].set(True)
    sv = jnp.asarray(rng.normal(size=shape[:-2] + (d,)), jnp.float32)
    out = ops.dequant_trimmed_mean(msg.payload, msg.scale, mask, sv, b, block_d=128)
    exp = ref.dequant_trimmed_mean_ref(msg.payload, msg.scale, mask, sv, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)
    # and the unfused pallas pipeline (dequant kernel -> screen kernel) too
    staged = ops.trimmed_mean(ops.dequant(msg.payload, msg.scale, block_d=128),
                              mask, sv, b, block_d=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(staged), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(9, 130), (5, 257), (3, 9, 130)])
def test_fused_dequant_median_matches_reference(shape):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(shape[-1])
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    msg = get_codec("int8").encode(jax.random.PRNGKey(1), x)
    lead, d = shape[:-1], shape[-1]
    mask = jnp.asarray(rng.random(lead) < 0.7)
    mask = mask.at[..., 0].set(True)
    sv = jnp.asarray(rng.normal(size=shape[:-2] + (d,)), jnp.float32)
    out = ops.dequant_median(msg.payload, msg.scale, mask, sv, block_d=128)
    exp = ref.dequant_median_ref(msg.payload, msg.scale, mask, sv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# benchmarks.check_regression tooling
# ---------------------------------------------------------------------------


def _write(path, record):
    with open(path, "w") as f:
        json.dump(record, f)


def test_check_regression_missing_baseline_warns_not_fails(tmp_path, capsys):
    from benchmarks import check_regression as cr

    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir(), base.mkdir()
    _write(fresh / "BENCH_comm.json", {"grid": {"wall_s": 1.0}})
    rc = cr.main(["--fresh-dir", str(fresh), "--baseline-dir", str(base),
                  "--names", "BENCH_comm.json"])
    assert rc == 0  # new benchmark without a committed baseline never fails
    assert "no committed baseline" in capsys.readouterr().out


def test_check_regression_per_file_update_and_gate(tmp_path):
    from benchmarks import check_regression as cr

    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir(), base.mkdir()
    _write(fresh / "BENCH_comm.json", {"grid": {"wall_s": 1.0}})
    _write(fresh / "BENCH_grid.json", {"grid": {"wall_s": 1.0}})
    _write(base / "BENCH_grid.json", {"grid": {"wall_s": 2.0}})
    args = ["--fresh-dir", str(fresh), "--baseline-dir", str(base),
            "--names", "BENCH_comm.json,BENCH_grid.json"]
    # `--update BENCH_comm.json` re-baselines ONLY the named file
    assert cr.main(args + ["--update", "BENCH_comm.json"]) == 0
    assert (base / "BENCH_comm.json").exists()
    # a typo'd / out-of-scope update name is an error, not a silent no-op
    assert cr.main(args + ["--update", "BENCH_typo.json"]) == 1
    assert json.load(open(base / "BENCH_grid.json"))["grid"]["wall_s"] == 2.0
    # gate passes (fresh faster than baseline), then fails on regression
    assert cr.main(args) == 0
    _write(fresh / "BENCH_grid.json", {"grid": {"wall_s": 4.0}})
    assert cr.main(args + ["--tol", "1.5"]) == 1
    # higher-is-better speedup metrics regress downward
    _write(fresh / "BENCH_comm.json", {"kernel": {"fused_speedup_vs_staged": 2.0}})
    _write(base / "BENCH_comm.json", {"kernel": {"fused_speedup_vs_staged": 8.0}})
    _write(fresh / "BENCH_grid.json", {"grid": {"wall_s": 1.0}})
    assert cr.main(args + ["--tol", "1.5"]) == 1
