"""repro.adversary: stateful adaptive adversaries + breakdown certification.

The subsystem's contract (ISSUE 4 acceptance):
* (a) a rule x adversary x b grid compiles ONCE and every cell is
  bit-identical to its sequential `BridgeTrainer` run;
* (b) property tests — an adversary with b=0 (empty Byzantine mask) is
  bit-identical to the `none` attack path; an adversary under the identity
  codec matches the adversary under the no-comm path; `AdvState` is inert
  (all-zeros carry) for stateless attacks riding in a stateful bank;
* (c) at least one adaptive adversary achieves strictly worse honest loss
  (on the global objective — Eq. (1)) than the best static attack at equal b;
* (d) breakdown certification yields a monotone-certified b* per rule, with
  bisect and ladder modes agreeing;
* (e) the red-team search runs every proposal generation at zero retrace
  cost (trace_count stays 1);
plus the four-tier attack-namespace partition and the mask_seed regression
(two seeds => two different Byzantine masks).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adversary import (
    ADVERSARIES,
    get_adversary,
    registry_tiers,
)
from repro.adversary import attack_names as all_attack_names
from repro.adversary.breakdown import BreakdownConfig, BreakdownEngine, feasible_b
from repro.adversary.search import SearchConfig, red_team_search
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.core import byzantine as byz_lib
from repro.net import AsyncBridgeConfig, AsyncBridgeTrainer, ChannelConfig
from repro.sim import Cell, ExperimentGrid, GridEngine
from repro.sim.engine import stack_batches

M, D, T = 10, 4, 12
ADAPTIVE = ("ipm", "alie_online", "dissensus", "inner_max")


def quad_grad_fn(params, batch):
    w, c = params["w"], batch
    loss = 0.5 * jnp.sum((w - c) ** 2)
    return loss, {"w": w - c}


@pytest.fixture(scope="module")
def topo():
    return erdos_renyi(M, 0.8, 2, seed=1)


@pytest.fixture(scope="module")
def targets():
    rng = np.random.default_rng(0)
    return jnp.asarray(3.0 * rng.normal(size=(M, D)), jnp.float32)


def init_fn(seed):
    return replicate({"w": jnp.zeros(D)}, M, perturb=0.1, key=jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def batches(targets):
    return stack_batches(lambda i: targets, T)


def _run_trainer(topo, targets, *, rule="trimmed_mean", b=0, adversary="none",
                 attack="none", codec="identity", mask_seed=0, seed=0, steps=T):
    cfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=b, attack=attack,
                       adversary=adversary, codec=codec, byzantine_seed=mask_seed,
                       lam=1.0, t0=10.0)
    tr = BridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(seed), seed=seed)
    losses = []
    for _ in range(steps):
        st, m = tr.step(st, targets)
        losses.append(m["loss"])
    return st, np.asarray(jnp.stack(losses))


# ---------------------------------------------------------------------------
# registry: the six-tier namespace partition
# ---------------------------------------------------------------------------


def test_registry_tiers_partition_every_name_exactly_once():
    tiers = registry_tiers()
    assert set(tiers) == {"broadcast", "message", "wire", "adversary",
                          "equivocator", "slanderer"}
    names = [n for tier in tiers.values() for n in tier]
    dupes = {n for n in names if names.count(n) > 1}
    assert not dupes, f"names in more than one tier: {dupes}"
    assert set(all_attack_names()) == set(names)
    # byzantine.attack_names() is exactly the three non-adversary tiers
    assert set(byz_lib.attack_names()) == (
        tiers["broadcast"] | tiers["message"] | tiers["wire"])
    # every broadcast attack doubles as a stateless adversary; adaptive
    # adversaries are stateful and in the adversary tier only
    for n in tiers["broadcast"]:
        assert not get_adversary(n).stateful
    for n in ADAPTIVE:
        assert n in tiers["adversary"] and get_adversary(n).stateful
    # the protocol-level tiers (repro.adversary.equivocation): equivocators
    # lie per receiver, slanderers lie only in the gossiped digests
    assert "equivocate" in tiers["equivocator"]
    assert "slander" in tiers["slanderer"]
    for n in tiers["slanderer"]:
        assert get_adversary(n).accuse_fn is not None
    with pytest.raises(ValueError, match="unknown adversary"):
        get_adversary("not_an_adversary")


def test_theta_specs_well_formed():
    from repro.adversary import THETA_DIM

    for name, adv in ADVERSARIES.items():
        assert len(adv.default_theta) == THETA_DIM, name
        assert len(adv.theta_bounds) == THETA_DIM, name
        for x, (lo, hi) in zip(adv.default_theta, adv.theta_bounds, strict=True):
            if hi > lo:
                assert lo <= x <= hi or x == 0.0, (name, x, lo, hi)


# ---------------------------------------------------------------------------
# (b) property tests
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("adversary", ADAPTIVE)
def test_b0_adversary_bit_identical_to_none_path(topo, targets, adversary):
    """An empty Byzantine mask makes every adversary exactly the `none`
    path: honest rows pass through the substitution bitwise."""
    st_none, loss_none = _run_trainer(topo, targets, b=0, steps=8)
    st_adv, loss_adv = _run_trainer(topo, targets, b=0, adversary=adversary, steps=8)
    np.testing.assert_array_equal(np.asarray(st_none.params["w"]),
                                  np.asarray(st_adv.params["w"]))
    np.testing.assert_array_equal(loss_none, loss_adv)


@pytest.mark.parametrize("group", [True, False])
def test_adv_state_inert_for_stateless_attacks(topo, targets, batches, group):
    """A stateless (re-registered static) adversary riding in a stateful bank
    threads the all-zeros AdvState through untouched."""
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("none",), (2,), (0,),
                          adversaries=("random", "ipm"), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, group=group)
    state = engine.init(init_fn)
    final, _ = engine.run(state, batches)
    assert final.adv is not None
    i_static = [c.adversary for c in engine.cells].index("random")
    i_adaptive = [c.adversary for c in engine.cells].index("ipm")
    for leaf in jax.tree_util.tree_leaves(final.adv):
        assert not np.any(np.asarray(leaf[i_static])), "stateless cell mutated AdvState"
    # ...while the stateful cell actually tracked something
    assert any(np.any(np.asarray(leaf[i_adaptive]))
               for leaf in jax.tree_util.tree_leaves(final.adv))


def test_adversary_identity_codec_matches_no_comm_path(topo, targets, batches):
    """adversary x identity-codec (inside a lossy multi-codec grid bank) ==
    adversary with no wire codec at all."""
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("none",), (2,), (0,),
                          adversaries=("ipm",), codecs=("identity", "int8"),
                          lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    i_ident = [c.codec for c in engine.cells].index("identity")
    cell = engine.cells[i_ident]
    st, losses = _run_trainer(topo, targets, b=2, adversary="ipm",
                              mask_seed=cell.mask_seed, seed=cell.seed)
    np.testing.assert_array_equal(np.asarray(st.params["w"]),
                                  np.asarray(final.params["w"][i_ident]))
    np.testing.assert_array_equal(losses, np.asarray(metrics["loss"][i_ident]))


# ---------------------------------------------------------------------------
# (a) grid: compile-once + per-cell bit-identity with the trainer
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_rule_adversary_b_grid_compiles_once_and_matches_trainer(topo, targets, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean", "median"), ("none",), (1, 2), (0, 1),
                          adversaries=("none", "ipm", "inner_max"), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    assert engine.trace_count == 1  # rule x adversary x b x seed, ONE compile
    assert engine.num_cells == 24
    for i in [0, 5, 11, 14, 19, 23]:  # spot-check across rules/advs/b/seeds
        cell = engine.cells[i]
        st, losses = _run_trainer(
            topo, targets, rule=cell.rule, b=cell.b, adversary=cell.adversary,
            mask_seed=cell.mask_seed, seed=cell.seed)
        np.testing.assert_array_equal(np.asarray(st.params["w"]),
                                      np.asarray(final.params["w"][i]),
                                      err_msg=f"params diverged for {cell}")
        np.testing.assert_array_equal(losses, np.asarray(metrics["loss"][i]),
                                      err_msg=f"loss trace diverged for {cell}")


def test_mask_seed_varies_byzantine_placement(topo):
    """Regression (ISSUE 4): the seed axis must vary WHICH nodes are
    Byzantine, not just data/init."""
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("random",), (2,), (0, 1, 2, 3),
                          lam=1.0, t0=10.0)
    cells = grid.cells()
    assert [c.mask_seed for c in cells] == [0, 1, 2, 3]
    from repro.sim import pick_byz_mask

    masks = [pick_byz_mask(M, c) for c in cells]
    assert any(not np.array_equal(masks[0], mk) for mk in masks[1:]), \
        "all seeds produced the same Byzantine mask"
    # legacy escape hatch: one shared mask across the seed axis
    legacy = ExperimentGrid(topo, ("trimmed_mean",), ("random",), (2,), (0, 1),
                            mask_from_seed=False, lam=1.0, t0=10.0)
    lm = [pick_byz_mask(M, c) for c in legacy.cells()]
    np.testing.assert_array_equal(lm[0], lm[1])


# ---------------------------------------------------------------------------
# runtime path: lifted adversaries + channel knowledge
# ---------------------------------------------------------------------------


def test_runtime_ideal_channel_matches_broadcast_path(topo, targets):
    st_sync, loss_sync = _run_trainer(topo, targets, b=2, adversary="ipm", steps=8)
    cfg = AsyncBridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                            adversary="ipm", lam=1.0, t0=10.0,
                            channel=ChannelConfig.ideal())
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    losses = []
    for _ in range(8):
        st, m = tr.step(st, targets)
        losses.append(m["loss"])
    np.testing.assert_array_equal(np.asarray(st_sync.params["w"]),
                                  np.asarray(st.params["w"]))
    np.testing.assert_array_equal(loss_sync, np.asarray(jnp.stack(losses)))


@pytest.mark.parametrize("adversary", ["dissensus", "alie_online"])
def test_adversary_over_lossy_capped_channel_runs(topo, targets, adversary):
    """Message-granularity adaptive variants over a dropping, laggy,
    bandwidth-capped channel: the staleness-exploiting path stays finite."""
    cfg = AsyncBridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=2,
                            adversary=adversary, lam=1.0, t0=10.0,
                            channel=ChannelConfig(drop_prob=0.2, latency_max=2,
                                                  bandwidth_cap=2))
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    for _ in range(6):
        st, m = tr.step(st, targets)
    assert np.isfinite(float(m["loss"]))
    assert st.adv is not None


def test_net_grid_adversary_cells_match_async_trainer(topo, targets, batches):
    """scenario x adversary cells through the scenario-banked grid runtime:
    the ideal-channel adversary cell is bit-identical to its dedicated
    AsyncBridgeTrainer run."""
    from repro.net.scenarios import get_scenario

    grid = ExperimentGrid(topo, ("trimmed_mean",), ("none",), (2,), (0,),
                          scenarios=("ideal", "lossy"),
                          adversaries=("none", "ipm"), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn, num_ticks=T)
    state = engine.init(init_fn)
    final, metrics = engine.run(state, batches)
    assert engine.trace_count == 1
    i = [(c.scenario, c.adversary) for c in engine.cells].index(("ideal", "ipm"))
    cell = engine.cells[i]
    spec = get_scenario("ideal")
    cfg = AsyncBridgeConfig(
        topology=topo, rule="trimmed_mean", num_byzantine=2, adversary="ipm",
        lam=1.0, t0=10.0, channel=spec.channel,
        staleness_bound=spec.staleness_bound,
        schedule=engine.runtime.schedule_for("ideal"),
        byzantine_seed=cell.mask_seed)
    tr = AsyncBridgeTrainer(cfg, quad_grad_fn)
    st = tr.init(init_fn(0), seed=0)
    st, ms = tr.run_scan(st, batches)
    np.testing.assert_array_equal(np.asarray(st.params["w"]),
                                  np.asarray(final.params["w"][i]))
    np.testing.assert_array_equal(np.asarray(ms["loss"]),
                                  np.asarray(metrics["loss"][i]))


def test_delivered_coord_mask_matches_exchange_draw():
    from repro.net.runtime import UnreliableRuntime

    topo = erdos_renyi(6, 0.9, 1, seed=0)
    capped = UnreliableRuntime(topo, ChannelConfig(bandwidth_cap=3))
    key = jax.random.PRNGKey(7)
    mask = capped.delivered_coord_mask(key, D)
    assert mask is not None and int(jnp.sum(mask)) == 3
    # same derivation exchange uses internally: split(key)[1] -> coord_mask
    expect = capped.channel.coord_mask(jax.random.split(key)[1], D)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(expect))
    uncapped = UnreliableRuntime(topo, ChannelConfig.ideal())
    assert uncapped.delivered_coord_mask(key, D) is None


# ---------------------------------------------------------------------------
# (c) adaptive beats the best static attack at equal b
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_adaptive_strictly_worse_honest_loss_than_best_static():
    """On the global objective (Eq. (1): mean local risk over ALL nodes,
    evaluated at honest iterates), the adaptive tier must beat every static
    attack at equal b — the reason the subsystem exists.  Needs enough nodes
    for heterogeneity to matter and a horizon long enough for trajectory
    tracking to pay off (the adaptive edge IS time-coupling)."""
    m2, d2, t2 = 12, 5, 50
    topo2 = erdos_renyi(m2, 0.8, 3, seed=1)
    rng = np.random.default_rng(0)
    tgt = jnp.asarray(3.0 * rng.normal(size=(m2, d2)), jnp.float32)

    def init2(seed):
        return replicate({"w": jnp.zeros(d2)}, m2, perturb=0.1,
                         key=jax.random.PRNGKey(seed))

    statics = ("random", "sign_flip", "same_value", "alie", "shift")
    adaptives = ("alie_online", "inner_max")
    grid = ExperimentGrid(topo2, ("trimmed_mean",), ("none",), (2,), (0,),
                          adversaries=statics + adaptives, lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    state = engine.init(init2)
    final, _ = engine.run(state, stack_batches(lambda i: tgt, t2))

    def global_honest_loss(i):
        w = np.asarray(final.params["w"][i])  # [M, D]
        hm = ~engine.byz_masks[i]
        c = np.asarray(tgt)
        # f(w) = (1/M) sum_j 0.5 ||w - c_j||^2 at each honest iterate; the
        # guarantee is per honest node, so breakdown is the WORST honest
        # node's global loss
        per_node = 0.5 * ((w[hm][:, None, :] - c[None, :, :]) ** 2).sum(-1).mean(1)
        return float(per_node.max())

    loss_of = {engine.cells[i].adversary: global_honest_loss(i)
               for i in range(engine.num_cells)}
    best_static = max(loss_of[a] for a in statics)
    best_adaptive = max(loss_of[a] for a in adaptives)
    assert best_adaptive > best_static, (
        f"adaptive tier ({best_adaptive:.4f}) failed to beat the best static "
        f"attack ({best_static:.4f}) at b=2: {loss_of}")


# ---------------------------------------------------------------------------
# (d) breakdown certification
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_breakdown_certification_monotone_and_bisect_matches_ladder(topo, targets, batches):
    cfg = BreakdownConfig(mode="ladder", seeds=(0,), loss_ratio=1.5, b_max=3)
    eng = BreakdownEngine(topo, ("trimmed_mean", "mean"), ("random", "inner_max"),
                          quad_grad_fn, init_fn, batches, lam=1.0, t0=10.0, config=cfg)
    res = eng.run()
    for rule, rrec in res["rules"].items():
        assert rrec["feasible_b"] == feasible_b(rule, topo, 3)
        for adv, arec in rrec["adversaries"].items():
            bstar, probes = arec["bstar"], arec["probes"]
            assert arec["certified_monotone"]
            # the certificate: every b <= b* was probed and survived
            for b in range(1, bstar + 1):
                assert probes[str(b)]["survived"], (rule, adv, b)
            if str(bstar + 1) in probes:
                assert not probes[str(bstar + 1)]["survived"]
        assert rrec["bstar_worst_adversary"] == min(
            a["bstar"] for a in rrec["adversaries"].values())
    # no screening ("mean") breaks immediately under the random broadcast
    assert res["rules"]["mean"]["adversaries"]["random"]["bstar"] == 0
    # bisect agrees with the exhaustive ladder
    cfg2 = BreakdownConfig(mode="bisect", seeds=(0,), loss_ratio=1.5, b_max=3)
    eng2 = BreakdownEngine(topo, ("trimmed_mean",), ("inner_max",),
                           quad_grad_fn, init_fn, batches, lam=1.0, t0=10.0, config=cfg2)
    res2 = eng2.run()
    assert (res2["rules"]["trimmed_mean"]["adversaries"]["inner_max"]["bstar"]
            == res["rules"]["trimmed_mean"]["adversaries"]["inner_max"]["bstar"])
    with pytest.raises(ValueError, match="reference"):
        BreakdownEngine(topo, ("mean",), ("none",), quad_grad_fn, init_fn, batches)


# ---------------------------------------------------------------------------
# (e) red-team search: zero retrace across generations
# ---------------------------------------------------------------------------


def test_red_team_search_single_compile_and_improves(topo, targets, batches):
    ledger = red_team_search(
        topo, "trimmed_mean", "ipm", 2, quad_grad_fn, init_fn, batches,
        lam=1.0, t0=10.0,
        config=SearchConfig(population=4, generations=3, elite=2, seed=0))
    assert ledger["trace_count"] == 1, "set_cells retraced the engine"
    assert len(ledger["generations"]) == 3
    fits = [g["best_fitness"] for g in ledger["generations"]]
    assert ledger["best_fitness"] == max(fits)
    assert len(ledger["best_theta"]) == 4
    # theta is live data: proposals produce distinct fitness values
    assert len({round(f, 6) for f in fits if np.isfinite(f)}) >= 1
    with pytest.raises(ValueError, match="searchable"):
        red_team_search(topo, "trimmed_mean", "random", 2, quad_grad_fn,
                        init_fn, batches, config=SearchConfig(population=2, generations=1))


def test_set_cells_rejects_structure_changes(topo, targets, batches):
    grid = ExperimentGrid(topo, ("trimmed_mean",), ("none",), (2,), (0,),
                          adversaries=("ipm",), lam=1.0, t0=10.0)
    engine = GridEngine(grid, quad_grad_fn)
    with pytest.raises(ValueError, match="compiled bank"):
        engine.set_cells([Cell("trimmed_mean", "none", 2, 0, adversary="inner_max")])
    with pytest.raises(ValueError, match="cells"):
        engine.set_cells([])
    # same structure, new data: allowed, and reuses the compiled program
    state = engine.init(init_fn)
    engine.run(state, batches)
    engine.set_cells([Cell("trimmed_mean", "none", 2, 0, adversary="ipm",
                           mask_seed=5, theta=(12.0, 2.0, 0.0, 0.0))])
    engine.run(state, batches)
    assert engine.trace_count == 1


# ---------------------------------------------------------------------------
# baselines wired into the CLI harness (ByRDiE / BRDSO)
# ---------------------------------------------------------------------------


def test_byrdie_brdso_cli_harness_smoke():
    from benchmarks.common import run_brdso, run_byrdie

    r = run_byrdie(num_nodes=6, num_byzantine=1, sweeps=1, block=4096)
    assert np.isfinite(r["loss"]) and 0.0 <= r["accuracy"] <= 1.0
    assert r["scalars_sent"] == 7850.0  # d scalars broadcast per sweep, exact
    r = run_brdso(num_nodes=6, num_byzantine=1, steps=5)
    assert np.isfinite(r["loss"]) and 0.0 <= r["accuracy"] <= 1.0
