"""repro.obs.monitor + perfetto + manifest: the out-of-process telemetry
consumers (ISSUE 9).

Everything here drives the artifacts a run leaves on disk — including the
killed-run case where only a partial ``metrics.jsonl`` and the start-bracket
manifest exist — through the monitor's incremental tailer and HTTP API, the
Chrome-trace exporter (golden-checked entry by entry), and the manifest
write/merge/read round-trip.
"""
import json
import os
import threading
import urllib.request

import pytest

from repro.obs import read_manifest, write_manifest
from repro.obs import monitor as obs_monitor
from repro.obs import perfetto as obs_perfetto
from repro.obs import report as obs_report
from repro.obs.metrics import AlertRules
from repro.obs.monitor import RunTail, serve


def _write_jsonl(path, records):
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


@pytest.fixture()
def run_dir(tmp_path):
    """A 'killed run': start-bracket manifest, a few metric rows (the last
    one divergent), chunk events — but no run.end and no ended manifest."""
    d = str(tmp_path / "run")
    write_manifest(d, kind="unit-test", config={"steps": 4, "rule": "median"})
    _write_jsonl(os.path.join(d, "metrics.jsonl"), [
        {"tag": "train", "wall": 0.1, "tick": 0, "loss": 2.0,
         "consensus_dist": 0.5, "nonfinite": 0.0},
        {"tag": "train", "wall": 0.2, "tick": 1, "loss": 1.5,
         "consensus_dist": 0.4, "nonfinite": 0.0},
        {"tag": "train", "wall": 0.3, "tick": 2, "loss": None,
         "consensus_dist": None, "nonfinite": 1.0},
    ])
    _write_jsonl(os.path.join(d, "events.jsonl"), [
        {"tag": "run.start", "wall": 0.0, "time": 1.0},
        {"tag": "train.chunk", "wall": 0.25, "time": 1.2, "train_tag": "train",
         "lo": 0, "hi": 2, "dispatch_s": 0.2},
    ])
    return d


# ---------------------------------------------------------------------------
# manifest round-trip
# ---------------------------------------------------------------------------


def test_manifest_round_trip_and_merge(tmp_path):
    d = str(tmp_path)
    write_manifest(d, kind="train", config={"lr": 0.1, "steps": 8})
    m = read_manifest(d)
    assert m["kind"] == "train"
    assert m["config"] == {"lr": 0.1, "steps": 8}
    assert len(m["config_digest"]) == 16
    assert "python" in m["environment"]
    assert "ended" not in m
    # the end bracket MERGES: kind/config survive, extras land on top
    write_manifest(d, extra={"ended": True, "wall_s": 3.5})
    m2 = read_manifest(d)
    assert m2["kind"] == "train"
    assert m2["config_digest"] == m["config_digest"]
    assert m2["ended"] is True and m2["wall_s"] == 3.5
    # no leftover temp file from the atomic write
    assert os.listdir(d) == ["manifest.json"]


def test_manifest_digest_is_config_stable(tmp_path):
    a = write_manifest(str(tmp_path / "a"), config={"x": 1, "y": [2, 3]})
    b = write_manifest(str(tmp_path / "b"), config={"y": [2, 3], "x": 1})
    da = read_manifest(str(tmp_path / "a"))["config_digest"]
    db = read_manifest(str(tmp_path / "b"))["config_digest"]
    assert a != b and da == db  # key order does not change the digest
    write_manifest(str(tmp_path / "b"), config={"x": 1, "y": [2, 4]})
    assert read_manifest(str(tmp_path / "b"))["config_digest"] != da


def test_manifest_absent_or_torn_reads_none(tmp_path):
    assert read_manifest(str(tmp_path)) is None
    with open(tmp_path / "manifest.json", "w") as f:
        f.write('{"kind": "tr')  # torn write from a killed process
    assert read_manifest(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# the tailer
# ---------------------------------------------------------------------------


def test_runtail_snapshot_of_killed_run(run_dir):
    tail = RunTail(run_dir)
    snap = tail.snapshot()
    assert snap["rows"] == 3 and snap["events"] == 2
    assert snap["tags"] == ["train"]
    assert snap["manifest"]["kind"] == "unit-test"
    assert snap["last"]["tick"] == 2
    # the monitor-side engine re-derives alerts, so the killed run (whose
    # writer never emitted obs.alert) still surfaces its divergence
    assert [a["kind"] for a in snap["alerts"]] == ["divergence"]
    assert snap["alerts"][0]["tag"] == "train"


def test_runtail_incremental_and_torn_line(run_dir):
    tail = RunTail(run_dir)
    tail.refresh()
    assert len(tail.rows) == 3
    mpath = os.path.join(run_dir, "metrics.jsonl")
    with open(mpath, "a") as f:  # a live writer mid-line: no newline yet
        f.write('{"tag": "train", "wall": 0.4, "tick": 3, "lo')
    tail.refresh()
    assert len(tail.rows) == 3  # torn tail is NOT consumed
    with open(mpath, "a") as f:
        f.write('ss": 1.0}\n')
    tail.refresh()
    assert len(tail.rows) == 4 and tail.rows[-1]["loss"] == 1.0
    assert tail.metrics_since(1, "train")[0]["tick"] == 2
    assert tail.metrics_since(1, "other") == []
    events, total = tail.events_since(1)
    assert total == 2 and [e["tag"] for e in events] == ["train.chunk"]


def test_runtail_dedupes_writer_emitted_alerts(run_dir):
    """obs.alert events from the run's own writer merge with (not duplicate)
    the monitor-side engine's alerts, keyed by (stream, kind)."""
    with open(os.path.join(run_dir, "events.jsonl"), "a") as f:
        f.write(json.dumps({"tag": "obs.alert", "wall": 0.35, "time": 1.3,
                            "kind": "divergence", "stream": "train",
                            "tick": 2}) + "\n")
        f.write(json.dumps({"tag": "obs.alert", "wall": 0.36, "time": 1.3,
                            "kind": "wire_budget", "stream": "train",
                            "tick": 2, "budget": 10.0}) + "\n")
    tail = RunTail(run_dir)
    tail.refresh()
    kinds = sorted(a["kind"] for a in tail.alerts)
    assert kinds == ["divergence", "wire_budget"]  # divergence only once
    wb = next(a for a in tail.alerts if a["kind"] == "wire_budget")
    assert wb["tag"] == "train" and "stream" not in wb


# ---------------------------------------------------------------------------
# the HTTP API
# ---------------------------------------------------------------------------


@pytest.fixture()
def server(run_dir):
    srv = serve(run_dir, port=0, rules=AlertRules())
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    yield srv
    srv.shutdown()
    srv.server_close()


def _get(server, path):
    port = server.server_address[1]
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}", timeout=10) as r:
        return r.status, r.headers.get_content_type(), r.read()


def test_monitor_http_smoke(server):
    code, ctype, body = _get(server, "/")
    assert code == 200 and ctype == "text/html"
    html = body.decode()
    assert "<svg" in html or "lineChart" in html  # the inline dashboard
    code, ctype, body = _get(server, "/api/run")
    snap = json.loads(body)
    assert code == 200 and snap["rows"] == 3
    assert snap["manifest"]["kind"] == "unit-test"
    code, _, body = _get(server, "/api/metrics?after=0&tag=train")
    rows = json.loads(body)["rows"]
    assert code == 200 and [r["tick"] for r in rows] == [1, 2]
    code, _, body = _get(server, "/api/events?offset=1")
    ev = json.loads(body)
    assert code == 200 and ev["total"] == 2 and len(ev["events"]) == 1


def test_monitor_http_unknown_path_404(server):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _get(server, "/api/nope")
    assert ei.value.code == 404


def test_monitor_once_cli(run_dir, capsys):
    assert obs_monitor.main([run_dir, "--once"]) == 0
    snap = json.loads(capsys.readouterr().out)
    assert snap["rows"] == 3 and snap["run_dir"] == run_dir


# ---------------------------------------------------------------------------
# perfetto export (golden)
# ---------------------------------------------------------------------------


def test_chrome_trace_golden():
    """Entry-by-entry check of the Trace Event Format conversion."""
    events = [
        {"tag": "run.start", "wall": 0.0, "time": 1.0, "steps": 4},
        {"tag": "train.chunk", "wall": 0.5, "time": 1.5, "train_tag": "train",
         "lo": 0, "hi": 2, "dispatch_s": 0.4},
        {"tag": "obs.alert", "wall": 0.6, "time": 1.6, "kind": "divergence",
         "stream": "train", "tick": 2},
    ]
    rows = [{"tag": "train", "wall": 0.45, "tick": 1, "loss": 1.5,
             "stale_p50": None}]
    trace = obs_perfetto.chrome_trace(events, rows, {"kind": "unit-test"})
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"] == {"kind": "unit-test"}
    te = trace["traceEvents"]
    # metadata: process + one thread_name per track, in tid order
    metas = [e for e in te if e["ph"] == "M"]
    assert metas[0]["args"] == {"name": "repro"}
    assert [(m["tid"], m["args"]["name"]) for m in metas[1:]] == [
        (1, "run"), (2, "train/train"), (3, "alerts")]
    # the dispatch becomes an X slice ENDING at its wall time
    x = next(e for e in te if e["ph"] == "X")
    assert x["name"] == "train.chunk"
    assert x["ts"] == pytest.approx((0.5 - 0.4) * 1e6)
    assert x["dur"] == pytest.approx(0.4 * 1e6)
    assert x["args"]["lo"] == 0 and x["args"]["hi"] == 2
    # run.start and the alert are instants on their own tracks
    instants = [e for e in te if e["ph"] == "i"]
    assert {e["name"] for e in instants} == {"run.start", "obs.alert"}
    # the metric row is one counter per non-null, non-tick column
    counters = [e for e in te if e["ph"] == "C"]
    assert [(c["name"], c["args"]) for c in counters] == [
        ("train/loss", {"loss": 1.5})]
    assert counters[0]["ts"] == pytest.approx(0.45 * 1e6)
    # the non-meta stream is globally ts-sorted
    ts = [e["ts"] for e in te if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_perfetto_export_of_killed_run(run_dir):
    path = obs_perfetto.export(run_dir)
    assert path == os.path.join(run_dir, "trace.json")
    with open(path) as f:
        trace = json.load(f)
    assert trace["otherData"]["kind"] == "unit-test"
    names = {e["name"] for e in trace["traceEvents"]}
    assert "train.chunk" in names and "train/loss" in names


def test_perfetto_export_metrics_only(tmp_path):
    """No events.jsonl at all (a run killed before its first chunk event)
    still renders as a counter-only trace."""
    d = str(tmp_path)
    _write_jsonl(os.path.join(d, "metrics.jsonl"),
                 [{"tag": "train", "wall": 0.1, "tick": 0, "loss": 2.0}])
    with open(obs_perfetto.export(d)) as f:
        trace = json.load(f)
    assert [e["name"] for e in trace["traceEvents"] if e["ph"] == "C"] == [
        "train/loss"]


def test_perfetto_cli(run_dir, tmp_path, capsys):
    out = str(tmp_path / "t.json")
    assert obs_perfetto.main([run_dir, "--out", out]) == 0
    assert "trace events" in capsys.readouterr().out
    assert json.load(open(out))["traceEvents"]


# ---------------------------------------------------------------------------
# the report CLI renders killed-run artifacts
# ---------------------------------------------------------------------------


def test_report_renders_manifest_and_live_streams(run_dir):
    from repro.obs import read_events
    from repro.obs.metrics import read_metrics

    text = obs_report.render(
        None, read_events(os.path.join(run_dir, "events.jsonl")),
        manifest=read_manifest(run_dir),
        metrics_rows=read_metrics(os.path.join(run_dir, "metrics.jsonl")))
    assert "unit-test" in text          # manifest kind
    assert "train" in text              # the live stream's tag
    assert "nonfinite" in text.lower() or "1" in text
