"""Static-analysis subsystem (`repro.analysis`): every pass PASSes on the
current tree and demonstrably FAILs on a seeded violation.

The seeded violations, one per pass:

* prng    — one key feeding two distinct draws (``normal`` + ``uniform``);
* fence   — `screening.fence` monkeypatched to identity, so the optimized
  flat program keeps zero trip-2 while loops;
* memory  — the canonical sparse config compiled with ``sparse=False``:
  the dense twin materializes the full ``[M, M, d]`` and busts the budget;
* retrace — a ragged chunk schedule (chunk lengths 4 and 2) against a
  single-trace budget;
* lint    — the stream partition broken by overlapping a rejected rule into
  ``STREAMABLE_RULES``, plus a duplicated contract name at collect().
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts as C
from repro.analysis import hlo as analysis_hlo
from repro.analysis import lint
from repro.analysis import prng
from repro.analysis import programs as programs_lib
from repro.analysis import retrace
from repro.core import screening


def _contract(kind, **params):
    return C.Contract(f"test.{kind}.contract", kind, "test fixture",
                      params=tuple(params.items()))


# ---------------------------------------------------------------------------
# prng pass
# ---------------------------------------------------------------------------


def test_prng_clean_split_discipline():
    def f(key):
        k1, k2 = jax.random.split(key)
        return jax.random.normal(k1, (3,)) + jax.random.normal(k2, (3,))

    assert prng.check(f, jax.random.PRNGKey(0)) == []


def test_prng_reused_key_flagged():
    def f(key):
        return (jnp.sum(jax.random.normal(key, (3,)))
                + jnp.sum(jax.random.normal(key, (5,))))

    reuse = prng.check(f, jax.random.PRNGKey(0))
    assert len(reuse) == 1
    assert reuse[0].uses == 2


def test_prng_cross_distribution_reuse_flagged():
    # normal and uniform draw IDENTICAL raw bits from the same key — the
    # insidious correlated-sample bug the sampler-frame discrimination exists
    # to catch
    def f(key):
        return jax.random.normal(key, ()) + jax.random.uniform(key, ())

    assert len(prng.check(f, jax.random.PRNGKey(0))) == 1


def test_prng_shared_coin_idiom_not_flagged():
    # two textually separate but identical draws are ONE value (the public
    # shared-coin idiom) — value numbering must unify them
    def f(key):
        a = jax.random.normal(key, (3,))
        b = jax.random.normal(key, (3,))
        return a + b

    assert prng.check(f, jax.random.PRNGKey(0)) == []


def test_prng_exclusive_branches_not_flagged():
    def f(key, p):
        return jax.lax.cond(p > 0,
                            lambda k: jax.random.normal(k, (3,)),
                            lambda k: jax.random.normal(k, (3,)) * 2.0,
                            key)

    assert prng.check(f, jax.random.PRNGKey(0), jnp.float32(0.5)) == []


def test_prng_reuse_inside_scan_flagged():
    def f(key):
        def body(c, _):
            return c + jax.random.normal(key, ()) * jax.random.uniform(key, ()), None

        out, _ = jax.lax.scan(body, 0.0, None, length=3)
        return out

    assert len(prng.check(f, jax.random.PRNGKey(0))) == 1


# ---------------------------------------------------------------------------
# fence pass
# ---------------------------------------------------------------------------


def test_fence_survives_alone():
    text = (jax.jit(screening.fence)
            .lower(jnp.zeros((8,), jnp.float32)).compile().as_text())
    assert analysis_hlo.count_fences(text) == 1


def test_stripped_fence_fails(monkeypatch):
    # strip every fence: the length-2 scan becomes identity, XLA sees no
    # while loops, and the floor contract must fire
    monkeypatch.setattr(screening, "fence", lambda x: x)
    prog = programs_lib.build_flat()
    res = analysis_hlo.check_fence_floor(
        _contract("fence", min_fences=1), prog.name, prog.hlo, min_fences=1)
    assert res.status == "FAIL"
    assert "stripped or unrolled" in res.detail


# ---------------------------------------------------------------------------
# memory pass
# ---------------------------------------------------------------------------


def test_dense_twin_busts_sparse_budget():
    # the same topology/model as the canonical sparse program, compiled on
    # the DENSE path: the [M, M, d] broadcast matrix materializes and the
    # dense_mmd budget must fire
    from repro.core.bridge import BridgeConfig, BridgeTrainer, replicate
    from repro.core.graph import erdos_renyi

    m, d = 12, 16
    topo = erdos_renyi(m, 0.45, 1, seed=3)
    cfg = BridgeConfig(topology=topo, rule="median", num_byzantine=1,
                       attack="sign_flip", codec="identity", lam=1.0, t0=10.0)
    trainer = BridgeTrainer(cfg, programs_lib.quad_grad_fn)
    seed = 0
    params = replicate({"w": jnp.zeros(d)}, m, perturb=0.1,
                       key=jax.random.PRNGKey(seed))
    state = trainer.init(params, seed=seed)
    batch = jnp.zeros((m, d), jnp.float32)
    text = (jax.jit(trainer._raw_step)
            .lower(trainer._cell, state, batch).compile().as_text())
    res = analysis_hlo.check_budget(
        _contract("memory", budget="dense_mmd"), "dense-twin", text,
        m * m * d * 4, "dense [M,M,d]")
    assert res.status == "FAIL"
    assert "materialized" in res.detail


def test_donation_dropped_fails_on_empty_alias_table():
    no_alias = "HloModule chunk\n\nENTRY %main (p: f32[4]) -> f32[4] {\n" \
               "  ROOT %p = f32[4]{0} parameter(0)\n}\n"
    res = analysis_hlo.check_donation(
        _contract("memory", check="donation"), "flat", no_alias,
        backend_supports=True)
    assert res.status == "FAIL"
    assert "silently copied" in res.detail


def test_donation_unsupported_backend_skips():
    res = analysis_hlo.check_donation(
        _contract("memory", check="donation"), "flat", "HloModule chunk",
        backend_supports=False)
    assert res.status == "SKIP"


# ---------------------------------------------------------------------------
# retrace pass
# ---------------------------------------------------------------------------


def test_guard_raises_on_growth():
    class Engine:
        trace_count = 0

    eng = Engine()
    with pytest.raises(retrace.RetraceError, match="went cold"):
        with retrace.guard(eng, "trace_count", budget=0):
            eng.trace_count += 1


def test_guard_allows_within_budget():
    class Engine:
        trace_count = 0

    eng = Engine()
    with retrace.guard(eng, "trace_count", budget=2):
        eng.trace_count += 2


def test_ragged_chunks_exceed_single_trace_budget():
    # 10 steps in chunks of 4 -> chunk lengths 4, 4, 2: two distinct scan
    # shapes, two traces, over the single-trace budget
    prog = programs_lib.build_flat()
    res = retrace.check_run_chunks(
        _contract("retrace", max_traces=1), prog.trainer, prog.state,
        prog.batch_fn, num_steps=10, chunk=4)
    assert res.status == "FAIL"
    assert "retracing" in res.detail or "budget" in res.detail


# ---------------------------------------------------------------------------
# lint pass
# ---------------------------------------------------------------------------


def test_stream_partition_overlap_fails(monkeypatch):
    # a duplicated registry entry: "krum" homed in BOTH partitions
    monkeypatch.setattr(screening, "STREAMABLE_RULES",
                        screening.STREAMABLE_RULES | {"krum"})
    res = lint.check_stream_partition(_contract("lint", check="stream_partition"))
    assert res.status == "FAIL"
    assert "krum" in res.detail


def test_stream_partition_unassigned_fails(monkeypatch):
    monkeypatch.setattr(screening, "STREAM_REJECTED_RULES",
                        screening.STREAM_REJECTED_RULES - {"bulyan"})
    res = lint.check_stream_partition(_contract("lint", check="stream_partition"))
    assert res.status == "FAIL"
    assert "bulyan" in res.detail


def test_duplicate_contract_name_rejected():
    # the same module collected twice duplicates every contract name
    with pytest.raises(ValueError, match="exactly one home"):
        C.collect(("repro.core.screening", "repro.core.screening"))


def test_seed_plumbing_flags_naked_key(tmp_path):
    pkg = tmp_path / "repro"
    pkg.mkdir()
    (pkg / "bad.py").write_text(
        "import jax\n\n\ndef init():\n    return jax.random.PRNGKey(42)\n")
    res = lint.check_seed_plumbing(
        _contract("lint", check="seed_plumbing"), tmp_path)
    assert res.status == "FAIL"
    assert "bad.py" in res.detail and "init" in res.detail


def test_seed_plumbing_stale_waiver_fails(tmp_path):
    (tmp_path / "repro").mkdir()
    res = lint.check_seed_plumbing(
        _contract("lint", check="seed_plumbing",
                  waivers=(("repro/gone.py", "nobody"),)), tmp_path)
    assert res.status == "FAIL"
    assert "stale" in res.detail


def test_unknown_lint_check_skips():
    out = lint.run_lint([_contract("lint", check="no_such_check")], ".")
    assert out[0].status == "SKIP"


# ---------------------------------------------------------------------------
# contracts / driver plumbing
# ---------------------------------------------------------------------------


def test_collect_finds_all_governed_modules():
    contracts = C.collect()
    homes = {c.name.split(".")[0] for c in contracts}
    assert {"bridge", "screening", "grid", "stream", "kernels",
            "launch", "adversary"} <= homes
    kinds = {c.kind for c in contracts}
    assert kinds == set(C.KINDS)


def test_contract_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown kind"):
        C.Contract("x.y", "vibes", "not a pass")


def test_summarize_counts_and_orders():
    results = [
        C.CheckResult("b.two", "lint", "FAIL", detail="boom"),
        C.CheckResult("a.one", "prng", "PASS", program="flat"),
        C.CheckResult("c.three", "fence", "SKIP"),
    ]
    text = C.summarize(results)
    lines = text.splitlines()
    assert lines[0].startswith("PASS prng")  # KINDS order, not input order
    assert "[flat]" in lines[0]
    assert lines[-1] == "1 passed, 1 failed, 1 skipped"


def test_driver_lint_pass_green_on_tree():
    from repro.analysis import driver

    results = driver.run_all(kinds=("lint",))
    lint_results = [r for r in results if r.kind == "lint"]
    assert lint_results and all(r.ok for r in lint_results)
    # deselected passes surface as SKIP, never vanish
    assert any(r.status == "SKIP" for r in results)


def test_driver_prng_pass_green_on_canonical_programs():
    from repro.analysis import driver

    results = driver.run_all(kinds=("prng",))
    checked = [r for r in results if r.kind == "prng"]
    assert {r.program for r in checked} == set(programs_lib.PROGRAM_NAMES)
    assert all(r.status == "PASS" for r in checked)
