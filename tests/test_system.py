"""End-to-end behaviour tests: decentralized LM training with BRIDGE over the
full stack (model zoo -> trainer -> data pipeline), reproducing the paper's
qualitative claims at CPU scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BridgeConfig, BridgeTrainer, erdos_renyi, replicate
from repro.data.tokens import TokenPipeline
from repro.models import api as model_api

M, BYZ = 6, 1


def _train_lm(arch, rule, attack, steps=25, seed=0, lr=0.1):
    cfg = get_config(arch).reduced()
    api = model_api.build(cfg)
    topo = erdos_renyi(M, 0.9, BYZ, seed=seed)
    bcfg = BridgeConfig(topology=topo, rule=rule, num_byzantine=BYZ,
                        attack=attack, lr=lr)
    trainer = BridgeTrainer(bcfg, api.grad_fn())
    key = jax.random.PRNGKey(seed)
    params = replicate(api.init_params(key, cfg), M, perturb=0.01, key=key)
    state = trainer.init(params)
    pipe = TokenPipeline(cfg.vocab_size, 48, 2, M, seed=seed)
    losses = []
    for step in range(steps):
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch(step))
        state, metrics = trainer.step(state, batch)
        losses.append(float(metrics["loss"]))
    return losses, float(metrics["consensus_dist"])


@pytest.mark.slow
def test_lm_training_loss_decreases_under_attack():
    losses, cons = _train_lm("qwen3-4b", "trimmed_mean", "random", steps=40)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert cons < 5.0


@pytest.mark.slow
def test_lm_dgd_vs_bridge_under_attack():
    """DGD (mean) degrades far more than BRIDGE-T under the same attack."""
    dgd, _ = _train_lm("qwen3-4b", "mean", "random", steps=25)
    brt, _ = _train_lm("qwen3-4b", "trimmed_mean", "random", steps=25)
    assert np.mean(brt[-5:]) < np.mean(dgd[-5:]) - 0.5


@pytest.mark.slow
def test_ssm_arch_trains_with_bridge():
    """Attention-free arch (RWKV6): the paper's technique is arch-agnostic."""
    losses, _ = _train_lm("rwkv6-3b", "trimmed_mean", "random", steps=40, lr=0.3)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


@pytest.mark.slow
def test_moe_arch_trains_with_bridge():
    """MoE incl. router params are screened coordinate-wise."""
    losses, _ = _train_lm("deepseek-v2-236b", "median", "random", steps=15)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 0.1


def test_checkpoint_resume_matches(tmp_path):
    """Deterministic resume: save at step k, resume, trajectories match."""
    from repro import checkpoint

    cfg = get_config("qwen3-4b").reduced()
    api = model_api.build(cfg)
    topo = erdos_renyi(M, 0.9, 0, seed=0)
    bcfg = BridgeConfig(topology=topo, rule="trimmed_mean", num_byzantine=0,
                        attack="none", lr=0.05)
    trainer = BridgeTrainer(bcfg, api.grad_fn())
    key = jax.random.PRNGKey(0)
    params = replicate(api.init_params(key, cfg), M, perturb=0.01, key=key)
    pipe = TokenPipeline(cfg.vocab_size, 32, 2, M, seed=0)
    state = trainer.init(params)
    for step in range(4):
        state, _ = trainer.step(state, jax.tree_util.tree_map(jnp.asarray, pipe.batch(step)))
        if step == 1:
            checkpoint.save(str(tmp_path), 2, (state.params, state.key))
    # resume from step 2 and replay
    (p, k), _ = checkpoint.restore(str(tmp_path), (state.params, state.key))
    st2 = trainer.init(p)._replace(key=jnp.asarray(k), t=jnp.asarray(2, jnp.int32))
    for step in range(2, 4):
        st2, _ = trainer.step(st2, jax.tree_util.tree_map(jnp.asarray, pipe.batch(step)))
    a = jax.tree_util.tree_leaves(state.params)
    b = jax.tree_util.tree_leaves(st2.params)
    err = max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a, b, strict=True))
    assert err < 1e-5
