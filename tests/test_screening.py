"""Unit + property tests for the BRIDGE screening rules (paper Sec. III).

Property-style tests enumerate seeded random cases (the environment has no
``hypothesis``; a fixed seed grid keeps them deterministic and CI-stable).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import complete_graph, erdos_renyi, screen_all, screening

RULES = ["trimmed_mean", "median", "krum", "bulyan"]


def _setup(m=15, d=6, b=2, seed=0):
    rng = np.random.default_rng(seed)
    topo = complete_graph(m, b)
    w = jnp.asarray(rng.random((m, d)), jnp.float32)
    return topo, w, rng


@pytest.mark.parametrize("rule", RULES)
def test_hull_invariant(rule):
    """The core robustness property (basis of Eq. 14): honest nodes' screened
    outputs stay inside the convex hull (per-coordinate) of honest values, no
    matter what the <=b Byzantine nodes broadcast."""
    m, b = 15, 2
    topo, w, rng = _setup(m=m, b=b)
    byz = [3, 7]
    w = w.at[3].set(1e4).at[7].set(-1e4)
    honest = np.setdiff1d(np.arange(m), byz)
    hv = np.asarray(w)[honest]
    topo.validate_for_rule(rule)
    y = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule=rule, b=b))[honest]
    assert (y >= hv.min(0) - 1e-4).all() and (y <= hv.max(0) + 1e-4).all()


@pytest.mark.parametrize("n,b,seed", [
    (7, 0, 0), (7, 1, 1), (7, 2, 2), (9, 2, 3), (11, 0, 4), (11, 1, 5),
    (12, 2, 6), (13, 1, 7), (14, 2, 8), (15, 0, 9), (15, 1, 10), (15, 2, 11),
])
def test_trimmed_mean_matches_numpy(n, b, seed):
    rng = np.random.default_rng(seed)
    vals = list(rng.uniform(-100, 100, size=n).astype(np.float32))
    v = jnp.asarray(vals, jnp.float32)[:, None]
    mask = jnp.ones((n,), bool)
    self_v = jnp.asarray([0.0], jnp.float32)
    out = screening.trimmed_mean(v, mask, self_v, b)
    s = np.sort(np.asarray(vals, np.float32))
    kept = s[b : n - b] if b else s
    expected = (kept.sum() + 0.0) / (n - 2 * b + 1)
    np.testing.assert_allclose(np.asarray(out)[0], expected, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,seed", [
    (3, 0), (4, 1), (5, 2), (6, 3), (7, 4), (8, 5), (9, 6), (10, 7),
    (11, 8), (12, 9), (13, 10), (14, 11),
])
def test_median_matches_numpy(n, seed):
    rng = np.random.default_rng(100 + seed)
    vals = list(rng.uniform(-50, 50, size=n).astype(np.float32))
    v = jnp.asarray(vals, jnp.float32)[:, None]
    mask = jnp.ones((n,), bool)
    self_v = jnp.asarray([vals[0]], jnp.float32)
    out = screening.coordinate_median(v, mask, self_v)
    expected = np.median(np.asarray(vals + [vals[0]], np.float32))
    np.testing.assert_allclose(np.asarray(out)[0], expected, rtol=1e-5, atol=1e-5)


def test_trimmed_mean_b0_is_dgd_mean():
    """BRIDGE-T reduces to (uniform-weight) DGD when b=0 (Sec. III)."""
    topo, w, _ = _setup(b=0)
    adj = jnp.asarray(topo.adjacency)
    yt = screen_all(w, adj, rule="trimmed_mean", b=0)
    ym = screen_all(w, adj, rule="mean", b=0)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(ym), rtol=1e-5)


def test_median_affine_equivariance():
    """Rank-based rules commute with positive affine maps per coordinate."""
    topo, w, _ = _setup()
    adj = jnp.asarray(topo.adjacency)
    a, c = 2.5, -1.0
    y1 = screen_all(a * w + c, adj, rule="median", b=2)
    y2 = a * screen_all(w, adj, rule="median", b=2) + c
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_krum_selects_inlier():
    """Krum must never output the obvious outlier vector."""
    m, b = 10, 1
    topo = complete_graph(m, b)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.1, (m, 8)), jnp.float32)
    w = w.at[4].set(50.0)
    y = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule="krum", b=b))
    honest = [i for i in range(m) if i != 4]
    assert np.abs(y[honest]).max() < 1.0


def test_varying_degrees_masked_correctly():
    """ER graph (varying |N_j|): output dims/finiteness + hull invariant."""
    topo = erdos_renyi(12, 0.8, 2, seed=3)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.random((12, 5)), jnp.float32)
    for rule in ["trimmed_mean", "median"]:
        y = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule=rule, b=2))
        assert np.isfinite(y).all()
        assert (y >= 0 - 1e-5).all() and (y <= 1 + 1e-5).all()


def test_chunked_screening_matches():
    topo, w, _ = _setup(d=137)
    adj = jnp.asarray(topo.adjacency)
    full = screen_all(w, adj, rule="trimmed_mean", b=2)
    chunked = screen_all(w, adj, rule="trimmed_mean", b=2, chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), rtol=1e-5)


# ---------------------------------------------------------------------------
# Extreme magnitudes: the inf-sentinel regression suite
# ---------------------------------------------------------------------------
#
# The old masking used a finite 1e30 sentinel: any legitimate value beyond it
# (fp32 goes to 3.4e38; bf16 overflow products routinely land there) sorted
# *past* the sentinel rows, so masked slots leaked into the trim window and
# silently corrupted the output.  Masking is now +inf with a NaN guard.


def test_trimmed_mean_huge_honest_values_not_corrupted():
    """Honest values in the 1e31..1e32 range (beyond the old sentinel) must
    still produce the exact trimmed mean."""
    n, b = 9, 2
    rng = np.random.default_rng(0)
    vals = (rng.uniform(1.0, 9.0, size=n) * 1e31).astype(np.float32)
    v = jnp.asarray(vals)[:, None]
    mask = jnp.ones((n,), bool)
    self_v = jnp.asarray([np.float32(5e31)])
    out = float(np.asarray(screening.trimmed_mean(v, mask, self_v, b))[0])
    s = np.sort(vals.astype(np.float64))
    expected = (s[b: n - b].sum() + 5e31) / (n - 2 * b + 1)
    np.testing.assert_allclose(out, expected, rtol=1e-5)


def test_trimmed_mean_extreme_attack_values_trimmed():
    """A colluding attacker broadcasting 1e38 / -1e38 / +-inf payloads is
    fully trimmed; honest values survive untouched."""
    m, b, d = 11, 2, 3
    honest_vals = np.linspace(1.0, 7.0, m - b).astype(np.float32)
    for bad in (3.4e38, -3.4e38, np.inf, -np.inf):
        vals = np.concatenate([honest_vals, np.full((b,), bad, np.float32)])
        v = jnp.asarray(np.broadcast_to(vals[:, None], (m, d)).copy())
        out = np.asarray(screening.trimmed_mean(v, jnp.ones((m,), bool),
                                                jnp.full((d,), 4.0, jnp.float32), b))
        assert np.isfinite(out).all(), f"attack value {bad} leaked"
        expected = (np.sort(vals.astype(np.float64))[b: m - b].sum() + 4.0) / (m - 2 * b + 1)
        np.testing.assert_allclose(out, expected, rtol=1e-5)


def test_median_huge_magnitudes_exact():
    n = 8
    vals = np.array([1e31, 2e31, 3e31, -4e31, 5e31, 2.5e31, 1.5e31, 4e31], np.float32)
    v = jnp.asarray(vals)[:, None]
    out = float(np.asarray(screening.coordinate_median(v, jnp.ones((n,), bool),
                                                       jnp.asarray([2.2e31], jnp.float32)))[0])
    expected = float(np.median(np.concatenate([vals, [np.float32(2.2e31)]]).astype(np.float64)))
    np.testing.assert_allclose(out, expected, rtol=1e-6)


def test_nan_payloads_guarded():
    """NaN payloads (the finite-count guard) are treated as maximal outliers:
    trimmed away, never propagated into honest outputs."""
    m, b, d = 11, 2, 4
    vals = np.linspace(-2.0, 2.0, m).astype(np.float32)
    v = np.broadcast_to(vals[:, None], (m, d)).copy()
    v[3] = np.nan
    v[7] = np.nan
    out_t = np.asarray(screening.trimmed_mean(jnp.asarray(v), jnp.ones((m,), bool),
                                              jnp.zeros((d,), jnp.float32), b))
    out_m = np.asarray(screening.coordinate_median(jnp.asarray(v), jnp.ones((m,), bool),
                                                   jnp.zeros((d,), jnp.float32)))
    assert np.isfinite(out_t).all() and np.isfinite(out_m).all()
    honest = np.delete(vals, [3, 7])
    assert (out_t >= honest.min() - 1e-5).all() and (out_t <= honest.max() + 1e-5).all()


def test_hull_invariant_under_extreme_attack():
    """Eq. 14's hull property holds even when the attack magnitude dwarfs the
    old finite sentinel."""
    m, b = 15, 2
    topo, w, _ = _setup(m=m, b=b)
    w = w.at[3].set(2.9e38).at[7].set(-2.9e38)
    honest = np.setdiff1d(np.arange(m), [3, 7])
    hv = np.asarray(w)[honest]
    for rule in ("trimmed_mean", "median"):
        y = np.asarray(screen_all(w, jnp.asarray(topo.adjacency), rule=rule, b=b))[honest]
        assert (y >= hv.min(0) - 1e-4).all() and (y <= hv.max(0) + 1e-4).all()
