"""`tools/check_docs.py`: the docs gate itself is tested — a checker that
silently matches nothing (regex rot, fence mis-tracking) would wave broken
docs through CI forever."""
import importlib.util
import os

_TOOL = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "tools", "check_docs.py")
_spec = importlib.util.spec_from_file_location("check_docs", _TOOL)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write(path, text):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def test_repo_docs_pass_link_check():
    assert check_docs.check_links(REPO_ROOT) == []


def test_broken_relative_link_is_reported(tmp_path):
    _write(str(tmp_path / "README.md"), "see [gone](docs/NOPE.md)\n")
    problems = check_docs.check_links(str(tmp_path))
    assert len(problems) == 1
    assert "README.md:1" in problems[0] and "docs/NOPE.md" in problems[0]


def test_anchor_fragments_resolve_against_github_slugs(tmp_path):
    _write(str(tmp_path / "docs" / "A.md"),
           "# Top\n\n## Trust layer (`repro.trust`)\n")
    _write(str(tmp_path / "README.md"),
           "[ok](docs/A.md#trust-layer-reprotrust)\n"
           "[bad](docs/A.md#no-such-heading)\n")
    problems = check_docs.check_links(str(tmp_path))
    assert len(problems) == 1
    assert "no-such-heading" in problems[0]


def test_code_spans_and_fences_are_not_links(tmp_path):
    _write(str(tmp_path / "README.md"),
           "shape `[M, K](gathered)` is code\n"
           "```\n[also](not/a/link.md)\n```\n"
           "but [this](missing.md) is real\n")
    problems = check_docs.check_links(str(tmp_path))
    assert len(problems) == 1
    assert "missing.md" in problems[0]


def test_external_urls_are_skipped(tmp_path):
    _write(str(tmp_path / "README.md"),
           "[arxiv](https://arxiv.org/abs/1908.08098) "
           "[mail](mailto:x@y.z)\n")
    assert check_docs.check_links(str(tmp_path)) == []


def test_duplicate_headings_get_suffixed_slugs(tmp_path):
    _write(str(tmp_path / "docs" / "A.md"), "## Setup\n\n## Setup\n")
    slugs = check_docs.heading_slugs(str(tmp_path / "docs" / "A.md"))
    assert {"setup", "setup-1"} <= slugs


def test_main_exit_codes(tmp_path, capsys):
    _write(str(tmp_path / "README.md"), "[ok](docs/A.md)\n")
    _write(str(tmp_path / "docs" / "A.md"), "# A\n")
    assert check_docs.main(["--root", str(tmp_path), "--no-help-smoke"]) == 0
    _write(str(tmp_path / "README.md"), "[bad](gone.md)\n")
    assert check_docs.main(["--root", str(tmp_path), "--no-help-smoke"]) == 1
    assert "docs check FAILED" in capsys.readouterr().out


def test_help_smoke_runs_documented_clis():
    # the real thing CI runs: every CLI the docs name answers --help
    assert check_docs.check_help(REPO_ROOT) == []
