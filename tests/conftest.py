import os
import sys

# NOTE: deliberately NOT forcing xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device.  Multi-device tests spawn
# subprocesses that set XLA_FLAGS before importing jax.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can exercise the benchmarks tooling (check_regression)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
