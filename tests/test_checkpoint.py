import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)},
            "t": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    tree = _tree()
    checkpoint.save(str(tmp_path), 3, tree)
    out, step = checkpoint.restore(str(tmp_path), jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    checkpoint.save(str(tmp_path), 1, _tree())
    checkpoint.save(str(tmp_path), 12, _tree())
    assert checkpoint.latest_step(str(tmp_path)) == 12


def test_shape_mismatch_raises(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.zeros(2, jnp.int32)}, "t": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        checkpoint.restore(str(tmp_path), bad)


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path / "nope"), _tree())
