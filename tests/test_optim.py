import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def test_bridge_schedule_properties():
    rho = optim.bridge_schedule(lam=2.0, t0=10)
    ts = np.arange(0, 100)
    vals = np.asarray([rho(t) for t in ts])
    assert (np.diff(vals) < 0).all()  # decreasing
    assert abs(vals[0] - 1 / 20) < 1e-7
    # divergent sum / convergent square-sum behavior (sampled proxy)
    assert vals.sum() > 10 * vals[0]


def test_cosine_schedule():
    rho = optim.cosine_schedule(1.0, 100, warmup=10)
    assert float(rho(0)) < 0.2
    assert abs(float(rho(10)) - 1.0) < 1e-5
    assert float(rho(100)) < 1e-6 + 0.0 + 1e-3


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.adamw_init(params)
    for _ in range(300):
        grads = {"w": params["w"] - jnp.asarray([1.0, 2.0])}
        params, state = optim.adamw_update(params, grads, state, lr=0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=0.05)


def test_momentum():
    state = optim.momentum_init({"w": jnp.zeros(2)})
    g = {"w": jnp.ones(2)}
    state, upd = optim.momentum_update(g, state, beta=0.5)
    state, upd = optim.momentum_update(g, state, beta=0.5)
    np.testing.assert_allclose(np.asarray(upd["w"]), 1.5)
